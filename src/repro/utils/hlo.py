"""Structural analysis of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts every ``while`` body ONCE, so for
scan-over-layers models it under-reports FLOPs/bytes/collectives by roughly
the layer count.  This module parses the module text into computations,
recovers each while loop's trip count from its condition, propagates
loop multipliers down the call graph, and then accumulates:

- ``flops``            exact MXU flops of every ``dot`` (2 * |out| * K)
- ``bytes``            operand+output bytes of top-level ops (fusion
                       boundaries = the HBM-traffic approximation XLA
                       itself uses), copies included, bitcast/GTE excluded
- ``collective_bytes`` output-shape bytes per collective kind

all scaled by the product of enclosing loop trip counts.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w[\w.]*)\[([\d,]*)\]")
# an op line:  %name = <type> opcode(...operands...), attrs
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_NO_TRAFFIC = {"bitcast", "get-tuple-element", "parameter", "constant",
               "tuple", "after-all", "partition-id", "replica-id", "iota"}


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str          # operands + attrs, raw


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    defs: Dict[str, str] = field(default_factory=dict)   # op name -> type str


def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            hdr = _COMP_HDR_RE.match(line)
            if hdr and line.rstrip().endswith("{"):
                cur = Computation(hdr.group(1))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.ops.append(op)
            cur.defs[op.name] = op.type_str
    return comps


def _callee(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", rest)
    return m.group(1) if m else None


def _trip_count(cond: Computation, comps: Dict[str, Computation]) -> int:
    """Max s32 constant in the condition (or its fusion callees) — the scan
    bound in every XLA-lowered lax.scan/while with a static trip count."""
    best = 1
    blocks = [cond]
    for op in cond.ops:
        if op.opcode == "fusion":
            callee = _callee(op.rest, "calls")
            if callee and callee in comps:
                blocks.append(comps[callee])
    for blk in blocks:
        for op in blk.ops:
            if op.opcode == "constant" and op.type_str.startswith("s32[]"):
                c = re.match(r"(\d+)\)", op.rest)
                if c:
                    best = max(best, int(c.group(1)))
    return best


def _compute_multipliers(comps: Dict[str, Computation], entry: str
                         ) -> Dict[str, float]:
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    frontier = [entry]
    visited_edges = set()
    while frontier:
        name = frontier.pop()
        if name not in comps:
            continue
        comp = comps[name]
        m = mult[name]
        for op in comp.ops:
            targets: List[Tuple[str, float]] = []
            if op.opcode == "while":
                cond = _callee(op.rest, "condition")
                body = _callee(op.rest, "body")
                trips = _trip_count(comps[cond], comps) if cond in comps else 1
                for t in (body, cond):
                    if t:
                        targets.append((t, m * trips))
            else:
                for key in ("calls", "to_apply", "condition", "body"):
                    t = _callee(op.rest, key)
                    if t and t in comps:
                        targets.append((t, m))
                for blist in re.findall(r"branch_computations=\{([^}]*)\}",
                                        op.rest):
                    for t in re.findall(r"%?([\w.\-]+)", blist):
                        if t in comps:
                            targets.append((t, m))
            for t, tm in targets:
                if tm > mult[t] or (name, t) not in visited_edges:
                    mult[t] = max(mult[t], tm)
                    visited_edges.add((name, t))
                    frontier.append(t)
    return dict(mult)


def _find_entry(text: str, comps: Dict[str, Computation]) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    return next(iter(comps))


def _dot_flops(op: Op, comp: Computation) -> float:
    out_dims = _shape_dims(op.type_str) or []
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    operands = _OPERAND_RE.findall(op.rest.split(")", 1)[0])
    k = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if m and operands:
        lhs_type = comp.defs.get(operands[0])
        dims = _shape_dims(lhs_type) if lhs_type else None
        if dims:
            for idx in m.group(1).split(","):
                if idx:
                    k *= dims[int(idx)]
    return 2.0 * out_elems * k


@dataclass
class HloAnalysis:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, int] = field(default_factory=dict)
    while_trips: List[int] = field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(text: str) -> HloAnalysis:
    comps = parse_computations(text)
    entry = _find_entry(text, comps)
    mult = _compute_multipliers(comps, entry)
    fusion_callees = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                target = _callee(op.rest, "calls")
                if target:
                    fusion_callees.add(target)

    # fusions whose root is a dynamic-(update-)slice are in-place slab
    # updates / slab reads: traffic is the slice, not the full accumulator
    def _root_opcode(comp_name: str) -> str:
        c = comps.get(comp_name)
        return c.ops[-1].opcode if c and c.ops else ""

    out = HloAnalysis()
    cb = defaultdict(float)
    cc = defaultdict(int)
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        in_fusion = comp.name in fusion_callees
        for op in comp.ops:
            base = re.sub(r"-(start|done)$", "", op.opcode)
            if base in COLLECTIVE_KINDS:
                if op.opcode.endswith("-done"):
                    continue
                cb[base] += m * _shape_bytes(op.type_str)
                cc[base] += int(m)
                continue
            if op.opcode == "dot":
                out.flops += m * _dot_flops(op, comp)
            if in_fusion:
                continue  # fusion internals are not HBM traffic
            if op.opcode in _NO_TRAFFIC or op.opcode == "while":
                continue
            out_bytes = _shape_bytes(op.type_str)
            opnd_bytes = []
            for operand in _OPERAND_RE.findall(op.rest.split("),", 1)[0]):
                t = comp.defs.get(operand)
                if t:
                    opnd_bytes.append(_shape_bytes(t))
            root = op.opcode
            if op.opcode == "fusion":
                root = _root_opcode(_callee(op.rest, "calls") or "")
            if root == "dynamic-update-slice" or (op.opcode == "fusion" and
                                                  "update-slice" in op.name):
                # in-place accumulator: read the slice-sized operands, write
                # the slice; the full-buffer operand is aliased, not moved
                small = [b for b in opnd_bytes if b < out_bytes]
                nbytes = 2 * max(sum(small), 1)
            elif root == "dynamic-slice" or (op.opcode == "fusion" and
                                             "dynamic-slice" in op.name and
                                             "update" not in op.name):
                # slab read: only the slice leaves HBM
                nbytes = 2 * out_bytes
            else:
                nbytes = out_bytes + sum(opnd_bytes)
            out.bytes += m * nbytes
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "while":
                cond = _callee(op.rest, "condition")
                if cond and cond in comps:
                    out.while_trips.append(_trip_count(comps[cond], comps))
    out.collective_bytes = dict(cb)
    out.collective_counts = dict(cc)
    return out


# ---------------------------------------------------------------------------
# flat counters (no loop scaling) — fast path + tests
# ---------------------------------------------------------------------------

_FLAT_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w[\w.]*)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Flat (no loop-trip scaling) output bytes per collective kind."""
    out: Dict[str, float] = defaultdict(float)
    for m in _FLAT_OP_RE.finditer(hlo_text):
        tuple_body, dtype, dims, kind = m.groups()
        if "-done(" in m.group(0):
            continue
        if tuple_body is not None:
            total = _shape_bytes("(" + tuple_body + ")")
        else:
            total = _shape_bytes(f"{dtype}[{dims}]")
        out[kind] += total
    return dict(out)


def collective_counts(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = defaultdict(int)
    for m in _FLAT_OP_RE.finditer(hlo_text):
        if "-done(" in m.group(0):
            continue
        out[m.group(4)] += 1
    return dict(out)
