"""Analysis utilities (loop-trip-aware HLO cost analysis)."""
