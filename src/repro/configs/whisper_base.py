"""whisper-base — enc-dec; mel+conv frontend stubbed to frame embeddings
[arXiv:2212.04356]"""
from repro.configs import register
from repro.configs.base import ModelConfig


@register("whisper-base")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="encdec", num_layers=6, d_model=512,
        num_heads=8, num_kv_heads=8, d_ff=2048, vocab_size=51865,
        encoder_layers=6, encoder_seq=1500,
        sharding="dp_tp", source="arXiv:2212.04356")
