"""deepseek-v2-236b — MLA kv_lora=512; 2 shared + 160 routed top-6 experts
[arXiv:2405.04434]"""
from repro.configs import register
from repro.configs.base import ModelConfig, MoEConfig


@register("deepseek-v2-236b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe", num_layers=60, d_model=5120,
        num_heads=128, num_kv_heads=128, head_dim=128, d_ff=12288,
        vocab_size=102400, attention="mla", mla_kv_lora=512, mla_rope_dim=64,
        moe=MoEConfig(num_experts=160, top_k=6, num_shared_experts=2,
                      d_ff_expert=1536, first_dense=1),
        sharding="fsdp_tp", source="arXiv:2405.04434")
