"""stablelm-3b — MHA (kv=heads) dense decoder
[hf:stabilityai/stablelm-2-1_6b family]"""
from repro.configs import register
from repro.configs.base import ModelConfig


@register("stablelm-3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b", family="dense", num_layers=32, d_model=2560,
        num_heads=32, num_kv_heads=32, d_ff=6912, vocab_size=50304,
        sharding="dp_tp", source="hf:stabilityai/stablelm-2-1_6b")
