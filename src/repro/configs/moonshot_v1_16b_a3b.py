"""moonshot-v1-16b-a3b — Moonlight-16B-A3B: 64 routed top-6 + 2 shared
[hf:moonshotai/Moonlight-16B-A3B]"""
from repro.configs import register
from repro.configs.base import ModelConfig, MoEConfig


@register("moonshot-v1-16b-a3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe", num_layers=48, d_model=2048,
        num_heads=16, num_kv_heads=16, head_dim=128, d_ff=11264,
        vocab_size=163840,
        moe=MoEConfig(num_experts=64, top_k=6, num_shared_experts=2,
                      d_ff_expert=1408, first_dense=1),
        sharding="dp_tp", source="hf:moonshotai/Moonlight-16B-A3B")
