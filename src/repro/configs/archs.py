"""Aggregator for the 10 assigned architectures (one module per arch)."""
from __future__ import annotations

# importing registers each config
from repro.configs import (jamba_v0_1_52b, command_r_35b, rwkv6_1_6b,          # noqa: F401
                           internvl2_2b, stablelm_3b, whisper_base,            # noqa: F401
                           deepseek_v2_236b, arctic_480b, deepseek_coder_33b,  # noqa: F401
                           moonshot_v1_16b_a3b)                                # noqa: F401

ALL_ARCHS = ["jamba-v0.1-52b", "command-r-35b", "rwkv6-1.6b", "internvl2-2b",
             "stablelm-3b", "whisper-base", "deepseek-v2-236b", "arctic-480b",
             "deepseek-coder-33b", "moonshot-v1-16b-a3b"]

# archs whose attention is full/quadratic: long_500k runs the sliding-window
# variant (see DESIGN.md §Arch-applicability); whisper skips long_500k.
FULL_ATTENTION = ["command-r-35b", "internvl2-2b", "stablelm-3b",
                  "deepseek-v2-236b", "arctic-480b", "deepseek-coder-33b",
                  "moonshot-v1-16b-a3b"]
LONG_SKIP = ["whisper-base"]
