"""jamba-v0.1-52b — Mamba+attention 1:7 interleave, MoE every other layer
[arXiv:2403.19887]"""
from repro.configs import register
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig


@register("jamba-v0.1-52b")
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid", num_layers=32, d_model=4096,
        num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=65536,
        moe=MoEConfig(num_experts=16, top_k=2, every=2, d_ff_expert=14336),
        ssm=SSMConfig(kind="mamba", d_state=16, d_conv=4, expand=2),
        hybrid_attn_period=8, hybrid_block_layers=8,
        sharding="fsdp_tp", source="arXiv:2403.19887")
