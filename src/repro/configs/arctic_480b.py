"""arctic-480b — 128 experts top-2 + always-on dense residual
[hf:Snowflake/snowflake-arctic-base]"""
from repro.configs import register
from repro.configs.base import ModelConfig, MoEConfig


@register("arctic-480b")
def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe", num_layers=35, d_model=7168,
        num_heads=56, num_kv_heads=8, d_ff=4864, vocab_size=32000,
        moe=MoEConfig(num_experts=128, top_k=2, num_shared_experts=1,
                      d_ff_expert=4864),
        sharding="fsdp_tp", source="hf:Snowflake/snowflake-arctic-base")
