"""Config registry: ``get_config("<arch-id>")`` for every assigned arch."""
from __future__ import annotations

from typing import Callable, Dict, List

from repro.configs.base import (CommConfig, InputShape, INPUT_SHAPES,
                                ModelConfig, MoEConfig, SSMConfig)

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # late imports register the configs
        import repro.configs.archs  # noqa: F401
    return _REGISTRY[name]()


def list_configs() -> List[str]:
    import repro.configs.archs  # noqa: F401
    return sorted(_REGISTRY)


__all__ = ["CommConfig", "InputShape", "INPUT_SHAPES", "ModelConfig",
           "MoEConfig", "SSMConfig", "get_config", "list_configs", "register"]
