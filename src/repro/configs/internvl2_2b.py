"""internvl2-2b — InternViT (stubbed to patch embeddings) + InternLM2-1.8B
[arXiv:2404.16821]"""
from repro.configs import register
from repro.configs.base import ModelConfig


@register("internvl2-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b", family="vlm", num_layers=24, d_model=2048,
        num_heads=16, num_kv_heads=8, d_ff=8192, vocab_size=92553,
        prefix_embeds=256, sharding="dp_tp", source="arXiv:2404.16821")
