"""rwkv6-1.6b — Finch: attention-free, data-dependent decay
[arXiv:2404.05892]"""
from repro.configs import register
from repro.configs.base import ModelConfig, SSMConfig


@register("rwkv6-1.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm", num_layers=24, d_model=2048,
        num_heads=32, num_kv_heads=32, d_ff=7168, vocab_size=65536,
        attention="none", ssm=SSMConfig(kind="rwkv6", head_dim=64),
        sharding="dp_tp", source="arXiv:2404.05892")
