"""command-r-35b — GQA, no-bias dense decoder
[hf:CohereForAI/c4ai-command-r-v01]"""
from repro.configs import register
from repro.configs.base import ModelConfig


@register("command-r-35b")
def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b", family="dense", num_layers=40, d_model=8192,
        num_heads=64, num_kv_heads=8, d_ff=22528, vocab_size=256000,
        sharding="fsdp_tp", source="hf:CohereForAI/c4ai-command-r-v01")
