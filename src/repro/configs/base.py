"""Configuration system for the repro framework.

Every assigned architecture gets a ``ModelConfig`` in ``repro.configs.<id>``;
``repro.configs.get_config(name)`` resolves them.  Configs are frozen
dataclasses so they can be used as static args to ``jax.jit``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (GShard-style einsum dispatch)."""

    num_experts: int
    top_k: int = 2
    num_shared_experts: int = 0      # DeepSeek-V2 shared experts
    d_ff_expert: int = 0             # expert FFN hidden size (0 -> use d_ff)
    every: int = 1                   # apply MoE every `every`-th layer
    first_dense: int = 0             # leading dense layers (DeepSeek-V2: 1)
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    """State-space / linear-recurrence settings (Mamba, RWKV6)."""

    kind: str = "mamba"              # "mamba" | "rwkv6"
    d_state: int = 16                # mamba state dim
    d_conv: int = 4                  # mamba conv width
    expand: int = 2                  # d_inner = expand * d_model
    dt_rank: int = 0                 # 0 -> ceil(d_model/16)
    head_dim: int = 64               # rwkv6 head size
    chunk_size: int = 128            # chunked-scan block length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # --- attention flavour ---
    attention: str = "gqa"           # gqa | mla | none
    mla_kv_lora: int = 512
    mla_rope_dim: int = 64
    sliding_window: int = 0          # 0 = full causal attention
    # --- MoE / SSM / hybrid ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_period: int = 0      # jamba: 1 attention layer per `period`
    hybrid_block_layers: int = 0     # layers per scanned super-block
    # --- enc-dec / multimodal frontends (stubs supply embeddings) ---
    encoder_layers: int = 0          # whisper encoder depth
    encoder_seq: int = 0             # frames / patches supplied by the stub
    prefix_embeds: int = 0           # VLM: patch embeddings prepended
    # --- numerics / misc ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_chunk: int = 512           # chunked cross-entropy block
    attn_chunk: int = 1024           # flash-attention KV block
    scan_layers: bool = True         # lax.scan over stacked layer params
    dtype: str = "bfloat16"
    # --- distribution ---
    sharding: str = "dp_tp"          # dp_tp | fsdp_tp
    remat: bool = True               # activation checkpointing per layer
    # --- §Perf hillclimb knobs (defaults = paper-faithful baseline) ---
    mamba_fused_y: bool = False      # contract d_state inside the chunk scan
    moe_shard: str = "edim_dmodel"   # edim_dmodel (baseline) | edim_dff
    fsdp_unshard_step: bool = False  # ZeRO-1: all-gather params once per step
    bf16_stream: bool = False        # keep residual/collective tensors bf16
    mamba_scan_impl: str = "assoc"   # assoc (log-depth) | seq (VMEM-carry)
    seq_parallel: str = ""           # batch axes, e.g. "data": shard the
                                     # residual stream's S dim over `model`
    remat_policy: str = "full"       # full | dots (save matmul outputs)
    use_pallas: str = "auto"         # auto (TPU only) | always | never
    # --- provenance ---
    source: str = ""                 # citation (arXiv / model card)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Embedding/lm_head table rows, padded so the vocab dim shards
        evenly over the model axis (256 = lcm-friendly for 16-way TP).
        Logits for the padding columns are masked in the loss."""
        pad_to = 256
        return (self.vocab_size + pad_to - 1) // pad_to * pad_to

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- reduced variant for CPU smoke tests -------------------------------
    def smoke(self) -> "ModelConfig":
        """A tiny same-family variant: 2 layers, d_model<=256, <=4 experts."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=2,
            d_model=min(self.d_model, 128),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=32,
            d_ff=min(self.d_ff, 256),
            vocab_size=min(self.vocab_size, 512),
            mla_kv_lora=32,
            mla_rope_dim=16,
            logit_chunk=64,
            attn_chunk=64,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 32),
            prefix_embeds=min(self.prefix_embeds, 8),
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else 0,
            remat=False,
            dtype="float32",
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                d_ff_expert=min(self.moe.d_ff_expert, 128) if self.moe.d_ff_expert else 0,
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=8, chunk_size=16, head_dim=16)
        if self.hybrid_block_layers:
            kw["num_layers"] = self.hybrid_block_layers  # one super-block
        return self.replace(**kw)


@dataclass(frozen=True)
class InputShape:
    """One of the four assigned global input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    def smoke(self) -> "InputShape":
        return InputShape(self.name + "-smoke", min(self.seq_len, 64), 2, self.kind)


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def production_overrides(cfg: "ModelConfig") -> dict:
    """The §Perf-validated beyond-paper flags per architecture family
    (EXPERIMENTS.md §Perf).  Baselines keep defaults; the optimized
    dry-run sweep (`dryrun --production`) and deployments apply these."""
    kw: dict = {"attn_chunk": 2048}
    if cfg.sharding == "fsdp_tp":
        kw["fsdp_unshard_step"] = True
    if cfg.ssm is not None and cfg.ssm.kind == "mamba":
        kw["mamba_fused_y"] = True
    if cfg.moe is not None:
        kw["moe_shard"] = "edim_dff"
    return kw


@dataclass(frozen=True)
class CommConfig:
    """The paper's technique as a first-class runtime feature.

    Controls how gradients are synchronised across the data-parallel axes:
    Horovod-style fusion buckets, hierarchical (in-pod / cross-pod)
    collectives, and optional gradient compression.
    """

    fusion_buffer_mb: float = 64.0   # paper's fusion buffer size
    timeout_ms: float = 5.0          # paper's fusion timeout (simulator only)
    hierarchical: bool = True        # in-pod RS -> cross-pod AR -> in-pod AG
    compression: str = "none"        # none | fp16 | int8 | ternary | topk
    topk_ratio: float = 0.01         # kept fraction for topk
    mode: str = "auto"               # auto (pjit collectives) | explicit (shard_map)
    scheduler: str = "fifo"          # comm schedule: fifo | priority | chunked
    sched_chunks: int = 4            # chunks/bucket for the pipelined schedulers
