"""Synthetic sharded data pipeline.

Deterministic on (seed, step) so every data-parallel worker can generate its
own shard without coordination — the same property a production loader gets
from sharded file sets.  Provides token batches for LM training, frame/patch
embedding stubs for the audio/VLM frontends, and an infinite iterator with
host-side prefetch.
"""
from __future__ import annotations

import threading
import queue as queue_lib
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig


class SyntheticLM:
    """Zipf-distributed token stream (vocab ranks follow a power law, like
    natural text) with next-token labels."""

    def __init__(self, cfg: ModelConfig, shape: InputShape, seed: int = 0,
                 zipf_a: float = 1.2):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-zipf_a)
        self.probs = p / p.sum()

    def batch(self, step: int, batch_size: Optional[int] = None
              ) -> Dict[str, np.ndarray]:
        B = batch_size or self.shape.global_batch
        S = self.shape.seq_len
        rng = np.random.default_rng((self.seed, step))
        stream = rng.choice(self.cfg.vocab_size, size=(B, S + 1), p=self.probs)
        batch = {"tokens": stream[:, :-1].astype(np.int32),
                 "labels": stream[:, 1:].astype(np.int32)}
        if self.cfg.family == "vlm" and self.cfg.prefix_embeds:
            batch["prefix_embeds"] = rng.standard_normal(
                (B, self.cfg.prefix_embeds, self.cfg.d_model)).astype(np.float32)
        if self.cfg.family == "encdec":
            batch["frames"] = rng.standard_normal(
                (B, self.cfg.encoder_seq, self.cfg.d_model)).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Host-side prefetch: overlaps next-batch generation with the step."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: queue_lib.Queue = queue_lib.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(item)

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue_lib.Empty:
            pass


def device_put_batch(batch: Dict[str, np.ndarray], shardings: Any):
    """Place a host batch on the mesh with the given shardings."""
    return {k: jax.device_put(v, shardings[k]) if k in shardings
            else jnp.asarray(v) for k, v in batch.items()}
