"""Deterministic synthetic sharded data pipeline (+ frontend stubs)."""
from repro.data.pipeline import Prefetcher, SyntheticLM, device_put_batch  # noqa: F401
