"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are tested against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).  The
same functions serve as the portable fallback on backends without Pallas.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# int8 block quantization (QSGD-style deterministic variant)
# ---------------------------------------------------------------------------

def quantize_int8(x: jnp.ndarray, block: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (n,) float -> (q (n,) int8, scales (n/block,) f32).

    Symmetric per-block scaling: scale = max|x| / 127, q = round(x / scale).
    n must be a multiple of ``block``.
    """
    n = x.shape[0]
    xb = x.reshape(n // block, block).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(n), scale[:, 0]


def dequantize_int8(q: jnp.ndarray, scales: jnp.ndarray, block: int = 256
                    ) -> jnp.ndarray:
    n = q.shape[0]
    qb = q.reshape(n // block, block).astype(jnp.float32)
    return (qb * scales[:, None]).reshape(n)


# ---------------------------------------------------------------------------
# ternary quantization (TernGrad)
# ---------------------------------------------------------------------------

def ternarize(x: jnp.ndarray, block: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (n,) -> (t (n,) int8 in {-1,0,1}, scales (n/block,) f32).

    scale = mean|x| per block; t = sign(x) where |x| >= scale else 0
    (deterministic TernGrad variant).
    """
    n = x.shape[0]
    xb = x.reshape(n // block, block).astype(jnp.float32)
    scale = jnp.mean(jnp.abs(xb), axis=1, keepdims=True)
    t = jnp.where(jnp.abs(xb) >= scale, jnp.sign(xb), 0.0).astype(jnp.int8)
    return t.reshape(n), scale[:, 0]


def deternarize(t: jnp.ndarray, scales: jnp.ndarray, block: int = 256
                ) -> jnp.ndarray:
    n = t.shape[0]
    tb = t.reshape(n // block, block).astype(jnp.float32)
    return (tb * scales[:, None]).reshape(n)


# ---------------------------------------------------------------------------
# top-k sparsification mask (DGC-style threshold selection)
# ---------------------------------------------------------------------------

def topk_threshold(x: jnp.ndarray, ratio: float) -> jnp.ndarray:
    """Exact magnitude threshold keeping ceil(ratio * n) entries."""
    n = x.shape[0]
    k = max(int(ratio * n), 1)
    vals = jax.lax.top_k(jnp.abs(x.astype(jnp.float32)), k)[0]
    return vals[-1]


def topk_mask(x: jnp.ndarray, threshold: jnp.ndarray) -> jnp.ndarray:
    """Mask keeping entries with |x| >= threshold; returns x * mask."""
    return jnp.where(jnp.abs(x.astype(jnp.float32)) >= threshold,
                     x, jnp.zeros((), x.dtype)).astype(x.dtype)


# ---------------------------------------------------------------------------
# fused multi-buffer add (the all-reduce reduction hot-spot; AddEst's object)
# ---------------------------------------------------------------------------

def fused_add(buffers: jnp.ndarray) -> jnp.ndarray:
    """buffers: (n_bufs, n) -> (n,) fp32 sum (one pass over memory)."""
    return jnp.sum(buffers.astype(jnp.float32), axis=0)
