"""Pallas TPU kernels for gradient compression: int8 block quantization and
TernGrad ternarization.

These are the element-wise streaming hot-spots of the paper's §3.2
(application-layer gradient compression).  TPU adaptation: gradients are
flattened to (rows, 256) with one quantization block per row — 256 lanes =
2 VREG lanes-dims, rows tiled in multiples of 8 (f32 sublane) so each grid
step works on an aligned VMEM tile.  Scales are emitted per row as a
(rows, 1) column so the layout stays 2-D (TPU Pallas wants >=2-D refs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256          # quantization block = one row
ROW_TILE = 64        # rows per grid step (64*256*4B = 64 KiB VMEM per ref)


# ---------------------------------------------------------------------------
# int8
# ---------------------------------------------------------------------------

def _quant_int8_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


def quantize_int8_2d(x: jnp.ndarray, *, interpret: bool = False):
    """x: (R, BLOCK) float32, R % ROW_TILE == 0 -> (q int8 (R, BLOCK), s (R, 1))."""
    R = x.shape[0]
    grid = (R // ROW_TILE,)
    return pl.pallas_call(
        _quant_int8_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROW_TILE, BLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROW_TILE, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, BLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)],
        interpret=interpret,
    )(x)


def _dequant_int8_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


def dequantize_int8_2d(q: jnp.ndarray, s: jnp.ndarray, *, interpret: bool = False):
    R = q.shape[0]
    grid = (R // ROW_TILE,)
    return pl.pallas_call(
        _dequant_int8_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROW_TILE, BLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROW_TILE, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, BLOCK), jnp.float32),
        interpret=interpret,
    )(q, s)


# ---------------------------------------------------------------------------
# ternary (TernGrad)
# ---------------------------------------------------------------------------

def _ternary_kernel(x_ref, t_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.mean(jnp.abs(x), axis=1, keepdims=True)
    t = jnp.where(jnp.abs(x) >= scale, jnp.sign(x), 0.0)
    t_ref[...] = t.astype(jnp.int8)
    s_ref[...] = scale


def ternarize_2d(x: jnp.ndarray, *, interpret: bool = False):
    R = x.shape[0]
    grid = (R // ROW_TILE,)
    return pl.pallas_call(
        _ternary_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROW_TILE, BLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROW_TILE, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, BLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)],
        interpret=interpret,
    )(x)
