"""Pallas TPU kernel: Mamba selective-scan with VMEM-resident state.

§Perf hillclimb 1/iter 4 showed why this must be a kernel: a jnp
``lax.scan`` round-trips the (B, di, n) carry through HBM on every one of
S time steps (10x the associative scan's traffic), while the associative
scan pays ~2*log2(C) full passes in pad/slice cascades.  This kernel is
the Mamba-paper dataflow on TPU terms: read decay/bx/C once, keep the
recurrent state in VMEM scratch across sequential grid steps, write y once
— ~3 HBM passes total.

Layout: operands arranged (B, S, n, di) so d_inner (128-aligned) rides the
lanes and d_state (16) the sublanes.  Grid = (B, di_blocks, chunks) with
the chunk axis sequential; scratch state is (n, di_blk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(d_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref, state,
                *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state[...] = h0_ref[0]

    d = d_ref[0]                     # (C, n, di_blk)
    b = b_ref[0]
    c = c_ref[0, :, :, 0]            # (C, n)

    def step(t, h):
        h = d[t] * h + b[t]                               # (n, di_blk)
        y_ref[0, t] = jnp.sum(h * c[t][:, None], axis=0)  # (di_blk,)
        return h

    h = jax.lax.fori_loop(0, chunk, step, state[...])
    state[...] = h

    @pl.when(ci == n_chunks - 1)
    def _finish():
        hout_ref[0] = h


def ssm_scan_pallas(decay, bx, c_t, h0, *, chunk: int = 128,
                    di_block: int = 512, interpret: bool = False):
    """decay, bx: (B, S, n, di) fp32; c_t: (B, S, n); h0: (B, n, di).

    Returns (y (B, S, di), h_final (B, n, di)).  S % chunk == 0 and
    di % di_block == 0.
    """
    B, S, n, di = decay.shape
    di_block = min(di_block, di)
    assert S % chunk == 0 and di % di_block == 0
    n_chunks = S // chunk
    grid = (B, di // di_block, n_chunks)
    op_spec = pl.BlockSpec((1, chunk, n, di_block),
                           lambda b, i, c: (b, c, 0, i))
    kernel = functools.partial(_ssm_kernel, chunk=chunk, n_chunks=n_chunks)
    y, h_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[op_spec, op_spec,
                  pl.BlockSpec((1, chunk, n, 1), lambda b, i, c: (b, c, 0, 0)),
                  pl.BlockSpec((1, n, di_block), lambda b, i, c: (b, 0, i))],
        out_specs=[pl.BlockSpec((1, chunk, di_block), lambda b, i, c: (b, c, i)),
                   pl.BlockSpec((1, n, di_block), lambda b, i, c: (b, 0, i))],
        out_shape=[jax.ShapeDtypeStruct((B, S, di), jnp.float32),
                   jax.ShapeDtypeStruct((B, n, di), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((n, di_block), jnp.float32)],
        interpret=interpret,
    )(decay, bx, c_t[..., None], h0)
    return y, h_out
