"""Pallas TPU kernel: magnitude-threshold sparsification mask (DGC-style).

Top-k selection over a 64 MB fusion bucket is done in two stages: the exact
threshold comes from ``jax.lax.top_k`` on a sampled subset (host/XLA side,
see kernels.ops), and applying the mask — the bandwidth-bound full pass over
the bucket — is this kernel.  One grid step masks a (ROW_TILE, 256) VMEM
tile; the threshold rides along as a (1, 1) scalar block broadcast to every
step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quantize import BLOCK, ROW_TILE


def _topk_mask_kernel(thr_ref, x_ref, o_ref):
    x = x_ref[...]
    thr = thr_ref[0, 0]
    o_ref[...] = jnp.where(jnp.abs(x.astype(jnp.float32)) >= thr, x,
                           jnp.zeros((), x.dtype))


def topk_mask_2d(x: jnp.ndarray, threshold: jnp.ndarray, *,
                 interpret: bool = False) -> jnp.ndarray:
    """x: (R, BLOCK); threshold: () f32 -> masked x."""
    R = x.shape[0]
    grid = (R // ROW_TILE,)
    return pl.pallas_call(
        _topk_mask_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((ROW_TILE, BLOCK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROW_TILE, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(threshold.reshape(1, 1).astype(jnp.float32), x)
