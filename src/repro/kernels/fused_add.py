"""Pallas TPU kernel: fused multi-buffer element-wise add.

This is the reduction stage of ring all-reduce — the paper's ``AddEst(x)``
object.  Naively adding K buffers pairwise reads 2(K-1) + writes (K-1)
vectors; the fused kernel reads K and writes 1, a (3K-3)/(K+1)x traffic
saving that directly shrinks the paper's ``(N-1) * AddEst(S/N)`` term.

Layout: buffers stacked (K, n) with n flattened to 128-lane tiles; grid
walks column tiles, each step accumulating all K rows in VMEM registers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COL_TILE = 2048      # 2048 lanes * 4B * K rows per VMEM tile


def _fused_add_kernel(x_ref, o_ref):
    o_ref[...] = jnp.sum(x_ref[...].astype(jnp.float32), axis=0,
                         keepdims=True)


def fused_add_2d(buffers: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """buffers: (K, n) with n % COL_TILE == 0 -> (1, n) f32 sum."""
    K, n = buffers.shape
    grid = (n // COL_TILE,)
    return pl.pallas_call(
        _fused_add_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((K, COL_TILE), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, COL_TILE), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(buffers)
