"""Pallas TPU kernels (+ pure-jnp oracles in ref.py, wrappers in ops.py).

Compression hot-spots the paper's §3.2 varies: quantize (int8/ternary),
topk_mask, fused_add.  Model hot-spots surfaced by the roofline analysis:
flash_attn (online softmax), wkv (RWKV6), ssm_scan (Mamba selective scan).
All validated in interpret mode against the oracles; model dispatch via
``ModelConfig.use_pallas``.
"""
