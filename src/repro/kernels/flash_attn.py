"""Pallas TPU kernel: causal flash attention (online softmax).

The attention-score hot-spot for the GQA/MLA families.  Grid =
(batch*heads, q_blocks, kv_blocks) with the kv axis sequential: running
(max, denominator, accumulator) live in VMEM scratch across kv steps of
the same q block; fully-masked kv blocks are skipped with ``pl.when``.

Blocks are (Cq, hd) x (Ck, hd) with Cq = Ck = 128 by default — MXU-aligned
for hd in {64, 128}.  fp32 accumulation regardless of input dtype.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, cq: int, ck: int, n_kv: int, scale: float, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block [qi*cq, qi*cq+cq) attends kv block [ki*ck, ki*ck+ck)
    run = (not causal) or (ki * ck <= qi * cq + cq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale       # (cq, hd)
        k = k_ref[0].astype(jnp.float32)               # (ck, hd)
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T                                     # (cq, ck)
        if causal:
            q_pos = qi * cq + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 0)
            k_pos = ki * ck + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_new = acc_prev * corr + p @ v
        m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           n_heads: int = 1, n_kv_heads: int = 1,
                           interpret: bool = False):
    """q: (B*Hq, Sq, hd); k, v: (B*Hkv, Skv, hd) with heads flattened into
    the leading dim.  GQA is handled in the BlockSpec index map (each q
    head reads its kv group's block — no kv repeat materialized).
    Returns (B*Hq, Sq, hd)."""
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    g = n_heads // max(n_kv_heads, 1)
    cq, ck = min(block_q, Sq), min(block_k, Skv)
    assert Sq % cq == 0 and Skv % ck == 0
    grid = (BH, Sq // cq, Skv // ck)

    def kv_map(b, i, j):
        # q index b = batch * Hq + h  ->  kv index = batch * Hkv + h // g
        return (b // n_heads) * n_kv_heads + (b % n_heads) // g, j, 0

    kernel = functools.partial(_flash_kernel, cq=cq, ck=ck,
                               n_kv=Skv // ck, scale=1.0 / math.sqrt(hd),
                               causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, cq, hd), lambda b, i, j: (b, i, 0)),
                  pl.BlockSpec((1, ck, hd), kv_map),
                  pl.BlockSpec((1, ck, hd), kv_map)],
        out_specs=pl.BlockSpec((1, cq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((cq, 1), jnp.float32),
                        pltpu.VMEM((cq, 1), jnp.float32),
                        pltpu.VMEM((cq, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
