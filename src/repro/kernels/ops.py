"""Public wrappers over the Pallas compression kernels.

Handles 1-D <-> tiled-2-D layout, padding to tile multiples, and backend
dispatch: on TPU the kernels run compiled; everywhere else (this CPU
container) they run with ``interpret=True``, which executes the kernel body
in Python — bit-identical semantics, validated against ``ref.py``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import fused_add as _fa
from repro.kernels import quantize as _q
from repro.kernels import ref
from repro.kernels import topk_mask as _tm

BLOCK = _q.BLOCK
_ROW = _q.ROW_TILE
_PAD_UNIT = BLOCK * _ROW


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_rows(x: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    """Flatten + zero-pad to a (R, BLOCK) grid with R % ROW_TILE == 0."""
    n = x.size
    flat = x.reshape(n)
    pad = (-n) % _PAD_UNIT
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(-1, BLOCK), n


def quantize_int8(x: jnp.ndarray, interpret: bool | None = None):
    """x: any shape float -> (q int8 (R, BLOCK), scales (R, 1), n)."""
    rows, n = _to_rows(x.astype(jnp.float32))
    q, s = _q.quantize_int8_2d(rows, interpret=_interpret() if interpret is None else interpret)
    return q, s, n


def dequantize_int8(q: jnp.ndarray, s: jnp.ndarray, n: int,
                    interpret: bool | None = None) -> jnp.ndarray:
    out = _q.dequantize_int8_2d(
        q, s, interpret=_interpret() if interpret is None else interpret)
    return out.reshape(-1)[:n]


def ternarize(x: jnp.ndarray, interpret: bool | None = None):
    rows, n = _to_rows(x.astype(jnp.float32))
    t, s = _q.ternarize_2d(rows, interpret=_interpret() if interpret is None else interpret)
    return t, s, n


def deternarize(t: jnp.ndarray, s: jnp.ndarray, n: int,
                interpret: bool | None = None) -> jnp.ndarray:
    out = _q.dequantize_int8_2d(      # dequant kernel is scale-multiply; reuse
        t, s, interpret=_interpret() if interpret is None else interpret)
    return out.reshape(-1)[:n]


def topk_sparsify(x: jnp.ndarray, ratio: float, sample: int = 0,
                  interpret: bool | None = None) -> jnp.ndarray:
    """DGC-style sparsification: keep the ~ratio largest-magnitude entries.

    ``sample > 0`` estimates the threshold from that many strided samples
    (the DGC trick — avoids a full sort over a 64 MB bucket).
    """
    flat = x.reshape(-1)
    n = flat.size
    if sample and sample < n:
        stride = n // sample
        thr = ref.topk_threshold(flat[::stride], ratio)
    else:
        thr = ref.topk_threshold(flat, ratio)
    rows, _ = _to_rows(flat)
    out = _tm.topk_mask_2d(rows, thr,
                           interpret=_interpret() if interpret is None else interpret)
    return out.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)


def fused_add(buffers: jnp.ndarray) -> jnp.ndarray:
    """buffers: (K, n) -> (n,) f32 sum via the fused Pallas reduction."""
    K, n = buffers.shape
    pad = (-n) % _fa.COL_TILE
    if pad:
        buffers = jnp.concatenate(
            [buffers, jnp.zeros((K, pad), buffers.dtype)], axis=1)
    out = _fa.fused_add_2d(buffers, interpret=_interpret())
    return out[0, :n]
