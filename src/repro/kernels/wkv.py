"""Pallas TPU kernel: chunked RWKV-6 WKV recurrence.

The mixer's hot-spot: per head, y_t = r_t (S_{t-1} + (u*k_t) v_t^T) with
S_t = diag(w_t) S_{t-1} + k_t v_t^T.  The chunked form (DESIGN.md §8) does
intra-chunk decay-weighted attention (MXU matmuls) plus a carried
(hd x hd) state.

TPU mapping: grid = (B, H, n_chunks) with dimension semantics
(parallel, parallel, arbitrary) — the chunk axis is sequential, and the
state lives in a VMEM scratch buffer that persists across grid steps of the
same (b, h).  Each grid step touches one (C, hd) tile per operand: for
C = hd = 64 that is 4 x 16 KiB in + 16 KiB out + 16 KiB scratch, far under
VMEM, and every matmul is 64x64 — MXU-aligned.

All math fp32; every decay exponent is <= 0 so underflow is the correct
limit (no logspace ratio explosions).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                y_ref, sout_ref, state, *, chunk: int, n_chunks: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state[...] = s0_ref[0, 0]

    rr = r_ref[0, 0]                      # (C, hd)
    kk = k_ref[0, 0]
    vv = v_ref[0, 0]
    ww = w_ref[0, 0]                      # log-decay <= 0
    u = u_ref[0]                          # (1, hd) -> broadcast
    s = state[...]                        # (hd, hd)

    L = jnp.cumsum(ww, axis=0)            # inclusive
    Lx = L - ww                           # exclusive
    # pairwise decay exp(Lx[t] - L[j]) for j < t, contracted over hd:
    # scores[t, j] = sum_d r[t,d] k[j,d] exp(Lx[t,d] - L[j,d])
    dec = jnp.exp(jnp.clip(Lx[:, None, :] - L[None, :, :], -60.0, 0.0))
    scores = jnp.einsum("td,jd,tjd->tj", rr, kk, dec)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(j_idx < t_idx, scores, 0.0)
    diag = jnp.sum(rr * u * kk, axis=1)   # (C,)
    y = scores @ vv + diag[:, None] * vv
    y += (rr * jnp.exp(Lx)) @ s           # carried-state contribution
    y_ref[0, 0] = y

    k_dec = kk * jnp.exp(L[-1:] - L)      # <= 1
    s_new = s * jnp.exp(L[-1])[:, None] + k_dec.T @ vv
    state[...] = s_new

    @pl.when(c == n_chunks - 1)
    def _finish():
        sout_ref[0, 0] = s_new


def wkv_pallas(r, k, v, logw, u, s0, *, chunk: int = 64,
               interpret: bool = False):
    """r, k, v, logw: (B, H, S, hd) fp32; u: (H, hd); s0: (B, H, hd, hd).

    Returns (y (B, H, S, hd), s_final (B, H, hd, hd)).  S % chunk == 0.
    """
    B, H, S, hd = r.shape
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    grid = (B, H, n_chunks)
    seq_spec = pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0))
    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=n_chunks)
    y, s_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, hd), lambda b, h, c: (h, 0)),
                  pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0))],
        out_specs=[seq_spec,
                   pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, H, S, hd), jnp.float32),
                   jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, s0)
    return y, s_out
