"""Learning-rate schedules and gradient clipping (pure JAX)."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Callable:
    """Linear warmup -> cosine decay to ``final_frac * peak_lr``."""
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps)
                     / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)
    return lr


def constant(lr_value: float) -> Callable:
    return lambda step: jnp.asarray(lr_value, jnp.float32)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float):
    """Returns (clipped grads, pre-clip norm)."""
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def get_schedule(name: str, peak_lr: float, warmup: int, total: int) -> Callable:
    if name == "cosine":
        return warmup_cosine(peak_lr, warmup, total)
    if name == "constant":
        return constant(peak_lr)
    raise ValueError(name)
