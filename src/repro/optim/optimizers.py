"""Optimizers in pure JAX (no optax dependency): SGD, momentum, AdamW.

State layout mirrors the param pytree so the ZeRO-1 sharding rules in
``repro.parallel.sharding`` apply leaf-by-leaf.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


class OptState(NamedTuple):
    count: jnp.ndarray
    mu: Params          # first moment (or momentum); zeros pytree for sgd
    nu: Params          # second moment; zeros pytree for sgd/momentum


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Params], OptState]
    update: Callable[[Params, OptState, Params, float], Tuple[Params, OptState]]


def _zeros_like_f32(params: Params) -> Params:
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _empty(params: Params) -> Params:
    return jax.tree_util.tree_map(lambda p: jnp.zeros((), jnp.float32), params)


def sgd() -> Optimizer:
    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _empty(params), _empty(params))

    def update(params, state, grads, lr):
        new_p = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_p, state._replace(count=state.count + 1)

    return Optimizer("sgd", init, update)


def momentum(beta: float = 0.9) -> Optimizer:
    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _zeros_like_f32(params), _empty(params))

    def update(params, state, grads, lr):
        mu = jax.tree_util.tree_map(
            lambda m, g: beta * m + g.astype(jnp.float32), state.mu, grads)
        new_p = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype), params, mu)
        return new_p, OptState(state.count + 1, mu, state.nu)

    return Optimizer("momentum", init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        return OptState(jnp.zeros((), jnp.int32),
                        _zeros_like_f32(params), _zeros_like_f32(params))

    def update(params, state, grads, lr):
        count = state.count + 1
        c = count.astype(jnp.float32)
        bc1 = 1.0 - b1 ** c
        bc2 = 1.0 - b2 ** c

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m / bc1
            vhat = v / bc2
            step = mhat / (jnp.sqrt(vhat) + eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        flat_v = tdef.flatten_up_to(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        mu = tdef.unflatten([o[1] for o in out])
        nu = tdef.unflatten([o[2] for o in out])
        return new_p, OptState(count, mu, nu)

    return Optimizer("adamw", init, update)


def get_optimizer(name: str, **kw) -> Optimizer:
    return {"sgd": sgd, "momentum": momentum, "adamw": adamw}[name](**kw)
