"""Optimizers (SGD/momentum/AdamW) + LR schedules and gradient clipping."""
from repro.optim.optimizers import OptState, Optimizer, get_optimizer  # noqa: F401
from repro.optim.schedule import clip_by_global_norm, get_schedule  # noqa: F401
