import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

# Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
# combination against the production meshes with ShapeDtypeStruct stand-ins.
#
# Outputs per combo: memory_analysis, cost_analysis (FLOPs/bytes), and the
# collective-bytes breakdown parsed from the compiled HLO — the inputs to the
# roofline analysis (EXPERIMENTS.md §Roofline).
#
# NOTE: the XLA_FLAGS lines above MUST stay the first statements in this file
# (jax locks the device count on first init), hence no module docstring.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-3b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--both-meshes]

import argparse
import gzip
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.archs import ALL_ARCHS, FULL_ATTENTION, LONG_SKIP
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh, mesh_num_devices
from repro.models.registry import get_model
from repro.optim.optimizers import get_optimizer
from repro.utils.hlo import analyze, collective_bytes

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def resolve_cfg(arch: str, shape_name: str, production: bool = False):
    """Apply the long-context variant policy (DESIGN.md §Arch-applicability)
    and, when ``production``, the §Perf-validated optimization flags."""
    cfg = get_config(arch)
    note = ""
    if production:
        from repro.configs.base import production_overrides
        kw = production_overrides(cfg)
        cfg = cfg.replace(**kw)
        note = "production flags: " + ",".join(sorted(kw))
    if shape_name == "long_500k":
        if arch in LONG_SKIP:
            return None, "skip: enc-dec full attention, 448-token decoder by design"
        if arch in FULL_ATTENTION:
            cfg = cfg.replace(sliding_window=4096)
            note = (note + "; " if note else "") + \
                "swa-4096 variant (sub-quadratic requirement)"
    return cfg, note


def dryrun_one(arch: str, shape_name: str, mesh, opt_name: str = "adamw",
               verbose: bool = True, save_hlo: bool = True,
               production: bool = False) -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg, note = resolve_cfg(arch, shape_name, production=production)
    if cfg is None:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "note": note}
    api = get_model(cfg)
    opt = get_optimizer(opt_name) if shape.kind == "train" else None
    t0 = time.time()
    spec = specs_lib.step_spec(api, shape, mesh, opt)
    fn = specs_lib.make_step_fn(api, spec.kind, opt)
    with mesh:
        jitted = jax.jit(fn, in_shardings=spec.in_shardings,
                         out_shardings=spec.out_shardings,
                         donate_argnums=spec.donate_argnums)
        lowered = jitted.lower(*spec.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    if save_hlo:
        hdir = ARTIFACT_DIR / "hlo"
        hdir.mkdir(parents=True, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'x'.join(map(str, mesh.devices.shape))}"
        with gzip.open(hdir / f"{tag}.hlo.gz", "wt") as f:
            f.write(hlo_text)
    coll = collective_bytes(hlo_text)
    # loop-trip-aware analysis: cost_analysis counts while bodies once, so
    # scan-over-layers models are under-reported by ~num_layers without this
    ana = analyze(hlo_text)
    n_dev = mesh_num_devices(mesh)
    result = {
        "arch": arch, "shape": shape_name, "kind": spec.kind,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "devices": n_dev, "status": "ok", "note": note,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": coll,
        "analyzed": {
            "flops": ana.flops,
            "bytes": ana.bytes,
            "collective_bytes": ana.collective_bytes,
            "collective_counts": ana.collective_counts,
            "while_trips": ana.while_trips,
        },
        "memory": {
            k: getattr(mem, k)
            for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                      "output_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes")
            if hasattr(mem, k)
        },
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} @ {result['mesh']}: OK "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s, "
              f"flops={ana.flops:.3e}, coll={ana.total_collective_bytes:.3e}B) {note}")
        print(f"         memory: {result['memory']}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 multi-pod mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--production", action="store_true",
                    help="apply the §Perf-validated optimization flags")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(), make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    archs = ALL_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]

    results = []
    for mesh in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    results.append(dryrun_one(arch, shape, mesh,
                                              production=args.production))
                except Exception as e:  # a failure here is a bug in the system
                    traceback.print_exc()
                    results.append({"arch": arch, "shape": shape,
                                    "mesh": "x".join(map(str, mesh.devices.shape)),
                                    "status": "FAIL", "error": repr(e)})
    ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
    out = Path(args.out) if args.out else ARTIFACT_DIR / "results.json"
    existing = []
    if out.exists():
        existing = json.loads(out.read_text())
        keys = {(r["arch"], r["shape"], r.get("mesh")) for r in results}
        existing = [r for r in existing
                    if (r["arch"], r["shape"], r.get("mesh")) not in keys]
    out.write_text(json.dumps(existing + results, indent=1))
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\n{len(results)} combos, {n_fail} failures -> {out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
