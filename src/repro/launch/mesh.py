"""Production mesh definitions (TPU v5e pods).

``make_production_mesh`` is a function, not a module constant, so importing
this module never touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; smoke tests and benchmarks see the single real CPU device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
DCN_BW = 25e9                   # bytes/s per host across pods (assumed)
HBM_BYTES = 16 * 1024 ** 3      # 16 GiB per chip


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh() -> Mesh:
    """1-device mesh with the same axis names, for CPU smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_num_devices(mesh: Mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
