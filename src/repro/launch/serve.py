"""Serving launcher: batched prefill + decode loop for any architecture.

Implements the two inference shapes the assignment exercises: a prefill
step over the prompt batch and an autoregressive decode loop against the
(ring-buffer / recurrent-state) cache.  Greedy sampling; reports prefill
and per-token decode latency/throughput.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --prompt-len 64 --gen 16 --batch 4

``--serve`` wraps the generate step in a stdlib HTTP front end (the seed
idiom for the ROADMAP sweep-server item): ``GET /healthz`` is the
readiness probe, ``POST /run`` executes one request under a per-request
wall-clock budget (504 on expiry), and SIGTERM triggers a graceful drain
— the probe flips to 503, in-flight requests finish, then the listener
exits.
"""
from __future__ import annotations

import argparse
import json
import signal
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, get_config
from repro.data.pipeline import SyntheticLM
from repro.models.registry import get_model, pad_cache


def run(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    api = get_model(cfg)
    B, P, G = args.batch, args.prompt_len, args.gen

    params = api.init(jax.random.key(args.seed))
    shape = INPUT_SHAPES["prefill_32k"].smoke()
    data = SyntheticLM(cfg, shape, seed=args.seed)
    raw = data.batch(0, batch_size=B)
    batch = {"tokens": jnp.asarray(raw["tokens"][:, :P])}
    for k in ("prefix_embeds", "frames"):
        if k in raw:
            batch[k] = jnp.asarray(raw[k])

    prefill = jax.jit(api.prefill)
    decode = jax.jit(api.decode_step, donate_argnums=(2,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    # cache entries written so far: prompt tokens (+ VLM prefix embeddings)
    n_cached = P + (cfg.prefix_embeds if cfg.family == "vlm" else 0)
    cache = pad_cache(cache, n_cached + G)  # headroom for generated tokens

    tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    generated = [tokens]
    t0 = time.perf_counter()
    for i in range(G):
        logits, cache = decode(params, {"tokens": tokens}, cache,
                               jnp.asarray(n_cached + i, jnp.int32))
        tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        generated.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(generated, axis=1)
    assert not jnp.any(out < 0) and not jnp.any(out >= cfg.padded_vocab)
    result = {
        "arch": cfg.name, "batch": B, "prompt_len": P, "generated": G,
        "prefill_s": t_prefill,
        "decode_tok_per_s": B * G / t_decode if G else 0.0,
        "decode_ms_per_token": t_decode / G * 1e3 if G else 0.0,
    }
    print(f"[serve] {cfg.name}: prefill({B}x{P}) {t_prefill*1e3:.0f} ms, "
          f"decode {result['decode_ms_per_token']:.1f} ms/tok "
          f"({result['decode_tok_per_s']:.0f} tok/s)")
    return result


# ---------------------------------------------------------------------------
# HTTP front end: readiness probe, per-request timeout, graceful drain
# ---------------------------------------------------------------------------

class ServeFrontend:
    """stdlib HTTP wrapper around a request handler callable.

    ``handler(payload: dict) -> dict`` runs on a worker thread per
    request; a request that blows ``request_timeout`` seconds gets a 504
    (the worker is abandoned to finish in the background — stdlib threads
    cannot be recalled, which is exactly why the probe exists).  Routes:

    - ``GET /healthz``  -> 200 ``{"status": "ok"}`` while serving,
      503 ``{"status": "draining"}`` once a drain began (load balancers
      stop routing here *before* the listener dies);
    - ``POST /run``     -> the handler's JSON result; 503 while
      draining, 504 on timeout, 500 on handler exceptions.

    :meth:`drain` is the graceful shutdown: flip the probe, wait up to
    ``grace`` seconds for in-flight requests, stop the listener.
    """

    def __init__(self, handler, *, request_timeout: float = 30.0,
                 host: str = "127.0.0.1", port: int = 0,
                 grace: float = 10.0):
        self.handler = handler
        self.request_timeout = request_timeout
        self.grace = grace
        self.draining = threading.Event()
        self._inflight = 0
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self.httpd = ThreadingHTTPServer((host, port), self._make_handler())
        self.httpd.daemon_threads = True

    @property
    def port(self) -> int:
        return self.httpd.server_address[1]

    def _make_handler(self):
        front = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *a):  # quiet: the probe polls
                pass

            def _reply(self, code: int, body: dict):
                data = json.dumps(body).encode()
                self.send_response(code)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(data)))
                self.end_headers()
                self.wfile.write(data)

            def do_GET(self):
                if self.path != "/healthz":
                    return self._reply(404, {"error": "unknown route"})
                if front.draining.is_set():
                    return self._reply(503, {"status": "draining"})
                return self._reply(200, {"status": "ok"})

            def do_POST(self):
                if self.path != "/run":
                    return self._reply(404, {"error": "unknown route"})
                if front.draining.is_set():
                    return self._reply(503, {"status": "draining"})
                n = int(self.headers.get("Content-Length") or 0)
                try:
                    payload = json.loads(self.rfile.read(n) or b"{}")
                except json.JSONDecodeError as e:
                    return self._reply(400, {"error": f"bad json: {e}"})
                with front._lock:
                    front._inflight += 1
                try:
                    box: dict = {}

                    def work():
                        try:
                            box["result"] = front.handler(payload)
                        except Exception as e:  # noqa: BLE001
                            box["error"] = f"{type(e).__name__}: {e}"

                    t = threading.Thread(target=work, daemon=True)
                    t.start()
                    t.join(front.request_timeout)
                    if t.is_alive():
                        return self._reply(504, {
                            "error": f"request exceeded "
                                     f"{front.request_timeout}s"})
                    if "error" in box:
                        return self._reply(500, {"error": box["error"]})
                    return self._reply(200, box["result"])
                finally:
                    with front._idle:
                        front._inflight -= 1
                        front._idle.notify_all()

        return Handler

    def serve_forever(self):
        self.httpd.serve_forever(poll_interval=0.1)

    def drain(self):
        """Graceful shutdown: refuse new work, wait for in-flight
        requests (bounded by ``grace``), stop the listener."""
        self.draining.set()
        deadline = time.monotonic() + self.grace
        with self._idle:
            while self._inflight > 0:
                left = deadline - time.monotonic()
                if left <= 0:
                    break
                self._idle.wait(left)
        self.httpd.shutdown()
        self.httpd.server_close()  # refuse, don't hang, new connections

    def install_sigterm(self):
        signal.signal(signal.SIGTERM,
                      lambda *_: threading.Thread(target=self.drain,
                                                  daemon=True).start())


def serve(args) -> None:
    """Blocking HTTP mode: each POST /run re-runs the generate step with
    per-request overrides for the small knobs (batch/prompt_len/gen)."""

    def handle(payload: dict) -> dict:
        ns = argparse.Namespace(**vars(args))
        for k in ("batch", "prompt_len", "gen"):
            if k in payload:
                setattr(ns, k, int(payload[k]))
        return run(ns)

    front = ServeFrontend(handle, request_timeout=args.request_timeout,
                          port=args.port, grace=args.grace)
    front.install_sigterm()
    print(f"[serve] listening on :{front.port} "
          f"(healthz probe, {args.request_timeout}s/request)")
    front.serve_forever()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--serve", action="store_true",
                    help="HTTP mode: /healthz probe + /run endpoint")
    ap.add_argument("--port", type=int, default=0,
                    help="HTTP port (0 = ephemeral)")
    ap.add_argument("--request-timeout", type=float, default=30.0,
                    dest="request_timeout",
                    help="per-request wall-clock budget (504 past it)")
    ap.add_argument("--grace", type=float, default=10.0,
                    help="drain budget on SIGTERM before the listener stops")
    args = ap.parse_args(argv)
    if args.serve:
        return serve(args)
    return run(args)


if __name__ == "__main__":
    main()
