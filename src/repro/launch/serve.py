"""Serving launcher: batched prefill + decode loop for any architecture.

Implements the two inference shapes the assignment exercises: a prefill
step over the prompt batch and an autoregressive decode loop against the
(ring-buffer / recurrent-state) cache.  Greedy sampling; reports prefill
and per-token decode latency/throughput.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --prompt-len 64 --gen 16 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, get_config
from repro.data.pipeline import SyntheticLM
from repro.models.registry import get_model, pad_cache


def run(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    api = get_model(cfg)
    B, P, G = args.batch, args.prompt_len, args.gen

    params = api.init(jax.random.key(args.seed))
    shape = INPUT_SHAPES["prefill_32k"].smoke()
    data = SyntheticLM(cfg, shape, seed=args.seed)
    raw = data.batch(0, batch_size=B)
    batch = {"tokens": jnp.asarray(raw["tokens"][:, :P])}
    for k in ("prefix_embeds", "frames"):
        if k in raw:
            batch[k] = jnp.asarray(raw[k])

    prefill = jax.jit(api.prefill)
    decode = jax.jit(api.decode_step, donate_argnums=(2,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    # cache entries written so far: prompt tokens (+ VLM prefix embeddings)
    n_cached = P + (cfg.prefix_embeds if cfg.family == "vlm" else 0)
    cache = pad_cache(cache, n_cached + G)  # headroom for generated tokens

    tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    generated = [tokens]
    t0 = time.perf_counter()
    for i in range(G):
        logits, cache = decode(params, {"tokens": tokens}, cache,
                               jnp.asarray(n_cached + i, jnp.int32))
        tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        generated.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(generated, axis=1)
    assert not jnp.any(out < 0) and not jnp.any(out >= cfg.padded_vocab)
    result = {
        "arch": cfg.name, "batch": B, "prompt_len": P, "generated": G,
        "prefill_s": t_prefill,
        "decode_tok_per_s": B * G / t_decode if G else 0.0,
        "decode_ms_per_token": t_decode / G * 1e3 if G else 0.0,
    }
    print(f"[serve] {cfg.name}: prefill({B}x{P}) {t_prefill*1e3:.0f} ms, "
          f"decode {result['decode_ms_per_token']:.1f} ms/tok "
          f"({result['decode_tok_per_s']:.0f} tok/s)")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    return run(args)


if __name__ == "__main__":
    main()
