"""Training launcher.

Runs data-parallel (+ tensor-parallel) training of any assigned architecture
with the paper's communication phase as a configurable feature:

- ``--comm-mode auto``      gradient averaging by XLA SPMD (pjit baseline)
- ``--comm-mode explicit``  bucketed hierarchical grad-sync (repro.parallel.
                            grad_sync) with optional compression — the
                            paper-faithful Horovod-style communication phase

and the paper's *measurement methodology* built in: per-step wall time, a
single-device baseline throughput T, and the resulting scaling factor
T_n / (n * T) (paper Eq. 1) printed at the end.

Examples (CPU container):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --smoke \
      --steps 20 --comm-mode explicit --compression int8
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CommConfig, INPUT_SHAPES, InputShape, get_config
from repro.data.pipeline import SyntheticLM, Prefetcher
from repro.models.registry import get_model
from repro.optim.optimizers import get_optimizer
from repro.optim.schedule import clip_by_global_norm, get_schedule
from repro.parallel import sharding as shd
from repro.parallel.grad_sync import sync_grads


def build_mesh():
    n = len(jax.devices())
    # widest data axis that divides the device count; model gets the rest
    data = n
    model = 1
    return jax.make_mesh((data, model), ("data", "model"))


def make_train_step(api, opt, mesh, comm: CommConfig, lr_fn,
                    clip_norm: float = 0.0):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            api.loss_fn, has_aux=True)(params, batch)
        if comm.mode == "explicit":
            grads = sync_grads(grads, mesh, comm, batch_axes=("data",))
        gnorm = jnp.zeros(())
        if clip_norm > 0:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(opt_state.count)
        new_p, new_o = opt.update(params, opt_state, grads, lr)
        return new_p, new_o, {"loss": loss, "grad_norm": gnorm, "lr": lr,
                              **metrics}
    return train_step


def comm_from_args(args) -> CommConfig:
    """CLI flags -> CommConfig, in one place so the dryrun test and the
    real launcher cannot diverge.  ``scheduler``/``sched_chunks`` select
    the comm-schedule IR order ``sync_grads`` issues its collectives in —
    the same CommPlan the simulator prices, closing the runtime-parity
    gap (the simulator predicting a priority schedule the runtime could
    not execute)."""
    return CommConfig(mode=args.comm_mode, compression=args.compression,
                      fusion_buffer_mb=args.fusion_mb,
                      hierarchical=not args.flat_allreduce,
                      topk_ratio=args.topk_ratio,
                      scheduler=args.scheduler,
                      sched_chunks=args.sched_chunks)


def dryrun(args) -> dict:
    """Build the comm config, bucket plan, and IR order without training.

    What the runtime *would* execute: enough for tests (and operators) to
    check the scheduler wiring end-to-end — CLI flag -> CommConfig ->
    BucketPlan.comm_plan -> bucket order — without touching the data
    pipeline or jit."""
    from repro.parallel.grad_sync import make_plan
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    comm = comm_from_args(args)
    api = get_model(cfg)
    params = api.init(jax.random.key(args.seed))
    plan, _ = make_plan(params, comm.fusion_buffer_mb)
    order = plan.comm_plan(comm).bucket_order()
    print(f"[dryrun] {cfg.name} | comm={comm.mode} "
          f"scheduler={comm.scheduler}/{comm.sched_chunks} | "
          f"{plan.n_buckets} buckets | issue order: {list(order)}")
    return {"arch": cfg.name, "dryrun": True, "comm_mode": comm.mode,
            "scheduler": comm.scheduler, "sched_chunks": comm.sched_chunks,
            "n_buckets": plan.n_buckets, "bucket_order": list(order)}


def run(args) -> dict:
    if args.dryrun:
        return dryrun(args)
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    shape = INPUT_SHAPES[args.shape].smoke() if args.smoke else INPUT_SHAPES[args.shape]
    if args.batch:
        shape = InputShape(shape.name, shape.seq_len, args.batch, shape.kind)

    comm = comm_from_args(args)
    mesh = build_mesh()
    api = get_model(cfg)
    opt = get_optimizer(args.optimizer)

    params = api.init(jax.random.key(args.seed))
    opt_state = opt.init(params)
    n_params = sum(int(p.size) for p in jax.tree_util.tree_leaves(params))
    print(f"[train] {cfg.name} | {n_params/1e6:.1f}M params | "
          f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} | "
          f"comm={comm.mode}/{comm.compression}")

    data = SyntheticLM(cfg, shape, seed=args.seed)
    it = Prefetcher(iter(data), depth=2)

    lr_fn = get_schedule(args.schedule, args.lr, args.warmup, args.steps)
    step_fn = jax.jit(make_train_step(api, opt, mesh, comm, lr_fn,
                                      clip_norm=args.clip_norm),
                      donate_argnums=(0, 1))
    with mesh:
        losses, times = [], []
        t_compile = None
        for step in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if step == 0:
                t_compile = dt
            else:
                times.append(dt)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0:
                print(f"  step {step:4d} loss {losses[-1]:.4f} "
                      f"({dt*1e3:.0f} ms)")
            if args.ckpt_dir and step and step % args.ckpt_every == 0:
                from repro.checkpoint.store import save
                save(args.ckpt_dir, {"params": params, "opt": opt_state}, step)
    it.close()

    tokens_per_step = shape.global_batch * shape.seq_len
    t_step = float(np.median(times)) if times else float("nan")
    result = {
        "arch": cfg.name, "steps": args.steps,
        "first_loss": losses[0], "last_loss": losses[-1],
        "median_step_s": t_step, "compile_s": t_compile,
        "tokens_per_s": tokens_per_step / t_step if times else 0.0,
        "loss_decreased": losses[-1] < losses[0],
    }
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}, "
          f"{result['tokens_per_s']:.0f} tok/s "
          f"(median {t_step*1e3:.0f} ms/step)")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shape (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "constant"])
    ap.add_argument("--warmup", type=int, default=5)
    ap.add_argument("--clip-norm", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--comm-mode", default="auto", choices=["auto", "explicit"])
    ap.add_argument("--compression", default="none",
                    choices=["none", "fp16", "int8", "ternary", "topk"])
    ap.add_argument("--scheduler", default="fifo",
                    choices=["fifo", "priority", "chunked"],
                    help="comm-schedule IR order for explicit grad sync "
                         "(the order the simulator prices)")
    ap.add_argument("--sched-chunks", type=int, default=4,
                    help="chunks per bucket for the pipelined schedulers")
    ap.add_argument("--dryrun", action="store_true",
                    help="build the comm plan and bucket order, skip training")
    ap.add_argument("--fusion-mb", type=float, default=64.0)
    ap.add_argument("--topk-ratio", type=float, default=0.01)
    ap.add_argument("--flat-allreduce", action="store_true")
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args(argv)
    return run(args)


if __name__ == "__main__":
    main()
