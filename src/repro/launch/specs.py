"""ShapeDtypeStruct stand-ins + shardings for every (arch x input-shape).

Nothing here allocates device memory: params, optimizer state, batches and
KV caches are all ``jax.ShapeDtypeStruct`` trees fed to ``jax.jit(...).lower``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models.registry import ModelApi, get_model
from repro.parallel import sharding as shd


class StepSpec(NamedTuple):
    """Everything dryrun needs to lower one (arch x shape x mesh) combo."""
    kind: str
    args: Tuple[Any, ...]            # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def params_shape(api: ModelApi) -> Any:
    return jax.eval_shape(api.init, jax.random.key(0))


def batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Optional[Mesh]):
    """Training/prefill batch: ShapeDtypeStructs + PartitionSpecs."""
    B, S = shape.global_batch, shape.seq_len
    bspec = shd.data_batch_spec(mesh, B) if mesh else P()
    batch = {"tokens": _sds((B, S), jnp.int32)}
    specs = {"tokens": bspec}
    if shape.kind == "train":
        batch["labels"] = _sds((B, S), jnp.int32)
        specs["labels"] = bspec
    if cfg.family == "vlm" and cfg.prefix_embeds:
        batch["prefix_embeds"] = _sds((B, cfg.prefix_embeds, cfg.d_model),
                                      jnp.dtype(cfg.dtype))
        specs["prefix_embeds"] = P(bspec[0], None, "model")
    if cfg.family == "encdec":
        batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                               jnp.dtype(cfg.dtype))
        specs["frames"] = P(bspec[0], None, None)
    return batch, specs


def decode_batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Optional[Mesh]):
    B = shape.global_batch
    bspec = shd.data_batch_spec(mesh, B) if mesh else P()
    batch = {"tokens": _sds((B, 1), jnp.int32)}
    specs = {"tokens": P(bspec[0], None)}
    # enc-dec cross-attention K/V live in the decode cache (computed once at
    # prefill), so the decode batch is tokens-only for every family.
    return batch, specs


def cache_structs(api: ModelApi, batch: int, cache_len: int):
    spec_tree = api.cache_spec(batch, cache_len)
    is_leaf = lambda s: isinstance(s, tuple) and len(s) == 2 and isinstance(s[1], jnp.dtype)
    return jax.tree_util.tree_map(lambda s: _sds(s[0], s[1]), spec_tree,
                                  is_leaf=is_leaf)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, cache_sds: Any):
    def one(path, leaf):
        names = shd._path_names(path)
        return NamedSharding(mesh, shd.cache_pspec(cfg, mesh, batch, names,
                                                   len(leaf.shape)))
    return jax.tree_util.tree_map_with_path(one, cache_sds)


# ---------------------------------------------------------------------------
# optimizer-state sharding (ZeRO-1)
# ---------------------------------------------------------------------------

def opt_state_specs(params_sds: Any, cfg: ModelConfig, mesh: Mesh):
    """m/nu are fp32 copies of params, additionally sharded over `data` on
    the first replicated dim that divides (ZeRO-1)."""
    pspecs = shd.param_specs(params_sds, cfg)
    dsize = shd.mesh_axis_size(mesh, "data")

    def zero1(leaf_sds, spec):
        dims = list(spec) + [None] * (len(leaf_sds.shape) - len(spec))
        if "data" not in jax.tree_util.tree_leaves(dims):
            for i, (d, s) in enumerate(zip(dims, leaf_sds.shape)):
                if d is None and s % dsize == 0 and s >= dsize:
                    dims[i] = "data"
                    break
        return P(*dims)

    moment_specs = jax.tree_util.tree_map(zero1, params_sds, pspecs)
    from repro.optim.optimizers import OptState
    return OptState(P(), moment_specs, moment_specs)


def opt_state_shape(params_sds: Any, opt) -> Any:
    return jax.eval_shape(opt.init, params_sds)


# ---------------------------------------------------------------------------
# top-level StepSpec builders
# ---------------------------------------------------------------------------

def _ns(mesh, tree):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), tree,
                                  is_leaf=lambda x: isinstance(x, P))


def train_step_spec(api: ModelApi, shape: InputShape, mesh: Mesh, opt) -> StepSpec:
    cfg = api.cfg
    p_sds = params_shape(api)
    o_sds = opt_state_shape(p_sds, opt)
    batch, bspecs = batch_specs(cfg, shape, mesh)
    p_specs = shd.param_specs(p_sds, cfg)
    o_specs = opt_state_specs(p_sds, cfg, mesh)
    # moments share param tree structure; broadcast their spec trees
    in_sh = (_ns(mesh, p_specs), _ns(mesh, o_specs), _ns(mesh, bspecs))
    out_sh = (_ns(mesh, p_specs), _ns(mesh, o_specs), None)
    return StepSpec("train", (p_sds, o_sds, batch), in_sh, out_sh, (0, 1))


def prefill_step_spec(api: ModelApi, shape: InputShape, mesh: Mesh) -> StepSpec:
    cfg = api.cfg
    p_sds = params_shape(api)
    batch, bspecs = batch_specs(cfg, shape, mesh)
    in_sh = (_ns(mesh, shd.param_specs(p_sds, cfg)), _ns(mesh, bspecs))
    return StepSpec("prefill", (p_sds, batch), in_sh, None, ())


def decode_step_spec(api: ModelApi, shape: InputShape, mesh: Mesh) -> StepSpec:
    cfg = api.cfg
    B = shape.global_batch
    p_sds = params_shape(api)
    batch, bspecs = decode_batch_specs(cfg, shape, mesh)
    cache = cache_structs(api, B, shape.seq_len)
    cache_sh = cache_shardings(cfg, mesh, B, cache)
    idx = _sds((), jnp.int32)
    in_sh = (_ns(mesh, shd.param_specs(p_sds, cfg)), _ns(mesh, bspecs),
             cache_sh, NamedSharding(mesh, P()))
    out_sh = (None, cache_sh)
    return StepSpec("decode", (p_sds, batch, cache, idx), in_sh, out_sh, (2,))


def _unshard_specs(api: ModelApi):
    """Param specs with the fsdp (`data`) axis removed — the compute-time
    layout for ZeRO-1-style stepping (cfg.fsdp_unshard_step)."""
    cfg = api.cfg.replace(sharding="dp_tp")
    return shd.param_specs(params_shape(api), cfg)


def make_step_fn(api: ModelApi, kind: str, opt=None):
    cfg = api.cfg
    unshard = (_unshard_specs(api)
               if getattr(cfg, "fsdp_unshard_step", False)
               and cfg.sharding == "fsdp_tp" else None)
    if kind == "train":
        def train_step(params, opt_state, batch):
            if unshard is not None:
                # ZeRO-1: one all-gather of the param stack per step; XLA
                # reshards (reduce-scatters) on the way out via out_shardings
                compute_params = jax.lax.with_sharding_constraint(
                    params, unshard)
            else:
                compute_params = params
            (loss, metrics), grads = jax.value_and_grad(api.loss_fn, has_aux=True)(
                compute_params, batch)
            lr = 3e-4
            new_p, new_o = opt.update(params, opt_state, grads, lr)
            return new_p, new_o, {"loss": loss, **metrics}
        return train_step
    if kind == "prefill":
        def prefill_step(params, batch):
            p = (jax.lax.with_sharding_constraint(params, unshard)
                 if unshard is not None else params)
            logits, caches = api.prefill(p, batch)
            return logits
        return prefill_step
    if kind == "decode":
        def decode_step(params, batch, cache, cache_index):
            return api.decode_step(params, batch, cache, cache_index)
        return decode_step
    raise ValueError(kind)


def step_spec(api: ModelApi, shape: InputShape, mesh: Mesh, opt=None) -> StepSpec:
    if shape.kind == "train":
        return train_step_spec(api, shape, mesh, opt)
    if shape.kind == "prefill":
        return prefill_step_spec(api, shape, mesh)
    return decode_step_spec(api, shape, mesh)
