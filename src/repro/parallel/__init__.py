"""Sharding rules and the bucketed/hierarchical/compressed grad sync."""
