"""Sharding rules: map every parameter / activation / cache leaf to a
PartitionSpec for the production meshes.

Strategies
----------
``dp_tp``   batch over (pod, data); tensor-parallel over `model`;
            params replicated across `data`.
``fsdp_tp`` as above, plus parameters and optimizer state sharded over
            `data` *within* a pod (hybrid FSDP: replicated across pods so
            param all-gathers stay on ICI, gradients cross DCN once).

Optimizer state is always ZeRO-1 sharded (see repro/optim).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def batch_shardable(mesh: Mesh, batch: int) -> Optional[Tuple[str, ...]]:
    axes = batch_axes(mesh)
    size = 1
    for a in axes:
        size *= mesh_axis_size(mesh, a)
    if batch % size == 0:
        return axes
    if batch % mesh_axis_size(mesh, "data") == 0:
        return ("data",)
    return None


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

# trailing-dims rules: (parent, leaf) -> tuple of axis roles
# roles: "f" = fsdp axis (data when fsdp_tp else None), "m" = model, None = repl.
_RULES: Dict[Tuple[str, str], Tuple[Optional[str], ...]] = {
    ("attn", "wq"): ("f", "m"),
    ("attn", "wk"): ("f", "m"),
    ("attn", "wv"): ("f", "m"),
    ("attn", "wo"): ("m", "f"),
    ("attn", "w_q"): ("f", "m"),
    ("attn", "w_uk"): ("f", "m"),
    ("attn", "w_uv"): ("f", "m"),
    ("attn", "w_o"): ("m", "f"),
    ("attn", "w_dkv"): ("f", None),
    ("attn", "w_kr"): ("f", None),
    ("attn", "ln_kv"): (None,),
    ("xattn", "wq"): ("f", "m"),
    ("xattn", "wk"): ("f", "m"),
    ("xattn", "wv"): ("f", "m"),
    ("xattn", "wo"): ("m", "f"),
    ("mlp", "wi"): ("f", "m"),
    ("mlp", "wg"): ("f", "m"),
    ("mlp", "wo"): ("m", "f"),
    ("shared", "wi"): ("f", "m"),
    ("shared", "wg"): ("f", "m"),
    ("shared", "wo"): ("m", "f"),
    ("moe", "router"): ("f", None),
    ("moe", "wi"): ("m", "f", None),
    ("moe", "wg"): ("m", "f", None),
    ("moe", "wo"): ("m", None, "f"),
    ("embed", "w"): ("f", "m"),
    ("lm_head", "w"): ("f", "m"),
    # SSM (mamba) ----------------------------------------------------------
    ("ssm", "w_in"): ("f", "m"),        # (D, 2*d_inner)
    ("ssm", "w_x"): ("f", "m"),         # conv/in projections on d_inner
    ("ssm", "conv_w"): (None, "m"),     # (d_conv, d_inner)
    ("ssm", "conv_b"): ("m",),
    ("ssm", "w_bcdt"): ("m", None),     # (d_inner, 2*d_state+dt_rank)
    ("ssm", "w_dt"): (None, "m"),       # (dt_rank, d_inner)
    ("ssm", "dt_bias"): ("m",),
    ("ssm", "a_log"): ("m", None),      # (d_inner, d_state)
    ("ssm", "d_skip"): ("m",),
    ("ssm", "w_out"): ("m", "f"),       # (d_inner, D)
    # RWKV6 ----------------------------------------------------------------
    ("rwkv", "w_r"): ("f", "m"),
    ("rwkv", "w_k"): ("f", "m"),
    ("rwkv", "w_v"): ("f", "m"),
    ("rwkv", "w_g"): ("f", "m"),
    ("rwkv", "w_o"): ("m", "f"),
    ("rwkv", "w_decay"): ("f", "m"),
    ("rwkv", "w_decay_lora_a"): ("f", None),
    ("rwkv", "w_decay_lora_b"): (None, "m"),
    ("rwkv", "u_bonus"): ("m",),
    ("rwkv", "mix"): (None, None),
    ("rwkv", "wk_ch"): ("f", "m"),      # channel-mix
    ("rwkv", "wv_ch"): ("m", "f"),
    ("rwkv", "wr_ch"): ("f", None),
}

# §Perf variant (cfg.moe_shard == "edim_dff"): keep experts on `model` but
# move the fsdp axis off the CONTRACTING d_model dim onto d_ff, so matmuls
# never contract a sharded dim — XLA stops all-gathering expert weights and
# instead all-reduces the (small) activations.  Same storage footprint.
_MOE_DFF_RULES: Dict[Tuple[str, str], Tuple[Optional[str], ...]] = {
    ("moe", "wi"): ("m", None, "f"),
    ("moe", "wg"): ("m", None, "f"),
    ("moe", "wo"): ("m", "f", None),
}

# §Perf variant "dff_only" (dp_tp MoE, e.g. moonshot): replicate the expert
# dim and TP-shard d_ff — the dispatch/combine einsums see no sharded E, so
# their backward stops all-gathering (E,B,C,D); the wo partial sums defer
# through the combine to a (B,S,D)-sized all-reduce.
_MOE_DFF_ONLY_RULES: Dict[Tuple[str, str], Tuple[Optional[str], ...]] = {
    ("moe", "wi"): (None, None, "m"),
    ("moe", "wg"): (None, None, "m"),
    ("moe", "wo"): (None, "m", None),
}


def _leaf_spec(path: Tuple[str, ...], ndim: int, strategy: str,
               moe_shard: str = "edim_dmodel") -> P:
    f = "data" if strategy == "fsdp_tp" else None
    key = None
    for i in range(len(path) - 1):
        if (path[i], path[-1]) in _RULES:
            key = (path[i], path[-1])
    if key is None and len(path) >= 2 and (path[-2], path[-1]) in _RULES:
        key = (path[-2], path[-1])
    if key is None:
        return P()  # norms, biases, scalars: replicated
    roles = _RULES[key]
    if moe_shard == "edim_dff" and key in _MOE_DFF_RULES:
        roles = _MOE_DFF_RULES[key]
    elif moe_shard == "dff_only" and key in _MOE_DFF_ONLY_RULES:
        roles = _MOE_DFF_ONLY_RULES[key]
    spec = tuple({"f": f, "m": "model"}.get(r, None) if isinstance(r, str) else None
                 for r in roles)
    if len(spec) > ndim:       # un-stacked single layer params
        spec = spec[-ndim:]
    pad = (None,) * (ndim - len(spec))
    return P(*(pad + spec))


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            names.append(str(p.key))
        else:
            names.append(str(p))
    return tuple(names)


def param_specs(params_shape: Any, cfg: ModelConfig) -> Any:
    """Pytree of PartitionSpec matching a pytree of ShapeDtypeStruct/arrays."""
    moe_shard = getattr(cfg, "moe_shard", "edim_dmodel")
    def one(path, leaf):
        return _leaf_spec(_path_names(path), len(leaf.shape), cfg.sharding,
                          moe_shard)
    return jax.tree_util.tree_map_with_path(one, params_shape)


def param_shardings(params_shape: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  param_specs(params_shape, cfg))


# ---------------------------------------------------------------------------
# activation / batch / cache specs
# ---------------------------------------------------------------------------

def data_batch_spec(mesh: Mesh, batch: int) -> P:
    axes = batch_shardable(mesh, batch)
    return P(axes, None) if axes else P(None, None)


def cache_pspec(cfg: ModelConfig, mesh: Mesh, batch: int, leaf_path: Tuple[str, ...],
                ndim: int) -> P:
    """Decode-cache sharding.

    Layout (L, B, W, ...) — batch over the data axes when divisible;
    otherwise the sequence (W) dim is sharded over them (flash-decoding);
    kv-heads over `model` when divisible, else W also takes `model`.

    Recurrent-state leaves (SSM/RWKV) have no W dim: their feature axis is
    `model`-sharded and batch over data when divisible.
    """
    baxes = batch_shardable(mesh, batch)
    leaf = leaf_path[-1]
    # --- recurrent / cross-attention states, dispatched by leaf name ------
    if leaf == "conv":          # (L, B, d_conv-1, d_inner)
        return P(None, baxes, None, "model")
    if leaf == "ssm":           # (L, B, d_inner, d_state)
        return P(None, baxes, "model", None)
    if leaf == "state":         # (L, B, H, hd, hd)  rwkv wkv state
        return P(None, baxes, "model", None, None)
    if leaf in ("tm_x", "cm_x"):  # (L, B, D)
        return P(None, baxes, "model")
    if leaf in ("xk", "xv"):    # (L, B, enc_seq, KV, hd)
        m = mesh_axis_size(mesh, "model")
        kv_ok = cfg.num_kv_heads % m == 0
        # enc_seq (1500) is not tile-friendly: replicate over `model`
        # unless the kv-heads divide.
        return P(None, baxes, None, "model" if kv_ok else None, None)
    m = mesh_axis_size(mesh, "model")
    kv_shardable = cfg.num_kv_heads % m == 0 and cfg.attention == "gqa"
    w_axes = []
    if baxes is None:
        w_axes.extend(batch_axes(mesh))
    if not kv_shardable:
        w_axes.append("model")
    spec = [None] * ndim
    # dims: (L, B, W, [KV, hd]) or (L, B, W, latent)
    b_dim, w_dim = ndim - 3 if ndim >= 4 else 1, ndim - 2 if ndim >= 4 else 2
    if ndim == 4:           # (L, B, W, latent) or (L, B, W, feat)
        b_dim, w_dim = 1, 2
    elif ndim == 5:         # (L, B, W, KV, hd)
        b_dim, w_dim = 1, 2
        if kv_shardable:
            spec[3] = "model"
    elif ndim == 3:         # (L, B, feat)  (ssm states)
        spec[1] = baxes
        spec[2] = "model"
        return P(*spec)
    if baxes:
        spec[b_dim] = baxes
    if w_axes:
        spec[w_dim] = tuple(w_axes) if len(w_axes) > 1 else w_axes[0]
    return P(*spec)
