"""Bucketed, hierarchical, optionally-compressed gradient synchronisation —
the paper's communication phase as a first-class runtime feature.

The paper shows that Horovod's transport leaves a 100 Gbps NIC at <32 Gbps
and that a *well-scheduled* communication phase (fusion buffers + full link
utilization) reaches a ~100 % scaling factor.  On TPU the transport is
XLA-driven, so the levers that remain at our layer are exactly the ones
this module implements:

- **fusion buckets** (paper: 64 MB / 5 ms): gradients are flattened and
  packed into <=``fusion_buffer_mb`` slabs so each collective moves a
  large contiguous buffer instead of per-tensor messages (the per-tensor
  negotiation overhead is the reason measured Horovod *degrades* with
  tensor count — §2.2);
- **hierarchical all-reduce**: reduce-scatter inside the pod over ICI,
  all-reduce across pods over the (slower) DCN on the 1/N-sized shard,
  all-gather inside the pod — wire-optimal for 2-level topologies;
- **gradient compression** (paper §3.2): fp16 / int8 / ternary / top-k via
  the Pallas kernels in ``repro.kernels``, applied per bucket.  Quantized
  buckets are exchanged with all-gather + local fused reduction (Horovod
  compression semantics: sums are computed on dequantized values, so
  compression error does not accumulate across hops).

Everything runs under ``shard_map`` with explicit ``jax.lax`` collectives;
``sync_grads`` is the one entry point (used by ``launch/train.py`` when
``CommConfig.mode == "explicit"``; ``mode == "auto"`` leaves gradient
averaging to XLA SPMD via pjit, which is the measured baseline the
roofline tables report).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import CommConfig
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# bucketing: pytree <-> fixed-size flat slabs
# ---------------------------------------------------------------------------

class BucketPlan:
    """Static packing plan: leaf -> (bucket id, offset) assignments.

    Built once per param-tree structure (shapes are static under jit).
    Leaves are packed in pytree order — the order backward produces them —
    mirroring the paper's fusion buffer fill order.
    """

    def __init__(self, shapes: Sequence[Tuple[int, ...]], dtypes,
                 limit_bytes: int):
        self.shapes = list(shapes)
        self.sizes = [int(np.prod(s)) if s else 1 for s in shapes]
        self.dtypes = list(dtypes)
        self.assignments: List[Tuple[int, int]] = []      # (bucket, offset)
        self.bucket_sizes: List[int] = []
        self.bucket_tensors: List[int] = []               # leaves per bucket
        cur, cur_bytes, cur_tensors = 0, 0, 0
        offset = 0
        for size, dtype in zip(self.sizes, self.dtypes):
            nbytes = size * jnp.dtype(dtype).itemsize
            if cur_bytes > 0 and cur_bytes + nbytes > limit_bytes:
                self.bucket_sizes.append(offset)
                self.bucket_tensors.append(cur_tensors)
                cur += 1
                cur_bytes, offset, cur_tensors = 0, 0, 0
            self.assignments.append((cur, offset))
            offset += size
            cur_bytes += nbytes
            cur_tensors += 1
        if offset:
            self.bucket_sizes.append(offset)
            self.bucket_tensors.append(cur_tensors)

    @property
    def n_buckets(self) -> int:
        return len(self.bucket_sizes)

    def comm_plan(self, comm: CommConfig):
        """Lower this packing into the shared comm-schedule IR.

        Buckets are packed (and flushed) in pytree order — the backward
        production order — so the plan's ``bucket_order()`` is exactly what
        the simulator predicts for the same scheduler: the runtime executes
        its collectives in that order (simulator <-> runtime parity).
        Packed buckets are f32, hence 4 bytes per element.
        """
        from repro.core.schedule import lower_buckets
        return lower_buckets(
            [(0.0, float(n_elems * 4), n_tensors)
             for n_elems, n_tensors in zip(self.bucket_sizes,
                                           self.bucket_tensors)],
            scheduler=comm.scheduler, n_chunks=comm.sched_chunks)


def make_plan(tree: Any, limit_mb: float) -> Tuple[BucketPlan, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    plan = BucketPlan([l.shape for l in leaves], [l.dtype for l in leaves],
                      int(limit_mb * 1024 * 1024))
    return plan, treedef


def pack(plan: BucketPlan, leaves: Sequence[jnp.ndarray]) -> List[jnp.ndarray]:
    """Leaves -> list of flat f32 buckets."""
    parts: List[List[jnp.ndarray]] = [[] for _ in range(plan.n_buckets)]
    for leaf, (b, _) in zip(leaves, plan.assignments):
        parts[b].append(leaf.astype(jnp.float32).reshape(-1))
    return [jnp.concatenate(p) for p in parts]


def unpack(plan: BucketPlan, buckets: Sequence[jnp.ndarray]) -> List[jnp.ndarray]:
    out = []
    for (b, off), size, shape, dtype in zip(plan.assignments, plan.sizes,
                                            plan.shapes, plan.dtypes):
        out.append(jax.lax.dynamic_slice(buckets[b], (off,), (size,))
                   .reshape(shape).astype(dtype))
    return out


# ---------------------------------------------------------------------------
# per-bucket collectives (run inside shard_map)
# ---------------------------------------------------------------------------

def _allreduce_mean(x: jnp.ndarray, axes) -> jnp.ndarray:
    return jax.lax.pmean(x, axes)


def _hierarchical_mean(x: jnp.ndarray, ici_axis: str, dcn_axis: str | None,
                       nd: int, n_dcn: int) -> jnp.ndarray:
    """In-pod reduce-scatter -> cross-pod all-reduce -> in-pod all-gather.

    ``nd`` / ``n_dcn`` are the static mesh sizes of the two axes (jax.lax
    has no axis_size query on this version; the caller knows the mesh).
    """
    pad = (-x.shape[0]) % nd
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    shard = jax.lax.psum_scatter(x.reshape(nd, -1), ici_axis,
                                 scatter_dimension=0, tiled=False)
    if dcn_axis is not None:
        shard = jax.lax.psum(shard, dcn_axis)
    full = jax.lax.all_gather(shard, ici_axis, axis=0, tiled=False)
    full = full.reshape(-1)
    if pad:
        full = full[:-pad]
    n_total = nd * (n_dcn if dcn_axis else 1)
    return full / n_total


def _compressed_mean(x: jnp.ndarray, comm: CommConfig, axes,
                     n_total: int) -> jnp.ndarray:
    """Horovod-compression semantics: all-gather compressed payloads, then
    one fused dequantize+reduce locally (Pallas ``fused_add``)."""
    if comm.compression == "fp16":
        g = jax.lax.all_gather(x.astype(jnp.bfloat16), axes, axis=0,
                               tiled=False)
        g = g.reshape(n_total, -1)
        return kops.fused_add(g) / n_total
    if comm.compression in ("int8", "ternary"):
        enc = (kops.quantize_int8 if comm.compression == "int8"
               else kops.ternarize)
        q, s, n = enc(x)
        qg = jax.lax.all_gather(q, axes, axis=0, tiled=False)
        sg = jax.lax.all_gather(s, axes, axis=0, tiled=False)
        qg = qg.reshape(n_total, *q.shape)
        sg = sg.reshape(n_total, *s.shape)
        deq = jax.vmap(lambda qq, ss: qq.astype(jnp.float32) * ss)(qg, sg)
        total = kops.fused_add(deq.reshape(n_total, -1))
        return total.reshape(q.shape).reshape(-1)[:n] / n_total
    if comm.compression == "topk":
        sparse = kops.topk_sparsify(x, comm.topk_ratio, sample=1 << 14)
        g = jax.lax.all_gather(sparse, axes, axis=0, tiled=False)
        return kops.fused_add(g.reshape(n_total, -1)) / n_total
    raise ValueError(comm.compression)


def _sync_bucket(x: jnp.ndarray, comm: CommConfig, axes: Tuple[str, ...],
                 axis_sizes: Tuple[int, ...]) -> jnp.ndarray:
    if comm.compression != "none":
        n_total = 1
        for s in axis_sizes:
            n_total *= s
        return _compressed_mean(x, comm, axes, n_total)
    if comm.hierarchical and len(axes) == 2:
        # axes = (pod, data): ICI inside the pod (data), DCN across (pod)
        return _hierarchical_mean(x, ici_axis=axes[1], dcn_axis=axes[0],
                                  nd=axis_sizes[1], n_dcn=axis_sizes[0])
    if comm.hierarchical:
        return _hierarchical_mean(x, ici_axis=axes[0], dcn_axis=None,
                                  nd=axis_sizes[0], n_dcn=1)
    return _allreduce_mean(x, axes)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def sync_grads(grads: Any, mesh: Mesh, comm: CommConfig,
               batch_axes: Tuple[str, ...] = ("data",)) -> Any:
    """Average ``grads`` (replicated-param gradients) over the batch axes.

    Equivalent to ``jax.tree.map(lambda g: pmean(g, batch_axes), grads)``
    but bucketed (fusion buffers), hierarchical, and optionally compressed —
    the paper's communication phase, implemented the way the what-if
    analysis says it should be.
    """
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    axis_sizes = tuple(mesh.shape[a] for a in axes)
    plan, treedef = make_plan(grads, comm.fusion_buffer_mb)
    leaves = jax.tree_util.tree_leaves(grads)

    # everything is replicated w.r.t. the batch axes inside this collective;
    # model-parallel sharding stays outside (pjit handles those dims)
    spec = P()

    # the comm-schedule IR orders the collectives: the same CommPlan the
    # simulator executes, so the runtime issues its buckets in the order the
    # analytic layer predicted (fifo keeps pack order; priority front-loads
    # the model's first layers).  Emission order alone would let XLA's
    # latency-hiding scheduler reorder independent collectives, so each
    # bucket's input is barrier-chained to the previous bucket's output —
    # one collective in flight, in plan order, matching the engine's
    # serialization semantics.
    order = plan.comm_plan(comm).bucket_order()

    @functools.partial(shard_map, mesh=mesh, in_specs=spec, out_specs=spec,
                       check_rep=False)
    def run(*flat_leaves):
        buckets = pack(plan, flat_leaves)
        synced: List[jnp.ndarray] = [None] * len(buckets)  # type: ignore[list-item]
        prev = None
        for b in order:
            x = buckets[b]
            if prev is not None:
                x, _ = jax.lax.optimization_barrier((x, prev))
            prev = synced[b] = _sync_bucket(x, comm, axes, axis_sizes)
        return tuple(unpack(plan, synced))

    new_leaves = run(*leaves)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def grad_sync_flops_and_bytes(total_bytes: int, n_workers: int,
                              comm: CommConfig) -> dict:
    """Analytic wire traffic of one sync — feeds the simulator/benchmarks."""
    ratio = {"none": 1.0, "fp16": 2.0, "int8": 4.0, "ternary": 4.0,
             "topk": 1.0 / max(comm.topk_ratio, 1e-9) / 2.0}[comm.compression]
    if comm.compression == "none":
        wire = 2.0 * total_bytes * (n_workers - 1) / n_workers
    else:  # all-gather of compressed payloads
        wire = total_bytes / ratio * (n_workers - 1)
    return {"wire_bytes_per_worker": wire, "compression_ratio": ratio}
