"""The paper's primary contribution: scaling-factor methodology, gradient
timelines, the two-process what-if simulator (a discrete-event network
engine executing a comm-schedule IR — ``events``/``schedule``), transport
curves, all-reduce cost models, and the per-figure what-if API."""
