"""The paper's §3.1 what-if simulator, on the discrete-event network engine.

Two logical processes communicate through a queue:

- the **backward process** replays the gradient-ready timeline and batches
  gradients into a Horovod-style fusion buffer (64 MB size limit OR 5 ms
  timeout from the first pending gradient, whichever fires first);
- the **communication process** lowers the flushed buckets into a
  :class:`~repro.core.schedule.CommPlan` under a named scheduler and
  executes it on the event engine (:mod:`repro.core.events`):

  * ``fifo``      — FIFO, one serialized collective in flight (Horovod's
                    semantics; bit-exact with the legacy serialized loop);
  * ``priority``  — first-layer-first with preemption at chunk boundaries
                    (ByteScheduler-style);
  * ``chunked``   — k chunks per bucket, transmission pipelined with
                    reduction (Sun et al.'s fused+pipelined all-reduce).

The ``topology``/``transport`` cost models become per-flow durations (a
wire part that scales under link sharing plus a fixed reduction latency),
so multi-job contention — two timelines on one link — is expressible via
:func:`simulate_contention`.

Two scenario axes the paper's testbed could not sweep ride on the same
lowering:

- ``n_rails`` splits the link into that many rails at ``1/n_rails`` of the
  aggregate bandwidth each (:func:`~repro.core.schedule.assign_rails`
  stamps ops onto rails; the engine runs one fluid clock per rail), so a
  2x50G multi-rail host and a single 100G NIC are different cells at equal
  aggregate bandwidth;
- ``jitter`` perturbs every flow's flush time by a seeded exponential draw
  (:func:`~repro.core.events.perturb_flows`) — the straggler axis.  Both
  default off and the default path is bit-exact with a build that never
  had them.

Outputs: t_sync, t_overhead = max(0, t_sync - t_back), and
f_sim = t_batch / (t_batch + t_overhead)   (paper Eq. in §3.1).
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import CommConfig
from repro.core.addest import AddEst
from repro.core.codec import NONE_CODEC, SIZE_ADAPTIVE, Codec, get_codec
from repro.core import events as _ev
from repro.core.events import (DEFAULT_LINK, FlowBatch, FlowResult, FlowSpec,
                               ResultBatch, concat_batches, perturb_batch,
                               perturb_flows, run_flow_batch, run_flows,
                               serialized_chain)
from repro.core.fabric import resolve_fabric
from repro.core.faults import (FaultModel, apply_faults_batch,
                               apply_faults_flows, churn_events,
                               parse_fault_model, worker_codes)
from repro.core.network_model import RingAllReduce, make_cost_model
from repro.core.schedule import (CodecLowering, CommPlan, assign_codec,
                                 assign_rails, canonical_scheduler,
                                 clone_flows, codec_compute_seconds,
                                 lower_buckets, plan_to_flow_batch,
                                 plan_to_flows)
from repro.core.timeline import GradTimeline
from repro.core.transport import (LinkProfile, Transport, get_transport,
                                  parse_link_profile, retx_events)


BUCKET_FIELDS = ("flush_time", "size", "n_tensors", "start", "end")

# scalar SimResult fields, in stable serialization order (artifact schema)
RESULT_FIELDS = ("name", "n_workers", "bandwidth", "effective_bw", "t_batch",
                 "t_back", "t_sync", "t_overhead", "scaling_factor",
                 "wire_bytes_per_worker", "network_utilization")


@dataclass(frozen=True)
class Bucket:
    flush_time: float        # when the backward process hands it over
    size: float              # bytes
    n_tensors: int = 1       # gradient tensors fused into this bucket
    start: float = 0.0       # all-reduce start (filled by the engine)
    end: float = 0.0

    def to_dict(self) -> dict:
        return {f: getattr(self, f) for f in BUCKET_FIELDS}

    @staticmethod
    def from_dict(d: dict) -> "Bucket":
        return Bucket(**{f: d[f] for f in BUCKET_FIELDS})


@dataclass(frozen=True)
class SimResult:
    name: str
    n_workers: int
    bandwidth: float                  # physical link bandwidth, bytes/s
    effective_bw: float               # after the transport curve
    t_batch: float
    t_back: float
    t_sync: float
    t_overhead: float
    scaling_factor: float
    buckets: Tuple[Bucket, ...]
    wire_bytes_per_worker: float      # actual bytes each worker moved
    network_utilization: float        # avg wire throughput / physical bw
    scheduler: str = "fifo"           # comm schedule the result was run under
    codec: str = "none"               # compression codec the run was under
    codec_compute_s: float = 0.0      # encode+decode GPU seconds spent

    def summary(self) -> str:
        return (f"{self.name}: n={self.n_workers} bw={self.bandwidth*8/1e9:.0f}Gbps "
                f"sched={self.scheduler} f_sim={self.scaling_factor:.3f} "
                f"overhead={self.t_overhead*1e3:.1f}ms "
                f"util={self.network_utilization:.2f}")

    def to_dict(self, include_buckets: bool = False) -> dict:
        """Stable JSON-ready form (the experiment-artifact cell schema).

        Buckets are summarized by count unless ``include_buckets``; full
        float repr round-trips through JSON bit-exactly either way.  The
        codec fields are elided at their defaults so codec-free artifacts
        keep their exact pre-codec bytes.
        """
        d = {f: getattr(self, f) for f in RESULT_FIELDS}
        d["scheduler"] = self.scheduler
        d["n_buckets"] = len(self.buckets)
        if self.codec != "none":
            d["codec"] = self.codec
            d["codec_compute_s"] = self.codec_compute_s
        if include_buckets:
            d["buckets"] = [b.to_dict() for b in self.buckets]
        return d

    @staticmethod
    def from_dict(d: dict) -> "SimResult":
        buckets = tuple(Bucket.from_dict(b) for b in d.get("buckets", ()))
        return SimResult(**{f: d[f] for f in RESULT_FIELDS}, buckets=buckets,
                         scheduler=d.get("scheduler", "fifo"),
                         codec=d.get("codec", "none"),
                         codec_compute_s=d.get("codec_compute_s", 0.0))


def fuse_buckets(timeline: GradTimeline, comm: CommConfig) -> List[Bucket]:
    """The backward process: fusion-buffer batching of the gradient stream.

    Faithful to Horovod semantics as described in the paper: a bucket is
    flushed when it reaches the size limit, or when ``timeout_ms`` has
    elapsed since its first pending gradient.  The tail bucket flushes when
    the last gradient arrives (backward completion ends the cycle — Horovod
    does not idle out the final timeout window).

    A gradient larger than the buffer flushes in ``limit``-sized slabs; the
    split tensor stays pending in the remainder bucket and is counted there
    (``n_pend = 1``), so per-tensor negotiation overhead is charged once per
    bucket the tensor occupies rather than undercounting every flush after
    a slab split.
    """
    limit = comm.fusion_buffer_mb * 1024 * 1024
    timeout = comm.timeout_ms / 1e3
    buckets: List[Bucket] = []
    pending, n_pend = 0.0, 0
    first_t: Optional[float] = None

    for t, size in zip(timeline.ready_times, timeline.sizes):
        if first_t is not None and t > first_t + timeout:
            buckets.append(Bucket(first_t + timeout, pending, n_pend))
            pending, n_pend, first_t = 0.0, 0, None
        if first_t is None:
            first_t = t
        pending += size
        n_pend += 1
        while pending >= limit:
            # a gradient larger than the buffer flushes in `limit` slabs
            buckets.append(Bucket(t, min(pending, limit), max(n_pend, 1)))
            pending -= min(pending, limit)
            n_pend = 0 if pending == 0.0 else 1   # the split tensor's tail
            first_t = None if pending == 0.0 else t
    if pending > 0.0 and first_t is not None:
        buckets.append(Bucket(timeline.t_back, pending, n_pend))
    return buckets


# The max-plus chain solver moved to repro.core.events (serialized_chain):
# the columnar lowering's codec encode chain needs it too.  Re-exported
# under its old name for the fifo fast path and the tests pinning its
# exactness against the serial loop.
_serialized_closed_form = serialized_chain


def _fifo_fast_results(plan: CommPlan, flows: Sequence[FlowSpec]
                       ) -> Optional[List[FlowResult]]:
    """Closed-form results for a single-job, unit-capacity fifo plan.

    A serialized fifo plan can never contend — one ``hold`` flow in flight
    on a dedicated link — so the event loop degenerates to the max-plus
    recurrence that :func:`_serialized_closed_form` vectorizes.  Dispatch
    is *checked*, not assumed: every precondition the closed form relies on
    (hold semantics with precomputed durations, one job, one link, ready
    times non-decreasing along service order) is verified on the actual
    flow list, and anything else returns ``None`` to take the engine path.
    The caller guarantees unit link capacity by constructing the default
    engine (``run_flows`` with no ``capacities``).
    """
    if not plan.serialized_fifo:
        return None
    if not flows:
        return []
    if len(flows) < _FASTPATH_MIN_OPS:
        return None     # numpy's fixed costs exceed the calendar's below this
    job = flows[0].job
    link = flows[0].link
    prev_ready = -float("inf")
    for f in flows:
        if (not f.hold or f.duration is None or f.job != job
                or f.link != link or f.ready < prev_ready):
            return None
        prev_ready = f.ready
    ready = np.array([f.ready for f in flows])
    dur = np.array([f.duration for f in flows])
    starts, ends = _serialized_closed_form(ready, dur)
    wire_ends = starts + np.array([f.work for f in flows])
    new = tuple.__new__
    return [new(FlowResult, (f.op_id, job, s, w, e, False))
            for f, s, w, e in zip(flows, starts.tolist(), wire_ends.tolist(),
                                  ends.tolist())]


def _fifo_fast_batch(plan: CommPlan, batch: FlowBatch
                     ) -> Optional[ResultBatch]:
    """Columnar twin of :func:`_fifo_fast_results`.

    Same dispatch checks, run on the columns instead of per tuple: hold
    semantics with precomputed durations, one job, one link, ready times
    non-decreasing.  Anything else returns ``None`` for the engine path.
    """
    if not plan.serialized_fifo:
        return None
    n = batch.n
    if n < _FASTPATH_MIN_OPS:
        return None
    if len(batch.jobs) != 1 or len(batch.links) != 1:
        return None
    dur = batch.duration
    if (not batch.hold.all() or np.isnan(dur).any()
            or not (batch.ready[1:] >= batch.ready[:-1]).all()):
        return None
    starts, ends = serialized_chain(batch.ready, dur)
    return ResultBatch(batch.op_id, batch.jobs, batch.job, starts,
                       starts + batch.work, ends, np.zeros(n, dtype=bool))


# below ~2 dozen ops the event calendar is cheaper than numpy dispatch; the
# closed form pays off on the long serialized plans large sweeps generate
_FASTPATH_MIN_OPS = 24


def _fastpath_enabled() -> bool:
    return os.environ.get("REPRO_SIM_FASTPATH", "1") != "0"


def _resolve_codec(codec: str, compression_ratio: float,
                   error_feedback: bool) -> Tuple[str, Codec]:
    """Resolve the simulate-level codec knobs into an assignment policy
    plus a priced :class:`~repro.core.codec.Codec`.

    ``size-adaptive[:base]`` selects the Hivemind per-bucket policy with
    ``base`` (default ``int8``) on large buckets; anything else is a
    uniform stamp.  The legacy ``compression_ratio`` float rides along:
    ``codec="none"`` with a non-unit ratio resolves to the free parametric
    ``ratio`` codec, which reproduces the deprecated byte-divisor path
    bit-identically.
    """
    if codec == SIZE_ADAPTIVE or codec.startswith(SIZE_ADAPTIVE + ":"):
        base = codec.partition(":")[2] or "int8"
        resolved = get_codec(base, compression_ratio=compression_ratio)
        policy = "size-adaptive"
    else:
        resolved = get_codec(codec, compression_ratio=compression_ratio)
        policy = "uniform"
    if error_feedback:
        resolved = resolved.with_error_feedback()
    return policy, resolved


def _codec_lowerings(plan: CommPlan, resolved: Codec, base_cost, codec_cost
                     ) -> dict:
    """The ``codecs`` table for a stamped plan: the resolved codec plus the
    ``none`` passthrough (present under size-adaptive plans)."""
    table = {resolved.name: CodecLowering(resolved, codec_cost)}
    if any(op.codec == "none" for op in plan.ops):
        table["none"] = CodecLowering(NONE_CODEC, base_cost)
    return table


def _serve_from_batch(plan: CommPlan, buckets: Sequence[Bucket],
                      rb: ResultBatch) -> Tuple[List[Bucket], float, float]:
    """Columnar twin of :func:`_serve_plan`'s result-mapping loop.

    Bucket chunks are contiguous in op order under every scheduler, so the
    per-bucket min(start)/max(end) are segment reductions; ``busy`` stays a
    strict left fold over op order (``sum`` of a list — ``np.sum`` is
    pairwise and would re-associate the adds).  Bucket fields are cast back
    to python floats at this boundary so downstream JSON writers never see
    ``np.float64``.
    """
    if rb.n == 0:
        return [], 0.0, 0.0
    bid = np.fromiter((op.bucket_id for op in plan.ops), dtype=np.intp,
                      count=rb.n)
    seg = np.concatenate(([0], np.flatnonzero(bid[1:] != bid[:-1]) + 1))
    ids = bid[seg]
    s_min = np.minimum.reduceat(rb.start, seg)
    e_max = np.maximum.reduceat(rb.end, seg)
    nb = plan.n_buckets
    start = np.full(nb, np.inf)
    end = np.zeros(nb)
    np.minimum.at(start, ids, s_min)       # tolerates non-contiguous ids
    np.maximum.at(end, ids, e_max)
    occ = rb.end - rb.start if plan.scheduler == "fifo" \
        else rb.wire_end - rb.start
    busy = sum(occ.tolist())
    served = [Bucket(b.flush_time, b.size, b.n_tensors,
                     float(start[i]) if start[i] != np.inf else b.flush_time,
                     float(end[i]))
              for i, b in enumerate(buckets)]
    t_sync = max((b.end for b in served), default=0.0)
    return served, t_sync, busy


def _fault_horizon(ready: np.ndarray, work: np.ndarray,
                   latency: np.ndarray) -> float:
    """The iteration span churn arrivals are drawn over.

    An upper-bound proxy (max over ``ready + work + latency`` of the
    already-perturbed flows) — any deterministic positive scale works,
    but computing it identically from columns and from tuple-built arrays
    keeps both lowering paths' churn draws bit-identical.
    """
    return float(np.max(ready + work + latency))


def _serve_plan(plan: CommPlan, buckets: Sequence[Bucket], cost,
                tr: Transport, *, job: str = "job0",
                results: Optional[Sequence[FlowResult]] = None,
                n_rails: int = 1, jitter: float = 0.0, jitter_seed: int = 0,
                stream: int = 0,
                codecs: Optional[dict] = None,
                fault: Optional[FaultModel] = None,
                fault_seed: int = 0, n_workers: int = 1,
                path: Tuple[str, ...] = (),
                capacities: Optional[dict] = None,
                link: Optional[LinkProfile] = None
                ) -> Tuple[List[Bucket], float, float]:
    """Map per-op flow results back to per-bucket (start, end) + busy time.

    ``plan`` must already carry its rail assignment (channels); ``n_rails``
    only sizes the per-rail links.  ``jitter``/``jitter_seed``/``stream``
    perturb flow ready times via :func:`~repro.core.events.perturb_flows`
    — the fifo fast path stays dispatch-checked on the *perturbed* flows,
    so it still applies whenever the jittered ready order happens to stay
    monotone, and falls back to the engine otherwise.

    ``fault`` (a non-null :class:`~repro.core.faults.FaultModel`) applies
    after jitter: correlated delays and bandwidth skew rewrite the flows
    (:func:`~repro.core.faults.apply_faults_batch` and its tuple twin),
    then churn events — if any were drawn — route the run to the engine's
    membership-change path.  ``fault=None`` leaves every branch of this
    function untouched, byte for byte.

    Plans at or above the engine's small-plan threshold lower columnar
    (:func:`~repro.core.schedule.plan_to_flow_batch` straight into
    :meth:`~repro.core.events.NetworkEngine.run_batch`, no tuple
    materialization); ``REPRO_SIM_FASTPATH=0`` disables that dispatch and
    the fifo closed form together.

    ``path``/``capacities`` lower the plan onto a fabric route (see
    :mod:`repro.core.fabric`): a multi-link ``path`` is stamped on every
    flow after jitter and faults, routing the run to the engine's
    max-min core with the fabric's link capacities.  A path of length
    <= 1 stamps nothing — the fabric elided its uplink — leaving every
    branch byte-identical to the flat topology.

    ``link`` (a non-null :class:`~repro.core.transport.LinkProfile`)
    prices the lossy-link axis: the lowering inflates wire work and adds
    the RTT deterministically (:func:`~repro.core.schedule._apply_link`),
    and seeded RTO stalls (:func:`~repro.core.transport.retx_events`,
    substream ``(4,)`` of ``fault_seed``) join the churn list — the
    engine's ``_RETX`` calendar entries.  ``link=None`` leaves every
    branch byte-identical to the clean-link build.
    """
    fabric_path = path if len(path) > 1 else ()
    if results is None:
        if _fastpath_enabled() and len(plan.ops) >= _ev._SMALL_PLAN_MAX_FLOWS:
            batch = plan_to_flow_batch(plan, cost, tr.per_tensor_overhead,
                                       job=job, n_rails=n_rails,
                                       codecs=codecs, link_profile=link)
            if jitter > 0.0:
                batch = perturb_batch(batch, jitter, jitter_seed, stream)
            churn = None
            if fault is not None and batch.n:
                codes = worker_codes(plan, n_workers)
                batch = apply_faults_batch(batch, codes, fault, n_workers,
                                           fault_seed, stream)
                churn = churn_events(
                    fault, n_workers,
                    _fault_horizon(batch.ready, batch.work, batch.latency),
                    fault_seed, stream, job=job) or None
            if link is not None and batch.n:
                retx = retx_events(
                    link, sum(op.size for op in plan.ops),
                    _fault_horizon(batch.ready, batch.work, batch.latency),
                    fault_seed, stream, job=job)
                if retx:
                    churn = list(churn or ()) + retx
            if fabric_path:
                batch = batch.with_path(fabric_path)
                rb = run_flow_batch(batch, capacities=capacities,
                                    churn=churn)
            else:
                rb = None if churn else _fifo_fast_batch(plan, batch)
                if rb is None:
                    rb = run_flow_batch(batch, rails={DEFAULT_LINK: n_rails}
                                        if n_rails > 1 else None, churn=churn)
            return _serve_from_batch(plan, buckets, rb)
        flows = plan_to_flows(plan, cost, tr.per_tensor_overhead, job=job,
                              n_rails=n_rails, codecs=codecs,
                              link_profile=link)
        if jitter > 0.0:
            flows = perturb_flows(flows, jitter, jitter_seed, stream)
        churn = None
        if fault is not None and flows:
            codes = worker_codes(plan, n_workers)
            flows = apply_faults_flows(flows, codes, fault, n_workers,
                                       fault_seed, stream)
            churn = churn_events(
                fault, n_workers,
                _fault_horizon(np.array([f.ready for f in flows]),
                               np.array([f.work for f in flows]),
                               np.array([f.latency for f in flows])),
                fault_seed, stream, job=job) or None
        if link is not None and flows:
            retx = retx_events(
                link, sum(op.size for op in plan.ops),
                _fault_horizon(np.array([f.ready for f in flows]),
                               np.array([f.work for f in flows]),
                               np.array([f.latency for f in flows])),
                fault_seed, stream, job=job)
            if retx:
                churn = list(churn or ()) + retx
        if fabric_path:
            flows = [f._replace(path=fabric_path) for f in flows]
            results = run_flows(flows, capacities=capacities, churn=churn)
        if results is None and _fastpath_enabled() and churn is None:
            results = _fifo_fast_results(plan, flows)
        if results is None:
            results = run_flows(flows, rails={DEFAULT_LINK: n_rails}
                                if n_rails > 1 else None, churn=churn)
    start = {b: None for b in range(plan.n_buckets)}
    end = {b: 0.0 for b in range(plan.n_buckets)}
    busy = 0.0
    for op, r in zip(plan.ops, results):
        b = op.bucket_id
        start[b] = r.start if start[b] is None else min(start[b], r.start)
        end[b] = max(end[b], r.end)
        busy += r.occupancy if plan.scheduler == "fifo" else r.wire_end - r.start
    served = [Bucket(b.flush_time, b.size, b.n_tensors,
                     start[i] if start[i] is not None else b.flush_time,
                     end[i])
              for i, b in enumerate(buckets)]
    t_sync = max((b.end for b in served), default=0.0)
    return served, t_sync, busy


def simulate(timeline: GradTimeline, *, n_workers: int, bandwidth: float,
             comm: Optional[CommConfig] = None,
             transport: str | Transport = "ideal",
             addest: Optional[AddEst] = None,
             compression_ratio: float = 1.0,
             topology: str = "ring", n_pods: int = 1,
             dcn_bandwidth: Optional[float] = None,
             scheduler: Optional[str] = None,
             n_chunks: Optional[int] = None,
             n_rails: int = 1, rail_policy: str = "round-robin",
             jitter: float = 0.0, jitter_seed: int = 0,
             codec: str = "none", error_feedback: bool = False,
             fault_model: str = "none", churn_rate: float = 0.0,
             worker_bw_skew: float = 0.0, fault_seed: int = 0,
             fabric: str = "none",
             oversubscription: float = 1.0,
             link_profile: str | LinkProfile = "none") -> SimResult:
    """Run the two-process simulation for one iteration.

    ``bandwidth`` in bytes/s.  ``transport`` maps physical to effective
    bandwidth (the paper's measured-vs-ideal axis).  ``scheduler`` selects
    the comm schedule (default: ``comm.scheduler``, i.e. ``fifo``);
    ``n_chunks`` the chunking granularity of the pipelined schedulers.

    ``n_rails`` splits ``bandwidth`` (the *aggregate*) into that many
    equal rails and spreads the plan's ops across them under
    ``rail_policy`` (see :func:`~repro.core.schedule.assign_rails`);
    ``jitter`` (seconds, mean of the per-flow exponential delay) with
    ``jitter_seed`` turns on the straggler axis.  Both at their defaults
    reproduce today's results bit-for-bit.

    ``codec`` names a gradient-compression codec (see
    :mod:`repro.core.codec`): real codecs (``int8``, ``ternary``,
    ``topk:r``, ``size-adaptive[:base]``) lower every op into encode ->
    wire -> decode with kernel-calibrated compute costs;
    ``error_feedback`` adds the EF-SGD residual traffic to encode (and
    rejects free codecs).  ``codec="none"`` — with or without the
    deprecated ``compression_ratio`` byte divisor, which now routes
    through the free parametric ``ratio`` codec — is bit-exact with the
    pre-codec build.

    ``fault_model`` (``"none"`` | ``"slowdown:<ms>[:<rho>]"``) with
    ``churn_rate``/``worker_bw_skew``/``fault_seed`` turn on the
    unreliable-world axes (:mod:`repro.core.faults`): worker-correlated
    slowdowns, dropout/rejoin churn with a priced re-bucketing stall, and
    asymmetric per-worker bandwidth.  All at their defaults resolve to a
    null model that bypasses the fault layer entirely — bit-identical to
    the pre-fault engine.

    ``fabric`` (``"none"`` | ``"clos"``) with ``oversubscription`` lowers
    the collective onto a datacenter fabric (:mod:`repro.core.fabric`):
    flows traverse NIC -> ToR-uplink paths and the engine prices them at
    the bottleneck max-min fair share.  ``fabric="none"`` — and any
    fabric whose uplink can never bind, e.g. ``clos`` at 1:1 — is
    *bitwise* identical to the flat single-link topology.

    ``link_profile`` (``"none"`` |
    ``"wan:loss=p,rtt=ms[:timeout=ms,backoff=x]"`` | a
    :class:`~repro.core.transport.LinkProfile`) turns on the lossy-link
    axis: wire work inflates by ``1/(1-loss)``, the RTT joins the fixed
    latency, and seeded retransmission-timeout stalls (substream ``(4,)``
    of ``fault_seed``) ride the engine's ``_RETX`` calendar.  The null
    profile is *bitwise* identical to the clean-link build.
    """
    comm = comm or CommConfig()
    addest = addest or AddEst.v100()
    tr = get_transport(transport) if isinstance(transport, str) else transport
    eff_bw = tr.effective(bandwidth)
    sched = canonical_scheduler(scheduler or comm.scheduler)
    k = n_chunks if n_chunks is not None else comm.sched_chunks
    n_rails = max(int(n_rails), 1)      # 0 and 1 both mean "no rails"
    policy, resolved = _resolve_codec(codec, compression_ratio,
                                      error_feedback)
    free = resolved.is_free and policy == "uniform"
    fm = parse_fault_model(fault_model, churn_rate=churn_rate,
                           bw_skew=worker_bw_skew)
    fault = None if fm.is_null else fm
    fab = resolve_fabric(fabric, oversubscription)
    lp = parse_link_profile(link_profile)
    lpr = None if lp.is_null else lp
    fpath = fab.path(topology) if fab is not None else ()
    fcaps = fab.capacities() if fab is not None else None
    if len(fpath) > 1 and n_rails > 1:
        raise ValueError("fabric paths and multi-rail links are mutually "
                         "exclusive (rails split the NIC, the fabric the "
                         "spine)")

    def _cost(ratio: float):
        return make_cost_model(
            n_workers, eff_bw, addest, topology=topology, n_pods=n_pods,
            dcn_bw=tr.effective(dcn_bandwidth or bandwidth / 2),
            compression_ratio=ratio)

    # free codecs keep the legacy path verbatim: the wire ratio lands in
    # the cost model exactly where compression_ratio used to
    cost = _cost(resolved.wire_ratio if free else 1.0)

    buckets = fuse_buckets(timeline, comm)
    plan = lower_buckets([(b.flush_time, b.size, b.n_tensors)
                          for b in buckets], scheduler=sched, n_chunks=k)
    plan = assign_rails(plan, n_rails, rail_policy)
    codecs = None
    if not free:
        plan = assign_codec(plan, resolved.name, policy=policy)
        codecs = _codec_lowerings(plan, resolved, cost,
                                  _cost(resolved.wire_ratio))
    served, t_sync, busy = _serve_plan(plan, buckets, cost, tr,
                                       n_rails=n_rails, jitter=jitter,
                                       jitter_seed=jitter_seed,
                                       codecs=codecs, fault=fault,
                                       fault_seed=fault_seed,
                                       n_workers=n_workers,
                                       path=fpath, capacities=fcaps,
                                       link=lpr)

    if not served:
        t_sync = timeline.t_back
    t_overhead = max(0.0, t_sync - timeline.t_back)
    f_sim = timeline.t_batch / (timeline.t_batch + t_overhead)

    # wire bytes from the active cost model (SwitchML moves ~S per worker,
    # hierarchical counts the ICI stage, ring the 2S(N-1)/N ring traffic);
    # under a codec each op's bytes go through its own codec's model
    if codecs is None:
        wire = sum(cost.wire_bytes(b.size) for b in served)
    else:
        wire = sum(codecs[op.codec].cost.wire_bytes(op.size)
                   for op in plan.ops)
    # utilization while the communication process occupies the link (paper
    # Fig. 4 measures real-time NIC throughput during the comm phase);
    # with rails, ``busy`` sums per-lane occupancy, so the denominator is
    # the per-rail share of the aggregate bandwidth
    util = (wire / busy) / (bandwidth / n_rails) if busy > 0 else 0.0

    return SimResult(
        name=timeline.name, n_workers=n_workers, bandwidth=bandwidth,
        effective_bw=eff_bw, t_batch=timeline.t_batch, t_back=timeline.t_back,
        t_sync=t_sync, t_overhead=t_overhead, scaling_factor=f_sim,
        buckets=tuple(served), wire_bytes_per_worker=wire,
        network_utilization=min(util, 1.0), scheduler=sched,
        codec=codec, codec_compute_s=codec_compute_seconds(plan, codecs))


def simulate_contention(timelines: Sequence[GradTimeline], *, n_workers: int,
                        bandwidth: float, comm: Optional[CommConfig] = None,
                        transport: str | Transport = "ideal",
                        addest: Optional[AddEst] = None,
                        compression_ratio: float = 1.0,
                        scheduler: Optional[str] = None,
                        n_chunks: Optional[int] = None,
                        n_rails: int = 1, rail_policy: str = "round-robin",
                        jitter: float = 0.0, jitter_seed: int = 0,
                        codec: str = "none",
                        error_feedback: bool = False,
                        fault_model: str = "none", churn_rate: float = 0.0,
                        worker_bw_skew: float = 0.0,
                        fault_seed: int = 0,
                        fabric: str = "none",
                        oversubscription: float = 1.0,
                        link_profile: str | LinkProfile = "none"
                        ) -> List[SimResult]:
    """Multiple jobs sharing one physical link (fair-share contention).

    Each timeline is an independent training job running the same ring
    collective over the *same* link: concurrent flows split the effective
    bandwidth evenly (progressive filling).  Returns one
    :class:`SimResult` per job; with a single timeline this degenerates to
    :func:`simulate` (ring topology).

    ``n_rails``/``rail_policy`` split the shared link into rails exactly
    as in :func:`simulate` — contention then happens per rail.  With
    ``jitter`` on, each job straggles independently (job ``j`` draws from
    stream ``j`` of ``jitter_seed``), so co-located jobs do not flush in
    lockstep.  ``codec``/``error_feedback`` price gradient compression
    exactly as in :func:`simulate`; each job encodes on its own GPU, so
    the encode chain embedded in the cloned flows is per job.  The fault
    axes (``fault_model``/``churn_rate``/``worker_bw_skew``/``fault_seed``,
    see :func:`simulate`) apply per job on the jitter streams' numbering
    (job ``j`` draws from fault stream ``j``), and churn events carry the
    job's name so a dropout only tears down its own fleet.

    ``fabric``/``oversubscription`` (see :func:`simulate`) put every job
    on the same NIC -> ToR-uplink route: co-located jobs striped over the
    same racks contend for the uplink too, and the engine's max-min solve
    arbitrates both links at once.  ``fabric="none"`` and the elided 1:1
    case stay bitwise identical to the flat shared link.

    ``link_profile`` (see :func:`simulate`) degrades the shared link for
    every job at once: the deterministic pricing rides the shared
    lowering (so relabeled clones stay bit-identical to fresh lowerings)
    and each job draws its own RTO stalls from fault stream ``j``.
    """
    comm = comm or CommConfig()
    addest = addest or AddEst.v100()
    tr = get_transport(transport) if isinstance(transport, str) else transport
    eff_bw = tr.effective(bandwidth)
    sched = canonical_scheduler(scheduler or comm.scheduler)
    k = n_chunks if n_chunks is not None else comm.sched_chunks
    n_rails = max(int(n_rails), 1)      # 0 and 1 both mean "no rails"
    policy, resolved = _resolve_codec(codec, compression_ratio,
                                      error_feedback)
    free = resolved.is_free and policy == "uniform"
    fm = parse_fault_model(fault_model, churn_rate=churn_rate,
                           bw_skew=worker_bw_skew)
    fault = None if fm.is_null else fm
    lp = parse_link_profile(link_profile)
    lpr = None if lp.is_null else lp
    fab = resolve_fabric(fabric, oversubscription)
    fpath = fab.path("ring") if fab is not None else ()
    if len(fpath) <= 1:
        fpath = ()
    fcaps = fab.capacities() if fab is not None and fpath else None
    if fpath and n_rails > 1:
        raise ValueError("fabric paths and multi-rail links are mutually "
                         "exclusive (rails split the NIC, the fabric the "
                         "spine)")
    cost = RingAllReduce(n_workers, eff_bw, addest,
                         resolved.wire_ratio if free else 1.0)
    codec_cost = None if free else RingAllReduce(n_workers, eff_bw, addest,
                                                 resolved.wire_ratio)

    # co-located jobs usually share one timeline object ([tl] * n_jobs):
    # lower it once and relabel per job (FlowBatch.relabel / clone_flows is
    # bit-identical to a fresh lowering), so an n-job cell costs one
    # lowering, not n.  Plans are built first so the columnar-vs-tuple
    # decision can see the cell's total flow count.
    lowered: dict = {}
    meta = []
    total_ops = 0
    for tl in timelines:
        got = lowered.get(id(tl))
        if got is None:
            buckets = fuse_buckets(tl, comm)
            plan = lower_buckets([(b.flush_time, b.size, b.n_tensors)
                                  for b in buckets], scheduler=sched,
                                 n_chunks=k)
            plan = assign_rails(plan, n_rails, rail_policy)
            codecs = None
            if not free:
                plan = assign_codec(plan, resolved.name, policy=policy)
                codecs = _codec_lowerings(plan, resolved, cost, codec_cost)
            got = lowered[id(tl)] = [buckets, plan, codecs, None, None]
        meta.append(got)
        total_ops += len(got[1].ops)

    # the whole cell goes columnar (lower once, relabel + jitter the
    # columns, one run_batch) when its combined flow count clears the
    # engine's small-plan threshold; small cells keep the tuple path and
    # its list-based setup.  REPRO_SIM_FASTPATH=0 forces the tuple path.
    use_batch = (_fastpath_enabled()
                 and total_ops >= _ev._SMALL_PLAN_MAX_FLOWS)
    rails = {DEFAULT_LINK: n_rails} if n_rails > 1 else None
    base = 0
    counts = []
    churn_all: list = []
    if use_batch:
        parts: List[FlowBatch] = []
        for j, got in enumerate(meta):
            if got[3] is None:
                got[3] = plan_to_flow_batch(got[1], cost,
                                            tr.per_tensor_overhead,
                                            op_id_base=0, n_rails=n_rails,
                                            codecs=got[2],
                                            link_profile=lpr)
            bj = got[3].relabel(base, f"job{j}")
            if jitter > 0.0:
                bj = perturb_batch(bj, jitter, jitter_seed, stream=j)
            if fault is not None and bj.n:
                if got[4] is None:
                    got[4] = worker_codes(got[1], n_workers)
                bj = apply_faults_batch(bj, got[4], fault, n_workers,
                                        fault_seed, j)
                churn_all.extend(churn_events(
                    fault, n_workers,
                    _fault_horizon(bj.ready, bj.work, bj.latency),
                    fault_seed, j, job=f"job{j}"))
            if lpr is not None and bj.n:
                churn_all.extend(retx_events(
                    lpr, sum(op.size for op in got[1].ops),
                    _fault_horizon(bj.ready, bj.work, bj.latency),
                    fault_seed, j, job=f"job{j}"))
            base += bj.n
            counts.append(bj.n)
            parts.append(bj)
        cell = concat_batches(parts)
        if fpath:
            rb = run_flow_batch(cell.with_path(fpath), capacities=fcaps,
                                churn=churn_all or None)
        else:
            rb = run_flow_batch(cell, rails=rails,
                                churn=churn_all or None)
    else:
        all_flows: List[FlowSpec] = []
        for j, got in enumerate(meta):
            if got[3] is None:
                got[3] = plan_to_flows(got[1], cost, tr.per_tensor_overhead,
                                       op_id_base=0, n_rails=n_rails,
                                       codecs=got[2], link_profile=lpr)
            flows = clone_flows(got[3], base, f"job{j}")
            if jitter > 0.0:
                flows = perturb_flows(flows, jitter, jitter_seed, stream=j)
            if fault is not None and flows:
                if got[4] is None:
                    got[4] = worker_codes(got[1], n_workers)
                flows = apply_faults_flows(flows, got[4], fault, n_workers,
                                           fault_seed, j)
                churn_all.extend(churn_events(
                    fault, n_workers,
                    _fault_horizon(np.array([f.ready for f in flows]),
                                   np.array([f.work for f in flows]),
                                   np.array([f.latency for f in flows])),
                    fault_seed, j, job=f"job{j}"))
            if lpr is not None and flows:
                churn_all.extend(retx_events(
                    lpr, sum(op.size for op in got[1].ops),
                    _fault_horizon(np.array([f.ready for f in flows]),
                                   np.array([f.work for f in flows]),
                                   np.array([f.latency for f in flows])),
                    fault_seed, j, job=f"job{j}"))
            base += len(flows)
            counts.append(len(flows))
            all_flows.extend(flows)
        if fpath:
            all_flows = [f._replace(path=fpath) for f in all_flows]
            results = run_flows(all_flows, capacities=fcaps,
                                churn=churn_all or None)
        else:
            results = run_flows(all_flows, rails=rails,
                                churn=churn_all or None)

    out: List[SimResult] = []
    pos = 0
    for j, got in enumerate(meta):
        tl = timelines[j]
        buckets, plan, codecs = got[0], got[1], got[2]
        n_flows = counts[j]
        if use_batch:
            sub = ResultBatch(rb.op_id[pos:pos + n_flows], rb.jobs,
                              rb.job[pos:pos + n_flows],
                              rb.start[pos:pos + n_flows],
                              rb.wire_end[pos:pos + n_flows],
                              rb.end[pos:pos + n_flows],
                              rb.contended[pos:pos + n_flows])
            served, t_sync, busy = _serve_from_batch(plan, buckets, sub)
        else:
            served, t_sync, busy = _serve_plan(
                plan, buckets, cost, tr, results=results[pos:pos + n_flows])
        pos += n_flows
        if not served:
            t_sync = tl.t_back
        t_overhead = max(0.0, t_sync - tl.t_back)
        if codecs is None:
            wire = sum(cost.wire_bytes(b.size) for b in served)
        else:
            wire = sum(codecs[op.codec].cost.wire_bytes(op.size)
                       for op in plan.ops)
        util = ((wire / busy) / (bandwidth / n_rails)
                if busy > 0 else 0.0)
        out.append(SimResult(
            name=tl.name, n_workers=n_workers, bandwidth=bandwidth,
            effective_bw=eff_bw, t_batch=tl.t_batch, t_back=tl.t_back,
            t_sync=t_sync, t_overhead=t_overhead,
            scaling_factor=tl.t_batch / (tl.t_batch + t_overhead),
            buckets=tuple(served), wire_bytes_per_worker=wire,
            network_utilization=min(util, 1.0), scheduler=sched,
            codec=codec,
            codec_compute_s=codec_compute_seconds(plan, codecs)))
    return out
