"""The paper's §3.1 what-if simulator.

Two logical processes communicate through a queue:

- the **backward process** replays the gradient-ready timeline and batches
  gradients into a Horovod-style fusion buffer (64 MB size limit OR 5 ms
  timeout from the first pending gradient, whichever fires first);
- the **all-reduce process** serves flushed buckets FIFO and serialized,
  each costing transmission + reduction per the plugged-in cost model
  (ring reduce-scatter/all-gather by default; hierarchical TPU optional).

Outputs: t_sync, t_overhead = max(0, t_sync - t_back), and
f_sim = t_batch / (t_batch + t_overhead)   (paper Eq. in §3.1).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.configs.base import CommConfig
from repro.core.addest import AddEst
from repro.core.network_model import (HierarchicalAllReduce, RingAllReduce,
                                      ring_transmission_time)
from repro.core.timeline import GradTimeline
from repro.core.transport import Transport, get_transport


BUCKET_FIELDS = ("flush_time", "size", "n_tensors", "start", "end")

# scalar SimResult fields, in stable serialization order (artifact schema)
RESULT_FIELDS = ("name", "n_workers", "bandwidth", "effective_bw", "t_batch",
                 "t_back", "t_sync", "t_overhead", "scaling_factor",
                 "wire_bytes_per_worker", "network_utilization")


@dataclass(frozen=True)
class Bucket:
    flush_time: float        # when the backward process hands it over
    size: float              # bytes
    n_tensors: int = 1       # gradient tensors fused into this bucket
    start: float = 0.0       # all-reduce start (filled by the server loop)
    end: float = 0.0

    def to_dict(self) -> dict:
        return {f: getattr(self, f) for f in BUCKET_FIELDS}

    @staticmethod
    def from_dict(d: dict) -> "Bucket":
        return Bucket(**{f: d[f] for f in BUCKET_FIELDS})


@dataclass(frozen=True)
class SimResult:
    name: str
    n_workers: int
    bandwidth: float                  # physical link bandwidth, bytes/s
    effective_bw: float               # after the transport curve
    t_batch: float
    t_back: float
    t_sync: float
    t_overhead: float
    scaling_factor: float
    buckets: Tuple[Bucket, ...]
    wire_bytes_per_worker: float      # actual bytes each worker moved
    network_utilization: float        # avg wire throughput / physical bw

    def summary(self) -> str:
        return (f"{self.name}: n={self.n_workers} bw={self.bandwidth*8/1e9:.0f}Gbps "
                f"f_sim={self.scaling_factor:.3f} overhead={self.t_overhead*1e3:.1f}ms "
                f"util={self.network_utilization:.2f}")

    def to_dict(self, include_buckets: bool = False) -> dict:
        """Stable JSON-ready form (the experiment-artifact cell schema).

        Buckets are summarized by count unless ``include_buckets``; full
        float repr round-trips through JSON bit-exactly either way.
        """
        d = {f: getattr(self, f) for f in RESULT_FIELDS}
        d["n_buckets"] = len(self.buckets)
        if include_buckets:
            d["buckets"] = [b.to_dict() for b in self.buckets]
        return d

    @staticmethod
    def from_dict(d: dict) -> "SimResult":
        buckets = tuple(Bucket.from_dict(b) for b in d.get("buckets", ()))
        return SimResult(**{f: d[f] for f in RESULT_FIELDS}, buckets=buckets)


def fuse_buckets(timeline: GradTimeline, comm: CommConfig) -> List[Bucket]:
    """The backward process: fusion-buffer batching of the gradient stream.

    Faithful to Horovod semantics as described in the paper: a bucket is
    flushed when it reaches the size limit, or when ``timeout_ms`` has
    elapsed since its first pending gradient.  The tail bucket flushes when
    the last gradient arrives (backward completion ends the cycle — Horovod
    does not idle out the final timeout window).
    """
    limit = comm.fusion_buffer_mb * 1024 * 1024
    timeout = comm.timeout_ms / 1e3
    buckets: List[Bucket] = []
    pending, n_pend = 0.0, 0
    first_t: Optional[float] = None

    for t, size in zip(timeline.ready_times, timeline.sizes):
        if first_t is not None and t > first_t + timeout:
            buckets.append(Bucket(first_t + timeout, pending, n_pend))
            pending, n_pend, first_t = 0.0, 0, None
        if first_t is None:
            first_t = t
        pending += size
        n_pend += 1
        while pending >= limit:
            # a gradient larger than the buffer flushes in `limit` slabs
            buckets.append(Bucket(t, min(pending, limit), max(n_pend, 1)))
            pending -= min(pending, limit)
            n_pend = 0
            first_t = None if pending == 0.0 else t
    if pending > 0.0 and first_t is not None:
        buckets.append(Bucket(timeline.t_back, pending, n_pend))
    return buckets


def simulate(timeline: GradTimeline, *, n_workers: int, bandwidth: float,
             comm: Optional[CommConfig] = None,
             transport: str | Transport = "ideal",
             addest: Optional[AddEst] = None,
             compression_ratio: float = 1.0,
             topology: str = "ring", n_pods: int = 1,
             dcn_bandwidth: Optional[float] = None) -> SimResult:
    """Run the two-process simulation for one iteration.

    ``bandwidth`` in bytes/s.  ``transport`` maps physical to effective
    bandwidth (the paper's measured-vs-ideal axis).
    """
    comm = comm or CommConfig()
    addest = addest or AddEst.v100()
    tr = get_transport(transport) if isinstance(transport, str) else transport
    eff_bw = tr.effective(bandwidth)

    if topology == "hierarchical":
        cost = HierarchicalAllReduce(
            n_pod_devices=n_workers // n_pods, n_pods=n_pods,
            ici_bw=eff_bw, dcn_bw=tr.effective(dcn_bandwidth or bandwidth / 2),
            addest=addest, compression_ratio=compression_ratio)
    elif topology == "ring":
        cost = RingAllReduce(n_workers, eff_bw, addest, compression_ratio)
    else:
        from repro.core.network_model import make_cost_model
        cost = make_cost_model(n_workers, eff_bw, addest, topology=topology,
                               compression_ratio=compression_ratio)

    buckets = fuse_buckets(timeline, comm)

    # the all-reduce process: FIFO, one collective in flight at a time
    served: List[Bucket] = []
    prev_end = 0.0
    busy = 0.0
    for b in buckets:
        start = max(b.flush_time, prev_end)
        dur = cost.time(b.size) + tr.per_tensor_overhead * b.n_tensors
        prev_end = start + dur
        busy += dur
        served.append(Bucket(b.flush_time, b.size, b.n_tensors, start, prev_end))

    t_sync = served[-1].end if served else timeline.t_back
    t_overhead = max(0.0, t_sync - timeline.t_back)
    f_sim = timeline.t_batch / (timeline.t_batch + t_overhead)

    wire = sum(ring_transmission_time(b.size, n_workers, 1.0)  # bytes at bw=1
               for b in served) / max(compression_ratio, 1e-9)
    # utilization while the all-reduce process is busy (paper Fig. 4 measures
    # real-time NIC throughput during the communication phase)
    util = (wire / busy) / bandwidth if busy > 0 else 0.0

    return SimResult(
        name=timeline.name, n_workers=n_workers, bandwidth=bandwidth,
        effective_bw=eff_bw, t_batch=timeline.t_batch, t_back=timeline.t_back,
        t_sync=t_sync, t_overhead=t_overhead, scaling_factor=f_sim,
        buckets=tuple(served), wire_bytes_per_worker=wire,
        network_utilization=min(util, 1.0))
