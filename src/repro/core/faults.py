"""Seeded worker-level fault models: correlated slowdowns, churn, bw skew.

The paper's scaling-factor analysis assumes a well-behaved cluster: every
worker flushes on time and membership is static, so the only overhead is
network contention.  Real fleets are not like that — Hivemind-style
training runs on unreliable mixed GPUs, and system-level effects decide
whether communication optimizations pay off at all.  This module prices
three ways a fleet misbehaves, all seeded and deterministic:

- **worker-correlated slowdowns** — one straggling worker delays *every*
  flow of its iteration by the same exponential draw, unlike the
  per-flow-independent jitter axis.  ``correlation`` interpolates: 1 is
  fully worker-correlated, 0 reduces *bitwise* to the existing per-flow
  jitter (same RNG stream, same ``jitter * Exp(1)`` arithmetic);
- **churn** — workers drop out and rejoin mid-iteration.  A dropout
  tears down the in-flight transfer (it restarts after a priced
  re-bucketing stall) and cancels the dead worker's pending flows (the
  re-formed collective skips its buckets this iteration); a rejoin costs
  another stall.  Arrival counts are Poisson in ``churn_rate`` (expected
  membership changes per iteration), times uniform over the iteration.
  Under a fabric lowering (multi-link :attr:`FlowSpec.path`), the
  teardown releases the flow's share on *every* link of its path at
  once — the max-min rate vector is re-solved without it, so survivors
  speed up on the freed uplink immediately;
- **asymmetric bandwidth** — each worker's effective link rate is scaled
  by ``1 + bw_skew * Exp(1)``, so its flows carry proportionally more
  wire work (a factor of 1 everywhere at ``bw_skew=0``).

Worker attribution is structural, not random: bucket ``b`` belongs to
worker ``b % n_workers`` (:func:`worker_codes`), so the same buckets
straggle together across seeds and the axis composes deterministically
with every scheduler/rails/codec axis.

Determinism contract (shared with :func:`repro.core.events.jitter_delays`
via :func:`repro.core.events._jitter_stream`): every draw depends only on
``(fault_seed, stream, substream, n)`` — never process, thread, or global
RNG state — so artifacts are bit-identical across executors.  Substreams:
``()`` per-flow component, ``(1,)`` worker slowdowns, ``(2,)`` bandwidth
factors, ``(3,)`` churn arrivals.  A null model
(:attr:`FaultModel.is_null`) must never touch a flow: the simulator
bypasses this module entirely, keeping zero-fault configs bit-identical
to the pre-fault engine.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.core.events import (ChurnEvent, FlowBatch, FlowSpec,
                               _jitter_stream, jitter_delays)

__all__ = [
    "FaultModel", "parse_fault_model", "worker_codes", "fault_delays",
    "bw_factors", "churn_events", "apply_faults_batch", "apply_faults_flows",
]


@dataclass(frozen=True)
class FaultModel:
    """One unreliable-world scenario, fully determined by its fields + seed.

    ``slowdown`` is the mean extra delay (seconds) a worker's iteration
    suffers; ``correlation`` is the worker-vs-flow mix (see module doc);
    ``churn_rate`` the expected dropout events per iteration; ``downtime``
    the mean seconds before a dropped worker rejoins; ``rebucket`` the
    stall (seconds) every membership change costs the survivors;
    ``bw_skew`` the per-worker bandwidth asymmetry scale.
    """

    slowdown: float = 0.0
    correlation: float = 1.0
    churn_rate: float = 0.0
    downtime: float = 0.010
    rebucket: float = 0.005
    bw_skew: float = 0.0

    @property
    def is_null(self) -> bool:
        """True when no fault mechanism is active (the bit-exact bypass)."""
        return (self.slowdown <= 0.0 and self.churn_rate <= 0.0
                and self.bw_skew <= 0.0)


NULL_FAULTS = FaultModel()


def parse_fault_model(spec: str, *, churn_rate: float = 0.0,
                      bw_skew: float = 0.0, downtime: float = 0.010,
                      rebucket: float = 0.005) -> FaultModel:
    """Parse the experiment axis string into a :class:`FaultModel`.

    ``"none"`` means no slowdown; ``"slowdown:<ms>[:<rho>]"`` sets the
    mean worker slowdown in *milliseconds* (axis strings stay unit-tagged
    and short) with an optional correlation ``rho`` in [0, 1] (default 1,
    fully worker-correlated).  ``churn_rate``/``bw_skew`` ride along from
    their own cell axes.
    """
    s = spec.strip().lower()
    if s in ("", "none"):
        return FaultModel(churn_rate=churn_rate, bw_skew=bw_skew,
                          downtime=downtime, rebucket=rebucket)
    parts = s.split(":")
    if parts[0] != "slowdown" or len(parts) not in (2, 3):
        raise ValueError(
            f"unknown fault model {spec!r} (expected 'none' or "
            f"'slowdown:<ms>[:<rho>]')")
    ms = float(parts[1])
    rho = float(parts[2]) if len(parts) == 3 else 1.0
    if not 0.0 <= rho <= 1.0:
        raise ValueError(f"fault correlation {rho} outside [0, 1] in {spec!r}")
    return FaultModel(slowdown=ms / 1e3, correlation=rho,
                      churn_rate=churn_rate, bw_skew=bw_skew,
                      downtime=downtime, rebucket=rebucket)


def worker_codes(plan, n_workers: int) -> np.ndarray:
    """Owning worker per plan op: bucket ``b`` belongs to worker ``b % n``.

    Plan order equals flow order in both lowerings (``plan_to_flows``
    and ``plan_to_flow_batch`` iterate ``plan.ops``), so the codes align
    with the lowered flows by position.
    """
    n = max(int(n_workers), 1)
    return np.fromiter((op.bucket_id for op in plan.ops),
                       dtype=np.intp, count=len(plan.ops)) % n


def fault_delays(fm: FaultModel, codes: np.ndarray, n_workers: int,
                 seed: int, stream: int = 0) -> Optional[np.ndarray]:
    """Per-flow ready-time delays, or None when ``slowdown <= 0``.

    ``rho * E[worker] + (1 - rho) * F[flow]``, scaled by ``slowdown``:
    at ``rho >= 1`` every flow of a worker shares one draw (the
    correlated straggler); at ``rho <= 0`` the expression collapses to
    :func:`repro.core.events.jitter_delays` — the *same* stream and the
    same single multiply, so correlation 0 is bitwise the per-flow jitter
    axis, not merely statistically equivalent.  Linear in ``slowdown``
    with shared draws, so a swept slowdown scale moves every ready time
    monotonically.
    """
    if fm.slowdown <= 0.0:
        return None
    n = int(codes.shape[0])
    rho = min(max(fm.correlation, 0.0), 1.0)
    if rho <= 0.0:
        return jitter_delays(n, fm.slowdown, seed, stream)
    nw = max(int(n_workers), 1)
    ew = _jitter_stream(seed, stream, 1).standard_exponential(nw)
    if rho >= 1.0:
        return fm.slowdown * ew[codes]
    fl = _jitter_stream(seed, stream).standard_exponential(n)
    return fm.slowdown * (rho * ew[codes] + (1.0 - rho) * fl)


def bw_factors(fm: FaultModel, n_workers: int, seed: int,
               stream: int = 0) -> Optional[np.ndarray]:
    """Per-worker wire-work multipliers, or None when ``bw_skew <= 0``.

    ``1 + bw_skew * Exp(1)`` per worker: a factor of exactly 1.0 means
    the nominal link rate; larger factors model the straggling NICs /
    oversubscribed hosts whose transfers take proportionally longer.
    """
    if fm.bw_skew <= 0.0:
        return None
    nw = max(int(n_workers), 1)
    return 1.0 + fm.bw_skew * _jitter_stream(
        seed, stream, 2).standard_exponential(nw)


def churn_events(fm: FaultModel, n_workers: int, horizon: float,
                 seed: int, stream: int = 0,
                 job: str = "job0") -> List[ChurnEvent]:
    """Draw the iteration's membership changes from the churn substream.

    ``Poisson(churn_rate)`` dropouts, each at a uniform time in
    ``[0, horizon)`` hitting a uniform worker, down for an exponential
    ``downtime`` before rejoining; both the drop and the rejoin cost the
    ``rebucket`` stall.  Returns events sorted by time (possibly empty —
    an empty list must leave the engine dispatch untouched).
    """
    if fm.churn_rate <= 0.0:
        return []
    rng = _jitter_stream(seed, stream, 3)
    k = int(rng.poisson(fm.churn_rate))
    if not k:
        return []
    nw = max(int(n_workers), 1)
    times = horizon * rng.random(k)
    workers = rng.integers(0, nw, size=k)
    downs = fm.downtime * rng.standard_exponential(k)
    out: List[ChurnEvent] = []
    for t, w, d in zip(times.tolist(), workers.tolist(), downs.tolist()):
        out.append(ChurnEvent(t=t, job=job, kind="drop", worker=int(w),
                              stall=fm.rebucket))
        out.append(ChurnEvent(t=t + d, job=job, kind="rejoin", worker=-1,
                              stall=fm.rebucket))
    out.sort()
    return out


def apply_faults_batch(batch: FlowBatch, codes: np.ndarray, fm: FaultModel,
                       n_workers: int, seed: int,
                       stream: int = 0) -> FlowBatch:
    """Stamp worker codes and apply slowdown delays + bw skew, columnar.

    Ready times gain :func:`fault_delays`; wire work of a skewed worker's
    flows is multiplied by its :func:`bw_factors` entry, with ``duration``
    adjusted by the same work delta so hold flows stay internally
    consistent (NaN durations propagate untouched).  All elementwise
    float64 — the scalar twin :func:`apply_faults_flows` performs the
    identical operations, so both lowering paths stay bit-identical.
    """
    out = batch._replace(worker=np.asarray(codes, dtype=np.intp))
    d = fault_delays(fm, codes, n_workers, seed, stream)
    if d is not None:
        out = out._replace(ready=out.ready + d)
    fac = bw_factors(fm, n_workers, seed, stream)
    if fac is not None:
        m = fac[codes]
        new_work = out.work * m
        out = out._replace(work=new_work,
                           duration=out.duration + (new_work - out.work))
    return out


def apply_faults_flows(flows: Sequence[FlowSpec], codes: np.ndarray,
                       fm: FaultModel, n_workers: int, seed: int,
                       stream: int = 0) -> List[FlowSpec]:
    """Tuple-path twin of :func:`apply_faults_batch`, bit-identical.

    The draws are the same numpy arrays; application is per-flow scalar
    float64 arithmetic, which matches the columnar elementwise ops
    bit-for-bit.
    """
    d = fault_delays(fm, codes, n_workers, seed, stream)
    fac = bw_factors(fm, n_workers, seed, stream)
    code_l = codes.tolist()
    d_l = d.tolist() if d is not None else None
    out: List[FlowSpec] = []
    for i, f in enumerate(flows):
        c = code_l[i]
        rdy = f.ready + d_l[i] if d_l is not None else f.ready
        wk = f.work
        du = f.duration
        if fac is not None:
            nw_ = f.work * float(fac[c])
            if du is not None:
                du = du + (nw_ - wk)
            wk = nw_
        out.append(f._replace(ready=rdy, work=wk, duration=du, worker=c))
    return out
