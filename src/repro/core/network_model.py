"""All-reduce cost models (the paper's §3.1 transmission/reduction model,
plus TPU-topology extensions).

Paper model (flat ring, reduce-scatter + all-gather):
    transmission(S, N, bw) = (2 * S * (N - 1) / N) / bw
    reduction(S, N)        = (N - 1) * AddEst(S / N)

Sizes in bytes, bandwidth in bytes/s, times in seconds.

``compression_ratio`` on these models is the paper's §3.2 free byte
divisor — it scales transmission with zero encode/decode cost.  It is
kept for the legacy figures (fig8) and stays bit-identical, but new work
should prefer the priced codec axis (``repro.core.codec``): the
simulator routes ``compression_ratio`` through the parametric ratio
codec (``get_codec("none", compression_ratio=r)``), which reproduces
this divisor exactly while making the zero-compute assumption explicit.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.core.addest import AddEst


def ring_transmission_time(size: int, n: int, bw: float) -> float:
    """Paper's transmission term for a flat N-worker ring all-reduce."""
    if n <= 1:
        return 0.0
    return (2.0 * size * (n - 1) / n) / bw


def ring_reduction_time(size: int, n: int, addest: AddEst) -> float:
    """Paper's vector-add term: (N-1) adds of S/N-sized chunks."""
    if n <= 1:
        return 0.0
    return (n - 1) * addest(size / n)


# Vectorized twins over a float64 size column.  Exactness contract (all the
# ``*_v``/``time_v`` functions below): elementwise numpy float64 arithmetic
# performs the scalar expressions' operations in the scalar expressions'
# order, so ``f_v(sizes)[i]`` is bit-identical to ``f(sizes[i])`` — the
# columnar lowering (:func:`repro.core.schedule.plan_to_flow_batch`)
# produces the same float values as the per-op loop, not approximations.

def ring_transmission_time_v(sizes: np.ndarray, n: int,
                             bw: float) -> np.ndarray:
    if n <= 1:
        return np.zeros_like(sizes)
    return (2.0 * sizes * (n - 1) / n) / bw


def ring_reduction_time_v(sizes: np.ndarray, n: int,
                          addest: AddEst) -> np.ndarray:
    if n <= 1:
        return np.zeros_like(sizes)
    return (n - 1) * addest.batch(sizes / n)


@dataclass(frozen=True)
class RingAllReduce:
    """The paper's cost model: flat ring over ``n`` workers at ``bw`` B/s."""

    n: int
    bw: float
    addest: AddEst
    # paper §3.2: divides transmission only.  Deprecated in favor of the
    # priced codec axis (repro.core.codec) — see the module docstring.
    compression_ratio: float = 1.0
    compress_reduction: bool = False # extended mode: also scales vector-adds

    def time(self, size: int) -> float:
        t = ring_transmission_time(size, self.n, self.bw) / self.compression_ratio
        red = ring_reduction_time(size, self.n, self.addest)
        if self.compress_reduction:
            red /= self.compression_ratio
        return t + red

    def wire_time(self, size: int) -> float:
        """Transmission share of :meth:`time` — scales under link sharing."""
        return ring_transmission_time(size, self.n, self.bw) / self.compression_ratio

    def time_v(self, sizes: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`time`, bit-identical per element."""
        t = ring_transmission_time_v(sizes, self.n, self.bw) \
            / self.compression_ratio
        red = ring_reduction_time_v(sizes, self.n, self.addest)
        if self.compress_reduction:
            red = red / self.compression_ratio
        return t + red

    def wire_time_v(self, sizes: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`wire_time`, bit-identical per element."""
        return ring_transmission_time_v(sizes, self.n, self.bw) \
            / self.compression_ratio

    def wire_bytes(self, size: int) -> float:
        """Bytes each worker actually moves on its NIC for one all-reduce."""
        return (ring_transmission_time(size, self.n, 1.0)
                / max(self.compression_ratio, 1e-9))


@dataclass(frozen=True)
class HierarchicalAllReduce:
    """TPU multi-pod extension: reduce-scatter inside the pod on ICI,
    all-reduce across pods on DCN, all-gather inside the pod.

    in-pod RS:   S*(nd-1)/nd / ici
    cross-pod AR: 2*(S/nd)*(np-1)/np / dcn
    in-pod AG:   S*(nd-1)/nd / ici
    adds: (nd-1) chunk adds in RS + (np-1) adds of S/nd across pods.
    """

    n_pod_devices: int               # chips participating per pod (data axis)
    n_pods: int
    ici_bw: float
    dcn_bw: float
    addest: AddEst
    compression_ratio: float = 1.0   # applied to the cross-pod (DCN) stage

    def time(self, size: int) -> float:
        nd, np_ = self.n_pod_devices, self.n_pods
        t = 0.0
        if nd > 1:
            t += 2.0 * size * (nd - 1) / nd / self.ici_bw
            t += (nd - 1) * self.addest(size / nd)
        if np_ > 1:
            shard = size / max(nd, 1)
            t += (2.0 * shard * (np_ - 1) / np_ / self.dcn_bw) / self.compression_ratio
            t += (np_ - 1) * self.addest(shard / np_)
        return t

    def wire_time(self, size: int) -> float:
        nd, np_ = self.n_pod_devices, self.n_pods
        t = 0.0
        if nd > 1:
            t += 2.0 * size * (nd - 1) / nd / self.ici_bw
        if np_ > 1:
            shard = size / max(nd, 1)
            t += (2.0 * shard * (np_ - 1) / np_ / self.dcn_bw) / self.compression_ratio
        return t

    def time_v(self, sizes: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`time`, bit-identical per element (the
        accumulation adds the same stage terms in the same order)."""
        nd, np_ = self.n_pod_devices, self.n_pods
        t = np.zeros_like(sizes)
        if nd > 1:
            t = t + 2.0 * sizes * (nd - 1) / nd / self.ici_bw
            t = t + (nd - 1) * self.addest.batch(sizes / nd)
        if np_ > 1:
            shard = sizes / max(nd, 1)
            t = t + (2.0 * shard * (np_ - 1) / np_ / self.dcn_bw) \
                / self.compression_ratio
            t = t + (np_ - 1) * self.addest.batch(shard / np_)
        return t

    def wire_time_v(self, sizes: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`wire_time`, bit-identical per element."""
        nd, np_ = self.n_pod_devices, self.n_pods
        t = np.zeros_like(sizes)
        if nd > 1:
            t = t + 2.0 * sizes * (nd - 1) / nd / self.ici_bw
        if np_ > 1:
            shard = sizes / max(nd, 1)
            t = t + (2.0 * shard * (np_ - 1) / np_ / self.dcn_bw) \
                / self.compression_ratio
        return t

    def wire_bytes(self, size: int) -> float:
        """Bytes on the *ICI* link (the bandwidth under study); the DCN stage
        moves the 1/nd shard and is reported via :meth:`wire_bytes_dcn`."""
        nd, np_ = self.n_pod_devices, self.n_pods
        if nd > 1:
            return 2.0 * size * (nd - 1) / nd
        return self.wire_bytes_dcn(size)

    def wire_bytes_dcn(self, size: int) -> float:
        nd, np_ = self.n_pod_devices, self.n_pods
        if np_ <= 1:
            return 0.0
        shard = size / max(nd, 1)
        return (2.0 * shard * (np_ - 1) / np_
                / max(self.compression_ratio, 1e-9))


@dataclass(frozen=True)
class TreeAllReduce:
    """Binomial-tree all-reduce: reduce up the tree, broadcast back down.

    ``ceil(log2 N)`` sequential reduce steps each move the full S bytes
    over one link and add full-size vectors at the receiving node, then
    the same number of broadcast steps move S back — wire time
    ``2 * ceil(log2 N) * S / bw`` and ``ceil(log2 N)`` full-size adds.
    Latency-optimal but bandwidth-poor versus the ring's ``2S(N-1)/N``
    (the classical trade-off); it earns its place on the fabric axis
    because its edges cross racks just like the ring's, so it pays the
    same oversubscription penalty from a worse baseline.
    """

    n: int
    bw: float
    addest: AddEst
    compression_ratio: float = 1.0   # free §3.2 divisor, transmission only

    @property
    def _steps(self) -> int:
        return int(math.ceil(math.log2(self.n))) if self.n > 1 else 0

    def time(self, size: int) -> float:
        if self.n <= 1:
            return 0.0
        steps = self._steps
        t = (2.0 * steps * size / self.bw) / self.compression_ratio
        return t + steps * self.addest(size)

    def wire_time(self, size: int) -> float:
        if self.n <= 1:
            return 0.0
        return (2.0 * self._steps * size / self.bw) / self.compression_ratio

    def time_v(self, sizes: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`time`, bit-identical per element."""
        if self.n <= 1:
            return np.zeros_like(sizes)
        steps = self._steps
        t = (2.0 * steps * sizes / self.bw) / self.compression_ratio
        return t + steps * self.addest.batch(sizes)

    def wire_time_v(self, sizes: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`wire_time`, bit-identical per element."""
        if self.n <= 1:
            return np.zeros_like(sizes)
        return (2.0 * self._steps * sizes / self.bw) / self.compression_ratio

    def wire_bytes(self, size: int) -> float:
        """Bytes a tree node moves: S up (reduce) + S down (broadcast),
        once per level it participates in; the root-adjacent links carry
        the full ``2 * steps * S`` stream that bounds the wire time."""
        if self.n <= 1:
            return 0.0
        return 2.0 * self._steps * size / max(self.compression_ratio, 1e-9)


@dataclass(frozen=True)
class SwitchMLAllReduce:
    """Paper §4 what-if: in-network aggregation (SwitchML).

    The programmable switch sums gradient chunks in flight: each worker
    streams its S bytes up while receiving aggregated bytes back on the
    full-duplex link — wire time ~S/bw independent of N (the ~2x over ring
    the SwitchML paper reports) — and the vector adds happen in the switch
    pipeline (no worker-side AddEst term).
    """

    n: int
    bw: float
    addest: AddEst                    # unused; kept for interface parity
    compression_ratio: float = 1.0

    def time(self, size: int) -> float:
        if self.n <= 1:
            return 0.0
        return (size / self.bw) / self.compression_ratio

    def wire_time(self, size: int) -> float:
        return self.time(size)        # all wire, no worker-side adds

    def time_v(self, sizes: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`time`, bit-identical per element."""
        if self.n <= 1:
            return np.zeros_like(sizes)
        return (sizes / self.bw) / self.compression_ratio

    def wire_time_v(self, sizes: np.ndarray) -> np.ndarray:
        return self.time_v(sizes)     # all wire, no worker-side adds

    def wire_bytes(self, size: int) -> float:
        """In-network aggregation streams ~S per worker (full duplex),
        independent of N — the point of SwitchML."""
        if self.n <= 1:
            return 0.0
        return float(size) / max(self.compression_ratio, 1e-9)


@dataclass(frozen=True)
class TwoTierParamServer:
    """Paper §4 what-if: parameter-server strategy.

    Each worker pushes S bytes to (sharded) servers and pulls S back:
    2*S/bw on the worker link, but the *server* ingests N shards — with
    servers co-located on the N workers (sharded PS), per-server ingest is
    S/N * N = S, so the bottleneck link carries 2*S*(N-1)/N plus the
    worker-side adds on its 1/N shard, matching ring cost asymptotically
    (the paper's reason for treating all-reduce as representative).
    """

    n: int
    bw: float
    addest: AddEst
    compression_ratio: float = 1.0

    def time(self, size: int) -> float:
        if self.n <= 1:
            return 0.0
        wire = (2.0 * size * (self.n - 1) / self.n / self.bw)
        return wire / self.compression_ratio + self.addest(size / self.n) * (self.n - 1)

    def wire_time(self, size: int) -> float:
        if self.n <= 1:
            return 0.0
        return (2.0 * size * (self.n - 1) / self.n / self.bw) / self.compression_ratio

    def time_v(self, sizes: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`time`, bit-identical per element."""
        if self.n <= 1:
            return np.zeros_like(sizes)
        wire = (2.0 * sizes * (self.n - 1) / self.n / self.bw)
        return wire / self.compression_ratio \
            + self.addest.batch(sizes / self.n) * (self.n - 1)

    def wire_time_v(self, sizes: np.ndarray) -> np.ndarray:
        if self.n <= 1:
            return np.zeros_like(sizes)
        return (2.0 * sizes * (self.n - 1) / self.n / self.bw) \
            / self.compression_ratio

    def wire_bytes(self, size: int) -> float:
        if self.n <= 1:
            return 0.0
        return (2.0 * size * (self.n - 1) / self.n
                / max(self.compression_ratio, 1e-9))


def make_cost_model(n: int, bw: float, addest: AddEst, *,
                    topology: str = "ring", n_pods: int = 1,
                    dcn_bw: Optional[float] = None,
                    compression_ratio: float = 1.0,
                    compress_reduction: bool = False):
    if topology == "ring":
        return RingAllReduce(n, bw, addest, compression_ratio, compress_reduction)
    if topology == "tree":
        return TreeAllReduce(n, bw, addest, compression_ratio)
    if topology == "hierarchical":
        return HierarchicalAllReduce(n // n_pods, n_pods, bw,
                                     dcn_bw or bw / 2, addest,
                                     compression_ratio)
    if topology == "switchml":
        return SwitchMLAllReduce(n, bw, addest, compression_ratio)
    if topology == "param_server":
        return TwoTierParamServer(n, bw, addest, compression_ratio)
    raise ValueError(topology)
