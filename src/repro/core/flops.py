"""Analytic per-layer parameter counts and FLOPs for every architecture.

Used by (1) the what-if simulator's TPU timelines, (2) the roofline's
MODEL_FLOPS = 6*N_active*D reference, and (3) sanity checks of the HLO cost
parser.  All formulas are per *global* batch.
"""
from __future__ import annotations

import math
from typing import List, Tuple

from repro.configs.base import InputShape, ModelConfig

Layer = Tuple[str, int, float]   # (name, params, fwd_flops)


# ---------------------------------------------------------------------------
# per-layer parameter counts
# ---------------------------------------------------------------------------

def attn_params(cfg: ModelConfig) -> int:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if cfg.attention == "mla":
        L, R = cfg.mla_kv_lora, cfg.mla_rope_dim
        return D * L + D * R + 2 * L * H * hd + D * H * (hd + R) + H * hd * D + L
    return D * H * hd + 2 * D * KV * hd + H * hd * D


def mlp_params(cfg: ModelConfig) -> int:
    return 3 * cfg.d_model * cfg.d_ff


def moe_params(cfg: ModelConfig, active: bool = False) -> int:
    moe = cfg.moe
    d_ff = moe.d_ff_expert or cfg.d_ff
    n_e = moe.top_k if active else moe.num_experts
    p = cfg.d_model * moe.num_experts              # router
    p += 3 * n_e * cfg.d_model * d_ff              # routed experts
    p += 3 * cfg.d_model * d_ff * moe.num_shared_experts
    return p


def mamba_params(cfg: ModelConfig) -> int:
    D = cfg.d_model
    di = cfg.ssm.expand * D
    dt = cfg.ssm.dt_rank or max(D // 16, 1)
    n = cfg.ssm.d_state
    return (D * 2 * di + cfg.ssm.d_conv * di + di * (dt + 2 * n)
            + dt * di + di * n + di + di * D)


def rwkv_params(cfg: ModelConfig) -> int:
    D = cfg.d_model
    lora = 64
    time_mix = 5 * D * D + D * lora + lora * D + D + 6 * D
    channel_mix = int(2 * D * cfg.d_ff) + D * D
    return time_mix + channel_mix


def norm_params(cfg: ModelConfig) -> int:
    return 2 * cfg.d_model


# ---------------------------------------------------------------------------
# per-layer forward FLOPs
# ---------------------------------------------------------------------------

def _attn_flops(cfg: ModelConfig, tokens: float, ctx: float, causal: bool) -> float:
    """Projections + score/value matmuls."""
    proj = 2.0 * attn_params(cfg) * tokens
    eff_ctx = ctx / 2 if causal else ctx
    if cfg.sliding_window:
        eff_ctx = min(eff_ctx, cfg.sliding_window)
    qk_pv = 2.0 * 2.0 * tokens * eff_ctx * cfg.num_heads * cfg.head_dim
    return proj + qk_pv


def _mamba_flops(cfg: ModelConfig, tokens: float) -> float:
    di = cfg.ssm.expand * cfg.d_model
    n = cfg.ssm.d_state
    proj = 2.0 * mamba_params(cfg) * tokens
    scan = 6.0 * tokens * di * n
    return proj + scan


def _rwkv_flops(cfg: ModelConfig, tokens: float) -> float:
    H = cfg.d_model // cfg.ssm.head_dim
    hd = cfg.ssm.head_dim
    proj = 2.0 * rwkv_params(cfg) * tokens
    wkv = 4.0 * tokens * H * hd * hd
    return proj + wkv


# ---------------------------------------------------------------------------
# full model breakdown
# ---------------------------------------------------------------------------

def _decoder_layer_kinds(cfg: ModelConfig) -> List[str]:
    """Per-layer mixer/mlp type for the decoder stack."""
    kinds = []
    for i in range(cfg.num_layers):
        if cfg.family == "hybrid":
            in_block = i % cfg.hybrid_block_layers
            mixer = "attn" if in_block == cfg.hybrid_attn_period // 2 else "mamba"
            use_moe = cfg.moe is not None and (in_block % cfg.moe.every == 1)
        elif cfg.family == "ssm":
            mixer, use_moe = "rwkv", False
        else:
            mixer = "attn"
            use_moe = cfg.moe is not None and i >= (cfg.moe.first_dense or 0)
        kinds.append(f"{mixer}+{'moe' if use_moe else 'mlp'}")
    return kinds


def layer_breakdown(cfg: ModelConfig, shape: InputShape) -> List[Layer]:
    """[(name, grad_params, fwd_flops)] in forward order, global batch."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        tokens, ctx, causal = float(B), float(S), False
    else:
        tokens, ctx, causal = float(B) * S, float(S), True
        if cfg.family == "vlm" and cfg.prefix_embeds:
            tokens += float(B) * cfg.prefix_embeds

    layers: List[Layer] = [("embed", cfg.vocab_size * cfg.d_model, 0.0)]
    if cfg.family == "encdec":
        enc_tokens = float(B) * cfg.encoder_seq
        for i in range(cfg.encoder_layers):
            p = attn_params(cfg) + mlp_params(cfg) + norm_params(cfg)
            f = (_attn_flops(cfg, enc_tokens, cfg.encoder_seq, False)
                 + 2.0 * mlp_params(cfg) * enc_tokens)
            layers.append((f"enc{i}", p, f))

    for i, kind in enumerate(_decoder_layer_kinds(cfg)):
        mixer, mlp_kind = kind.split("+")
        p, f = norm_params(cfg), 0.0
        if mixer == "attn":
            p += attn_params(cfg)
            f += _attn_flops(cfg, tokens, ctx, causal)
            if cfg.family == "encdec":       # cross-attention
                p += attn_params(cfg)
                f += _attn_flops(cfg, tokens, cfg.encoder_seq, False)
        elif mixer == "mamba":
            p += mamba_params(cfg)
            f += _mamba_flops(cfg, tokens)
        else:
            p += rwkv_params(cfg)
            f += _rwkv_flops(cfg, tokens)
        if mlp_kind == "moe":
            p += moe_params(cfg)
            f += 2.0 * moe_params(cfg, active=True) * tokens
        elif mixer != "rwkv":          # rwkv_params includes its channel-mix
            p += mlp_params(cfg)
            f += 2.0 * mlp_params(cfg) * tokens
        layers.append((f"layer{i}", p, f))

    head_p = 0 if cfg.tie_embeddings else cfg.vocab_size * cfg.d_model
    head_f = 2.0 * cfg.d_model * cfg.vocab_size * (tokens if shape.kind == "train"
                                                   else float(B))
    layers.append(("lm_head", head_p + cfg.d_model, head_f))
    return layers


def param_count(cfg: ModelConfig) -> int:
    shape = InputShape("probe", 128, 1, "train")
    return sum(p for _, p, _ in layer_breakdown(cfg, shape))


def active_param_count(cfg: ModelConfig) -> int:
    if cfg.moe is None:
        return param_count(cfg)
    total = 0
    shape = InputShape("probe", 128, 1, "train")
    for name, p, _ in layer_breakdown(cfg, shape):
        total += p
    # subtract inactive expert weights
    d_ff = cfg.moe.d_ff_expert or cfg.d_ff
    n_moe_layers = sum(1 for k in _decoder_layer_kinds(cfg) if k.endswith("moe"))
    inactive = 3 * (cfg.moe.num_experts - cfg.moe.top_k) * cfg.d_model * d_ff
    return total - n_moe_layers * inactive


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """The roofline's MODEL_FLOPS reference: 6*N_active*tokens for training,
    2*N_active*tokens for inference (fwd only)."""
    n_active = active_param_count(cfg) - cfg.vocab_size * cfg.d_model  # embed lookup is free
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch


def total_fwd_flops(cfg: ModelConfig, shape: InputShape) -> float:
    return sum(f for _, _, f in layer_breakdown(cfg, shape))


def layer_breakdown_from_params(params, cfg: ModelConfig) -> List[Layer]:
    """Measured-mode helper: chunk real param tree into top-level entries with
    FLOPs proportional to parameter count."""
    import jax

    out: List[Layer] = []
    for key, sub in params.items():
        n = sum(int(p.size) for p in jax.tree_util.tree_leaves(sub))
        out.append((key, n, float(n)))
    return out
