"""Network-transport models: how much of the physical bandwidth the
communication phase actually achieves.

This is the paper's central object of study — measured Horovod-over-TCP
leaves a 100 Gbps NIC at <32 Gbps utilization, and the what-if analysis
asks what happens at 100 %.  We model a transport as an *effective
bandwidth curve* ``effective(bw) -> bytes/s``:

- ``ideal``        full utilization (the paper's what-if),
- ``horovod_tcp``  calibrated to the paper's Fig. 3/4 measurements:
                   full utilization up to ~3 Gbps, a soft knee, and a hard
                   ~32 Gbps ceiling at 100 Gbps NICs,
- ``tpu_ici``      near-ideal with a small per-hop protocol overhead
                   (XLA-driven ICI achieves ~95 % of peak in practice).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict

GBPS = 1e9 / 8.0  # bytes/s per Gbps


@dataclass(frozen=True)
class Transport:
    name: str
    curve: Callable[[float], float]
    per_tensor_overhead: float = 0.0   # coordination latency per gradient tensor

    def effective(self, bw: float) -> float:
        return self.curve(bw)

    def utilization(self, bw: float) -> float:
        return self.effective(bw) / bw if bw > 0 else 0.0


def _ideal(bw: float) -> float:
    return bw


# Calibration targets from the paper:
#   Fig. 4 — 1 Gbps (and 10 Gbps) fully utilized; a 100 Gbps NIC peaks below
#            32 Gbps during the communication phase.
#   Fig. 3 — scaling plateaus after 25 Gbps.
#   Fig. 1 — 2-server scaling 75 / 69 / 56 % (RN50 / RN101 / VGG16).
# Sharp-knee saturating cap:  eff = bw*cap / (bw^k + cap^k)^(1/k), k=4 —
# ~bw below the cap, ~cap above it.  On top of the bandwidth ceiling,
# Horovod's tensor negotiation costs ~250 us per gradient tensor (this is
# why ResNet101, with ~2x the tensors of ResNet50, measures *worse* despite
# a mid-sized model).
_HOROVOD_CAP = 30.0 * GBPS
_KNEE = 4.0


def _horovod_tcp(bw: float) -> float:
    return bw * _HOROVOD_CAP / (bw ** _KNEE + _HOROVOD_CAP ** _KNEE) ** (1.0 / _KNEE)


def _tpu_ici(bw: float) -> float:
    return 0.95 * bw


TRANSPORTS: Dict[str, Transport] = {
    "ideal": Transport("ideal", _ideal),
    "horovod_tcp": Transport("horovod_tcp", _horovod_tcp,
                             per_tensor_overhead=250e-6),
    "tpu_ici": Transport("tpu_ici", _tpu_ici, per_tensor_overhead=0.0),
}


def get_transport(name: str) -> Transport:
    return TRANSPORTS[name]
