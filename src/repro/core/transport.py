"""Network-transport models: how much of the physical bandwidth the
communication phase actually achieves.

This is the paper's central object of study — measured Horovod-over-TCP
leaves a 100 Gbps NIC at <32 Gbps utilization, and the what-if analysis
asks what happens at 100 %.  We model a transport as an *effective
bandwidth curve* ``effective(bw) -> bytes/s``:

- ``ideal``        full utilization (the paper's what-if),
- ``horovod_tcp``  calibrated to the paper's Fig. 3/4 measurements:
                   full utilization up to ~3 Gbps, a soft knee, and a hard
                   ~32 Gbps ceiling at 100 Gbps NICs,
- ``tpu_ici``      near-ideal with a small per-hop protocol overhead
                   (XLA-driven ICI achieves ~95 % of peak in practice).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Union

GBPS = 1e9 / 8.0  # bytes/s per Gbps


@dataclass(frozen=True)
class Transport:
    name: str
    curve: Callable[[float], float]
    per_tensor_overhead: float = 0.0   # coordination latency per gradient tensor

    def effective(self, bw: float) -> float:
        return self.curve(bw)

    def utilization(self, bw: float) -> float:
        return self.effective(bw) / bw if bw > 0 else 0.0


def _ideal(bw: float) -> float:
    return bw


# Calibration targets from the paper:
#   Fig. 4 — 1 Gbps (and 10 Gbps) fully utilized; a 100 Gbps NIC peaks below
#            32 Gbps during the communication phase.
#   Fig. 3 — scaling plateaus after 25 Gbps.
#   Fig. 1 — 2-server scaling 75 / 69 / 56 % (RN50 / RN101 / VGG16).
# Sharp-knee saturating cap:  eff = bw*cap / (bw^k + cap^k)^(1/k), k=4 —
# ~bw below the cap, ~cap above it.  On top of the bandwidth ceiling,
# Horovod's tensor negotiation costs ~250 us per gradient tensor (this is
# why ResNet101, with ~2x the tensors of ResNet50, measures *worse* despite
# a mid-sized model).
_HOROVOD_CAP = 30.0 * GBPS
_KNEE = 4.0


def _horovod_tcp(bw: float) -> float:
    return bw * _HOROVOD_CAP / (bw ** _KNEE + _HOROVOD_CAP ** _KNEE) ** (1.0 / _KNEE)


def _tpu_ici(bw: float) -> float:
    return 0.95 * bw


TRANSPORTS: Dict[str, Transport] = {
    "ideal": Transport("ideal", _ideal),
    "horovod_tcp": Transport("horovod_tcp", _horovod_tcp,
                             per_tensor_overhead=250e-6),
    "tpu_ici": Transport("tpu_ici", _tpu_ici, per_tensor_overhead=0.0),
}


def get_transport(name: str) -> Transport:
    return TRANSPORTS[name]


# ---------------------------------------------------------------------------
# lossy links: loss / retransmission / backoff as a priced, seeded axis
# ---------------------------------------------------------------------------
#
# The transport curves above say how much of the NIC a *clean* datacenter
# link yields; a LinkProfile says what happens when the link itself is not
# clean (WAN hops, congested uplinks).  It prices two effects:
#
# - deterministically, in the lowering (`schedule.plan_to_flows` /
#   `plan_to_flow_batch`): every flow's wire work inflates by the expected
#   retransmission factor 1/(1-loss), and the propagation RTT joins the
#   fixed post-wire latency — the fluid-model mean of the loss process;
# - stochastically, in the engine: seeded retransmission-timeout events
#   (`retx_events`) stall the owning job for `timeout * backoff^k` and pull
#   its in-flight flow back, riding the `_RETX` calendar kind in
#   `core.events` (same fence machinery as `_FAULT`, so bulk commit stays
#   bit-identical).
#
# The null profile (loss=0, rtt=0) must bypass both bitwise — the contract
# every pre-WAN golden artifact rides on.

@dataclass(frozen=True)
class LinkProfile:
    """A lossy-link regime: propagation delay + Bernoulli segment loss.

    ``loss`` is the per-segment loss probability, ``rtt`` the round-trip
    propagation delay in *seconds*, ``timeout`` the retransmission timeout
    (seconds), ``backoff`` the exponential-backoff multiplier applied per
    consecutive loss of the same segment, ``segment`` the wire segment
    size in bytes (the unit the Bernoulli process draws over).
    """

    loss: float = 0.0
    rtt: float = 0.0
    timeout: float = 0.2
    backoff: float = 2.0
    segment: float = 64e3

    @property
    def is_null(self) -> bool:
        return self.loss <= 0.0 and self.rtt <= 0.0


NULL_LINK = LinkProfile()

# share of lost segments whose recovery needs a full RTO stall rather than
# an in-window fast retransmit (those are already priced by the 1/(1-loss)
# wire inflation); keeps the event count physical instead of per-segment
RTO_SHARE = 0.05
# fixed candidate-event pool per (seed, stream): a thinning gate keeps a
# loss-monotone *subset* of the same draws, so raising the loss axis only
# adds events (never reshuffles them) — what the monotonicity validators
# gate on
_RETX_POOL = 256
_RETX_MAX_BACKOFF = 6
# RTO episodes come from *burst* loss: the congestion that dropped the
# first segment persists across its retransmission, so the conditional
# loss of a retry is far above the marginal rate.  We model the retry
# loss as loss**_RETRY_LOSS_EXP (0.01 marginal -> ~0.32 conditional),
# which keeps the backoff depth monotone in the loss axis while giving
# the backoff multiplier a real lever to act on.
_RETRY_LOSS_EXP = 0.25


def parse_link_profile(spec: Union[str, LinkProfile, None]) -> LinkProfile:
    """``"none"`` | ``"wan:loss=p,rtt=ms[:timeout=ms,backoff=x]"``.

    ``loss`` is a probability, ``rtt``/``timeout`` are milliseconds,
    ``backoff`` a multiplier, ``segment`` bytes.  Sections after ``wan``
    are ``key=value`` pairs separated by ``,`` (the ``:`` between sections
    is cosmetic — any pair may appear in any section).  Mirrors
    :func:`repro.core.faults.parse_fault_model`: unknown names raise.
    """
    if isinstance(spec, LinkProfile):
        return spec
    if spec is None or spec == "" or spec == "none":
        return NULL_LINK
    head, _, rest = spec.partition(":")
    if head != "wan" or not rest:
        raise ValueError(f"unknown link profile {spec!r} "
                         "(expected 'none' or 'wan:loss=p,rtt=ms[...]')")
    kw: Dict[str, float] = {}
    for section in rest.split(":"):
        for pair in section.split(","):
            if not pair:
                continue
            key, eq, val = pair.partition("=")
            if not eq:
                raise ValueError(
                    f"link profile field {pair!r} is not key=value")
            try:
                kw[key] = float(val)
            except ValueError:
                raise ValueError(
                    f"link profile field {key!r} has non-numeric "
                    f"value {val!r}") from None
    unknown = set(kw) - {"loss", "rtt", "timeout", "backoff", "segment"}
    if unknown:
        raise ValueError(
            f"unknown link profile field(s) {sorted(unknown)} in {spec!r}")
    loss = kw.get("loss", 0.0)
    if not 0.0 <= loss < 1.0:
        raise ValueError(f"loss must be in [0, 1), got {loss}")
    return LinkProfile(
        loss=loss,
        rtt=kw.get("rtt", 0.0) / 1e3,
        timeout=kw.get("timeout", 200.0) / 1e3,
        backoff=kw.get("backoff", 2.0),
        segment=kw.get("segment", 64e3))


def retx_events(lp: LinkProfile, total_bytes: float, horizon: float,
                seed: int = 0, stream: int = 0, *,
                job: str = "job0") -> List:
    """Seeded retransmission-timeout stalls over one iteration.

    Returns :class:`repro.core.events.ChurnEvent` entries of kind
    ``"retx"`` (pull-back + stall, no worker cancellation), drawn from
    substream ``(4,)`` of the engine-wide fault RNG so draws depend only
    on ``(seed, stream)`` — the determinism contract shared with
    :mod:`repro.core.faults`.

    Monotonicity by construction (what the ``wan`` validators gate):

    - arrival times come from a fixed :data:`_RETX_POOL`-slot candidate
      pool; a thinning gate keeps slot ``i`` iff ``gate_i < rate/POOL``,
      so a higher loss keeps a *superset* of the same timed slots;
    - the backoff depth inverts a geometric CDF at a pooled uniform:
      ``k = floor(log(u)/log(p_retry))`` with the burst-correlated retry
      loss ``p_retry = loss**_RETRY_LOSS_EXP`` is non-decreasing in
      ``loss`` for a fixed ``u``, and the stall ``timeout * backoff**k``
      is analytic in ``timeout``/``backoff`` — sweeping the backoff axis
      scales stalls without touching the event set.
    """
    from repro.core.events import ChurnEvent, _jitter_stream

    if lp.loss <= 0.0 or total_bytes <= 0.0 or horizon <= 0.0:
        return []
    rng = _jitter_stream(seed, stream, 4)
    times = horizon * rng.random(_RETX_POOL)
    gate = rng.random(_RETX_POOL)
    depth_u = rng.random(_RETX_POOL)
    rate = lp.loss * (total_bytes / lp.segment) * RTO_SHARE
    thin = min(1.0, rate / _RETX_POOL)
    log_retry = _RETRY_LOSS_EXP * math.log(lp.loss)
    out = []
    for i in range(_RETX_POOL):
        if gate[i] >= thin:
            continue
        k = int(min(math.log(max(float(depth_u[i]), 1e-300)) / log_retry,
                    float(_RETX_MAX_BACKOFF)))
        out.append(ChurnEvent(float(times[i]), job, "retx", -1,
                              lp.timeout * lp.backoff ** k))
    out.sort()
    return out
