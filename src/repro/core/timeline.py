"""Gradient-ready timelines — the white-box timing input to the simulator.

The paper instruments training scripts with per-parameter hooks to log
*gradient-computation-done* times.  We build the same timeline three ways:

- ``from_layer_profile``: analytic — distribute a known batch time across
  layers proportional to FLOPs (paper CNNs on V100, our archs on v5e);
- ``from_cnn``: the paper's three workloads;
- ``from_transformer``: any assigned architecture x input shape, using the
  per-layer parameter/FLOP model in ``repro.core.flops``;
- ``measure``: empirical smoke-scale timing on the local device.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cnn_profiles import CNNProfile, get_profile

# fraction of compute time spent in backward (2x fwd FLOPs for matmul nets)
BWD_FRACTION = 2.0 / 3.0


@dataclass(frozen=True)
class GradTimeline:
    """Backward-pass gradient availability schedule.

    ``ready_times[i]`` (seconds from backward start, ascending) is when
    gradient chunk i (``sizes[i]`` bytes) becomes available; ``t_back`` is
    backward completion, ``t_batch`` the full fwd+bwd iteration time.
    """

    name: str
    ready_times: Tuple[float, ...]
    sizes: Tuple[float, ...]
    t_back: float
    t_batch: float

    @property
    def total_bytes(self) -> float:
        return float(sum(self.sizes))


def from_layer_profile(name: str, layer_bytes: Sequence[float],
                       layer_bwd_times: Sequence[float],
                       t_batch: float) -> GradTimeline:
    """layer_bytes / layer_bwd_times in *forward* order."""
    assert len(layer_bytes) == len(layer_bwd_times)
    n = len(layer_bytes)
    # backward visits layers last -> first
    ready, sizes = [], []
    t = 0.0
    for i in reversed(range(n)):
        t += layer_bwd_times[i]
        ready.append(t)
        sizes.append(float(layer_bytes[i]))
    return GradTimeline(name, tuple(ready), tuple(sizes), t_back=t,
                        t_batch=float(t_batch))


def from_cnn(name: str, t_batch: Optional[float] = None,
             grad_dtype_bytes: int = 4) -> GradTimeline:
    """Timeline for resnet50 / resnet101 / vgg16 on a V100 (paper setup)."""
    prof: CNNProfile = get_profile(name)
    tb = t_batch if t_batch is not None else prof.t_batch_v100
    flops = np.array([l.flops for l in prof.layers], dtype=np.float64)
    total = flops.sum()
    # layers with zero conv FLOPs (bn) get a tiny epsilon share
    share = (flops + 1e-9 * total) / (flops + 1e-9 * total).sum()
    bwd_times = share * (tb * BWD_FRACTION)
    layer_bytes = [l.params * grad_dtype_bytes for l in prof.layers]
    return from_layer_profile(prof.name, layer_bytes, bwd_times, tb)


def from_transformer(cfg, shape, *, mfu: float = 0.4,
                     chip_flops: float = 197e12, n_chips_compute: int = 1,
                     grad_dtype_bytes: int = 2) -> GradTimeline:
    """Timeline for an assigned architecture on TPU v5e.

    ``n_chips_compute`` divides the per-layer compute time (model-parallel
    group size); gradient sizes are the *per-replica* gradient bytes.
    """
    from repro.core.flops import layer_breakdown

    layers = layer_breakdown(cfg, shape)     # [(name, params, fwd_flops)]
    eff = mfu * chip_flops * n_chips_compute
    fwd_times = np.array([l[2] for l in layers], dtype=np.float64) / eff
    t_fwd = fwd_times.sum()
    bwd_times = 2.0 * fwd_times
    t_batch = float(t_fwd + bwd_times.sum())
    layer_bytes = [l[1] * grad_dtype_bytes for l in layers]
    return from_layer_profile(f"{cfg.name}:{shape.name}", layer_bytes,
                              bwd_times, t_batch)


def measure(api, cfg, batch, repeats: int = 3) -> GradTimeline:
    """Empirical smoke-scale timeline on the local device.

    JAX has no per-layer backward hooks (the graph is compiled), so we time
    the full fwd+bwd and distribute backward time across layers proportional
    to analytic FLOPs — the same shape of data the paper logs, measured at
    the granularity XLA exposes.
    """
    import time as _time

    import jax

    from repro.core.flops import layer_breakdown_from_params

    step = jax.jit(lambda p, b: jax.grad(lambda q: api.loss_fn(q, b)[0])(p))
    params = api.init(jax.random.key(0))
    g = step(params, batch)
    jax.block_until_ready(g)
    t0 = _time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(step(params, batch))
    t_batch = (_time.perf_counter() - t0) / repeats
    layers = layer_breakdown_from_params(params, cfg)
    fl = np.array([l[2] for l in layers], dtype=np.float64)
    share = (fl + 1e-9 * fl.sum()) / (fl + 1e-9 * fl.sum()).sum()
    bwd = share * t_batch * BWD_FRACTION
    layer_bytes = [l[1] * 4 for l in layers]
    return from_layer_profile(f"{cfg.name}-measured", layer_bytes, bwd, t_batch)
