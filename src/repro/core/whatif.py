"""Top-level what-if analysis API — one entry point per paper figure.

Each function returns plain dict/list data (the benchmark scripts print the
CSV); nothing here touches jax, so the analysis runs anywhere.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence

from repro.configs.base import CommConfig
from repro.core.addest import AddEst
from repro.core.simulator import SimResult, simulate
from repro.core.timeline import GradTimeline, from_cnn
from repro.core.transport import GBPS, get_transport

PAPER_MODELS = ("resnet50", "resnet101", "vgg16")
GPUS_PER_SERVER = 8          # p3dn.24xlarge


def paper_timeline(model: str) -> GradTimeline:
    return from_cnn(model)


def sim_scaling(model: str, *, n_servers: int = 8, bandwidth_gbps: float = 100.0,
                transport: str = "ideal", compression_ratio: float = 1.0,
                comm: Optional[CommConfig] = None) -> SimResult:
    tl = paper_timeline(model)
    return simulate(tl, n_workers=n_servers * GPUS_PER_SERVER,
                    bandwidth=bandwidth_gbps * GBPS, transport=transport,
                    compression_ratio=compression_ratio, comm=comm,
                    addest=AddEst.v100())


# ---------------------------------------------------------------------------
# figure reproductions
# ---------------------------------------------------------------------------

def fig1_scaling_vs_servers(models: Sequence[str] = PAPER_MODELS,
                            servers: Sequence[int] = (2, 4, 8),
                            bandwidth_gbps: float = 100.0) -> List[Dict]:
    """Measured-mode scaling factors (horovod_tcp transport)."""
    return [dict(model=m, servers=n,
                 scaling=sim_scaling(m, n_servers=n,
                                     bandwidth_gbps=bandwidth_gbps,
                                     transport="horovod_tcp").scaling_factor)
            for m in models for n in servers]


def fig3_scaling_vs_bandwidth(model: str = "resnet50",
                              servers: Sequence[int] = (2, 4, 8),
                              bws: Sequence[float] = (1, 2, 5, 10, 25, 50, 75, 100),
                              transport: str = "horovod_tcp") -> List[Dict]:
    return [dict(model=model, servers=n, bandwidth_gbps=bw,
                 scaling=sim_scaling(model, n_servers=n, bandwidth_gbps=bw,
                                     transport=transport).scaling_factor)
            for n in servers for bw in bws]


def fig4_utilization(models: Sequence[str] = PAPER_MODELS,
                     bws: Sequence[float] = (1, 10, 25, 50, 100),
                     transport: str = "horovod_tcp") -> List[Dict]:
    out = []
    for m in models:
        for bw in bws:
            r = sim_scaling(m, bandwidth_gbps=bw, transport=transport)
            out.append(dict(model=m, bandwidth_gbps=bw,
                            utilization=r.network_utilization,
                            effective_gbps=r.effective_bw / GBPS))
    return out


def fig6_sim_vs_measured(models: Sequence[str] = PAPER_MODELS,
                         bws: Sequence[float] = (1, 10, 25, 50, 100),
                         n_servers: int = 8) -> List[Dict]:
    out = []
    for m in models:
        for bw in bws:
            ideal = sim_scaling(m, n_servers=n_servers, bandwidth_gbps=bw,
                                transport="ideal").scaling_factor
            meas = sim_scaling(m, n_servers=n_servers, bandwidth_gbps=bw,
                               transport="horovod_tcp").scaling_factor
            out.append(dict(model=m, bandwidth_gbps=bw,
                            simulated_full_util=ideal, measured_mode=meas))
    return out


def fig7_scaling_vs_workers(models: Sequence[str] = PAPER_MODELS,
                            servers: Sequence[int] = (1, 2, 4, 8),
                            bandwidth_gbps: float = 100.0) -> List[Dict]:
    return [dict(model=m, servers=n, gpus=n * GPUS_PER_SERVER,
                 simulated=sim_scaling(m, n_servers=n,
                                       bandwidth_gbps=bandwidth_gbps,
                                       transport="ideal").scaling_factor,
                 measured_mode=sim_scaling(m, n_servers=n,
                                           bandwidth_gbps=bandwidth_gbps,
                                           transport="horovod_tcp").scaling_factor)
            for m in models for n in servers]


def fig8_compression(models: Sequence[str] = PAPER_MODELS,
                     ratios: Sequence[float] = (1, 2, 5, 10, 100),
                     bws: Sequence[float] = (10, 100),
                     n_servers: int = 8) -> List[Dict]:
    return [dict(model=m, bandwidth_gbps=bw, ratio=r,
                 scaling=sim_scaling(m, n_servers=n_servers, bandwidth_gbps=bw,
                                     transport="ideal",
                                     compression_ratio=r).scaling_factor)
            for m in models for bw in bws for r in ratios]


def transmission_table(bandwidth_gbps: float = 100.0) -> List[Dict]:
    """§4: time to transmit all parameters (paper: 7.8 / 13.6 / 42.2 ms)."""
    from repro.core.cnn_profiles import get_profile
    bw = bandwidth_gbps * GBPS
    out = []
    for m in PAPER_MODELS:
        p = get_profile(m)
        out.append(dict(model=m, size_mb=p.total_bytes / 1e6,
                        time_ms=p.total_bytes / bw * 1e3))
    return out


def fig9_other_systems(models: Sequence[str] = PAPER_MODELS,
                       bws: Sequence[float] = (10, 25, 100),
                       n_servers: int = 8) -> List[Dict]:
    """Paper §4 ("What-if analysis for other approaches"): apply the same
    full-utilization what-if to SwitchML-style in-network aggregation and a
    sharded parameter server, against ring all-reduce."""
    out = []
    for m in models:
        tl = paper_timeline(m)
        for bw in bws:
            row = dict(model=m, bandwidth_gbps=bw)
            for topo in ("ring", "switchml", "param_server"):
                r = simulate(tl, n_workers=n_servers * GPUS_PER_SERVER,
                             bandwidth=bw * GBPS, transport="ideal",
                             topology=topo)
                row[topo] = r.scaling_factor
            out.append(row)
    return out


def bytescheduler_whatif(model: str = "vgg16", bandwidth_gbps: float = 10.0,
                         n_servers: int = 8) -> Dict:
    """ByteScheduler's insight: transmit *front* layers first so the next
    iteration's forward pass can start before the sync finishes.  In the
    simulator this bounds the overhead by the sync tail that extends past
    the point where the front layers are available again — we approximate
    the benefit as overlapping the next forward with the remaining sync
    (the upper bound the paper suggests evaluating)."""
    tl = paper_timeline(model)
    base = simulate(tl, n_workers=n_servers * GPUS_PER_SERVER,
                    bandwidth=bandwidth_gbps * GBPS, transport="ideal")
    t_fwd = tl.t_batch - tl.t_back
    overhead_sched = max(0.0, base.t_overhead - t_fwd)
    f_sched = tl.t_batch / (tl.t_batch + overhead_sched)
    return dict(model=model, bandwidth_gbps=bandwidth_gbps,
                baseline=base.scaling_factor, bytescheduler_bound=f_sched)


# ---------------------------------------------------------------------------
# beyond-paper: the same analysis for the assigned TPU architectures
# ---------------------------------------------------------------------------

def tpu_whatif(cfg, shape, *, n_chips: int = 256, n_pods: int = 1,
               ici_gbps: float = 400.0, dcn_gbps: float = 200.0,
               mfu: float = 0.4, compression_ratio: float = 1.0,
               transport: str = "tpu_ici",
               data_parallel: Optional[int] = None) -> SimResult:
    """Paper's analysis transplanted to a v5e pod: is the ICI the bottleneck
    for data-parallel training of the assigned archs?

    ``data_parallel``: size of the gradient all-reduce group (defaults to 16,
    the production mesh's data axis); the model-parallel group accelerates
    per-layer compute instead.
    """
    from repro.core.timeline import from_transformer
    dp = data_parallel or 16
    mp = max(n_chips // dp // max(n_pods, 1), 1)
    tl = from_transformer(cfg, shape, mfu=mfu, n_chips_compute=mp,
                          grad_dtype_bytes=2)
    # per-replica gradient shard: model-parallel shards gradients mp-ways
    tl = GradTimeline(tl.name, tl.ready_times,
                      tuple(s / mp for s in tl.sizes), tl.t_back, tl.t_batch)
    return simulate(tl, n_workers=dp * max(n_pods, 1),
                    bandwidth=ici_gbps * GBPS, transport=transport,
                    addest=AddEst.tpu_v5e(),
                    compression_ratio=compression_ratio,
                    topology="hierarchical" if n_pods > 1 else "ring",
                    n_pods=max(n_pods, 1), dcn_bandwidth=dcn_gbps * GBPS)
