"""Top-level what-if analysis API — one entry point per paper figure.

Each function returns plain dict/list data (the benchmark scripts print the
CSV); nothing here touches jax, so the analysis runs anywhere.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence

from repro.configs.base import CommConfig
from repro.core.addest import AddEst
from repro.core.simulator import SimResult, simulate
from repro.core.timeline import GradTimeline, from_cnn
from repro.core.transport import GBPS, get_transport

PAPER_MODELS = ("resnet50", "resnet101", "vgg16")
GPUS_PER_SERVER = 8          # p3dn.24xlarge


def paper_timeline(model: str) -> GradTimeline:
    return from_cnn(model)


def sim_scaling(model: str, *, n_servers: int = 8, bandwidth_gbps: float = 100.0,
                transport: str = "ideal", compression_ratio: float = 1.0,
                comm: Optional[CommConfig] = None) -> SimResult:
    tl = paper_timeline(model)
    return simulate(tl, n_workers=n_servers * GPUS_PER_SERVER,
                    bandwidth=bandwidth_gbps * GBPS, transport=transport,
                    compression_ratio=compression_ratio, comm=comm,
                    addest=AddEst.v100())


# ---------------------------------------------------------------------------
# figure reproductions — thin spec builders over the experiment engine
# (repro.experiments); each returns the same row dicts as the historical
# per-figure loops, sourced from engine cells.
# ---------------------------------------------------------------------------

def _grid(name: str, **overrides):
    """The registered paper grid, with any swept axis overridden.

    Defaults come from ``repro.experiments.grids`` — the single source of
    truth the golden artifact is built from — so these builders cannot
    drift from the committed sweep definitions.
    """
    import dataclasses

    from repro.experiments import GRIDS
    if not overrides:
        return GRIDS[name]
    # a custom sweep is not the registered grid: rename it so the engine
    # doesn't apply the grid's paper-claim validators to a partial sweep
    return dataclasses.replace(GRIDS[name], name=f"{name}-custom",
                               **overrides)


def _cells(spec) -> Dict[tuple, Dict]:
    """Run a grid and index its cells by (model, servers, bw, transport,
    ratio, topology)."""
    from repro.experiments import index_cells, run_spec
    return index_cells(run_spec(spec)["cells"])



def _k(model, servers, bw, transport, ratio=1.0, topo="ring", sched="fifo",
       n_jobs=1, n_rails=1, jitter_ms=0.0, codec="none", fault_model="none",
       churn_rate=0.0, worker_bw_skew=0.0, fabric="none",
       oversubscription=1.0, link_profile="none"):
    """An ``index_cells`` key in CELL_AXES order, with trailing-axis
    defaults — figure builders only name the axes their sweep varies."""
    return (model, servers, bw, transport, ratio, topo, sched, n_jobs,
            n_rails, jitter_ms, codec, fault_model, churn_rate,
            worker_bw_skew, fabric, oversubscription, link_profile)

def fig1_scaling_vs_servers(models: Optional[Sequence[str]] = None,
                            servers: Optional[Sequence[int]] = None,
                            bandwidth_gbps: Optional[float] = None) -> List[Dict]:
    """Measured-mode scaling factors (horovod_tcp transport)."""
    spec = _grid("paper-fig1",
                 **({} if models is None else dict(models=tuple(models))),
                 **({} if servers is None else dict(n_servers=tuple(servers))),
                 **({} if bandwidth_gbps is None
                    else dict(bandwidth_gbps=(float(bandwidth_gbps),))))
    ix = _cells(spec)
    bw = spec.bandwidth_gbps[0]
    return [dict(model=m, servers=n,
                 scaling=ix[_k(m, n, bw, "horovod_tcp")]
                 ["scaling_factor"])
            for m in spec.models for n in spec.n_servers]


def fig3_scaling_vs_bandwidth(model: Optional[str] = None,
                              servers: Optional[Sequence[int]] = None,
                              bws: Optional[Sequence[float]] = None,
                              transport: Optional[str] = None) -> List[Dict]:
    spec = _grid("paper-fig3",
                 **({} if model is None else dict(models=(model,))),
                 **({} if servers is None else dict(n_servers=tuple(servers))),
                 **({} if bws is None
                    else dict(bandwidth_gbps=tuple(float(b) for b in bws))),
                 **({} if transport is None else dict(transport=(transport,))))
    ix = _cells(spec)
    tr = spec.transport[0]
    return [dict(model=spec.models[0], servers=n, bandwidth_gbps=bw,
                 scaling=ix[_k(spec.models[0], n, bw, tr)]
                 ["scaling_factor"])
            for n in spec.n_servers for bw in spec.bandwidth_gbps]


def fig4_utilization(models: Optional[Sequence[str]] = None,
                     bws: Optional[Sequence[float]] = None,
                     transport: Optional[str] = None) -> List[Dict]:
    spec = _grid("paper-fig4",
                 **({} if models is None else dict(models=tuple(models))),
                 **({} if bws is None
                    else dict(bandwidth_gbps=tuple(float(b) for b in bws))),
                 **({} if transport is None else dict(transport=(transport,))))
    ix = _cells(spec)
    n, tr = spec.n_servers[0], spec.transport[0]
    return [dict(model=m, bandwidth_gbps=bw,
                 utilization=ix[_k(m, n, bw, tr)]
                 ["network_utilization"],
                 effective_gbps=ix[_k(m, n, bw, tr)]
                 ["effective_gbps"])
            for m in spec.models for bw in spec.bandwidth_gbps]


def fig6_sim_vs_measured(models: Optional[Sequence[str]] = None,
                         bws: Optional[Sequence[float]] = None,
                         n_servers: Optional[int] = None) -> List[Dict]:
    spec = _grid("paper-fig6",
                 **({} if models is None else dict(models=tuple(models))),
                 **({} if bws is None
                    else dict(bandwidth_gbps=tuple(float(b) for b in bws))),
                 **({} if n_servers is None
                    else dict(n_servers=(n_servers,))))
    ix = _cells(spec)
    n = spec.n_servers[0]
    return [dict(model=m, bandwidth_gbps=bw,
                 simulated_full_util=ix[_k(m, n, bw, "ideal")]["scaling_factor"],
                 measured_mode=ix[_k(m, n, bw, "horovod_tcp")]["scaling_factor"])
            for m in spec.models for bw in spec.bandwidth_gbps]


def fig7_scaling_vs_workers(models: Optional[Sequence[str]] = None,
                            servers: Optional[Sequence[int]] = None,
                            bandwidth_gbps: Optional[float] = None) -> List[Dict]:
    spec = _grid("paper-fig7",
                 **({} if models is None else dict(models=tuple(models))),
                 **({} if servers is None else dict(n_servers=tuple(servers))),
                 **({} if bandwidth_gbps is None
                    else dict(bandwidth_gbps=(float(bandwidth_gbps),))))
    ix = _cells(spec)
    bw = spec.bandwidth_gbps[0]
    return [dict(model=m, servers=n, gpus=n * GPUS_PER_SERVER,
                 simulated=ix[_k(m, n, bw, "ideal")]
                 ["scaling_factor"],
                 measured_mode=ix[_k(m, n, bw, "horovod_tcp")]
                 ["scaling_factor"])
            for m in spec.models for n in spec.n_servers]


def fig8_compression(models: Optional[Sequence[str]] = None,
                     ratios: Optional[Sequence[float]] = None,
                     bws: Optional[Sequence[float]] = None,
                     n_servers: Optional[int] = None) -> List[Dict]:
    spec = _grid("paper-fig8",
                 **({} if models is None else dict(models=tuple(models))),
                 **({} if ratios is None
                    else dict(compression_ratio=tuple(float(r) for r in ratios))),
                 **({} if bws is None
                    else dict(bandwidth_gbps=tuple(float(b) for b in bws))),
                 **({} if n_servers is None
                    else dict(n_servers=(n_servers,))))
    ix = _cells(spec)
    n = spec.n_servers[0]
    return [dict(model=m, bandwidth_gbps=bw, ratio=r,
                 scaling=ix[_k(m, n, bw, "ideal", r)]["scaling_factor"])
            for m in spec.models for bw in spec.bandwidth_gbps
            for r in spec.compression_ratio]


def transmission_table(bandwidth_gbps: float = 100.0) -> List[Dict]:
    """§4: time to transmit all parameters (paper: 7.8 / 13.6 / 42.2 ms)."""
    from repro.core.cnn_profiles import get_profile
    bw = bandwidth_gbps * GBPS
    out = []
    for m in PAPER_MODELS:
        p = get_profile(m)
        out.append(dict(model=m, size_mb=p.total_bytes / 1e6,
                        time_ms=p.total_bytes / bw * 1e3))
    return out


def fig9_other_systems(models: Optional[Sequence[str]] = None,
                       bws: Optional[Sequence[float]] = None,
                       n_servers: Optional[int] = None) -> List[Dict]:
    """Paper §4 ("What-if analysis for other approaches"): apply the same
    full-utilization what-if to SwitchML-style in-network aggregation and a
    sharded parameter server, against ring all-reduce."""
    spec = _grid("paper-fig9",
                 **({} if models is None else dict(models=tuple(models))),
                 **({} if bws is None
                    else dict(bandwidth_gbps=tuple(float(b) for b in bws))),
                 **({} if n_servers is None
                    else dict(n_servers=(n_servers,))))
    ix = _cells(spec)
    n = spec.n_servers[0]
    out = []
    for m in spec.models:
        for bw in spec.bandwidth_gbps:
            row = dict(model=m, bandwidth_gbps=bw)
            for topo in spec.topology:
                row[topo] = ix[_k(m, n, bw, "ideal", topo=topo)]["scaling_factor"]
            out.append(row)
    return out


def fig10_schedulers(models: Optional[Sequence[str]] = None,
                     bws: Optional[Sequence[float]] = None,
                     schedulers: Optional[Sequence[str]] = None,
                     transport: Optional[str] = None) -> List[Dict]:
    """The scheduling what-if the event engine opens: f_sim vs bandwidth per
    comm scheduler (fifo = Horovod baseline, priority = ByteScheduler-style,
    chunked = pipelined transmission+reduction).  Rows come from the
    registered ``scheduler-suite`` grid, the same sweep the
    ``scheduler_suite`` golden artifact gates in CI."""
    spec = _grid("scheduler-suite",
                 **({} if models is None else dict(models=tuple(models))),
                 **({} if bws is None
                    else dict(bandwidth_gbps=tuple(float(b) for b in bws))),
                 **({} if schedulers is None
                    else dict(scheduler=tuple(schedulers))),
                 **({} if transport is None else dict(transport=(transport,))))
    ix = _cells(spec)
    n = spec.n_servers[0]
    out = []
    for m in spec.models:
        for tr in spec.transport:
            for bw in spec.bandwidth_gbps:
                row = dict(model=m, transport=tr, bandwidth_gbps=bw)
                for s in spec.scheduler:
                    c = ix[_k(m, n, bw, tr, sched=s)]
                    row[s] = c["scaling_factor"]
                    row[f"{s}_overhead_ms"] = c["t_overhead"] * 1e3
                out.append(row)
    return out


def fig11_multirail(models: Optional[Sequence[str]] = None,
                    bws: Optional[Sequence[float]] = None,
                    rails: Optional[Sequence[int]] = None,
                    schedulers: Optional[Sequence[str]] = None) -> List[Dict]:
    """Multi-rail what-if: f_sim/t_overhead per (scheduler, n_rails) at
    equal *aggregate* bandwidth — the multi-NIC scenario the paper's
    single-NIC testbed could not measure.  Rows come from the registered
    ``multirail`` grid, the sweep the ``scenario`` golden suite gates in
    CI (chunked stripes and is rails-invariant; serialized fifo gains on
    latency-bound models and loses on bandwidth-bound ones)."""
    spec = _grid("multirail",
                 **({} if models is None else dict(models=tuple(models))),
                 **({} if bws is None
                    else dict(bandwidth_gbps=tuple(float(b) for b in bws))),
                 **({} if rails is None
                    else dict(n_rails=tuple(int(r) for r in rails))),
                 **({} if schedulers is None
                    else dict(scheduler=tuple(schedulers))))
    ix = _cells(spec)
    n, tr = spec.n_servers[0], spec.transport[0]
    out = []
    for m in spec.models:
        for bw in spec.bandwidth_gbps:
            row = dict(model=m, bandwidth_gbps=bw)
            for s in spec.scheduler:
                for r in spec.n_rails:
                    c = ix[_k(m, n, bw, tr, sched=s, n_rails=r)]
                    row[f"{s}_x{r}"] = c["scaling_factor"]
                    row[f"{s}_x{r}_overhead_ms"] = c["t_overhead"] * 1e3
            out.append(row)
    return out


def fig12_stragglers(models: Optional[Sequence[str]] = None,
                     bws: Optional[Sequence[float]] = None,
                     jitters_ms: Optional[Sequence[float]] = None,
                     schedulers: Optional[Sequence[str]] = None) -> List[Dict]:
    """Straggler what-if: overhead vs the seeded flush-jitter axis, per
    scheduler.  Rows come from the registered ``straggler`` grid (gated by
    the ``scenario`` golden suite): at full bandwidth the straggler tail
    lands in t_overhead; in the bandwidth-bound regime the transmission
    queue absorbs it."""
    spec = _grid("straggler",
                 **({} if models is None else dict(models=tuple(models))),
                 **({} if bws is None
                    else dict(bandwidth_gbps=tuple(float(b) for b in bws))),
                 **({} if jitters_ms is None
                    else dict(jitter_ms=tuple(float(j) for j in jitters_ms))),
                 **({} if schedulers is None
                    else dict(scheduler=tuple(schedulers))))
    ix = _cells(spec)
    n, tr = spec.n_servers[0], spec.transport[0]
    out = []
    for m in spec.models:
        for s in spec.scheduler:
            for bw in spec.bandwidth_gbps:
                row = dict(model=m, scheduler=s, bandwidth_gbps=bw)
                for j in spec.jitter_ms:
                    c = ix[_k(m, n, bw, tr, sched=s, jitter_ms=j)]
                    row[f"jitter{j:g}ms"] = c["scaling_factor"]
                    row[f"jitter{j:g}ms_overhead_ms"] = c["t_overhead"] * 1e3
                out.append(row)
    return out


def fig13_compression_regimes(models: Optional[Sequence[str]] = None,
                              bws: Optional[Sequence[float]] = None,
                              codecs: Optional[Sequence[str]] = None,
                              schedulers: Optional[Sequence[str]] = None,
                              n_jobs: Optional[Sequence[int]] = None) -> List[Dict]:
    """Compression-regime what-if: each priced codec against its
    ``codec="none"`` twin, classified as wins / loses / pure-overhead /
    neutral by :func:`repro.core.codec.classify_regime`.  Rows come from
    the registered ``compression`` grid, the sweep the
    ``compression_suite`` golden artifact gates in CI: at 10 Gbps the
    network is the bottleneck and int8 wins outright; at 100 Gbps the
    baseline overhead is already negligible and every codec is pure
    GPU-time overhead."""
    from repro.core.codec import classify_regime
    spec = _grid("compression",
                 **({} if models is None else dict(models=tuple(models))),
                 **({} if bws is None
                    else dict(bandwidth_gbps=tuple(float(b) for b in bws))),
                 **({} if codecs is None
                    else dict(codec=tuple(codecs) if "none" in codecs
                              else ("none",) + tuple(codecs))),
                 **({} if schedulers is None
                    else dict(scheduler=tuple(schedulers))),
                 **({} if n_jobs is None
                    else dict(n_jobs=tuple(int(j) for j in n_jobs))))
    ix = _cells(spec)
    n, tr = spec.n_servers[0], spec.transport[0]
    out = []
    for m in spec.models:
        for bw in spec.bandwidth_gbps:
            for s in spec.scheduler:
                for j in spec.n_jobs:
                    base = ix[_k(m, n, bw, tr, sched=s, n_jobs=j)]
                    for cd in spec.codec:
                        if cd == "none":
                            continue
                        c = ix[_k(m, n, bw, tr, sched=s, n_jobs=j, codec=cd)]
                        out.append(dict(
                            model=m, bandwidth_gbps=bw, scheduler=s,
                            n_jobs=j, codec=cd,
                            scaling=c["scaling_factor"],
                            baseline=base["scaling_factor"],
                            overhead_ms=c["t_overhead"] * 1e3,
                            baseline_overhead_ms=base["t_overhead"] * 1e3,
                            codec_compute_ms=c["codec_compute_s"] * 1e3,
                            regime=classify_regime(
                                c["t_overhead"], base["t_overhead"],
                                base["t_batch"], c["codec_compute_s"])))
    return out


# 8 servers x 8 GPUs: the churn grid's fleet size, the W in fig14's
# churn_rate = W * (1 - p) conversion
CHURN_FLEET = 8 * GPUS_PER_SERVER


def fig14_unreliable_workers(models: Optional[Sequence[str]] = None,
                             bws: Optional[Sequence[float]] = None,
                             nines: Sequence[int] = (1, 2, 3, 4),
                             target: float = 0.95) -> List[Dict]:
    """Unreliable-world what-if: how many nines of per-worker reliability
    does each bandwidth tier need to *retain* >= ``target`` of its
    churn-free scaling factor?

    A fleet of ``W`` workers where each is up with probability
    ``p = 1 - 10**-nines`` per iteration sees an expected ``W * (1 - p)``
    dropout events per iteration — the engine's ``churn_rate`` axis.
    Each row sweeps the nines at one (model, bandwidth) point of the
    registered ``churn`` grid (fifo, one rail, no slowdown/skew: churn
    isolated), reporting per nines count the retention
    ``f_churn / f_churn_free`` and the smallest count that clears
    ``target`` (None when even the most reliable swept fleet does not).
    Retention, not absolute scaling, is the right yardstick: the
    measured-transport baseline tops out well below 0.95 at every
    bandwidth, so an absolute target would only restate the paper's
    transport-bound story, not the churn cost."""
    spec = _grid("churn",
                 models=tuple(models) if models is not None
                 else ("resnet50", "vgg16"),
                 bandwidth_gbps=tuple(float(b) for b in bws)
                 if bws is not None else (5.0, 10.0, 25.0, 100.0),
                 scheduler=("fifo",), n_rails=(1,),
                 fault_model=("none",), worker_bw_skew=(0.0,),
                 churn_rate=(0.0,) + tuple(CHURN_FLEET * (10.0 ** -k)
                                           for k in nines))
    ix = _cells(spec)
    n = spec.n_servers[0]
    tr = spec.transport[0]
    out = []
    for m in spec.models:
        for bw in spec.bandwidth_gbps:
            base = ix[_k(m, n, bw, tr)]["scaling_factor"]
            row = dict(model=m, bandwidth_gbps=bw, churn_free=base,
                       nines_needed=None)
            ret = []
            for k, cr in zip(nines, spec.churn_rate[1:]):
                f = ix[_k(m, n, bw, tr, churn_rate=cr)]["scaling_factor"]
                row[f"nines{k}_retention"] = f / base
                ret.append((k, f / base))
            # smallest count that clears the target *and keeps it cleared*
            # at every higher count — a violent-churn fluke (drops cancel
            # enough pending wire work to beat the baseline) must not
            # report a 1-nines fleet as sufficient
            for i, (k, r) in enumerate(ret):
                if all(rj >= target for _, rj in ret[i:]):
                    row["nines_needed"] = k
                    break
            out.append(row)
    return out


def fig15_fabric_oversubscription(models: Optional[Sequence[str]] = None,
                                  bws: Optional[Sequence[float]] = None,
                                  oversubs: Optional[Sequence[float]] = None,
                                  topologies: Optional[Sequence[str]] = None
                                  ) -> List[Dict]:
    """Fabric what-if: the same collectives priced on a Clos fabric with
    oversubscribed ToR uplinks instead of one flat link.  Rows come from
    the registered ``fabric`` grid, the sweep the ``fabric_suite`` golden
    artifact gates in CI.  Per (model, bandwidth, topology) the row holds
    the scaling factor at each oversubscription ratio plus the retention
    of the 1:1 (bitwise-flat) baseline — the striped ring and tree pay
    the full 1/oversub rate cut, while hierarchical's rack-local
    reduction keeps only the leader on the spine and retains ~100 %."""
    spec = _grid("fabric",
                 **({} if models is None else dict(models=tuple(models))),
                 **({} if bws is None
                    else dict(bandwidth_gbps=tuple(float(b) for b in bws))),
                 **({} if oversubs is None
                    else dict(oversubscription=tuple(float(o)
                                                     for o in oversubs))),
                 **({} if topologies is None
                    else dict(topology=tuple(topologies))))
    ix = _cells(spec)
    n, tr = spec.n_servers[0], spec.transport[0]
    out = []
    for m in spec.models:
        for bw in spec.bandwidth_gbps:
            for topo in spec.topology:
                base = ix[_k(m, n, bw, tr, topo=topo, fabric="clos",
                             oversubscription=spec.oversubscription[0])]
                row = dict(model=m, bandwidth_gbps=bw, topology=topo)
                for ov in spec.oversubscription:
                    c = ix[_k(m, n, bw, tr, topo=topo, fabric="clos",
                              oversubscription=ov)]
                    row[f"oversub{ov:g}"] = c["scaling_factor"]
                    row[f"oversub{ov:g}_retention"] = (
                        c["scaling_factor"] / base["scaling_factor"])
                out.append(row)
    return out


def fig16_wan_loss_regimes(bws: Optional[Sequence[float]] = None,
                           schedulers: Optional[Sequence[str]] = None
                           ) -> List[Dict]:
    """Lossy-transport what-if: the paper's compression verdict re-derived
    under WAN loss.  Fig 8 concludes 2x-5x compression suffices at
    datacenter bandwidths — but its clean fluid link is exactly what the
    follow-up literature (Agarwal et al., Han et al.) shows is decisive:
    the utility judgment flips with the transport regime.  Rows come from
    the registered ``wan`` grid (the sweep the ``wan_suite`` golden
    artifact gates in CI): per (bandwidth, scheduler, loss profile) the
    int8 codec's t_sync against its codec=none twin.  On a lossy link
    every saved wire byte is saved ``1/(1-loss)`` times *and* shrinks the
    retransmission-stall exposure, so the compression-wins region only
    widens as loss grows — the regime boundary the grid's
    ``compression_wins_region_widens_with_loss`` validator pins."""
    spec = _grid("wan",
                 **({} if bws is None
                    else dict(bandwidth_gbps=tuple(float(b) for b in bws))),
                 **({} if schedulers is None
                    else dict(scheduler=tuple(schedulers))))
    ix = _cells(spec)
    n, tr, m = spec.n_servers[0], spec.transport[0], spec.models[0]
    # the loss ladder: clean link + the fixed-rtt profiles (the backoff
    # variants probe the stall model, not the compression regime)
    ladder = [p for p in spec.link_profile if "timeout" not in p]
    out = []
    for bw in spec.bandwidth_gbps:
        for s in spec.scheduler:
            for lp in ladder:
                base = ix[_k(m, n, bw, tr, sched=s, link_profile=lp)]
                comp = ix[_k(m, n, bw, tr, sched=s, codec="int8",
                             link_profile=lp)]
                out.append(dict(
                    model=m, bandwidth_gbps=bw, scheduler=s,
                    link_profile=lp,
                    t_sync_none=base["t_sync"],
                    t_sync_int8=comp["t_sync"],
                    int8_speedup=base["t_sync"] / max(comp["t_sync"], 1e-12),
                    compression_wins=comp["t_sync"] < base["t_sync"]))
    return out


def multirail_whatif(model: str = "resnet101", bandwidth_gbps: float = 100.0,
                     n_servers: int = 8, n_rails: int = 2,
                     scheduler: str = "fifo") -> Dict:
    """One-cell multi-rail comparison at equal aggregate bandwidth:
    ``n_rails`` rails of ``bandwidth/n_rails`` each versus one fat NIC.
    The direct-simulate twin of :func:`fig11_multirail` for exploration
    outside the registered grid."""
    n = n_servers * GPUS_PER_SERVER
    bw = bandwidth_gbps * GBPS
    tl = paper_timeline(model)
    one = simulate(tl, n_workers=n, bandwidth=bw, transport="horovod_tcp",
                   scheduler=scheduler)
    split = simulate(tl, n_workers=n, bandwidth=bw, transport="horovod_tcp",
                     scheduler=scheduler, n_rails=n_rails)
    return dict(model=model, bandwidth_gbps=bandwidth_gbps,
                scheduler=scheduler, n_rails=n_rails,
                one_nic=one.scaling_factor, multirail=split.scaling_factor,
                overhead_delta_ms=(split.t_overhead - one.t_overhead) * 1e3)


def contention_whatif(models: Sequence[str] = ("resnet50", "vgg16"),
                      bandwidth_gbps: float = 25.0, n_servers: int = 8,
                      scheduler: str = "fifo") -> List[Dict]:
    """Two training jobs sharing one link — the multi-tenant scenario the
    event engine's fair-share links make expressible.  Each job's scaling
    factor under contention vs running the link alone."""
    from repro.core.simulator import simulate_contention
    n = n_servers * GPUS_PER_SERVER
    bw = bandwidth_gbps * GBPS
    tls = [paper_timeline(m) for m in models]
    shared = simulate_contention(tls, n_workers=n, bandwidth=bw,
                                 scheduler=scheduler)
    out = []
    for tl, r in zip(tls, shared):
        alone = simulate(tl, n_workers=n, bandwidth=bw, scheduler=scheduler)
        out.append(dict(model=tl.name, bandwidth_gbps=bandwidth_gbps,
                        scheduler=scheduler, alone=alone.scaling_factor,
                        contended=r.scaling_factor,
                        slowdown=alone.scaling_factor / max(r.scaling_factor,
                                                            1e-12)))
    return out


def bytescheduler_whatif(model: str = "vgg16", bandwidth_gbps: float = 10.0,
                         n_servers: int = 8) -> Dict:
    """ByteScheduler's insight: transmit *front* layers first so the next
    iteration's forward pass can start before the sync finishes.  In the
    simulator this bounds the overhead by the sync tail that extends past
    the point where the front layers are available again — we approximate
    the benefit as overlapping the next forward with the remaining sync
    (the upper bound the paper suggests evaluating)."""
    tl = paper_timeline(model)
    base = simulate(tl, n_workers=n_servers * GPUS_PER_SERVER,
                    bandwidth=bandwidth_gbps * GBPS, transport="ideal")
    t_fwd = tl.t_batch - tl.t_back
    overhead_sched = max(0.0, base.t_overhead - t_fwd)
    f_sched = tl.t_batch / (tl.t_batch + overhead_sched)
    return dict(model=model, bandwidth_gbps=bandwidth_gbps,
                baseline=base.scaling_factor, bytescheduler_bound=f_sched)


# ---------------------------------------------------------------------------
# beyond-paper: the same analysis for the assigned TPU architectures
# ---------------------------------------------------------------------------

def tpu_whatif(cfg, shape, *, n_chips: int = 256, n_pods: int = 1,
               ici_gbps: float = 400.0, dcn_gbps: float = 200.0,
               mfu: float = 0.4, compression_ratio: float = 1.0,
               transport: str = "tpu_ici",
               data_parallel: Optional[int] = None) -> SimResult:
    """Paper's analysis transplanted to a v5e pod: is the ICI the bottleneck
    for data-parallel training of the assigned archs?

    ``data_parallel``: size of the gradient all-reduce group (defaults to 16,
    the production mesh's data axis); the model-parallel group accelerates
    per-layer compute instead.
    """
    from repro.core.timeline import from_transformer
    dp = data_parallel or 16
    mp = max(n_chips // dp // max(n_pods, 1), 1)
    tl = from_transformer(cfg, shape, mfu=mfu, n_chips_compute=mp,
                          grad_dtype_bytes=2)
    # per-replica gradient shard: model-parallel shards gradients mp-ways
    tl = GradTimeline(tl.name, tl.ready_times,
                      tuple(s / mp for s in tl.sizes), tl.t_back, tl.t_batch)
    return simulate(tl, n_workers=dp * max(n_pods, 1),
                    bandwidth=ici_gbps * GBPS, transport=transport,
                    addest=AddEst.tpu_v5e(),
                    compression_ratio=compression_ratio,
                    topology="hierarchical" if n_pods > 1 else "ring",
                    n_pods=max(n_pods, 1), dcn_bandwidth=dcn_gbps * GBPS)
