"""AddEst — the paper's element-wise vector-add timing model.

The paper measures vector-add latency on a V100 across sizes and linearly
interpolates.  We provide:

- ``AddEst.from_bandwidth``: analytic model ``t(x) = alpha + 3x / mem_bw``
  (read two operands + write one) — used with V100 (900 GB/s) for the
  faithful reproduction and with TPU v5e HBM (819 GB/s) for the TPU mode;
- ``AddEst.measure``: empirical measurement on the local host (jnp adds),
  mirroring the paper's white-box methodology, with linear interpolation
  between measured sizes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Sequence, Tuple

import numpy as np

V100_MEM_BW = 900e9
V100_LAUNCH_OVERHEAD = 5e-6      # CUDA kernel launch latency
TPU_V5E_MEM_BW = 819e9
TPU_LAUNCH_OVERHEAD = 1e-6


@dataclass(frozen=True)
class AddEst:
    """Piecewise-linear interpolated time (seconds) of adding two vectors of
    ``x`` bytes each."""

    sizes: Tuple[float, ...]          # bytes
    times: Tuple[float, ...]          # seconds

    def __call__(self, x: float) -> float:
        if x <= 0:
            return 0.0
        return float(np.interp(x, self.sizes, self.times))

    def batch(self, x: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`__call__` over a float64 size column.

        ``np.interp`` evaluates each element with the same compiled
        interpolation the scalar call uses, so ``batch(x)[i]`` is
        bit-identical to ``self(x[i])`` — the columnar lowering
        (:func:`repro.core.schedule.plan_to_flow_batch`) relies on this.
        """
        return np.where(x <= 0.0, 0.0, np.interp(x, self.sizes, self.times))

    # -- constructors --------------------------------------------------------

    @staticmethod
    def from_bandwidth(mem_bw: float, overhead: float = 0.0,
                       max_bytes: float = 1 << 33) -> "AddEst":
        sizes = np.logspace(0, np.log10(max_bytes), 64)
        times = overhead + 3.0 * sizes / mem_bw
        return AddEst(tuple(sizes), tuple(times))

    @staticmethod
    def v100() -> "AddEst":
        return AddEst.from_bandwidth(V100_MEM_BW, V100_LAUNCH_OVERHEAD)

    @staticmethod
    def tpu_v5e() -> "AddEst":
        return AddEst.from_bandwidth(TPU_V5E_MEM_BW, TPU_LAUNCH_OVERHEAD)

    @staticmethod
    def measure(sizes: Sequence[int] = (1 << 12, 1 << 16, 1 << 20, 1 << 23,
                                        1 << 26), repeats: int = 5) -> "AddEst":
        """Empirical local measurement (paper §3.1 methodology)."""
        import jax
        import jax.numpy as jnp

        add = jax.jit(lambda a, b: a + b)
        out_s, out_t = [], []
        for nbytes in sizes:
            n = max(nbytes // 4, 1)
            a = jnp.ones((n,), jnp.float32)
            b = jnp.ones((n,), jnp.float32)
            add(a, b).block_until_ready()          # warmup/compile
            t0 = time.perf_counter()
            for _ in range(repeats):
                add(a, b).block_until_ready()
            out_s.append(float(nbytes))
            out_t.append((time.perf_counter() - t0) / repeats)
        return AddEst(tuple(out_s), tuple(out_t))
