"""Layer-wise parameter/FLOP profiles of the paper's three workloads
(ResNet50, ResNet101, VGG16), generated analytically from the architectures.

The paper's what-if simulator only needs, per layer: gradient size (bytes)
and backward-completion timing.  Sizes come from exact parameter counts
(they reproduce the paper's 97/170/527 MB model sizes); timing distributes a
measured V100 batch time across layers proportional to conv FLOPs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple


@dataclass(frozen=True)
class LayerProfile:
    name: str
    params: int          # parameter count (fp32 gradients -> 4 bytes each)
    flops: int           # forward FLOPs per image


@dataclass(frozen=True)
class CNNProfile:
    name: str
    layers: Tuple[LayerProfile, ...]   # forward order
    t_batch_v100: float                # measured V100 batch-32 iteration (s)

    @property
    def total_params(self) -> int:
        return sum(l.params for l in self.layers)

    @property
    def total_bytes(self) -> int:
        return 4 * self.total_params

    @property
    def size_mib(self) -> float:
        return self.total_bytes / (1024.0 ** 2)


def _conv(name, cin, cout, k, hw, stride=1, bias=False) -> LayerProfile:
    out_hw = hw // stride
    params = k * k * cin * cout + (cout if bias else 0)
    flops = 2 * k * k * cin * cout * out_hw * out_hw
    return LayerProfile(name, params, flops)


def _bn(name, c) -> LayerProfile:
    return LayerProfile(name, 2 * c, 0)


def _fc(name, cin, cout) -> LayerProfile:
    return LayerProfile(name, cin * cout + cout, 2 * cin * cout)


# ---------------------------------------------------------------------------
# VGG16
# ---------------------------------------------------------------------------

_VGG_CFG = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
            512, 512, 512, "M", 512, 512, 512, "M"]


def vgg16() -> CNNProfile:
    layers: List[LayerProfile] = []
    cin, hw = 3, 224
    i = 0
    for v in _VGG_CFG:
        if v == "M":
            hw //= 2
            continue
        layers.append(_conv(f"conv{i}", cin, v, 3, hw, bias=True))
        cin = v
        i += 1
    layers.append(_fc("fc1", 512 * 7 * 7, 4096))   # the paper's ~400 MB layer
    layers.append(_fc("fc2", 4096, 4096))
    layers.append(_fc("fc3", 4096, 1000))
    # public V100 fp32 batch-32 training throughput ~170 img/s
    return CNNProfile("vgg16", tuple(layers), t_batch_v100=32 / 170.0)


# ---------------------------------------------------------------------------
# ResNet-50 / ResNet-101
# ---------------------------------------------------------------------------

def _bottleneck(layers, name, cin, width, hw, stride, downsample):
    cout = width * 4
    layers.append(_conv(f"{name}.conv1", cin, width, 1, hw))
    layers.append(_bn(f"{name}.bn1", width))
    layers.append(_conv(f"{name}.conv2", width, width, 3, hw, stride))
    layers.append(_bn(f"{name}.bn2", width))
    hw = hw // stride
    layers.append(_conv(f"{name}.conv3", width, cout, 1, hw))
    layers.append(_bn(f"{name}.bn3", cout))
    if downsample:
        layers.append(_conv(f"{name}.down", cin, cout, 1, hw * stride, stride))
        layers.append(_bn(f"{name}.down_bn", cout))
    return cout, hw


def _resnet(name: str, blocks: Tuple[int, ...], t_batch: float) -> CNNProfile:
    layers: List[LayerProfile] = []
    layers.append(_conv("conv1", 3, 64, 7, 224, 2))
    layers.append(_bn("bn1", 64))
    hw = 56                                   # after maxpool
    cin = 64
    for stage, n in enumerate(blocks):
        width = 64 * (2 ** stage)
        for b in range(n):
            stride = 2 if (b == 0 and stage > 0) else 1
            down = b == 0
            cin, hw = _bottleneck(layers, f"s{stage}.b{b}", cin, width, hw,
                                  stride, down)
    layers.append(_fc("fc", 2048, 1000))
    return CNNProfile(name, tuple(layers), t_batch)


def resnet50() -> CNNProfile:
    # public V100 fp32 batch-32 training throughput ~345 img/s
    return _resnet("resnet50", (3, 4, 6, 3), 32 / 345.0)


def resnet101() -> CNNProfile:
    # ~205 img/s
    return _resnet("resnet101", (3, 4, 23, 3), 32 / 205.0)


PROFILES = {"vgg16": vgg16, "resnet50": resnet50, "resnet101": resnet101}


def get_profile(name: str) -> CNNProfile:
    return PROFILES[name]()
