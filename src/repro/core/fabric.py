"""Datacenter fabric topology: hosts, racks, and oversubscribed uplinks.

The engine's links were born as one shared NIC per job mix; this module
describes the resource a real fleet contends for — a Clos-style fabric
where every host's NIC feeds a top-of-rack (ToR) switch whose uplink into
the spine is *oversubscribed*: ``hosts_per_tor`` NICs share an uplink
provisioned for ``hosts_per_tor / oversubscription`` of their aggregate
rate.  A collective's flows are lowered onto *paths* through that fabric
(:attr:`repro.core.events.FlowSpec.path`), and the engine prices them at
the bottleneck max-min fair share across every link crossed
(:func:`repro.core.events.maxmin_rates`).

Units follow the engine's convention: link capacities are NIC-relative
(the host NIC is 1.0), and a path link repeated ``m`` times encodes
demand multiplicity — the flow consumes ``m`` units of that link's
capacity per unit of rate.

**How collectives map onto the fabric.**  The simulator's representative
flow stands for one host's share of the collective, so the path is the
representative host's route and the multiplicities are how much of each
shared resource the *whole rack* pushes through it while the collective
runs:

- ``ring`` / ``tree``: workers are striped round-robin across racks, so
  every ring edge (or tree edge) crosses racks and all ``hosts_per_tor``
  hosts of the representative rack drive the uplink simultaneously —
  uplink multiplicity ``hosts_per_tor``, hence a lone collective runs at
  ``min(1, 1 / oversubscription)``.
- ``hierarchical``: the rack reduces locally over NICs first and only a
  leader crosses the spine — uplink multiplicity 1, so rack-local
  reduction rides out oversubscription until it exceeds
  ``hosts_per_tor``.

**The elision contract.**  Every flow crosses the NIC with multiplicity
1, so an uplink whose capacity/multiplicity ratio is at least the NIC's
(``uplink_capacity >= demand``) can never be the binding constraint — any
load pattern hits the NIC at least as hard.  :meth:`Fabric.path` drops
such uplinks, collapsing the path to ``(nic,)``; the engine then
normalizes the one-element path into a plain single-link flow and runs
the original code bit-for-bit.  A 1:1 fabric is therefore *bitwise*
identical to the flat topology, which is both the compatibility contract
and the ``fabric`` golden suite's 1:1-vs-flat validator.
"""
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

__all__ = ["Fabric", "resolve_fabric", "FABRICS", "DEFAULT_HOSTS_PER_TOR"]

#: Registered fabric names: ``none`` (flat single link — today's model)
#: and ``clos`` (racks of ``hosts_per_tor`` hosts behind oversubscribed
#: ToR uplinks).
FABRICS = ("none", "clos")

DEFAULT_HOSTS_PER_TOR = 4

_NIC_LINK = "nic"
_UPLINK = "up0"


@dataclass(frozen=True)
class Fabric:
    """A symmetric Clos pod: racks of hosts behind oversubscribed uplinks.

    Symmetry means one representative rack suffices: all racks see the
    same load, so the engine models a single NIC link (capacity 1.0, the
    existing default link) plus a single uplink ``up0`` of capacity
    ``hosts_per_tor / oversubscription``.  Co-scheduled jobs striped over
    the same racks share both, which is exactly the contention the
    max-min solve arbitrates.
    """

    hosts_per_tor: int = DEFAULT_HOSTS_PER_TOR
    oversubscription: float = 1.0
    nic: str = _NIC_LINK
    uplink: str = _UPLINK

    def __post_init__(self):
        if self.hosts_per_tor < 1:
            raise ValueError(f"hosts_per_tor must be >= 1, "
                             f"got {self.hosts_per_tor}")
        if self.oversubscription <= 0.0:
            raise ValueError(f"oversubscription must be > 0, "
                             f"got {self.oversubscription}")

    @property
    def uplink_capacity(self) -> float:
        """ToR uplink capacity in NIC units."""
        return self.hosts_per_tor / self.oversubscription

    def demand(self, topology: str) -> int:
        """Uplink multiplicity of one collective on the representative rack."""
        if topology == "hierarchical":
            return 1                 # only the rack leader crosses the spine
        return self.hosts_per_tor    # striped ring/tree: every host does

    def path(self, topology: str) -> Tuple[str, ...]:
        """The representative flow's route, with never-binding links elided.

        Returns ``(nic,)`` when the uplink can never be the bottleneck
        (capacity >= multiplicity: see the elision contract in the module
        docstring) — the engine then runs the flat single-link code
        bit-for-bit — and ``(nic, up0 * multiplicity)`` otherwise.
        """
        d = self.demand(topology)
        if self.uplink_capacity >= d:
            return (self.nic,)
        return (self.nic,) + (self.uplink,) * d

    def capacities(self) -> Dict[str, float]:
        """Engine capacity overrides (the NIC keeps its default 1.0)."""
        return {self.uplink: self.uplink_capacity}


def resolve_fabric(name: str, oversubscription: float = 1.0,
                   hosts_per_tor: int = DEFAULT_HOSTS_PER_TOR
                   ) -> Optional[Fabric]:
    """Build the named fabric, or ``None`` for the flat topology.

    ``none`` rejects a non-default oversubscription rather than silently
    ignoring it — there is no uplink to oversubscribe.
    """
    if name == "none":
        if oversubscription != 1.0:
            raise ValueError(
                "oversubscription requires a fabric (fabric='none' has no "
                f"uplink to oversubscribe, got {oversubscription})")
        return None
    if name != "clos":
        raise ValueError(f"unknown fabric {name!r}; expected one of "
                         f"{FABRICS}")
    return Fabric(hosts_per_tor=hosts_per_tor,
                  oversubscription=float(oversubscription))
