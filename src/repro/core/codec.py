"""Gradient-compression codecs as priced pipeline stages.

The paper's §3.2 what-if models compression as a byte divisor on the wire
term — ``compression_ratio`` in :mod:`repro.core.network_model`.  The
follow-up literature (Agarwal et al., "Beyond Throughput and Compression
Ratios") shows that shortcut flips the conclusion once encode/decode
compute enters the picture, so this module makes a codec a first-class
cost object: a wire ratio (what the bytes shrink to) **plus** calibrated
encode/decode compute costs, lowered by
:func:`repro.core.schedule.plan_to_flows` into an encode -> wire -> decode
pipeline per :class:`~repro.core.schedule.CommOp`.

Codecs (``name[:param]`` strings, parsed by :func:`get_codec`):

- ``none``         identity; zero cost, ratio 1 — bit-exact with a build
                   that never heard of codecs;
- ``ratio[:r]``    the *parametric byte divisor*: wire ratio ``r`` with
                   **zero** compute cost.  This is the deprecated
                   ``NetworkModel.compression_ratio`` float reborn as a
                   codec — legacy ``compression_ratio=r`` calls route
                   through it and reproduce bit-identically;
- ``int8``         per-256-block absmax int8 quantization — the Pallas
                   kernel pair ``quantize_int8_2d``/``dequantize_int8_2d``
                   in :mod:`repro.kernels.quantize`;
- ``ternary``      TernGrad ternarization (``ternarize_2d``), wire format
                   2 bits/element packed plus a per-block scale;
- ``topk[:r]``     DGC-style magnitude sparsification to a requested wire
                   ratio ``r`` (``topk_sparsify`` estimates the threshold
                   from samples), costs calibrated off the top-k kernel.

Cost model: encode/decode are element-wise streaming kernels, so their
device-scale cost follows the same analytic idiom as
:class:`~repro.core.addest.AddEst` — a kernel-launch overhead plus
*memory passes* over the gradient bytes at the modeled device's memory
bandwidth (V100, matching the paper's testbed).  The pass counts are
**measured**, not guessed: ``benchmarks/kernel_bench.py --calibrate``
times the real Pallas kernels against a same-tiling copy-kernel probe
(machine speed cancels in the ratio) and writes the committed calibration
table ``artifacts/bench/BENCH_codec.json``; CI re-derives the table in
``--quick`` interpret mode and fails on >2x drift or a kernel codec
missing from it.  :data:`FALLBACK_PASSES` embeds the committed numbers so
simulation is deterministic even without the artifact checkout (a test
pins the two sources equal).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, replace
from functools import lru_cache
from pathlib import Path
from typing import Dict, Optional, Tuple

from repro.core.addest import V100_LAUNCH_OVERHEAD, V100_MEM_BW

# wire formats, from the kernels' block layout (BLOCK = 256 f32 elements):
# int8 emits 256 int8 values + one f32 scale per block; ternary packs
# 2 bits/element + one f32 scale per block.
_BLOCK_BYTES = 256 * 4
INT8_WIRE_RATIO = _BLOCK_BYTES / (256 + 4)          # ~3.94x
TERNARY_WIRE_RATIO = _BLOCK_BYTES / (256 // 4 + 4)  # ~15.06x

# the calibration probe is a same-tiling Pallas copy kernel: one read +
# one write per byte, so one "pass" moves 2 bytes of memory traffic
PROBE_BYTES_PER_BYTE = 2.0

# error feedback (EF-SGD) keeps a per-bucket residual: the encoder reads
# gradient + residual, writes the compensated gradient, and writes the new
# residual back — ~3 extra probe-passes of streaming traffic per encode
ERROR_FEEDBACK_PASSES = 3.0

# Hivemind's size-adaptive idiom (SNIPPETS.md snippet 1): buckets at or
# above the threshold get the real codec, smaller ones go uncompressed
# (their wire time is negotiation-dominated; compute would be pure loss)
SIZE_ADAPTIVE = "size-adaptive"
SIZE_ADAPTIVE_THRESHOLD = float(2 ** 16 + 1)         # bytes

# committed calibration (see module docstring): probe-normalized memory
# passes per codec stage.  MUST stay equal to the ``codecs`` section of
# artifacts/bench/BENCH_codec.json — tests/test_codec.py pins it, and the
# CI calibration step gates the JSON against fresh kernel measurements.
FALLBACK_PASSES: Dict[str, Dict[str, float]] = {
    "int8": {"encode": 1.088, "decode": 0.304},
    "ternary": {"encode": 0.906, "decode": 0.232},
    "topk": {"encode": 0.939, "decode": 1.0},
}

TABLE_PATH = Path(__file__).resolve().parents[3] / "artifacts" / "bench" / \
    "BENCH_codec.json"


@lru_cache(maxsize=1)
def load_codec_table(path: Optional[str] = None) -> Dict[str, Dict[str, float]]:
    """The committed calibration table (pass counts per codec stage).

    Reads ``artifacts/bench/BENCH_codec.json`` when the repo checkout is
    present, else falls back to :data:`FALLBACK_PASSES` (pinned equal by
    test, so both paths price codecs identically)."""
    p = Path(path) if path else TABLE_PATH
    try:
        table = json.loads(p.read_text())["codecs"]
        return {k: {"encode": float(v["encode_passes"]),
                    "decode": float(v["decode_passes"])}
                for k, v in table.items()}
    except (OSError, KeyError, ValueError):
        return FALLBACK_PASSES


@dataclass(frozen=True)
class Codec:
    """A priced compression codec: wire ratio + calibrated compute passes.

    ``encode_passes``/``decode_passes`` are probe-normalized memory passes
    over the *uncompressed* bytes (see module docstring); the device-scale
    seconds come from :meth:`encode_seconds`/:meth:`decode_seconds` at the
    modeled V100 memory bandwidth, plus :attr:`launch_overhead` once per
    bucket per stage (charged by the lowering on each bucket's first
    chunk).  A ``free`` codec (both pass counts zero) is the legacy byte
    divisor and must leave the lowering's arithmetic untouched.
    """

    name: str                     # canonical instance name, e.g. "topk:8"
    kind: str                     # base codec: none|ratio|int8|ternary|topk
    wire_ratio: float             # uncompressed bytes / wire bytes
    encode_passes: float = 0.0    # probe-normalized memory passes
    decode_passes: float = 0.0
    mem_bw: float = V100_MEM_BW
    launch_overhead: float = V100_LAUNCH_OVERHEAD

    @property
    def is_free(self) -> bool:
        return self.encode_passes == 0.0 and self.decode_passes == 0.0

    def encode_seconds(self, nbytes: float) -> float:
        """Linear encode cost of ``nbytes`` uncompressed gradient bytes
        (launch overhead is charged separately, once per bucket).

        Pure scalar arithmetic, so a numpy array of sizes broadcasts
        elementwise — ``schedule.plan_to_flow_batch`` relies on that to
        price whole codec groups in one call, bit-identical to the
        per-op scalar calls."""
        return (self.encode_passes * PROBE_BYTES_PER_BYTE * nbytes
                / self.mem_bw)

    def decode_seconds(self, nbytes: float) -> float:
        return (self.decode_passes * PROBE_BYTES_PER_BYTE * nbytes
                / self.mem_bw)

    def with_error_feedback(self) -> "Codec":
        """EF-SGD residual accumulation: extra encode-side memory traffic."""
        if self.is_free:
            raise ValueError(
                f"error feedback needs a lossy codec, got {self.name!r} "
                f"(the free byte divisor has no residual to feed back)")
        return replace(self, name=self.name + "+ef",
                       encode_passes=self.encode_passes
                       + ERROR_FEEDBACK_PASSES)


NONE_CODEC = Codec(name="none", kind="none", wire_ratio=1.0)


def parse_codec(spec: str) -> Tuple[str, Optional[float]]:
    """``"name[:param]"`` -> ``(name, param-or-None)``."""
    if ":" in spec:
        base, _, raw = spec.partition(":")
        try:
            return base, float(raw)
        except ValueError:
            raise ValueError(f"bad codec parameter in {spec!r}") from None
    return spec, None


def get_codec(spec: str, *, compression_ratio: float = 1.0,
              table: Optional[Dict[str, Dict[str, float]]] = None) -> Codec:
    """Resolve a codec string (plus the legacy ``compression_ratio`` float)
    into a priced :class:`Codec`.

    - ``none`` with ``compression_ratio != 1`` routes through the
      parametric ``ratio`` codec (zero compute) — the deprecated
      ``NetworkModel.compression_ratio`` path, bit-identical by
      construction since the ratio float lands unchanged in the cost
      model;
    - ``ratio``/``topk`` take their ratio from the ``:param`` suffix, or
      fall back to ``compression_ratio``;
    - fixed-format codecs (``int8``, ``ternary``) refuse a non-unit
      ``compression_ratio`` — their wire ratio is intrinsic.
    """
    base, param = parse_codec(spec)
    passes = table if table is not None else load_codec_table()

    def _kernel(kind: str, ratio: float, name: str) -> Codec:
        p = passes[kind]
        return Codec(name=name, kind=kind, wire_ratio=float(ratio),
                     encode_passes=p["encode"], decode_passes=p["decode"])

    if base == "none":
        if param is not None:
            raise ValueError(f"codec 'none' takes no parameter: {spec!r}")
        if compression_ratio != 1.0:
            return Codec(name=f"ratio:{compression_ratio:g}", kind="ratio",
                         wire_ratio=float(compression_ratio))
        return NONE_CODEC
    if base == "ratio":
        r = param if param is not None else compression_ratio
        return Codec(name=f"ratio:{r:g}", kind="ratio", wire_ratio=float(r))
    if base in ("int8", "ternary"):
        if param is not None:
            raise ValueError(f"codec {base!r} takes no parameter: {spec!r}")
        if compression_ratio != 1.0:
            raise ValueError(
                f"codec {base!r} has an intrinsic wire ratio; it does not "
                f"compose with compression_ratio={compression_ratio:g}")
        ratio = INT8_WIRE_RATIO if base == "int8" else TERNARY_WIRE_RATIO
        return _kernel(base, ratio, base)
    if base == "topk":
        r = param if param is not None else compression_ratio
        return _kernel("topk", r, f"topk:{r:g}")
    known = "none, ratio[:r], int8, ternary, topk[:r], " + SIZE_ADAPTIVE
    raise ValueError(f"unknown codec {spec!r}; known: {known}")


# ---------------------------------------------------------------------------
# regime classification (fig13)
# ---------------------------------------------------------------------------

REGIME_WINS = "wins"
REGIME_LOSES = "loses"
REGIME_PURE_OVERHEAD = "pure-overhead"
REGIME_NEUTRAL = "neutral"

# baseline overhead below this fraction of t_batch means there was nothing
# for compression to win (the paper's "no compression needed at 100 Gbps")
_NOTHING_TO_WIN = 0.01


def classify_regime(overhead_codec: float, overhead_none: float,
                    t_batch: float, codec_compute: float,
                    eps: float = 1e-6) -> str:
    """fig13's cell classification: does compression *win*, *lose*, or is
    it *pure overhead* against the same cell run uncompressed?

    - ``pure-overhead``: the baseline was already compute-bound (overhead
      under 1% of t_batch), so there was nothing for the wire savings to
      buy and the encode/decode compute is dead weight — this is checked
      *first*, so a micro-delta on a negligible baseline never counts as
      a win or a loss;
    - ``wins``: the codec materially reduced a real t_overhead (by more
      than 1% of it);
    - ``loses``: the codec's compute outweighed its wire savings;
    - ``neutral``: nothing material changed (e.g. free codecs).
    """
    if overhead_none <= _NOTHING_TO_WIN * t_batch:
        return REGIME_PURE_OVERHEAD if codec_compute > 0.0 else REGIME_NEUTRAL
    margin = max(eps, 0.01 * overhead_none)
    if overhead_codec < overhead_none - margin:
        return REGIME_WINS
    if overhead_codec > overhead_none + margin:
        return REGIME_LOSES
    return REGIME_PURE_OVERHEAD if codec_compute > 0.0 else REGIME_NEUTRAL
