"""Discrete-event network engine: link resources with fair-share bandwidth.

The serialized bucket loop the simulator used to hard-code is one point in
a much larger scheduling space.  This engine executes *flows* — wire
transfers with a fixed post-wire latency (the reduction/vector-add phase of
a collective) — against named link resources:

- **links** split their bandwidth fairly among concurrent flows (progressive
  filling: each of the k active flows progresses at 1/k of full rate), which
  is what makes multi-job contention expressible;
- **jobs** serialize their own flows (one wire in flight per job): a ring
  all-reduce occupies the full NIC, so intra-job concurrency happens at
  chunk granularity via the scheduler that *ordered* the flows, not via the
  link;
- a job admits its highest-priority ready flow whenever it is free; a flow
  with ``hold=True`` keeps the job busy through its latency (Horovod's
  serialized all-reduce process), otherwise the job frees at wire end and
  the latency overlaps the next flow's transmission (pipelined chunks).

Exactness: a ``hold`` flow whose wire phase never shared its link completes
at ``start + duration`` with ``duration`` precomputed by the caller as a
single float expression — so the ``fifo`` schedule reproduces the legacy
serialized loop bit-for-bit, not just within tolerance.  A flow counts as
``contended`` only if it shared its link for a *nonzero* duration; the seed
engine also flagged zero-duration overlaps (two flows co-admitted at an
instant where one has zero residual work), which changed no completion time
beyond re-rounding but cosmetically dropped the closed form.

Times in seconds; ``work`` is wire time at full link rate (the caller bakes
bandwidth into it via the cost model).

Engine architecture (the O((n+e) log n) event calendar)
-------------------------------------------------------

The seed implementation rescanned every pending/running flow at every event
and advanced all wires step by step — quadratic once plans reach thousands
of flows.  This version is indexed end to end:

- **per-job admission state**: flows sort once into service order
  ``(priority, op_id)``.  When ready times are non-decreasing along that
  order (fifo/chunked plans), the next admissible flow is a pointer
  increment; otherwise (priority plans, where late-flushed buckets preempt)
  the job keeps a ready-time heap of *gated* flows plus a priority heap of
  admissible ones, so an admission is O(log n) instead of a rescan.
- **per-link fluid service clocks**: all flows on a link progress at the
  same fair share, so in link-service time a flow admitted when the link
  had delivered ``S`` per-flow seconds completes at exactly ``S + work`` —
  a *static* order.  Each link keeps a heap of these completion marks;
  membership changes rescale only the rate at which the clock advances,
  never the order, so projections are recomputed only when a link's
  membership (and hence share) changes, and only for the heap top.
- **versioned calendar entries**: the global ``heapq`` calendar holds each
  link's next projected completion stamped with the link's membership
  version, plus per-job admission triggers.  A membership change bumps the
  version; stale entries are lazily discarded on pop rather than searched
  for and removed.
- **completion spin + bulk commit**: when a link's next completion precedes
  everything else on the calendar, completions are served in a tight loop
  without calendar round-trips; and while membership is *constant* (every
  completion instantly re-admits the job's next flow), each job's future
  completion marks are plain prefix sums of its works, so whole saturated
  stretches are computed with vectorized numpy cumulative sums and
  committed in one pass, up to the first membership-changing boundary
  (ready gate, ``hold`` flow, job exhaustion, or calendar interrupt).
  Completion *times* for the merged stretch are produced by the same
  chained left fold the scalar spin performs — one ``np.cumsum`` over the
  per-step deltas of the (mark, flow)-sorted merge of every job's chain —
  so bulk-committed results are **bit-identical** to the scalar event
  loop, not merely within tolerance.
- **heap-mode resolved prefix**: a priority-scheduled job (ready times
  regress along service order) cannot expose a pointer chain, but its
  *ready frontier* is still a resolved sequence: sorting the admissible
  heap by ``(priority, op_id)`` yields exactly the order the scalar loop
  would pop, valid until the next gated flow's ready time (the *gating
  boundary*) is crossed.  Heap-mode jobs therefore contribute that sorted
  prefix to the bulk chain decomposition, with the gating boundary folded
  into the job's violation point; the sorted suffix left after a commit
  is itself a valid heap, so no re-heapify is needed.
- **small-plan setup**: the columnar numpy views that pay for themselves on
  thousand-flow plans cost more than the whole event loop on the two-dozen-
  op plans the paper grids generate, so below
  :data:`_SMALL_PLAN_MAX_FLOWS` the setup runs on plain lists and the bulk
  commit (which needs the arrays, and can never engage on single-job plans
  anyway) is skipped.  The scalar event loop is identical either way, so
  single-job results are bit-identical across the two setups.

Termination is progress-based: the engine raises only when the calendar
drains with flows outstanding, or when event processing stops advancing
time, admitting, or completing — not on an iteration-count heuristic, which
could false-trip on heavily contended multi-job plans.

Multi-rail links
----------------

A physical NIC with ``r`` rails is ``r`` independent fluid links that
happen to share a name: ``NetworkEngine(rails={"nic": r})`` turns the named
link into a :class:`_LinkSet` of ``r`` per-rail service clocks, and each
flow's ``rail`` field selects which clock serves it (rail selection is part
of the *plan* — see :func:`repro.core.schedule.assign_rails` — so the
engine stays deterministic and a one-rail plan is bit-exact with a plain
link).  Rails do not fair-share with each other: contention is per rail,
which is exactly what distinguishes a 2x50G multi-rail host from a single
100G NIC.  The caller models per-rail bandwidth by scaling ``work`` (see
``plan_to_flows(..., n_rails=...)``).
"""
from __future__ import annotations

from heapq import heapify, heappop, heappush
from typing import Dict, List, NamedTuple, Optional, Sequence

import numpy as np

DEFAULT_LINK = "nic"
DEFAULT_JOB = "job0"

_DONE, _ADMIT = 0, 1       # calendar event kinds; completions sort first
_INF = float("inf")


class FlowSpec(NamedTuple):
    """One wire transfer plus a fixed post-wire latency.

    The engine's unit of work: the schedule layer lowers every
    :class:`~repro.core.schedule.CommOp` to exactly one ``FlowSpec``.

    - ``op_id`` identifies the flow in results (results come back in input
      order, but ``op_id`` survives any caller-side regrouping);
    - ``ready`` is the earliest admission time (the bucket's flush time,
      possibly perturbed by :func:`perturb_flows`);
    - ``work`` is wire seconds *at full link rate* — the caller bakes
      bandwidth into it via the cost model, so a rail at 1/n of the
      aggregate bandwidth simply carries ``n`` times the work;
    - ``latency`` is the fixed post-wire phase (vector adds + negotiation)
      that does not scale under link sharing;
    - ``priority`` orders admission within a job (smaller first; ties broken
      by ``op_id``);
    - ``job`` names the serialization resource (one wire in flight per job);
    - ``link``/``rail`` name the bandwidth resource: ``rail`` selects the
      per-rail service clock when the engine was built with
      ``rails={link: n}``, and is ignored (must be 0) otherwise;
    - ``hold`` keeps the job busy through the latency (Horovod's serialized
      all-reduce); ``duration``, when given, must equal ``work + latency``
      up to the caller's own float rounding — it is used verbatim for the
      closed-form uncontended completion of ``hold`` flows, which is what
      makes the fifo schedule bit-exact with the legacy serialized loop.
    """

    op_id: int
    ready: float                     # earliest admission time
    work: float                      # wire seconds at full link rate
    latency: float = 0.0             # fixed post-wire time (reduction etc.)
    priority: float = 0.0
    job: str = DEFAULT_JOB
    link: str = DEFAULT_LINK
    hold: bool = False               # job held busy through the latency
    duration: Optional[float] = None  # precomputed work+latency (hold flows)
    rail: int = 0                    # which rail of a multi-rail link


class FlowResult(NamedTuple):
    """Execution record of one flow, in the input list's order.

    ``start`` is the admission time (wire begins), ``wire_end`` when the
    link was released, ``end`` when the post-wire latency finished.
    ``contended`` is True only if the wire phase shared its link (or rail)
    for a *nonzero* duration — uncontended flows take exact closed forms,
    so ``start + work == wire_end`` bit-for-bit.
    """

    op_id: int
    job: str
    start: float                     # admission (wire begins)
    wire_end: float                  # link released
    end: float                       # wire + latency complete
    contended: bool                  # wire phase ever shared its link

    @property
    def occupancy(self) -> float:
        """Time this flow kept its serialization resource busy."""
        return self.end - self.start


def perturb_flows(flows: Sequence[FlowSpec], jitter: float, seed: int,
                  stream: int = 0) -> List[FlowSpec]:
    """Seeded straggler model: delay every flow's ``ready`` time.

    Each flow's flush is pushed back by an independent exponential draw
    with mean ``jitter`` seconds — the long-tailed per-flow perturbation
    that models slow workers, GC pauses, and negotiation stalls jittering
    bucket flush times.  Determinism contract:

    - the draws depend only on ``(seed, stream, len(flows))`` — never on
      process, thread, or global RNG state — so artifacts are bit-identical
      across executors (``stream`` separates jobs in a contention scenario
      so co-located jobs straggle independently);
    - with a fixed seed the delays scale *linearly* in ``jitter``
      (``jitter * standard_exponential``), so a swept jitter axis moves
      every ready time monotonically — the straggler grid's
      ``t_sync`` monotonicity validator rests on this;
    - ``jitter <= 0`` returns the flows unchanged (same objects), keeping
      the zero-jitter path bit-exact with a run that never heard of jitter.
    """
    if jitter <= 0.0 or not flows:
        return list(flows)
    rng = np.random.default_rng(
        np.random.SeedSequence(entropy=int(seed), spawn_key=(int(stream),)))
    delays = (jitter * rng.standard_exponential(len(flows))).tolist()
    return [f._replace(ready=f.ready + d) for f, d in zip(flows, delays)]


class _Link:
    """Fluid fair-share link: a service clock plus a completion-mark heap.

    ``S`` is the per-flow service delivered since the link last went idle;
    a flow admitted at service mark ``S`` completes when the clock reaches
    ``S + work``.  ``version`` stamps calendar entries for lazy
    invalidation on membership changes.
    """

    __slots__ = ("cap", "n", "share", "S", "t_last", "heap", "version",
                 "all_contended", "bulk_cap", "bulk_skip")

    def __init__(self, cap: float):
        self.cap = cap
        self.n = 0
        self.share = 1.0 if cap >= 1.0 else cap
        self.S = 0.0
        self.t_last = 0.0
        self.heap: List = []        # (service completion mark, flow index)
        self.version = 0
        self.all_contended = False
        # adaptive per-call chain cap for the bulk path: grows with actual
        # commit sizes so short windows (dense jittered ready gates) pay
        # O(committed), not O(remaining), per call
        self.bulk_cap = 64
        # back-off: after a weak commit or a gate rejection, let this many
        # completions go scalar before attempting bulk again — a window
        # too small to amortize the numpy setup is served cheaper event by
        # event, and a large window only ever waits O(skip) scalar events
        self.bulk_skip = 0


class _LinkSet:
    """One named multi-rail link: ``n_rails`` independent per-rail clocks.

    Every rail is a full :class:`_Link` (its own fluid service clock,
    completion heap, and membership version); flows are routed to
    ``rails[flow.rail]`` at setup, after which the event loop sees only
    plain links.  Rails therefore never fair-share with each other — the
    defining property of a multi-rail NIC versus one fat link.
    """

    __slots__ = ("rails",)

    def __init__(self, cap: float, n_rails: int):
        self.rails = [_Link(cap) for _ in range(n_rails)]


class _Job:
    """Serialization resource: one wire in flight, priority admission."""

    __slots__ = ("order", "rdy", "ptr", "gated", "gptr", "g_rd", "readyq",
                 "n_ready", "free", "busy", "link", "onp", "wk", "rd", "hd",
                 "lt")

    def __init__(self):
        self.order: List[int] = []   # flow indices in (priority, op_id) order
        self.rdy: List[float] = []   # ready times along ``order`` (ptr mode)
        self.ptr = 0
        # heap mode — two representations behind one mode flag
        # (``gated is None`` still means pointer mode):
        #
        # * small plans: ``gated`` is a build-once list of (ready, priority,
        #   op_id, idx) tuples sorted by ready; flows only ever *leave* it,
        #   so a pointer (``gptr``) replaces a heap and draining is a
        #   slice.  ``readyq`` is the classic (priority, op_id, idx) heap.
        # * columnar plans: ``gated`` is the position-into-``order`` array
        #   sorted by ready (``g_rd`` holds the sorted ready times), and
        #   ``readyq`` is a boolean *mask* over ``order`` positions.  The
        #   admissible set in service order is just ``nonzero(mask)`` — the
        #   bulk path's resolved prefix — a drain is one sliced scatter,
        #   and a scalar pop is ``argmax(mask)`` (``order`` is sorted by
        #   (priority, op_id), so the first set bit is the best flow).
        self.gated = None
        self.gptr = 0
        self.g_rd = None
        self.readyq = None
        self.n_ready = 0
        self.free = 0.0
        self.busy = False
        self.link: Optional[_Link] = None   # sole link, if homogeneous
        # numpy views along ``order`` for the bulk-commit path (lazy)
        self.onp = self.wk = self.rd = self.hd = self.lt = None


# below this many flows the engine skips its columnar numpy setup (and the
# bulk-commit path that needs it): asarray/lexsort/zeros dominate the whole
# event loop on the two-dozen-op plans the paper grids generate, while the
# bulk path only ever engages on contended multi-job plans far above this
_SMALL_PLAN_MAX_FLOWS = 64

# bulk commit engages once a link serves at least this many concurrent
# flows; tests raise it to infinity to force the scalar path (bulk must be
# bit-identical, so the knob is a dispatch threshold, not a semantic one)
_BULK_MIN_ACTIVE = 2

# hard upper bound on a bulk call's per-job candidate chain (the adaptive
# per-link cap never exceeds it): bounds the numpy work a short commit
# window can waste on chains it will not commit; correctness is unaffected
# — a capped chain just ends in an artificial boundary and the next call
# continues the same cumsum bit-exactly
_BULK_CHAIN_CAP = 2048

# drains of this many newly-ready flows rebuild the admissible heap with
# one extend+heapify instead of per-item pushes (same pop order: a heap's
# pop sequence is the sorted multiset regardless of internal layout)
_DRAIN_BATCH_MIN = 16

# stall detection: the engine raises after this many consecutive
# no-progress calendar pops (stale projections / superseded admissions);
# the counter resets on any committed work — an admission, a served
# completion, or a bulk commit.  Module-level so tests can tighten them.
_STALL_FACTOR = 4
_STALL_BASE = 1000


class NetworkEngine:
    """Event-calendar executor for a set of flows over shared links.

    ``capacities`` maps link name -> number of flows that can run at full
    rate before fair sharing kicks in (default 1.0 — the whole link).
    ``rails`` maps link name -> rail count: a name with ``n > 1`` becomes a
    :class:`_LinkSet` of ``n`` independent per-rail service clocks and each
    flow's ``rail`` field selects its clock (modulo ``n``).  Links absent
    from ``rails`` (or mapped to 1) behave exactly as before, bit-for-bit.
    """

    def __init__(self, capacities: Optional[Dict[str, float]] = None,
                 rails: Optional[Dict[str, int]] = None):
        self.capacities = dict(capacities or {})
        self.rails = dict(rails or {})

    def run(self, flows: Sequence[FlowSpec]) -> List[FlowResult]:
        """Execute ``flows``; returns results in input order."""
        n_total = len(flows)
        if not n_total:
            return []
        caps = self.capacities
        small = n_total < _SMALL_PLAN_MAX_FLOWS

        # -- setup: columnar views, grouping, service order, mode -----------
        (op_col, rdy_col, wk_col, lt_col, pr_col, job_col, lk_col, hd_col,
         _du_col, rl_col) = zip(*flows)

        rail_counts = self.rails
        if rail_counts and any(rail_counts.get(nm, 1) > 1
                               for nm in set(lk_col)):
            sets = {nm: _LinkSet(caps.get(nm, 1.0),
                                 max(rail_counts.get(nm, 1), 1))
                    for nm in set(lk_col)}
            link_of = [sets[nm].rails[r % len(sets[nm].rails)]
                       for nm, r in zip(lk_col, rl_col)]
            one_link = sum(len(s.rails) for s in sets.values()) == 1
        else:
            links: Dict[str, _Link] = {
                nm: _Link(caps.get(nm, 1.0)) for nm in set(lk_col)}
            link_of = list(map(links.__getitem__, lk_col))
            one_link = len(links) == 1

        by_job: Dict[str, List[int]] = {}
        for i, name in enumerate(job_col):
            try:
                by_job[name].append(i)
            except KeyError:
                by_job[name] = [i]
        jobs: Dict[str, _Job] = {name: _Job() for name in by_job}
        job_of = list(map(jobs.__getitem__, job_col))

        if small:
            pr_np = op_np = rd_np = None
        else:
            pr_np = np.asarray(pr_col)
            op_np = np.asarray(op_col)
            rd_np = np.asarray(rdy_col)
        g_wk = g_hd = g_lt = None           # global columns (lazy, for bulk)

        cal: List = []              # (time, kind, seq, ...) event calendar
        seq = 0
        for name, idxs in by_job.items():
            jb = jobs[name]
            if small:
                # plain-list service order: identical (priority, op_id)
                # total order, without paying numpy's fixed costs
                if len(idxs) > 1:
                    idxs.sort(key=lambda i: (pr_col[i], op_col[i]))
                order = jb.order = idxs
                rdy = jb.rdy = [rdy_col[i] for i in order]
                monotone = all(a <= b for a, b in zip(rdy, rdy[1:]))
            else:
                ix = np.asarray(idxs, dtype=np.intp)
                if ix.shape[0] > 1:
                    ix = ix[np.lexsort((op_np[ix], pr_np[ix]))]
                order = jb.order = ix.tolist()
                rd_ix = rd_np[ix]
                rdy = jb.rdy = rd_ix.tolist()
                monotone = (len(rdy) == 1
                            or bool((rd_ix[1:] >= rd_ix[:-1]).all()))
            first = link_of[order[0]]
            jb.link = first if one_link or all(link_of[i] is first
                                               for i in order) else None
            if monotone:
                trigger = rdy[0]
            else:
                # ready times regress along service order (e.g. priority
                # plans): gate admissions on ready order.  ``order`` is
                # already (priority, op_id)-sorted, so sorting *positions*
                # stably by ready yields (ready, priority, op_id) order.
                if small:
                    jb.gated = sorted((rdy_col[i], pr_col[i], op_col[i], i)
                                      for i in order)
                    jb.readyq = []
                    trigger = jb.gated[0][0]
                else:
                    g_pos = np.argsort(rd_ix, kind="stable")
                    jb.gated = g_pos
                    jb.g_rd = rd_ix[g_pos]
                    jb.readyq = np.zeros(len(order), dtype=bool)
                    trigger = float(jb.g_rd[0])
            seq += 1
            cal.append((trigger if trigger > 0.0 else 0.0, _ADMIT, seq, jb))
        heapify(cal)                # one pass beats n pushes at setup

        if small:
            start: List[float] = [0.0] * n_total
            wire: List[float] = [0.0] * n_total
            end: List[float] = [0.0] * n_total
            contended: List[bool] = [False] * n_total
        else:
            start = np.zeros(n_total)
            wire = np.zeros(n_total)
            end = np.zeros(n_total)
            contended = np.zeros(n_total, dtype=bool)
        n_done = 0
        stale = 0                   # consecutive no-progress calendar pops
        stall_limit = _STALL_FACTOR * n_total + _STALL_BASE
        sweep_at = 256              # calendar size that triggers a compaction
        flws = flows                # local alias for the hot loops

        # -- admission: put flow ``i`` on its link at time ``t`` ------------
        def _admit(i: int, jb: _Job, t: float) -> _Link:
            nonlocal stale
            stale = 0               # an admission is committed work
            L = link_of[i]
            if L.n:
                if t > L.t_last:
                    L.S += (t - L.t_last) * L.share
                L.t_last = t
                contended[i] = True
                if not L.all_contended:
                    for _, k in L.heap:
                        contended[k] = True
                    L.all_contended = True
            else:
                # fresh busy period: restart the service clock so the
                # single-flow closed form stays exact (mark == work)
                L.S = 0.0
                L.t_last = t
                if L.cap < 1.0:
                    contended[i] = True
                    L.all_contended = True
            heappush(L.heap, (L.S + wk_col[i], i))
            L.n += 1
            c = L.cap
            L.share = 1.0 if c >= L.n else c / L.n
            L.version += 1
            start[i] = t
            jb.busy = True
            return L

        # -- next-admission trigger for a job that just freed ---------------
        def _schedule_admit(jb: _Job, t: float) -> None:
            nonlocal seq
            if jb.gated is None:
                if jb.ptr < len(jb.order):
                    trig = jb.rdy[jb.ptr]
                    if trig < jb.free:
                        trig = jb.free
                    seq += 1
                    heappush(cal, (trig, _ADMIT, seq, jb))
            else:
                if small:
                    have_ready = bool(jb.readyq)
                    nxt = jb.gated[jb.gptr][0] \
                        if jb.gptr < len(jb.gated) else None
                else:
                    have_ready = jb.n_ready > 0
                    nxt = float(jb.g_rd[jb.gptr]) \
                        if jb.gptr < jb.g_rd.shape[0] else None
                if have_ready:
                    seq += 1
                    heappush(cal, (jb.free, _ADMIT, seq, jb))
                elif nxt is not None:
                    trig = nxt if nxt > jb.free else jb.free
                    seq += 1
                    heappush(cal, (trig, _ADMIT, seq, jb))

        # -- heap mode: move gated flows with ready <= t to the admissible
        # set.  Draining earlier than the next service event is sound: any
        # scalar drain happens at a service time t' >= t and moves a
        # superset, and pops always consider the whole admissible set.
        if small:
            def _drain(jb: _Job, t: float) -> None:
                g = jb.gated
                gp = jb.gptr
                ng = len(g)
                if gp >= ng or g[gp][0] > t:
                    return
                j = gp + 1
                while j < ng and g[j][0] <= t:
                    j += 1
                rq = jb.readyq
                if j - gp >= _DRAIN_BATCH_MIN:
                    # bulk heappush: one heapify over the merged contents
                    rq.extend((pr, op, i) for _r, pr, op, i in g[gp:j])
                    heapify(rq)
                else:
                    for _r, pr, op, i in g[gp:j]:
                        heappush(rq, (pr, op, i))
                jb.gptr = j
        else:
            def _drain(jb: _Job, t: float) -> None:
                gp = jb.gptr
                grd = jb.g_rd
                if gp >= grd.shape[0] or grd[gp] > t:
                    return
                j = int(grd.searchsorted(t, side="right"))
                jb.readyq[jb.gated[gp:j]] = True   # one sliced scatter
                jb.n_ready += j - gp
                jb.gptr = j

        # -- bulk commit: vectorized saturated stretch on link ``L`` --------
        def _try_bulk(L: _Link, t0: float) -> int:
            """While every completion instantly re-admits (constant
            membership, constant share), each job's future completion marks
            are prefix sums of its works — a pointer-mode job's marks walk
            ``order[ptr:]``, a heap-mode job's walk its *resolved prefix*
            (the admissible mask in (priority, op_id) order, valid until
            the next gated ready time).  The per-job chains merge into one
            (mark, flow)-sorted sequence whose completion times are a
            single chained left fold — the exact float operations the
            scalar spin performs, so bulk commits are bit-identical to
            scalar processing.  Every completion strictly before the first
            boundary (ready gate, gating boundary, hold flow, chain cap,
            or foreign calendar event) commits in one vectorized pass.
            Returns the number of flows committed."""
            nonlocal n_done, g_wk, g_hd, g_lt, stale
            S0 = L.S
            share = L.share
            # drop lazily-invalidated projections so a stale early entry
            # cannot mask how far the bulk window really extends
            while cal and cal[0][1] == _DONE and cal[0][3] != cal[0][4].version:
                heappop(cal)
            t_cal = cal[0][0] if cal else _INF
            # O(1) pre-checks on the earliest completion: if its own job
            # cannot instantly re-admit, the very first completion is a
            # boundary and nothing can commit
            m_top, i_top = L.heap[0]
            t_first = t0 + (m_top - S0) / share
            if t_cal <= t_first:
                return 0
            jb_top = job_of[i_top]
            if hd_col[i_top]:
                return 0
            if jb_top.gated is None:
                p = jb_top.ptr
                if p >= len(jb_top.order) or jb_top.rdy[p] > t_first:
                    return 0
            else:
                _drain(jb_top, t0)
                if not jb_top.n_ready:
                    return 0
            # every heap-mode job's gating boundary caps the whole window
            # (commits stop at the earliest gate), so if any gate precedes
            # the first completion the call cannot commit — an O(jobs)
            # rejection that keeps gate-dense phases (jittered plans) cheap
            for _m_x, i_x in L.heap:
                jx = job_of[i_x]
                if jx.gated is not None:
                    _drain(jx, t0)
                    if (jx.gptr < jx.g_rd.shape[0]
                            and jx.g_rd[jx.gptr] <= t_first):
                        L.bulk_skip = 4     # locally gate-dense: go scalar
                        return 0
            if g_wk is None:
                g_wk = np.asarray(wk_col)
                g_hd = np.asarray(hd_col, dtype=bool)
                g_lt = np.asarray(lt_col)
            # no mark beyond this can commit (commit times are < t_cal), so
            # chains truncate here before the merge sort — a truncation is
            # just an earlier artificial boundary, never an arithmetic
            # change, and the next call continues the same cumsum exactly
            mark_limit = S0 + (t_cal - t0) * share
            chains = []
            mark_segs = []
            id_segs = []
            for m0, i0 in L.heap:
                jb = job_of[i0]
                if jb.link is not L:
                    return 0
                if jb.wk is None:
                    onp = jb.onp = np.asarray(jb.order, dtype=np.intp)
                    jb.wk = g_wk[onp]
                    jb.rd = rd_np[onp]
                    jb.hd = g_hd[onp]
                    jb.lt = g_lt[onp]
                kcap = L.bulk_cap
                if jb.gated is None:
                    ptr = jb.ptr
                    k = len(jb.order) - ptr
                    if k > kcap:
                        k = kcap
                    ids = np.empty(k + 1, dtype=np.intp)
                    ids[0] = i0
                    ids[1:] = jb.onp[ptr:ptr + k]
                    marks = np.empty(k + 1)
                    marks[0] = m0
                    marks[1:] = jb.wk[ptr:ptr + k]
                    pos = None
                else:
                    # resolved prefix: the admissible mask in service order
                    # (this job was already drained by the gate pre-check)
                    pos = jb.readyq.nonzero()[0]
                    k = pos.shape[0]
                    if k > kcap:
                        k = kcap
                        pos = pos[:k]
                    ids = np.empty(k + 1, dtype=np.intp)
                    ids[0] = i0
                    ids[1:] = jb.onp[pos]
                    marks = np.empty(k + 1)
                    marks[0] = m0
                    marks[1:] = jb.wk[pos]
                marks = marks.cumsum()          # exact left fold, like scalar
                if marks.shape[0] > 8:
                    kk = int(marks.searchsorted(mark_limit,
                                                side="right")) + 2
                    if kk < marks.shape[0]:
                        marks = marks[:kk]
                        ids = ids[:kk]
                        if pos is not None:
                            pos = pos[:kk - 1]
                chains.append((jb, m0, i0, marks, ids, pos))
                mark_segs.append(marks)
                id_segs.append(ids)
            # merge all chains into global service order (ties break on the
            # flow index, exactly like the link heap's (mark, i) tuples),
            # then chain completion times with the scalar spin's own
            # arithmetic: t_{j} = t_{j-1} + (m_j - m_{j-1}) / share
            M = np.concatenate(mark_segs)
            I = np.concatenate(id_segs)
            order_g = np.lexsort((I, M))
            Ms = M[order_g]
            d = np.empty_like(Ms)
            d[0] = t_first
            if Ms.shape[0] > 1:
                d[1:] = (Ms[1:] - Ms[:-1]) / share
            times_sorted = d.cumsum()
            times_flat = np.empty_like(times_sorted)
            times_flat[order_g] = times_sorted
            t_stop = t_cal
            metas = []
            off = 0
            for jb, m0, i0, marks, ids, pos in chains:
                n_j = marks.shape[0]
                times = times_flat[off:off + n_j]
                off += n_j
                k = n_j - 1                     # future flows in the chain
                if jb.gated is None:
                    ptr = jb.ptr
                    if k:
                        viol = ((jb.rd[ptr:ptr + k] > times[:k])
                                | jb.hd[ptr - 1:ptr + k - 1])
                        nz = viol.nonzero()[0]
                        v = int(nz[0]) + 1 if nz.size else k + 1
                    else:
                        v = 1
                    bt = times[v - 1]           # this job's boundary time
                else:
                    if k:
                        hd_prev = g_hd[ids[:k]]
                        nz = hd_prev.nonzero()[0]
                        v = int(nz[0]) + 1 if nz.size else k + 1
                        bt = times[v - 1]
                        # gating boundary: a commit window reaching the
                        # next gated ready time would let a fresh flow
                        # preempt the resolved prefix
                        gp = jb.gptr
                        if gp < jb.g_rd.shape[0]:
                            tg = jb.g_rd[gp]
                            if tg < bt:
                                bt = tg
                    else:
                        v = 1
                        bt = times[0]
                if bt < t_stop:
                    t_stop = bt
                metas.append((jb, m0, i0, marks, times, v, ids, pos))
            total = 0
            entries = []
            for jb, m0, i0, marks, times, v, ids, pos in metas:
                c = int(times[:v].searchsorted(t_stop, side="left"))
                if c == 0:
                    entries.append((m0, i0))
                    continue
                tc = times[:c]
                idc = ids[:c]
                if c > 1:
                    start[ids[1:c]] = tc[:-1]
                wire[idc] = tc
                if jb.gated is None:
                    ptr = jb.ptr
                    end[idc] = tc + jb.lt[ptr - 1:ptr + c - 1]
                    ia = jb.order[ptr + c - 1]  # the job's new active flow
                    jb.ptr = ptr + c
                else:
                    end[idc] = tc + g_lt[idc]
                    ia = int(ids[c])
                    # consume the committed prefix plus the new active flow
                    jb.readyq[pos[:c]] = False
                    jb.n_ready -= c
                contended[idc] = True
                tl = float(tc[-1])
                start[ia] = tl
                contended[ia] = True
                entries.append((float(marks[c]), ia))
                total += c
            if not total:
                return 0
            L.heap = entries
            heapify(entries)
            # final link state = exactly the scalar spin's after serving
            # the last committed completion of the merged sequence
            n_commit = int(times_sorted.searchsorted(t_stop, side="left"))
            L.S = float(Ms[n_commit - 1])
            L.t_last = float(times_sorted[n_commit - 1])
            L.version += 1
            # geometric cap adaptation: big commits earn longer chains next
            # call, near-empty windows shrink the per-call numpy work
            nc = 2 * total
            L.bulk_cap = (_BULK_CHAIN_CAP if nc > _BULK_CHAIN_CAP
                          else nc if nc > 32 else 32)
            if total < 4 * L.n:
                L.bulk_skip = 64    # window too small to pay numpy setup
            n_done += total
            stale = 0               # bulk-committed work is progress
            return total

        while n_done < n_total:
            if not cal:
                raise RuntimeError(
                    f"event engine stalled: {n_done}/{n_total} flows done "
                    "with an empty calendar")
            ev = heappop(cal)
            t = ev[0]

            if ev[1] == _DONE:
                ver, L = ev[3], ev[4]
                if ver != L.version or not L.n:
                    stale += 1      # lazily-invalidated projection
                    if stale > stall_limit:
                        raise RuntimeError(
                            "event engine made no progress over "
                            f"{stale} events ({n_done}/{n_total} flows done)")
                    if len(cal) > sweep_at:
                        # batched stale sweep: one filter pass + heapify
                        # beats popping invalidated projections one by one
                        cal[:] = [e for e in cal if e[1] == _ADMIT
                                  or e[3] == e[4].version]
                        heapify(cal)
                        sweep_at = max(256, 2 * len(cal))
                    continue
                stale = 0
                # ---- completion spin: serve this link's completions while
                # they precede everything else on the calendar --------------
                while True:
                    if t > L.t_last:
                        L.S += (t - L.t_last) * L.share
                    L.t_last = t
                    s_top, i = heappop(L.heap)
                    L.S = s_top
                    L.n -= 1
                    L.version += 1
                    if L.n:
                        c = L.cap
                        L.share = 1.0 if c >= L.n else c / L.n
                    else:
                        L.all_contended = False
                    if contended[i]:
                        w = t
                        e = t + lt_col[i]
                    else:
                        # exact closed form: share was 1.0 throughout
                        w = float(start[i]) + wk_col[i]
                        d = flws[i].duration
                        if hd_col[i] and d is not None:
                            e = float(start[i]) + d
                        else:
                            e = w + lt_col[i]
                    wire[i] = w
                    end[i] = e
                    n_done += 1
                    jb = job_of[i]
                    jb.busy = False
                    jb.free = e if hd_col[i] else w
                    # instant re-admission keeps the spin going (the
                    # saturated steady state); anything else goes back
                    # through the calendar
                    readmitted = None
                    if not hd_col[i]:
                        if jb.gated is None:
                            p = jb.ptr
                            if p < len(jb.order) and jb.rdy[p] <= t:
                                jb.ptr = p + 1
                                readmitted = _admit(jb.order[p], jb, t)
                        elif small:
                            _drain(jb, t)
                            if jb.readyq:
                                k = heappop(jb.readyq)[2]
                                readmitted = _admit(k, jb, t)
                        else:
                            _drain(jb, t)
                            if jb.n_ready:
                                # first set bit = best (priority, op_id)
                                p = int(jb.readyq.argmax())
                                jb.readyq[p] = False
                                jb.n_ready -= 1
                                readmitted = _admit(jb.order[p], jb, t)
                    if readmitted is None:
                        _schedule_admit(jb, t)
                    elif readmitted is not L:
                        # cross-link re-admission: project the other link
                        seq += 1
                        s2 = readmitted.heap[0][0]
                        proj2 = t + (s2 - readmitted.S) / readmitted.share
                        heappush(cal, (proj2 if proj2 > t else t, _DONE,
                                       seq, readmitted.version, readmitted))
                    if not L.n:
                        break
                    if not small and L.n >= _BULK_MIN_ACTIVE:
                        if L.bulk_skip:
                            L.bulk_skip -= 1
                        elif _try_bulk(L, t):
                            t = L.t_last
                            if not L.n:
                                break
                    proj = t + (L.heap[0][0] - L.S) / L.share
                    if proj < t:
                        proj = t
                    if cal and cal[0][0] < proj:
                        seq += 1
                        heappush(cal, (proj, _DONE, seq, L.version, L))
                        break
                    t = proj
                continue

            # ---- admission event ------------------------------------------
            jb = ev[3]
            if jb.busy:
                stale += 1          # superseded by an instant re-admission
                if stale > stall_limit:
                    raise RuntimeError(
                        "event engine made no progress over "
                        f"{stale} events ({n_done}/{n_total} flows done)")
                continue
            if jb.free > t:         # defensive: fire again once free
                stale += 1
                _schedule_admit(jb, t)
                continue
            stale = 0               # a serviced admission trigger is progress
            admitted = None
            if jb.gated is None:
                p = jb.ptr
                if p < len(jb.order):
                    if jb.rdy[p] <= t:
                        jb.ptr = p + 1
                        admitted = _admit(jb.order[p], jb, t)
                    else:
                        _schedule_admit(jb, t)
            elif small:
                _drain(jb, t)
                if jb.readyq:
                    k = heappop(jb.readyq)[2]
                    admitted = _admit(k, jb, t)
                elif jb.gptr < len(jb.gated):
                    _schedule_admit(jb, t)
            else:
                _drain(jb, t)
                if jb.n_ready:
                    p = int(jb.readyq.argmax())
                    jb.readyq[p] = False
                    jb.n_ready -= 1
                    admitted = _admit(jb.order[p], jb, t)
                elif jb.gptr < jb.g_rd.shape[0]:
                    _schedule_admit(jb, t)
            if admitted is not None:
                seq += 1
                s_top = admitted.heap[0][0]
                proj = t + (s_top - admitted.S) / admitted.share
                heappush(cal, (proj if proj > t else t, _DONE, seq,
                               admitted.version, admitted))

        if small:
            rows = zip(op_col, job_col, start, wire, end, contended)
        else:
            rows = zip(op_col, job_col, start.tolist(), wire.tolist(),
                       end.tolist(), contended.tolist())
        new = tuple.__new__
        return [new(FlowResult, row) for row in rows]


def run_flows(flows: Sequence[FlowSpec],
              capacities: Optional[Dict[str, float]] = None,
              rails: Optional[Dict[str, int]] = None) -> List[FlowResult]:
    """Convenience wrapper: execute ``flows`` on a fresh engine.

    ``capacities`` and ``rails`` are per-link-name maps — see
    :class:`NetworkEngine`.
    """
    return NetworkEngine(capacities, rails).run(flows)
