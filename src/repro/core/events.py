"""Discrete-event network engine: link resources with fair-share bandwidth.

The serialized bucket loop the simulator used to hard-code is one point in
a much larger scheduling space.  This engine executes *flows* — wire
transfers with a fixed post-wire latency (the reduction/vector-add phase of
a collective) — against named link resources:

- **links** split their bandwidth fairly among concurrent flows (progressive
  filling: each of the k active flows progresses at 1/k of full rate), which
  is what makes multi-job contention expressible;
- **jobs** serialize their own flows (one wire in flight per job): a ring
  all-reduce occupies the full NIC, so intra-job concurrency happens at
  chunk granularity via the scheduler that *ordered* the flows, not via the
  link;
- a job admits its highest-priority ready flow whenever it is free; a flow
  with ``hold=True`` keeps the job busy through its latency (Horovod's
  serialized all-reduce process), otherwise the job frees at wire end and
  the latency overlaps the next flow's transmission (pipelined chunks).

Exactness: a ``hold`` flow whose wire phase never shared its link completes
at ``start + duration`` with ``duration`` precomputed by the caller as a
single float expression — so the ``fifo`` schedule reproduces the legacy
serialized loop bit-for-bit, not just within tolerance.

Times in seconds; ``work`` is wire time at full link rate (the caller bakes
bandwidth into it via the cost model).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

DEFAULT_LINK = "nic"
DEFAULT_JOB = "job0"


@dataclass(frozen=True)
class FlowSpec:
    """One wire transfer plus a fixed post-wire latency.

    ``priority`` orders admission within a job (smaller first; ties broken
    by ``op_id``).  ``duration``, when given, must equal ``work + latency``
    up to the caller's own float rounding — it is used verbatim for the
    closed-form uncontended completion of ``hold`` flows.
    """

    op_id: int
    ready: float                     # earliest admission time
    work: float                      # wire seconds at full link rate
    latency: float = 0.0             # fixed post-wire time (reduction etc.)
    priority: float = 0.0
    job: str = DEFAULT_JOB
    link: str = DEFAULT_LINK
    hold: bool = False               # job held busy through the latency
    duration: Optional[float] = None  # precomputed work+latency (hold flows)


@dataclass(frozen=True)
class FlowResult:
    op_id: int
    job: str
    start: float                     # admission (wire begins)
    wire_end: float                  # link released
    end: float                       # wire + latency complete
    contended: bool                  # wire phase ever shared its link

    @property
    def occupancy(self) -> float:
        """Time this flow kept its serialization resource busy."""
        return self.end - self.start


class _Run:
    __slots__ = ("flow", "start", "remaining", "contended")

    def __init__(self, flow: FlowSpec, start: float):
        self.flow = flow
        self.start = start
        self.remaining = flow.work
        self.contended = False


class NetworkEngine:
    """Event-queue executor for a set of flows over shared links.

    ``capacities`` maps link name -> number of flows that can run at full
    rate before fair sharing kicks in (default 1.0 — the whole link).
    """

    def __init__(self, capacities: Optional[Dict[str, float]] = None):
        self.capacities = dict(capacities or {})

    def _share(self, link: str, n_active: int) -> float:
        cap = self.capacities.get(link, 1.0)
        return min(1.0, cap / n_active) if n_active else 1.0

    def run(self, flows: Sequence[FlowSpec]) -> List[FlowResult]:
        """Execute ``flows``; returns results in input order."""
        pending: Dict[str, List[FlowSpec]] = {}
        for f in flows:
            pending.setdefault(f.job, []).append(f)
        for q in pending.values():
            # stable service order: (priority, op_id); ready gates admission
            q.sort(key=lambda f: (f.priority, f.op_id), reverse=True)

        job_free: Dict[str, float] = {j: 0.0 for j in pending}
        running: Dict[str, _Run] = {}          # job -> active wire
        on_link: Dict[str, List[_Run]] = {}
        results: Dict[int, FlowResult] = {}
        t = 0.0
        n_total = len(flows)
        max_iters = 10 * n_total + 100

        def _pick(job: str) -> Optional[FlowSpec]:
            """Highest-priority flow of ``job`` that is ready at ``t``."""
            q = pending[job]
            best_i = -1
            for i in range(len(q) - 1, -1, -1):  # sorted reverse: best last
                if q[i].ready <= t:
                    best_i = i
                    break
            if best_i < 0:
                return None
            return q.pop(best_i)

        iters = 0
        while len(results) < n_total:
            iters += 1
            if iters > max_iters:
                raise RuntimeError("event engine failed to converge "
                                   f"({len(results)}/{n_total} flows done)")

            # -- admissions at the current time ------------------------------
            admitted = False
            for job in pending:
                if job in running or job_free[job] > t or not pending[job]:
                    continue
                flow = _pick(job)
                if flow is None:
                    continue
                run = _Run(flow, start=t)
                active = on_link.setdefault(flow.link, [])
                if active:
                    run.contended = True
                    for other in active:
                        other.contended = True
                if self._share(flow.link, 1) < 1.0:
                    # a link with fractional capacity never runs a flow at
                    # full rate, so the closed-form completion is invalid
                    run.contended = True
                active.append(run)
                running[job] = run
                admitted = True
            if admitted:
                continue  # shares changed; recompute projections

            # -- next event: a wire completion or a job becoming serviceable -
            t_next = None
            for run in running.values():
                share = self._share(run.flow.link, len(on_link[run.flow.link]))
                proj = t + run.remaining / share
                if t_next is None or proj < t_next:
                    t_next = proj
            for job, q in pending.items():
                if job in running or not q:
                    continue
                earliest = min(f.ready for f in q)
                trigger = max(job_free[job], earliest)
                if t_next is None or trigger < t_next:
                    t_next = trigger
            if t_next is None:
                raise RuntimeError("event engine stalled with pending flows")
            t_next = max(t_next, t)

            # -- advance all running wires to t_next -------------------------
            dt = t_next - t
            done: List[Tuple[str, _Run]] = []
            for job, run in running.items():
                share = self._share(run.flow.link, len(on_link[run.flow.link]))
                run.remaining -= dt * share
                # done when the residual is negligible — or too small to
                # advance the clock at all (absorbed below ulp(t_next)),
                # which would otherwise stall the loop
                if (run.remaining <= run.flow.work * 1e-12 + 1e-18
                        or t_next + run.remaining / share <= t_next):
                    done.append((job, run))
            t = t_next

            for job, run in done:
                flow = run.flow
                if not run.contended:
                    # exact closed form: share was 1.0 throughout
                    wire_end = run.start + flow.work
                    if flow.hold and flow.duration is not None:
                        end = run.start + flow.duration
                    else:
                        end = wire_end + flow.latency
                else:
                    wire_end = t
                    end = wire_end + flow.latency
                results[flow.op_id] = FlowResult(
                    flow.op_id, job, run.start, wire_end, end, run.contended)
                on_link[flow.link].remove(run)
                del running[job]
                job_free[job] = end if flow.hold else wire_end

        return [results[f.op_id] for f in flows]


def run_flows(flows: Sequence[FlowSpec],
              capacities: Optional[Dict[str, float]] = None
              ) -> List[FlowResult]:
    """Convenience wrapper: execute ``flows`` on a fresh engine."""
    return NetworkEngine(capacities).run(flows)
