"""Discrete-event network engine: link resources with fair-share bandwidth.

The serialized bucket loop the simulator used to hard-code is one point in
a much larger scheduling space.  This engine executes *flows* — wire
transfers with a fixed post-wire latency (the reduction/vector-add phase of
a collective) — against named link resources:

- **links** split their bandwidth fairly among concurrent flows (progressive
  filling: each of the k active flows progresses at 1/k of full rate), which
  is what makes multi-job contention expressible;
- **jobs** serialize their own flows (one wire in flight per job): a ring
  all-reduce occupies the full NIC, so intra-job concurrency happens at
  chunk granularity via the scheduler that *ordered* the flows, not via the
  link;
- a job admits its highest-priority ready flow whenever it is free; a flow
  with ``hold=True`` keeps the job busy through its latency (Horovod's
  serialized all-reduce process), otherwise the job frees at wire end and
  the latency overlaps the next flow's transmission (pipelined chunks).

Exactness: a ``hold`` flow whose wire phase never shared its link completes
at ``start + duration`` with ``duration`` precomputed by the caller as a
single float expression — so the ``fifo`` schedule reproduces the legacy
serialized loop bit-for-bit, not just within tolerance.  A flow counts as
``contended`` only if it shared its link for a *nonzero* duration; the seed
engine also flagged zero-duration overlaps (two flows co-admitted at an
instant where one has zero residual work), which changed no completion time
beyond re-rounding but cosmetically dropped the closed form.

Times in seconds; ``work`` is wire time at full link rate (the caller bakes
bandwidth into it via the cost model).

Engine architecture (the O((n+e) log n) event calendar)
-------------------------------------------------------

The seed implementation rescanned every pending/running flow at every event
and advanced all wires step by step — quadratic once plans reach thousands
of flows.  This version is indexed end to end:

- **per-job admission state**: flows sort once into service order
  ``(priority, op_id)``.  When ready times are non-decreasing along that
  order (fifo/chunked plans), the next admissible flow is a pointer
  increment; otherwise (priority plans, where late-flushed buckets preempt)
  the job keeps a ready-time heap of *gated* flows plus a priority heap of
  admissible ones, so an admission is O(log n) instead of a rescan.
- **per-link fluid service clocks**: all flows on a link progress at the
  same fair share, so in link-service time a flow admitted when the link
  had delivered ``S`` per-flow seconds completes at exactly ``S + work`` —
  a *static* order.  Each link keeps a heap of these completion marks;
  membership changes rescale only the rate at which the clock advances,
  never the order, so projections are recomputed only when a link's
  membership (and hence share) changes, and only for the heap top.
- **versioned calendar entries**: the global ``heapq`` calendar holds each
  link's next projected completion stamped with the link's membership
  version, plus per-job admission triggers.  A membership change bumps the
  version; stale entries are lazily discarded on pop rather than searched
  for and removed.
- **completion spin + bulk commit**: when a link's next completion precedes
  everything else on the calendar, completions are served in a tight loop
  without calendar round-trips; and while membership is *constant* (every
  completion instantly re-admits the job's next flow), each job's future
  completion marks are plain prefix sums of its works, so whole saturated
  stretches are computed with vectorized numpy cumulative sums and
  committed in one pass, up to the first membership-changing boundary
  (ready gate, ``hold`` flow, job exhaustion, or calendar interrupt).
  Completion *times* for the merged stretch are produced by the same
  chained left fold the scalar spin performs — one ``np.cumsum`` over the
  per-step deltas of the (mark, flow)-sorted merge of every job's chain —
  so bulk-committed results are **bit-identical** to the scalar event
  loop, not merely within tolerance.
- **heap-mode resolved prefix**: a priority-scheduled job (ready times
  regress along service order) cannot expose a pointer chain, but its
  *ready frontier* is still a resolved sequence: sorting the admissible
  heap by ``(priority, op_id)`` yields exactly the order the scalar loop
  would pop, valid until the next gated flow's ready time (the *gating
  boundary*) is crossed.  Heap-mode jobs therefore contribute that sorted
  prefix to the bulk chain decomposition, with the gating boundary folded
  into the job's violation point; the sorted suffix left after a commit
  is itself a valid heap, so no re-heapify is needed.
- **multi-link bulk window**: when the spin attempts a bulk commit, other
  links' *valid* projected completions at the front of the calendar would
  artificially fence the window at the first foreign event.  If such a
  link is itself bulk-eligible and **self-contained** (every job in its
  heap runs all of its flows on that link, so nothing it commits can
  admit work elsewhere), its calendar entry is parked, the window extends
  to the first non-parkable event, and each parked link retires its own
  saturated stretch against the same fence — one window, all eligible
  links.  A parked link's first completion uses the exact time its
  calendar entry carried, so the arithmetic is the scalar loop's.
- **small-plan setup**: the columnar numpy views that pay for themselves on
  thousand-flow plans cost more than the whole event loop on the two-dozen-
  op plans the paper grids generate, so below
  :data:`_SMALL_PLAN_MAX_FLOWS` the setup runs on plain lists and the bulk
  commit (which needs the arrays, and can never engage on single-job plans
  anyway) is skipped.  The scalar event loop is identical either way, so
  single-job results are bit-identical across the two setups.

Termination is progress-based: the engine raises only when the calendar
drains with flows outstanding, or when event processing stops advancing
time, admitting, or completing — not on an iteration-count heuristic, which
could false-trip on heavily contended multi-job plans.

Columnar batches (structure-of-arrays end to end)
-------------------------------------------------

:class:`FlowBatch` is the columnar twin of a ``FlowSpec`` list: one numpy
record batch (float64 ``ready``/``work``/``latency``/``priority``/
``duration`` columns, a bool ``hold`` column, ``intp`` ``op_id``/``rail``
columns, and *interned* job/link name tables with ``intp`` code columns).
``NetworkEngine.run_batch`` consumes it directly — the large-plan setup
becomes one global lexsort plus per-job column slices, with no tuple
materialization on either side (results come back as a
:class:`ResultBatch`).  The glue is O(columns), not O(flows):

- :meth:`FlowBatch.relabel` replaces ``schedule.clone_flows`` — a
  contention cell relabels the shared lowering per job by rewriting the
  interned *name table* and shifting ``op_id``; every float column is the
  same array object;
- :func:`perturb_batch` replaces :func:`perturb_flows` — the same RNG
  stream, one vectorized ``ready + delays`` (elementwise float64 adds are
  the scalar adds, so jittered batches are bit-identical to the tuple
  path);
- :func:`concat_batches` merges per-job batches for one engine call,
  re-interning names in first-appearance order.

The name tables preserve **first-appearance order** by construction
(interning, relabeling, and concatenation all keep it), which is what
makes the columnar setup's calendar insertion order — and therefore every
same-time admission tie-break — identical to the tuple path's.  Plans
below :data:`_SMALL_PLAN_MAX_FLOWS` bounce to the list path unchanged.
``run(flows)`` above the threshold routes through the same batch core, so
there is exactly one large-plan engine.

Multi-rail links
----------------

A physical NIC with ``r`` rails is ``r`` independent fluid links that
happen to share a name: ``NetworkEngine(rails={"nic": r})`` turns the named
link into a :class:`_LinkSet` of ``r`` per-rail service clocks, and each
flow's ``rail`` field selects which clock serves it (rail selection is part
of the *plan* — see :func:`repro.core.schedule.assign_rails` — so the
engine stays deterministic and a one-rail plan is bit-exact with a plain
link).  Rails do not fair-share with each other: contention is per rail,
which is exactly what distinguishes a 2x50G multi-rail host from a single
100G NIC.  The caller models per-rail bandwidth by scaling ``work`` (see
``plan_to_flows(..., n_rails=...)``).
"""
from __future__ import annotations

from heapq import heapify, heappop, heappush
from typing import Dict, Iterable, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

DEFAULT_LINK = "nic"
DEFAULT_JOB = "job0"

# calendar event kinds: completions sort first at a tie, then faults (a
# completion landing exactly at a fault instant still completes), then
# admissions (a fault precedes any same-time admission it should gate),
# then retransmission timeouts (a same-time admission is admitted first
# and then pulled back — the segment was already in flight when it timed
# out).  _RETX shares _FAULT's handler: pull-back + stall, no worker
# cancellation (ChurnEvent kind "retx" never matches the "drop" gate).
_DONE, _FAULT, _ADMIT, _RETX = 0, 1, 2, 3
_INF = float("inf")
_NAN = float("nan")


class FlowSpec(NamedTuple):
    """One wire transfer plus a fixed post-wire latency.

    The engine's unit of work: the schedule layer lowers every
    :class:`~repro.core.schedule.CommOp` to exactly one ``FlowSpec``.

    - ``op_id`` identifies the flow in results (results come back in input
      order, but ``op_id`` survives any caller-side regrouping);
    - ``ready`` is the earliest admission time (the bucket's flush time,
      possibly perturbed by :func:`perturb_flows`);
    - ``work`` is wire seconds *at full link rate* — the caller bakes
      bandwidth into it via the cost model, so a rail at 1/n of the
      aggregate bandwidth simply carries ``n`` times the work;
    - ``latency`` is the fixed post-wire phase (vector adds + negotiation)
      that does not scale under link sharing;
    - ``priority`` orders admission within a job (smaller first; ties broken
      by ``op_id``);
    - ``job`` names the serialization resource (one wire in flight per job);
    - ``link``/``rail`` name the bandwidth resource: ``rail`` selects the
      per-rail service clock when the engine was built with
      ``rails={link: n}``, and is ignored (must be 0) otherwise;
    - ``hold`` keeps the job busy through the latency (Horovod's serialized
      all-reduce); ``duration``, when given, must equal ``work + latency``
      up to the caller's own float rounding — it is used verbatim for the
      closed-form uncontended completion of ``hold`` flows, which is what
      makes the fifo schedule bit-exact with the legacy serialized loop;
    - ``worker`` attributes the flow to a physical worker for the fault
      layer (:mod:`repro.core.faults`): a :class:`ChurnEvent` dropping
      worker ``w`` cancels the job's pending flows with ``worker == w``.
      Ignored unless the engine runs with churn events.
    - ``path``, when non-empty, is the tuple of link ids the flow
      traverses (host NIC -> ToR uplink -> ...); the flow progresses at
      its bottleneck **max-min fair share** across all of them
      (progressive filling — see :meth:`NetworkEngine._run_maxmin`).  A
      link id repeated ``m`` times encodes demand multiplicity: the flow
      consumes ``m`` units of that link's capacity per unit of rate (a
      rack uplink crossed by every host of the rack).  An empty path
      means "use ``link``" — today's single-resource semantics, and a
      one-element path is normalized to exactly that, so any plan whose
      paths all have length <= 1 runs the original engine bit-for-bit.
    """

    op_id: int
    ready: float                     # earliest admission time
    work: float                      # wire seconds at full link rate
    latency: float = 0.0             # fixed post-wire time (reduction etc.)
    priority: float = 0.0
    job: str = DEFAULT_JOB
    link: str = DEFAULT_LINK
    hold: bool = False               # job held busy through the latency
    duration: Optional[float] = None  # precomputed work+latency (hold flows)
    rail: int = 0                    # which rail of a multi-rail link
    worker: int = 0                  # owning worker (fault attribution)
    path: Tuple[str, ...] = ()       # multi-link route (empty: use ``link``)


class FlowResult(NamedTuple):
    """Execution record of one flow, in the input list's order.

    ``start`` is the admission time (wire begins), ``wire_end`` when the
    link was released, ``end`` when the post-wire latency finished.
    ``contended`` is True only if the wire phase shared its link (or rail)
    for a *nonzero* duration — uncontended flows take exact closed forms,
    so ``start + work == wire_end`` bit-for-bit.
    """

    op_id: int
    job: str
    start: float                     # admission (wire begins)
    wire_end: float                  # link released
    end: float                       # wire + latency complete
    contended: bool                  # wire phase ever shared its link

    @property
    def occupancy(self) -> float:
        """Time this flow kept its serialization resource busy."""
        return self.end - self.start


class ChurnEvent(NamedTuple):
    """One membership change of a job's worker fleet, at engine level.

    ``kind == "drop"``: at time ``t`` worker ``worker`` leaves the fleet —
    the job's in-flight flow (whoever owns it) is pulled back to restart
    after the re-bucketing stall, and every pending flow with a matching
    ``FlowSpec.worker`` is cancelled (it completes trivially at ``t``:
    the re-formed collective skips the dead worker's buckets this
    iteration).  ``kind == "rejoin"``: the worker comes back — only the
    pull-back and the stall apply (its cancelled flows stay cancelled;
    re-admission costs, not recovered work, are the priced quantity).
    ``kind == "retx"``: a retransmission timeout on a lossy link
    (:func:`repro.core.transport.retx_events`) — pull-back + stall like a
    rejoin, lowered onto its own ``_RETX`` calendar kind so a timeout at
    an admission instant fires *after* the admission it interrupts.
    ``stall`` is the re-bucketing/remap cost (for ``retx``: the backoff
    ``timeout * backoff**k``): the job admits nothing before
    ``t + stall``.  ``job`` matches the flow's job name exactly or
    as a rail-lane prefix (``job0`` also hits ``job0@r1``).  Events are
    plain data — :func:`repro.core.faults.churn_events` draws them from
    the seeded fault stream.
    """

    t: float
    job: str
    kind: str                        # "drop" | "rejoin"
    worker: int = -1                 # dropped worker (-1: no cancellation)
    stall: float = 0.0               # re-bucketing stall, seconds


def _jitter_stream(seed: int, stream: int, *extra: int) -> np.random.Generator:
    """The engine-wide perturbation RNG: ``(seed, stream[, substream])``.

    One construction shared by every stochastic scenario axis (per-flow
    jitter, correlated fault delays, bandwidth skew, churn arrivals), so
    the determinism contract — draws depend only on the explicit key,
    never on process/thread/global state — holds everywhere by
    construction.  ``extra`` selects an independent substream for draws
    that must not consume the base stream (worker-level draws, churn
    arrivals); the bare ``(seed, stream)`` stream is the one
    :func:`perturb_flows` has always used.
    """
    return np.random.default_rng(np.random.SeedSequence(
        entropy=int(seed), spawn_key=(int(stream), *map(int, extra))))


def jitter_delays(n: int, jitter: float, seed: int,
                  stream: int = 0) -> np.ndarray:
    """The independent-jitter draws: ``jitter * Exp(1)`` per flow.

    Depends only on ``(seed, stream, n)`` and scales linearly in
    ``jitter`` (same draws, scaled) — the contract both perturb
    functions and the fault model's ``correlation=0`` mode share.
    """
    return jitter * _jitter_stream(seed, stream).standard_exponential(n)


def perturb_flows(flows: Sequence[FlowSpec], jitter: float, seed: int,
                  stream: int = 0) -> List[FlowSpec]:
    """Seeded straggler model: delay every flow's ``ready`` time.

    Each flow's flush is pushed back by an independent exponential draw
    with mean ``jitter`` seconds — the long-tailed per-flow perturbation
    that models slow workers, GC pauses, and negotiation stalls jittering
    bucket flush times.  Determinism contract:

    - the draws depend only on ``(seed, stream, len(flows))`` — never on
      process, thread, or global RNG state — so artifacts are bit-identical
      across executors (``stream`` separates jobs in a contention scenario
      so co-located jobs straggle independently);
    - with a fixed seed the delays scale *linearly* in ``jitter``
      (``jitter * standard_exponential``), so a swept jitter axis moves
      every ready time monotonically — the straggler grid's
      ``t_sync`` monotonicity validator rests on this;
    - ``jitter <= 0`` returns the flows unchanged (same objects), keeping
      the zero-jitter path bit-exact with a run that never heard of jitter.

    :func:`perturb_batch` is the columnar twin: same RNG construction,
    same float adds, bit-identical ready times.
    """
    if jitter <= 0.0 or not flows:
        return list(flows)
    delays = jitter_delays(len(flows), jitter, seed, stream).tolist()
    return [f._replace(ready=f.ready + d) for f, d in zip(flows, delays)]


# ---------------------------------------------------------------------------
# columnar batches: structure-of-arrays flows and results
# ---------------------------------------------------------------------------

def _intern(names: Sequence[str]) -> Tuple[Tuple[str, ...], np.ndarray]:
    """Name column -> (first-appearance-ordered table, intp code column)."""
    table: Dict[str, int] = {}
    codes = np.empty(len(names), dtype=np.intp)
    for i, nm in enumerate(names):
        c = table.get(nm)
        if c is None:
            c = table[nm] = len(table)
        codes[i] = c
    return tuple(table), codes


class FlowBatch(NamedTuple):
    """A columnar batch of flows: the structure-of-arrays ``FlowSpec`` list.

    Float columns are float64 (``duration`` holds NaN where a ``FlowSpec``
    would hold ``None``); ``op_id``/``rail`` are ``intp``; ``job``/``link``
    are ``intp`` codes into the interned ``jobs``/``links`` name tables.
    Invariant: the name tables are in **first-appearance order** along the
    batch — every constructor here preserves it, and the engine's columnar
    setup relies on it to reproduce the tuple path's calendar insertion
    order (and therefore every same-time tie-break) exactly.

    Batches are immutable in the NamedTuple sense; ``relabel`` and
    :func:`perturb_batch` share every column they do not change (except
    the path CSR columns, which ``relabel`` deep-copies — a relabeled
    job's route must be independently mutable without leaking into the
    source batch).

    Multi-link routes are stored CSR-style: flow ``i`` traverses the
    link codes ``path_link[path_off[i]:path_off[i+1]]`` (codes into
    ``links``, repeats = demand multiplicity, exactly mirroring
    ``FlowSpec.path``).  Both columns are ``None`` when no flow in the
    batch has a path — the common case, and the representation every
    pre-fabric constructor produces.
    """

    op_id: np.ndarray
    ready: np.ndarray
    work: np.ndarray
    latency: np.ndarray
    priority: np.ndarray
    duration: np.ndarray             # NaN = no precomputed duration
    hold: np.ndarray                 # bool
    jobs: Tuple[str, ...]            # interned names, first-appearance order
    job: np.ndarray                  # intp codes into ``jobs``
    links: Tuple[str, ...]
    link: np.ndarray                 # intp codes into ``links``
    rail: np.ndarray                 # intp
    worker: np.ndarray               # intp (fault attribution)
    path_off: Optional[np.ndarray] = None   # CSR offsets (n+1) into path_link
    path_link: Optional[np.ndarray] = None  # intp codes into ``links``

    @property
    def n(self) -> int:
        return int(self.op_id.shape[0])

    @classmethod
    def from_flows(cls, flows: Sequence[FlowSpec]) -> "FlowBatch":
        """Columnarize a flow list (``None`` durations become NaN)."""
        if not flows:
            return _EMPTY_BATCH
        (op_col, rdy_col, wk_col, lt_col, pr_col, job_col, lk_col, hd_col,
         du_col, rl_col, w_col, pth_col) = zip(*flows)
        jobs, jcode = _intern(job_col)
        if any(pth_col):
            # Intern link names and path entries together, still in
            # first-appearance order along the batch (flow i contributes
            # its ``link`` then its path entries).
            table: Dict[str, int] = {}
            lcode = np.empty(len(flows), dtype=np.intp)
            plinks: List[int] = []
            path_off = np.zeros(len(flows) + 1, dtype=np.intp)
            for i, (nm, p) in enumerate(zip(lk_col, pth_col)):
                c = table.get(nm)
                if c is None:
                    c = table[nm] = len(table)
                lcode[i] = c
                for pn in p:
                    pc = table.get(pn)
                    if pc is None:
                        pc = table[pn] = len(table)
                    plinks.append(pc)
                path_off[i + 1] = len(plinks)
            links = tuple(table)
            path_link: Optional[np.ndarray] = np.asarray(plinks, dtype=np.intp)
        else:
            links, lcode = _intern(lk_col)
            path_off = path_link = None
        return cls(
            op_id=np.asarray(op_col, dtype=np.intp),
            ready=np.asarray(rdy_col, dtype=np.float64),
            work=np.asarray(wk_col, dtype=np.float64),
            latency=np.asarray(lt_col, dtype=np.float64),
            priority=np.asarray(pr_col, dtype=np.float64),
            duration=np.array([_NAN if d is None else d for d in du_col]),
            hold=np.asarray(hd_col, dtype=bool),
            jobs=jobs, job=jcode, links=links, link=lcode,
            rail=np.asarray(rl_col, dtype=np.intp),
            worker=np.asarray(w_col, dtype=np.intp),
            path_off=path_off, path_link=path_link)

    def to_flows(self) -> List[FlowSpec]:
        """Materialize the tuple view (NaN durations become ``None``)."""
        jobs, links = self.jobs, self.links
        du = [None if d != d else d for d in self.duration.tolist()]
        if self.path_link is not None and self.path_link.shape[0]:
            off = self.path_off.tolist()
            pl = [links[c] for c in self.path_link.tolist()]
            paths: List[Tuple[str, ...]] = [
                tuple(pl[off[i]:off[i + 1]]) for i in range(self.n)]
        else:
            paths = [()] * self.n
        rows = zip(self.op_id.tolist(), self.ready.tolist(),
                   self.work.tolist(), self.latency.tolist(),
                   self.priority.tolist(),
                   [jobs[c] for c in self.job.tolist()],
                   [links[c] for c in self.link.tolist()],
                   self.hold.tolist(), du, self.rail.tolist(),
                   self.worker.tolist(), paths)
        new = tuple.__new__
        return [new(FlowSpec, row) for row in rows]

    def relabel(self, op_id_base: int, job: str,
                old_job: str = DEFAULT_JOB) -> "FlowBatch":
        """O(names) relabel for another identical co-located job.

        The columnar twin of :func:`repro.core.schedule.clone_flows`:
        rewrites the interned job-name table (``old_job`` prefix ->
        ``job``, covering the rail lanes ``old_job@r<k>``) and shifts
        ``op_id``; every float column is shared, so an n-job contention
        cell pays one lowering and n column relabels.  ``op_id_base == 0``
        with ``job == old_job`` returns ``self``.
        """
        if op_id_base == 0 and job == old_job:
            return self
        shift = len(old_job)
        jobs = tuple(job + nm[shift:] if nm.startswith(old_job) else nm
                     for nm in self.jobs)
        # Copy the path CSR columns rather than aliasing them: relabeled
        # batches model *other* jobs, and an in-place route edit on the
        # clone (re-homing a job to a different uplink) must never leak
        # into the source batch the way a shared ``ready`` column would.
        path_off = None if self.path_off is None else self.path_off.copy()
        path_link = None if self.path_link is None else self.path_link.copy()
        return self._replace(op_id=self.op_id + op_id_base, jobs=jobs,
                             path_off=path_off, path_link=path_link)

    def with_path(self, path: Tuple[str, ...]) -> "FlowBatch":
        """Stamp one shared multi-link route on every flow of the batch.

        Extends the interned ``links`` table with any new names (appended
        after the existing entries, preserving first-appearance order for
        the single-link columns) and builds the uniform CSR columns.  An
        empty ``path`` clears the route columns instead.
        """
        if not path:
            return self._replace(path_off=None, path_link=None)
        table = {nm: k for k, nm in enumerate(self.links)}
        codes = []
        for nm in path:
            c = table.get(nm)
            if c is None:
                c = table[nm] = len(table)
            codes.append(c)
        k = len(path)
        path_off = np.arange(0, (self.n + 1) * k, k, dtype=np.intp)
        path_link = np.tile(np.asarray(codes, dtype=np.intp), self.n)
        return self._replace(links=tuple(table), path_off=path_off,
                             path_link=path_link)


_EMPTY_BATCH = FlowBatch(
    op_id=np.zeros(0, dtype=np.intp), ready=np.zeros(0), work=np.zeros(0),
    latency=np.zeros(0), priority=np.zeros(0), duration=np.zeros(0),
    hold=np.zeros(0, dtype=bool), jobs=(), job=np.zeros(0, dtype=np.intp),
    links=(), link=np.zeros(0, dtype=np.intp),
    rail=np.zeros(0, dtype=np.intp), worker=np.zeros(0, dtype=np.intp))


class ResultBatch(NamedTuple):
    """Columnar flow results, aligned with the batch that produced them."""

    op_id: np.ndarray
    jobs: Tuple[str, ...]
    job: np.ndarray                  # intp codes into ``jobs``
    start: np.ndarray
    wire_end: np.ndarray
    end: np.ndarray
    contended: np.ndarray            # bool

    @property
    def n(self) -> int:
        return int(self.op_id.shape[0])

    @property
    def occupancy(self) -> np.ndarray:
        return self.end - self.start

    def to_results(self) -> List[FlowResult]:
        jobs = self.jobs
        rows = zip(self.op_id.tolist(),
                   [jobs[c] for c in self.job.tolist()],
                   self.start.tolist(), self.wire_end.tolist(),
                   self.end.tolist(), self.contended.tolist())
        new = tuple.__new__
        return [new(FlowResult, row) for row in rows]


def concat_batches(batches: Iterable[FlowBatch]) -> FlowBatch:
    """Concatenate batches, re-interning names in first-appearance order.

    The columnar twin of ``all_flows.extend(...)`` across jobs: per-batch
    name tables merge through a small LUT (O(names) python work), code
    columns remap vectorized, float columns concatenate.
    """
    bs = [b for b in batches]
    if not bs:
        return _EMPTY_BATCH
    if len(bs) == 1:
        return bs[0]
    job_table: Dict[str, int] = {}
    link_table: Dict[str, int] = {}
    job_cols = []
    link_cols = []
    path_cols = []
    off_cols = []
    off_base = 0
    has_paths = False
    for b in bs:
        jl = np.empty(len(b.jobs), dtype=np.intp)
        for k, nm in enumerate(b.jobs):
            c = job_table.get(nm)
            if c is None:
                c = job_table[nm] = len(job_table)
            jl[k] = c
        job_cols.append(jl[b.job] if len(b.jobs) else b.job)
        ll = np.empty(len(b.links), dtype=np.intp)
        for k, nm in enumerate(b.links):
            c = link_table.get(nm)
            if c is None:
                c = link_table[nm] = len(link_table)
            ll[k] = c
        link_cols.append(ll[b.link] if len(b.links) else b.link)
        if b.path_link is not None and b.path_link.shape[0]:
            has_paths = True
            path_cols.append(ll[b.path_link])
            off_cols.append(b.path_off[1:] + off_base)
            off_base += int(b.path_off[-1])
        else:
            path_cols.append(np.zeros(0, dtype=np.intp))
            off_cols.append(np.full(b.n, off_base, dtype=np.intp))
    if has_paths:
        path_off = np.concatenate(
            [np.zeros(1, dtype=np.intp)] + off_cols)
        path_link = np.concatenate(path_cols)
    else:
        path_off = path_link = None
    return FlowBatch(
        op_id=np.concatenate([b.op_id for b in bs]),
        ready=np.concatenate([b.ready for b in bs]),
        work=np.concatenate([b.work for b in bs]),
        latency=np.concatenate([b.latency for b in bs]),
        priority=np.concatenate([b.priority for b in bs]),
        duration=np.concatenate([b.duration for b in bs]),
        hold=np.concatenate([b.hold for b in bs]),
        jobs=tuple(job_table), job=np.concatenate(job_cols),
        links=tuple(link_table), link=np.concatenate(link_cols),
        rail=np.concatenate([b.rail for b in bs]),
        worker=np.concatenate([b.worker for b in bs]),
        path_off=path_off, path_link=path_link)


def perturb_batch(batch: FlowBatch, jitter: float, seed: int,
                  stream: int = 0) -> FlowBatch:
    """Columnar :func:`perturb_flows`: one vectorized ``ready + delays``.

    Same RNG construction and draw count, and elementwise float64 adds are
    exactly the scalar adds — a perturbed batch is bit-identical to
    perturbing the tuple view.  ``jitter <= 0`` returns ``batch`` itself.
    """
    if jitter <= 0.0 or not batch.n:
        return batch
    delays = jitter_delays(batch.n, jitter, seed, stream)
    return batch._replace(ready=batch.ready + delays)


def serialized_chain(ready: np.ndarray, dur: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized max-plus recurrence, bit-exact with the serial loop.

    Solves ``start_i = max(ready_i, end_{i-1}); end_i = start_i + dur_i``
    with numpy.  Exactness hinges on two properties: ``np.cumsum`` is a
    strict left fold (the same float additions in the same order as the
    serial loop), and folding each chain's start into the summand array
    (``cumsum([ready_j, dur_j, ...])``) preserves the serial association
    ``((ready_j + dur_j) + dur_{j+1}) + ...``.

    Chain starts (indices where the resource went idle) are found
    iteratively: begin with the superset ``ready_i >= ready_{i-1} +
    dur_{i-1}`` (every true chain start satisfies it, since ``end >= ready
    + dur``), compute ends as if those were the starts, then demote any
    candidate whose gap closes (``ready_j < end_{j-1}``).  Ends only grow
    when chains merge, so each pass removes at least one false candidate
    and the fixpoint makes exactly the serial loop's max choices.

    Serves both the simulator's closed-form fifo fast path and the codec
    encode chain in :func:`repro.core.schedule.plan_to_flow_batch` (a naive
    ``np.maximum.accumulate`` would re-associate the adds and drift).
    """
    n = ready.shape[0]
    cand = np.empty(n, dtype=bool)
    cand[0] = True
    if n > 1:
        cand[1:] = ready[1:] >= ready[:-1] + dur[:-1]
    starts = np.empty(n)
    ends = np.empty(n)
    for _ in range(n):
        idx = np.flatnonzero(cand)
        if idx.shape[0] == n:
            # every op finds the resource idle: no queueing anywhere
            starts[:] = ready
            ends[:] = ready + dur
        else:
            bounds = np.append(idx, n)
            for a, b in zip(bounds[:-1], bounds[1:]):
                seg = np.cumsum(np.concatenate(([ready[a]], dur[a:b])))
                starts[a] = ready[a]
                starts[a + 1:b] = seg[1:-1]
                ends[a:b] = seg[1:]
        bad = idx[1:][ready[idx[1:]] < ends[idx[1:] - 1]]
        if not bad.shape[0]:
            return starts, ends
        cand[bad] = False
    raise AssertionError("closed-form chain decomposition did not converge")


def maxmin_rates(demands: Sequence[Dict[str, float]],
                 capacities: Dict[str, float]) -> List[float]:
    """Bottleneck max-min fair rates by progressive filling.

    ``demands[i]`` maps link id -> multiplicity for flow ``i`` (a flow at
    rate ``r`` consumes ``m * r`` of a link it crosses with multiplicity
    ``m``); links absent from ``capacities`` have capacity 1.0.  The fill
    level rises uniformly for all unfrozen flows until some link
    saturates — link ``l`` with residual capacity ``c_l`` and unfrozen
    demand ``d_l`` saturates at level ``c_l / d_l`` — then the flows
    crossing the tightest link freeze at that level, their consumption is
    subtracted, and the process repeats.  Per-flow rates are capped at
    1.0 (full NIC-relative rate), matching the single-link engine's
    ``share = min(1, cap / n)``; the allocation this produces is the
    unique max-min fair point, so any correct solver agrees with it to
    rounding error (the contract behind ``tests/_reference_fabric.py``).

    Links co-saturating within a relative ``1e-12`` of the minimum level
    freeze in the same round: the residual updates are floating-point
    subtractions, and a tie partner left behind with a tiny negative
    residual would otherwise produce a bogus near-zero level (and a flow
    frozen at rate ~0) on the next round.
    """
    n = len(demands)
    rates = [0.0] * n
    un = list(range(n))
    residual: Dict[str, float] = {}
    load: Dict[str, float] = {}
    for i in un:
        for nm, m in demands[i].items():
            if nm not in residual:
                residual[nm] = float(capacities.get(nm, 1.0))
                load[nm] = 0.0
            load[nm] += m
    while un:
        phi = None
        for nm, ld in load.items():
            if ld <= 0.0:
                continue
            lvl = residual[nm] / ld
            if phi is None or lvl < phi:
                phi = lvl
        if phi is None or phi >= 1.0:
            for i in un:
                rates[i] = 1.0       # per-flow full-rate cap
            return rates
        if phi < 0.0:
            phi = 0.0
        cut = phi * (1.0 + 1e-12) + 1e-18
        tight = {nm for nm, ld in load.items()
                 if ld > 0.0 and residual[nm] / ld <= cut}
        nxt = []
        for i in un:
            d = demands[i]
            if tight.isdisjoint(d):
                nxt.append(i)
                continue
            rates[i] = phi
            for nm, m in d.items():
                residual[nm] -= m * phi
                load[nm] -= m
        un = nxt
    return rates


class _Link:
    """Fluid fair-share link: a service clock plus a completion-mark heap.

    ``S`` is the per-flow service delivered since the link last went idle;
    a flow admitted at service mark ``S`` completes when the clock reaches
    ``S + work``.  ``version`` stamps calendar entries for lazy
    invalidation on membership changes.
    """

    __slots__ = ("cap", "n", "share", "S", "t_last", "heap", "version",
                 "all_contended", "bulk_cap", "bulk_skip")

    def __init__(self, cap: float):
        self.cap = cap
        self.n = 0
        self.share = 1.0 if cap >= 1.0 else cap
        self.S = 0.0
        self.t_last = 0.0
        self.heap: List = []        # (service completion mark, flow index)
        self.version = 0
        self.all_contended = False
        # adaptive per-call chain cap for the bulk path: grows with actual
        # commit sizes so short windows (dense jittered ready gates) pay
        # O(committed), not O(remaining), per call
        self.bulk_cap = 64
        # back-off: after a weak commit or a gate rejection, let this many
        # completions go scalar before attempting bulk again — a window
        # too small to amortize the numpy setup is served cheaper event by
        # event, and a large window only ever waits O(skip) scalar events
        self.bulk_skip = 0


class _LinkSet:
    """One named multi-rail link: ``n_rails`` independent per-rail clocks.

    Every rail is a full :class:`_Link` (its own fluid service clock,
    completion heap, and membership version); flows are routed to
    ``rails[flow.rail]`` at setup, after which the event loop sees only
    plain links.  Rails therefore never fair-share with each other — the
    defining property of a multi-rail NIC versus one fat link.
    """

    __slots__ = ("rails",)

    def __init__(self, cap: float, n_rails: int):
        self.rails = [_Link(cap) for _ in range(n_rails)]


class _Job:
    """Serialization resource: one wire in flight, priority admission."""

    __slots__ = ("order", "rdy", "ptr", "gated", "gptr", "g_rd", "readyq",
                 "n_ready", "free", "busy", "link", "onp", "wk", "rd", "hd",
                 "lt", "apos")

    def __init__(self):
        self.order: List[int] = []   # flow indices in (priority, op_id) order
        self.rdy: List[float] = []   # ready times along ``order`` (ptr mode)
        self.ptr = 0
        # heap mode — two representations behind one mode flag
        # (``gated is None`` still means pointer mode):
        #
        # * small plans: ``gated`` is a build-once list of (ready, priority,
        #   op_id, idx) tuples sorted by ready; flows only ever *leave* it,
        #   so a pointer (``gptr``) replaces a heap and draining is a
        #   slice.  ``readyq`` is the classic (priority, op_id, idx) heap.
        # * columnar plans: ``gated`` is the position-into-``order`` array
        #   sorted by ready (``g_rd`` holds the sorted ready times), and
        #   ``readyq`` is a boolean *mask* over ``order`` positions.  The
        #   admissible set in service order is just ``nonzero(mask)`` — the
        #   bulk path's resolved prefix — a drain is one sliced scatter,
        #   and a scalar pop is ``argmax(mask)`` (``order`` is sorted by
        #   (priority, op_id), so the first set bit is the best flow).
        self.gated = None
        self.gptr = 0
        self.g_rd = None
        self.readyq = None
        self.n_ready = 0
        self.free = 0.0
        self.busy = False
        self.link: Optional[_Link] = None   # sole link, if homogeneous
        # numpy views along ``order`` for the bulk-commit path (lazy)
        self.onp = self.wk = self.rd = self.hd = self.lt = None
        # order-position of the in-flight flow (heap mode only; a fault
        # pulling the flow back needs it to restore the readyq bit)
        self.apos = -1


# below this many flows the engine skips its columnar numpy setup (and the
# bulk-commit path that needs it): asarray/lexsort/zeros dominate the whole
# event loop on the two-dozen-op plans the paper grids generate, while the
# bulk path only ever engages on contended multi-job plans far above this
_SMALL_PLAN_MAX_FLOWS = 64

# bulk commit engages once a link serves at least this many concurrent
# flows; tests raise it to infinity to force the scalar path (bulk must be
# bit-identical, so the knob is a dispatch threshold, not a semantic one)
_BULK_MIN_ACTIVE = 2

# hard upper bound on a bulk call's per-job candidate chain (the adaptive
# per-link cap never exceeds it): bounds the numpy work a short commit
# window can waste on chains it will not commit; correctness is unaffected
# — a capped chain just ends in an artificial boundary and the next call
# continues the same cumsum bit-exactly
_BULK_CHAIN_CAP = 2048

# drains of this many newly-ready flows rebuild the admissible heap with
# one extend+heapify instead of per-item pushes (same pop order: a heap's
# pop sequence is the sorted multiset regardless of internal layout)
_DRAIN_BATCH_MIN = 16

# stall detection: the engine raises after this many consecutive
# no-progress calendar pops (stale projections / superseded admissions);
# the counter resets on any committed work — an admission, a served
# completion, or a bulk commit.  Module-level so tests can tighten them.
_STALL_FACTOR = 4
_STALL_BASE = 1000


class NetworkEngine:
    """Event-calendar executor for a set of flows over shared links.

    ``capacities`` maps link name -> number of flows that can run at full
    rate before fair sharing kicks in (default 1.0 — the whole link).
    ``rails`` maps link name -> rail count: a name with ``n > 1`` becomes a
    :class:`_LinkSet` of ``n`` independent per-rail service clocks and each
    flow's ``rail`` field selects its clock (modulo ``n``).  Links absent
    from ``rails`` (or mapped to 1) behave exactly as before, bit-for-bit.
    """

    def __init__(self, capacities: Optional[Dict[str, float]] = None,
                 rails: Optional[Dict[str, int]] = None):
        self.capacities = dict(capacities or {})
        self.rails = dict(rails or {})

    def run(self, flows: Sequence[FlowSpec],
            churn: Optional[Sequence[ChurnEvent]] = None
            ) -> List[FlowResult]:
        """Execute ``flows``; returns results in input order.

        Plans below :data:`_SMALL_PLAN_MAX_FLOWS` run the plain-list setup;
        anything larger columnarizes once and runs the batch core — the
        same engine :meth:`run_batch` uses, so tuple and batch callers
        share one large-plan code path (and its bit-identity proofs).
        ``churn`` events force the batch core regardless of size (the
        membership-change handler lives only there).

        Flows carrying a multi-link ``path`` dispatch to the max-min
        event loop (:meth:`_run_maxmin`); single-element paths normalize
        into ``link`` first, so any plan whose paths all have length
        <= 1 runs the original single-resource engine bit-for-bit.
        """
        if not flows:
            return []
        plen = 0
        for f in flows:
            if len(f.path) > plen:
                plen = len(f.path)
        if plen > 1:
            return self._run_maxmin(flows, churn)
        if plen:
            flows = [f._replace(link=f.path[0], path=()) if f.path else f
                     for f in flows]
        if len(flows) < _SMALL_PLAN_MAX_FLOWS and not churn:
            return self._run_small(flows)
        return self.run_batch(FlowBatch.from_flows(flows),
                              churn=churn).to_results()

    def _run_small(self, flows: Sequence[FlowSpec]) -> List[FlowResult]:
        """Plain-list setup and event loop for paper-size plans.

        No numpy anywhere: columnar setup costs more than the whole event
        loop below :data:`_SMALL_PLAN_MAX_FLOWS`, and the bulk path can
        never engage on the single-job plans this size.  The scalar event
        loop is the same as the batch core's, so results are bit-identical
        across the two setups.
        """
        n_total = len(flows)
        caps = self.capacities

        (op_col, rdy_col, wk_col, lt_col, pr_col, job_col, lk_col, hd_col,
         _du_col, rl_col, _w_col, _pth_col) = zip(*flows)

        rail_counts = self.rails
        if rail_counts and any(rail_counts.get(nm, 1) > 1
                               for nm in set(lk_col)):
            sets = {nm: _LinkSet(caps.get(nm, 1.0),
                                 max(rail_counts.get(nm, 1), 1))
                    for nm in set(lk_col)}
            link_of = [sets[nm].rails[r % len(sets[nm].rails)]
                       for nm, r in zip(lk_col, rl_col)]
        else:
            links: Dict[str, _Link] = {
                nm: _Link(caps.get(nm, 1.0)) for nm in set(lk_col)}
            link_of = list(map(links.__getitem__, lk_col))

        by_job: Dict[str, List[int]] = {}
        for i, name in enumerate(job_col):
            try:
                by_job[name].append(i)
            except KeyError:
                by_job[name] = [i]
        jobs: Dict[str, _Job] = {name: _Job() for name in by_job}
        job_of = list(map(jobs.__getitem__, job_col))

        cal: List = []              # (time, kind, seq, ...) event calendar
        seq = 0
        for name, idxs in by_job.items():
            jb = jobs[name]
            # plain-list service order: identical (priority, op_id)
            # total order, without paying numpy's fixed costs
            if len(idxs) > 1:
                idxs.sort(key=lambda i: (pr_col[i], op_col[i]))
            order = jb.order = idxs
            rdy = jb.rdy = [rdy_col[i] for i in order]
            monotone = all(a <= b for a, b in zip(rdy, rdy[1:]))
            if monotone:
                trigger = rdy[0]
            else:
                # ready times regress along service order (e.g. priority
                # plans): gate admissions on ready order.  ``order`` is
                # already (priority, op_id)-sorted, so sorting *positions*
                # stably by ready yields (ready, priority, op_id) order.
                jb.gated = sorted((rdy_col[i], pr_col[i], op_col[i], i)
                                  for i in order)
                jb.readyq = []
                trigger = jb.gated[0][0]
            seq += 1
            cal.append((trigger if trigger > 0.0 else 0.0, _ADMIT, seq, jb))
        heapify(cal)                # one pass beats n pushes at setup

        start: List[float] = [0.0] * n_total
        wire: List[float] = [0.0] * n_total
        end: List[float] = [0.0] * n_total
        contended: List[bool] = [False] * n_total
        n_done = 0
        stale = 0                   # consecutive no-progress calendar pops
        stall_limit = _STALL_FACTOR * n_total + _STALL_BASE
        sweep_at = 256              # calendar size that triggers a compaction
        flws = flows                # local alias for the hot loops

        # -- admission: put flow ``i`` on its link at time ``t`` ------------
        def _admit(i: int, jb: _Job, t: float) -> _Link:
            nonlocal stale
            stale = 0               # an admission is committed work
            L = link_of[i]
            if L.n:
                if t > L.t_last:
                    L.S += (t - L.t_last) * L.share
                L.t_last = t
                contended[i] = True
                if not L.all_contended:
                    for _, k in L.heap:
                        contended[k] = True
                    L.all_contended = True
            else:
                # fresh busy period: restart the service clock so the
                # single-flow closed form stays exact (mark == work)
                L.S = 0.0
                L.t_last = t
                if L.cap < 1.0:
                    contended[i] = True
                    L.all_contended = True
            heappush(L.heap, (L.S + wk_col[i], i))
            L.n += 1
            c = L.cap
            L.share = 1.0 if c >= L.n else c / L.n
            L.version += 1
            start[i] = t
            jb.busy = True
            return L

        # -- next-admission trigger for a job that just freed ---------------
        def _schedule_admit(jb: _Job, t: float) -> None:
            nonlocal seq
            if jb.gated is None:
                if jb.ptr < len(jb.order):
                    trig = jb.rdy[jb.ptr]
                    if trig < jb.free:
                        trig = jb.free
                    seq += 1
                    heappush(cal, (trig, _ADMIT, seq, jb))
            else:
                have_ready = bool(jb.readyq)
                nxt = jb.gated[jb.gptr][0] \
                    if jb.gptr < len(jb.gated) else None
                if have_ready:
                    seq += 1
                    heappush(cal, (jb.free, _ADMIT, seq, jb))
                elif nxt is not None:
                    trig = nxt if nxt > jb.free else jb.free
                    seq += 1
                    heappush(cal, (trig, _ADMIT, seq, jb))

        # -- heap mode: move gated flows with ready <= t to the admissible
        # set.  Draining earlier than the next service event is sound: any
        # scalar drain happens at a service time t' >= t and moves a
        # superset, and pops always consider the whole admissible set.
        def _drain(jb: _Job, t: float) -> None:
            g = jb.gated
            gp = jb.gptr
            ng = len(g)
            if gp >= ng or g[gp][0] > t:
                return
            j = gp + 1
            while j < ng and g[j][0] <= t:
                j += 1
            rq = jb.readyq
            if j - gp >= _DRAIN_BATCH_MIN:
                # bulk heappush: one heapify over the merged contents
                rq.extend((pr, op, i) for _r, pr, op, i in g[gp:j])
                heapify(rq)
            else:
                for _r, pr, op, i in g[gp:j]:
                    heappush(rq, (pr, op, i))
            jb.gptr = j

        while n_done < n_total:
            if not cal:
                raise RuntimeError(
                    f"event engine stalled: {n_done}/{n_total} flows done "
                    "with an empty calendar")
            ev = heappop(cal)
            t = ev[0]

            if ev[1] == _DONE:
                ver, L = ev[3], ev[4]
                if ver != L.version or not L.n:
                    stale += 1      # lazily-invalidated projection
                    if stale > stall_limit:
                        raise RuntimeError(
                            "event engine made no progress over "
                            f"{stale} events ({n_done}/{n_total} flows done)")
                    if len(cal) > sweep_at:
                        # batched stale sweep: one filter pass + heapify
                        # beats popping invalidated projections one by one
                        cal[:] = [e for e in cal if e[1] != _DONE
                                  or e[3] == e[4].version]
                        heapify(cal)
                        sweep_at = max(256, 2 * len(cal))
                    continue
                stale = 0
                # ---- completion spin: serve this link's completions while
                # they precede everything else on the calendar --------------
                while True:
                    if t > L.t_last:
                        L.S += (t - L.t_last) * L.share
                    L.t_last = t
                    s_top, i = heappop(L.heap)
                    L.S = s_top
                    L.n -= 1
                    L.version += 1
                    if L.n:
                        c = L.cap
                        L.share = 1.0 if c >= L.n else c / L.n
                    else:
                        L.all_contended = False
                    if contended[i]:
                        w = t
                        e = t + lt_col[i]
                    else:
                        # exact closed form: share was 1.0 throughout
                        w = float(start[i]) + wk_col[i]
                        d = flws[i].duration
                        if hd_col[i] and d is not None:
                            e = float(start[i]) + d
                        else:
                            e = w + lt_col[i]
                    wire[i] = w
                    end[i] = e
                    n_done += 1
                    jb = job_of[i]
                    jb.busy = False
                    jb.free = e if hd_col[i] else w
                    # instant re-admission keeps the spin going (the
                    # saturated steady state); anything else goes back
                    # through the calendar
                    readmitted = None
                    if not hd_col[i]:
                        if jb.gated is None:
                            p = jb.ptr
                            if p < len(jb.order) and jb.rdy[p] <= t:
                                jb.ptr = p + 1
                                readmitted = _admit(jb.order[p], jb, t)
                        else:
                            _drain(jb, t)
                            if jb.readyq:
                                k = heappop(jb.readyq)[2]
                                readmitted = _admit(k, jb, t)
                    if readmitted is None:
                        _schedule_admit(jb, t)
                    elif readmitted is not L:
                        # cross-link re-admission: project the other link
                        seq += 1
                        s2 = readmitted.heap[0][0]
                        proj2 = t + (s2 - readmitted.S) / readmitted.share
                        heappush(cal, (proj2 if proj2 > t else t, _DONE,
                                       seq, readmitted.version, readmitted))
                    if not L.n:
                        break
                    proj = t + (L.heap[0][0] - L.S) / L.share
                    if proj < t:
                        proj = t
                    if cal and cal[0][0] < proj:
                        seq += 1
                        heappush(cal, (proj, _DONE, seq, L.version, L))
                        break
                    t = proj
                continue

            # ---- admission event ------------------------------------------
            jb = ev[3]
            if jb.busy:
                stale += 1          # superseded by an instant re-admission
                if stale > stall_limit:
                    raise RuntimeError(
                        "event engine made no progress over "
                        f"{stale} events ({n_done}/{n_total} flows done)")
                continue
            if jb.free > t:         # defensive: fire again once free
                stale += 1
                _schedule_admit(jb, t)
                continue
            stale = 0               # a serviced admission trigger is progress
            admitted = None
            if jb.gated is None:
                p = jb.ptr
                if p < len(jb.order):
                    if jb.rdy[p] <= t:
                        jb.ptr = p + 1
                        admitted = _admit(jb.order[p], jb, t)
                    else:
                        _schedule_admit(jb, t)
            else:
                _drain(jb, t)
                if jb.readyq:
                    k = heappop(jb.readyq)[2]
                    admitted = _admit(k, jb, t)
                elif jb.gptr < len(jb.gated):
                    _schedule_admit(jb, t)
            if admitted is not None:
                seq += 1
                s_top = admitted.heap[0][0]
                proj = t + (s_top - admitted.S) / admitted.share
                heappush(cal, (proj if proj > t else t, _DONE, seq,
                               admitted.version, admitted))

        rows = zip(op_col, job_col, start, wire, end, contended)
        new = tuple.__new__
        return [new(FlowResult, row) for row in rows]

    def _run_maxmin(self, flows: Sequence[FlowSpec],
                    churn: Optional[Sequence[ChurnEvent]] = None
                    ) -> List[FlowResult]:
        """Multi-resource event loop: bottleneck max-min fair shares.

        Flows whose ``path`` spans several links progress at the rate
        progressive filling assigns them (:func:`maxmin_rates`), and the
        piecewise-constant rate vector is re-derived at every
        path-membership change — admission, completion, churn teardown —
        which is exactly the set of instants where it can change.  Between
        change-points each active flow's remaining work drains linearly at
        its rate, and the next completion is the minimum projection
        ``t + remaining / rate``.

        Job semantics are the single-resource engine's, verbatim: one
        in-flight flow per job in (priority, op_id) service order, ready
        gating, ``hold``/``latency``/``duration`` completion bookkeeping,
        and the closed-form ``start + work`` wire time for flows that were
        never contended.  A flow is contended when it ever shared a link
        with another active flow or could not run at full rate alone
        (some link's capacity is below the flow's own demand on it).

        Churn tears down the in-flight flow on **every** link of its path
        at once — the active set is the only link state, so removal frees
        its share on all of them for the next rate solve — then cancels a
        dropped worker's pending flows and applies the re-bucketing stall,
        mirroring the single-resource ``_apply_fault``.

        The loop is O(events x active x path): fabric cells keep at most
        one flow per job in flight, so the rate solve spans the handful of
        co-scheduled jobs, not the plan size.
        """
        caps = self.capacities
        n_total = len(flows)
        if self.rails and any(v > 1 for v in self.rails.values()):
            raise ValueError("multi-link paths and multi-rail links are "
                             "mutually exclusive on one engine")

        # per-flow demand: link -> multiplicity (repeats in ``path``)
        demand: List[Dict[str, float]] = []
        for f in flows:
            d: Dict[str, float] = {}
            for nm in (f.path or (f.link,)):
                d[nm] = d.get(nm, 0.0) + 1.0
            demand.append(d)
        link_cap: Dict[str, float] = {}
        for d in demand:
            for nm in d:
                if nm not in link_cap:
                    link_cap[nm] = float(caps.get(nm, 1.0))

        by_job: Dict[str, List[int]] = {}
        for i, f in enumerate(flows):
            by_job.setdefault(f.job, []).append(i)
        for q in by_job.values():
            # service order (priority, op_id); best last for cheap picks
            q.sort(key=lambda k: (flows[k].priority, flows[k].op_id),
                   reverse=True)
        job_free: Dict[str, float] = {j: 0.0 for j in by_job}
        active: Dict[str, int] = {}          # job -> in-flight flow index

        start = [0.0] * n_total
        wire = [0.0] * n_total
        end = [0.0] * n_total
        contended = [False] * n_total
        remaining = [0.0] * n_total
        rate = [0.0] * n_total
        n_done = 0

        events = sorted(churn or [],
                        key=lambda fe: fe.t if fe.t > 0.0 else 0.0)
        ep = 0
        t = 0.0
        guard = 0
        guard_max = _STALL_FACTOR * (n_total + len(events)) * 4 + _STALL_BASE

        def _pick(job: str) -> int:
            q = by_job[job]
            for k in range(len(q) - 1, -1, -1):  # sorted reverse: best last
                if flows[q[k]].ready <= t:
                    return q.pop(k)
            return -1

        def _rates() -> None:
            ids = list(active.values())
            rs = maxmin_rates([demand[i] for i in ids], link_cap)
            for k, i in enumerate(ids):
                rate[i] = rs[k]

        def _apply_churn(fe: ChurnEvent, tf: float) -> None:
            nonlocal n_done, guard
            pref = fe.job + "@"
            for j in by_job:
                if j != fe.job and not j.startswith(pref):
                    continue
                guard = 0
                # (a) the in-flight transfer is torn down by the membership
                # change on every link of its path and restarts from
                # scratch after the stall: push it back into the queue
                i = active.pop(j, None)
                if i is not None:
                    contended[i] = False  # readmission re-derives contention
                    q = by_job[j]
                    q.append(i)
                    q.sort(key=lambda k: (flows[k].priority,
                                          flows[k].op_id), reverse=True)
                # (b) dropout: the re-formed collective skips the dead
                # worker's buckets — its pending flows complete trivially
                if fe.kind == "drop" and fe.worker >= 0:
                    q = by_job[j]
                    dead = [k for k in q
                            if flows[k].worker == fe.worker]
                    if dead:
                        by_job[j] = [k for k in q
                                     if flows[k].worker != fe.worker]
                        for k in dead:
                            start[k] = tf
                            wire[k] = tf
                            end[k] = tf
                            contended[k] = False
                            n_done += 1
                # (c) the priced re-bucketing stall gates the next admission
                if fe.stall > 0.0:
                    ft = tf + fe.stall
                    if ft > job_free[j]:
                        job_free[j] = ft

        while n_done < n_total:
            guard += 1
            if guard > guard_max:
                raise RuntimeError(
                    "max-min engine made no progress "
                    f"({n_done}/{n_total} flows done)")

            # -- admissions at the current time ----------------------------
            admitted = False
            for j, q in by_job.items():
                if j in active or job_free[j] > t or not q:
                    continue
                i = _pick(j)
                if i < 0:
                    continue
                start[i] = t
                remaining[i] = flows[i].work
                d = demand[i]
                if any(link_cap[nm] < m for nm, m in d.items()):
                    # some link cannot carry even this flow alone at full
                    # rate: the closed-form completion is invalid
                    contended[i] = True
                for oi in active.values():
                    od = demand[oi]
                    shared = any(nm in od for nm in d)
                    if shared:
                        contended[oi] = True
                        contended[i] = True
                active[j] = i
                admitted = True
            if admitted:
                guard = 0
                continue            # membership changed; recompute rates

            _rates()

            # -- next event: completion, admission trigger, or churn -------
            t_next = None
            for i in active.values():
                if rate[i] > 0.0:
                    proj = t + remaining[i] / rate[i]
                    if t_next is None or proj < t_next:
                        t_next = proj
            for j, q in by_job.items():
                if j in active or not q:
                    continue
                earliest = min(flows[k].ready for k in q)
                trigger = max(job_free[j], earliest)
                if t_next is None or trigger < t_next:
                    t_next = trigger
            if ep < len(events):
                ft = events[ep].t
                if ft < 0.0:
                    ft = 0.0
                if t_next is None or ft < t_next:
                    t_next = ft
            if t_next is None:
                raise RuntimeError(
                    "max-min engine stalled with pending flows")
            if t_next < t:
                t_next = t

            # -- advance every active wire at its current rate -------------
            dt = t_next - t
            completions: List[Tuple[str, int]] = []
            for j, i in active.items():
                r = rate[i]
                remaining[i] -= dt * r
                # done when the residual is negligible — or too small to
                # advance the clock at all (absorbed below ulp(t_next))
                if r > 0.0 and (
                        remaining[i] <= flows[i].work * 1e-12 + 1e-18
                        or t_next + remaining[i] / r <= t_next):
                    completions.append((j, i))
            t = t_next

            for j, i in completions:
                f = flows[i]
                if not contended[i]:
                    w = start[i] + f.work  # exact: rate was 1.0 throughout
                    if f.hold and f.duration is not None:
                        e = start[i] + f.duration
                    else:
                        e = w + f.latency
                else:
                    w = t
                    e = w + f.latency
                wire[i] = w
                end[i] = e
                job_free[j] = e if f.hold else w
                del active[j]
                n_done += 1
                guard = 0

            # -- churn due now fires after same-time completions, before
            # the next round of admissions (the _DONE < _FAULT < _ADMIT
            # calendar order of the single-resource core) ------------------
            while ep < len(events) and (
                    events[ep].t if events[ep].t > 0.0 else 0.0) <= t:
                _apply_churn(events[ep], t)
                ep += 1

        rows = zip([f.op_id for f in flows], [f.job for f in flows],
                   start, wire, end, contended)
        new = tuple.__new__
        return [new(FlowResult, row) for row in rows]

    def run_batch(self, batch: FlowBatch,
                  churn: Optional[Sequence[ChurnEvent]] = None
                  ) -> ResultBatch:
        """Execute a columnar batch; results align with the batch's order.

        The large-plan setup is fully vectorized: one global
        ``lexsort((op_id, priority, job))`` yields every job's
        (priority, op_id) service order *and* groups jobs in
        first-appearance order (the job-code invariant), so per-job state
        is built from contiguous slices — no tuple materialization and no
        per-job sorts.  Below :data:`_SMALL_PLAN_MAX_FLOWS` the batch
        bounces to the plain-list path (columnar setup must never engage
        on paper-size plans); either way results are bit-identical to
        ``run(batch.to_flows())``.

        ``churn`` events (membership changes — see :class:`ChurnEvent`)
        enter the calendar as ``_FAULT`` entries and keep the batch on
        the columnar core whatever its size; an empty/None ``churn`` is
        bit-identical to a run that never heard of faults.
        """
        n_total = batch.n
        if not n_total:
            z = np.zeros(0)
            return ResultBatch(batch.op_id, batch.jobs, batch.job,
                               z, np.zeros(0), np.zeros(0),
                               np.zeros(0, dtype=bool))
        if batch.path_link is not None and batch.path_link.shape[0]:
            plens = np.diff(batch.path_off)
            if plens.max() > 1:
                res = self._run_maxmin(batch.to_flows(), churn)
                return ResultBatch(
                    batch.op_id, batch.jobs, batch.job,
                    np.array([r.start for r in res]),
                    np.array([r.wire_end for r in res]),
                    np.array([r.end for r in res]),
                    np.array([r.contended for r in res], dtype=bool))
            # every path has length <= 1: normalize one-element paths into
            # the ``link`` column and run the single-resource engine —
            # bit-identical by construction (it only ever reads ``link``)
            m = plens > 0
            link = batch.link.copy()
            link[m] = batch.path_link[batch.path_off[:-1][m]]
            batch = batch._replace(link=link, path_off=None, path_link=None)
        if n_total < _SMALL_PLAN_MAX_FLOWS and not churn:
            res = self._run_small(batch.to_flows())
            return ResultBatch(
                batch.op_id, batch.jobs, batch.job,
                np.array([r.start for r in res]),
                np.array([r.wire_end for r in res]),
                np.array([r.end for r in res]),
                np.array([r.contended for r in res], dtype=bool))

        caps = self.capacities
        names = batch.links
        li_col = batch.link
        rail_counts = self.rails
        li_dense = None             # dense per-flow link index, when needed
        if rail_counts and any(rail_counts.get(nm, 1) > 1 for nm in names):
            rail_objs: List[_Link] = []
            base = np.empty(len(names), dtype=np.intp)
            nr = np.empty(len(names), dtype=np.intp)
            for k, nm in enumerate(names):
                r = max(rail_counts.get(nm, 1), 1)
                base[k] = len(rail_objs)
                nr[k] = r
                cap = caps.get(nm, 1.0)
                rail_objs.extend(_Link(cap) for _ in range(r))
            li_dense = base[li_col] + batch.rail % nr[li_col]
            link_of = np.asarray(rail_objs, dtype=object)[li_dense].tolist()
            one_link = len(rail_objs) == 1
        elif len(names) == 1:
            link_of = [_Link(caps.get(names[0], 1.0))] * n_total
            one_link = True
        else:
            rail_objs = [_Link(caps.get(nm, 1.0)) for nm in names]
            li_dense = li_col
            link_of = np.asarray(rail_objs, dtype=object)[li_col].tolist()
            one_link = len(rail_objs) == 1

        rd_np = batch.ready
        jcode = batch.job
        n_jobs = len(batch.jobs)
        if n_jobs > 1:
            # stable 3-key sort == per-job (priority, op_id) lexsorts, with
            # segments in job-code (= first-appearance) order
            order_g = np.lexsort((batch.op_id, batch.priority, jcode))
            jc_sorted = jcode[order_g]
            cuts = np.flatnonzero(jc_sorted[1:] != jc_sorted[:-1]) + 1
            bounds = np.concatenate((
                np.zeros(1, dtype=np.intp), cuts,
                np.full(1, n_total, dtype=np.intp)))
        else:
            order_g = np.lexsort((batch.op_id, batch.priority))
            bounds = np.array([0, n_total], dtype=np.intp)

        wk_col = batch.work.tolist()
        lt_col = batch.latency.tolist()
        hd_col = batch.hold.tolist()
        du_col = batch.duration.tolist()

        cal: List = []
        seq = 0
        job_list: List[_Job] = []
        for s_, e_ in zip(bounds[:-1].tolist(), bounds[1:].tolist()):
            ix = order_g[s_:e_]
            jb = _Job()
            jb.onp = ix
            order = jb.order = ix.tolist()
            rd_ix = rd_np[ix]
            rdy = jb.rdy = rd_ix.tolist()
            monotone = (len(rdy) == 1
                        or bool((rd_ix[1:] >= rd_ix[:-1]).all()))
            if one_link:
                jb.link = link_of[order[0]]
            else:
                li_ix = li_dense[ix]
                jb.link = (link_of[order[0]]
                           if bool((li_ix == li_ix[0]).all()) else None)
            if monotone:
                trigger = rdy[0]
            else:
                g_pos = np.argsort(rd_ix, kind="stable")
                jb.gated = g_pos
                jb.g_rd = rd_ix[g_pos]
                jb.readyq = np.zeros(len(order), dtype=bool)
                trigger = float(jb.g_rd[0])
            seq += 1
            cal.append((trigger if trigger > 0.0 else 0.0, _ADMIT, seq, jb))
            job_list.append(jb)
        if n_jobs > 1:
            job_of = np.asarray(job_list, dtype=object)[jcode].tolist()
        else:
            job_of = [job_list[0]] * n_total

        if churn:
            # resolve job names to _Job objects once; sort for a
            # deterministic seq order at equal fault times.  A name
            # matches exactly or as a rail-lane prefix (job0 -> job0@r1).
            jnames = batch.jobs
            for fe in sorted(churn):
                matched = [job_list[ci] for ci, nm in enumerate(jnames)
                           if nm == fe.job or nm.startswith(fe.job + "@")]
                if not matched:
                    continue
                seq += 1
                cal.append((fe.t if fe.t > 0.0 else 0.0,
                            _RETX if fe.kind == "retx" else _FAULT, seq,
                            matched, fe))

        start, wire, end, contended = _run_core(
            n_total, wk_col, lt_col, hd_col, du_col, rd_np, link_of,
            job_of, cal, seq, batch.work, batch.hold, batch.latency,
            batch.worker)
        return ResultBatch(batch.op_id, batch.jobs, batch.job,
                           start, wire, end, contended)


def run_flows(flows: Sequence[FlowSpec],
              capacities: Optional[Dict[str, float]] = None,
              rails: Optional[Dict[str, int]] = None,
              churn: Optional[Sequence[ChurnEvent]] = None
              ) -> List[FlowResult]:
    """Convenience wrapper: execute ``flows`` on a fresh engine.

    ``capacities`` and ``rails`` are per-link-name maps — see
    :class:`NetworkEngine`; ``churn`` is a list of membership-change
    events (:class:`ChurnEvent`).
    """
    return NetworkEngine(capacities, rails).run(flows, churn=churn)


def run_flow_batch(batch: FlowBatch,
                   capacities: Optional[Dict[str, float]] = None,
                   rails: Optional[Dict[str, int]] = None,
                   churn: Optional[Sequence[ChurnEvent]] = None
                   ) -> ResultBatch:
    """Columnar :func:`run_flows`: execute a batch on a fresh engine."""
    return NetworkEngine(capacities, rails).run_batch(batch, churn=churn)


def _run_core(n_total: int, wk_col, lt_col, hd_col, du_col, rd_np,
              link_of, job_of, cal, seq, g_wk, g_hd, g_lt, g_wr=None):
    """The large-plan event loop over columnar state.

    ``wk_col``/``lt_col``/``hd_col``/``du_col`` are plain python lists
    (scalar indexing in the hot loop), ``g_wk``/``g_hd``/``g_lt``/``rd_np``
    the matching numpy columns (the bulk path's gathers); ``du_col`` holds
    NaN where a duration is absent.  ``cal`` arrives as an unheapified
    list of per-job admission triggers in job first-appearance order,
    plus any ``_FAULT`` entries (``g_wr`` is the worker column their
    dropout cancellation filters on).
    Returns ``(start, wire, end, contended)`` numpy arrays.
    """
    heapify(cal)                # one pass beats n pushes at setup
    start = np.zeros(n_total)
    wire = np.zeros(n_total)
    end = np.zeros(n_total)
    contended = np.zeros(n_total, dtype=bool)
    n_done = 0
    stale = 0                   # consecutive no-progress calendar pops
    # the budget scales with every entry that can legitimately pop without
    # serving a flow: each fault/retx entry both pops once itself and can
    # supersede one pending admission, so a dense _RETX calendar (long
    # backoff stalls, zero committed work in between) must widen the
    # limit rather than trip it.  _apply_fault resets the counter — a
    # fault *is* committed calendar work — so this is belt and braces.
    n_faults = sum(1 for ev in cal if ev[1] == _FAULT or ev[1] == _RETX)
    stall_limit = _STALL_FACTOR * (n_total + 2 * n_faults) + _STALL_BASE
    sweep_at = 256              # calendar size that triggers a compaction

    # -- admission: put flow ``i`` on its link at time ``t`` ----------------
    def _admit(i: int, jb: _Job, t: float) -> _Link:
        nonlocal stale
        stale = 0               # an admission is committed work
        L = link_of[i]
        if L.n:
            if t > L.t_last:
                L.S += (t - L.t_last) * L.share
            L.t_last = t
            contended[i] = True
            if not L.all_contended:
                for _, k in L.heap:
                    contended[k] = True
                L.all_contended = True
        else:
            # fresh busy period: restart the service clock so the
            # single-flow closed form stays exact (mark == work)
            L.S = 0.0
            L.t_last = t
            if L.cap < 1.0:
                contended[i] = True
                L.all_contended = True
        heappush(L.heap, (L.S + wk_col[i], i))
        L.n += 1
        c = L.cap
        L.share = 1.0 if c >= L.n else c / L.n
        L.version += 1
        start[i] = t
        jb.busy = True
        return L

    # -- next-admission trigger for a job that just freed -------------------
    def _schedule_admit(jb: _Job, t: float) -> None:
        nonlocal seq
        if jb.gated is None:
            if jb.ptr < len(jb.order):
                trig = jb.rdy[jb.ptr]
                if trig < jb.free:
                    trig = jb.free
                seq += 1
                heappush(cal, (trig, _ADMIT, seq, jb))
        else:
            have_ready = jb.n_ready > 0
            nxt = float(jb.g_rd[jb.gptr]) \
                if jb.gptr < jb.g_rd.shape[0] else None
            if have_ready:
                seq += 1
                heappush(cal, (jb.free, _ADMIT, seq, jb))
            elif nxt is not None:
                trig = nxt if nxt > jb.free else jb.free
                seq += 1
                heappush(cal, (trig, _ADMIT, seq, jb))

    # -- heap mode: move gated flows with ready <= t to the admissible
    # set.  Draining earlier than the next service event is sound: any
    # scalar drain happens at a service time t' >= t and moves a
    # superset, and pops always consider the whole admissible set.
    def _drain(jb: _Job, t: float) -> None:
        gp = jb.gptr
        grd = jb.g_rd
        if gp >= grd.shape[0] or grd[gp] > t:
            return
        j = int(grd.searchsorted(t, side="right"))
        jb.readyq[jb.gated[gp:j]] = True   # one sliced scatter
        jb.n_ready += j - gp
        jb.gptr = j

    # -- membership change: pull back the wire, cancel the dead worker's
    # pending flows, stall the survivors through the re-bucketing ----------
    def _apply_fault(jb: _Job, fe, t: float) -> None:
        nonlocal n_done, seq, stale
        stale = 0                   # a fault is committed calendar work
        # (a) the in-flight transfer is torn down by the membership change
        # and restarts from scratch after the stall: un-admit it
        if jb.busy:
            if jb.gated is None:
                jb.ptr -= 1
                i = jb.order[jb.ptr]
            else:
                p = jb.apos
                jb.readyq[p] = True
                jb.n_ready += 1
                i = jb.order[p]
            L = link_of[i]
            if t > L.t_last:
                L.S += (t - L.t_last) * L.share
            L.t_last = t
            L.heap = [e for e in L.heap if e[1] != i]
            heapify(L.heap)
            L.n -= 1
            L.version += 1
            if L.n:
                c = L.cap
                L.share = 1.0 if c >= L.n else c / L.n
                seq += 1
                proj = t + (L.heap[0][0] - L.S) / L.share
                heappush(cal, (proj if proj > t else t, _DONE, seq,
                               L.version, L))
            else:
                L.all_contended = False
            contended[i] = False    # readmission re-derives contention
            jb.busy = False
        # (b) dropout: the re-formed collective skips the dead worker's
        # buckets this iteration — its pending flows complete trivially now
        if fe.kind == "drop" and fe.worker >= 0 and g_wr is not None:
            onp = jb.onp
            if onp is None:
                onp = jb.onp = np.asarray(jb.order, dtype=np.intp)
            if jb.gated is None:
                tail = onp[jb.ptr:]
                dead_m = g_wr[tail] == fe.worker
                if dead_m.any():
                    ids = tail[dead_m]
                    start[ids] = t
                    wire[ids] = t
                    end[ids] = t
                    contended[ids] = False
                    n_done += int(ids.size)
                    live = tail[~dead_m]
                    jb.order = jb.order[:jb.ptr] + live.tolist()
                    jb.rdy = jb.rdy[:jb.ptr] + rd_np[live].tolist()
                    jb.onp = None   # invalidate the bulk path's views
                    jb.wk = None
            else:
                wk_pos = g_wr[onp]  # worker per order position
                pos_r = np.flatnonzero(jb.readyq)
                dead_r = pos_r[wk_pos[pos_r] == fe.worker]
                g_tail = jb.gated[jb.gptr:]
                live_m = wk_pos[g_tail] != fe.worker
                dead_g = g_tail[~live_m]
                if dead_r.size or dead_g.size:
                    ids = onp[np.concatenate((dead_r, dead_g))]
                    start[ids] = t
                    wire[ids] = t
                    end[ids] = t
                    contended[ids] = False
                    n_done += int(ids.size)
                if dead_r.size:
                    jb.readyq[dead_r] = False
                    jb.n_ready -= int(dead_r.size)
                if dead_g.size:
                    jb.gated = g_tail[live_m]
                    jb.g_rd = jb.g_rd[jb.gptr:][live_m]
                    jb.gptr = 0
        # (c) the priced re-bucketing stall gates the next admission
        if fe.stall > 0.0:
            ft = t + fe.stall
            if ft > jb.free:
                jb.free = ft
        _schedule_admit(jb, t)

    # -- bulk commit: vectorized saturated stretch on link ``L`` ------------
    def _try_bulk(L: _Link, t0: float, t_cal: float,
                  t_first: Optional[float] = None) -> int:
        """While every completion instantly re-admits (constant
        membership, constant share), each job's future completion marks
        are prefix sums of its works — a pointer-mode job's marks walk
        ``order[ptr:]``, a heap-mode job's walk its *resolved prefix*
        (the admissible mask in (priority, op_id) order, valid until
        the next gated ready time).  The per-job chains merge into one
        (mark, flow)-sorted sequence whose completion times are a
        single chained left fold — the exact float operations the
        scalar spin performs, so bulk commits are bit-identical to
        scalar processing.  Every completion strictly before the first
        boundary (ready gate, gating boundary, hold flow, chain cap,
        or the ``t_cal`` calendar fence) commits in one vectorized pass.
        ``t_first`` overrides the first completion time — a *parked*
        link's calendar entry carries the exact (possibly clamped) time
        the scalar loop would have served it at.  Returns the number of
        flows committed."""
        nonlocal n_done, stale
        S0 = L.S
        share = L.share
        # O(1) pre-checks on the earliest completion: if its own job
        # cannot instantly re-admit, the very first completion is a
        # boundary and nothing can commit
        m_top, i_top = L.heap[0]
        if t_first is None:
            t_first = t0 + (m_top - S0) / share
        if t_cal <= t_first:
            return 0
        jb_top = job_of[i_top]
        if hd_col[i_top]:
            return 0
        if jb_top.gated is None:
            p = jb_top.ptr
            if p >= len(jb_top.order) or jb_top.rdy[p] > t_first:
                return 0
        else:
            _drain(jb_top, t0)
            if not jb_top.n_ready:
                return 0
        # every heap-mode job's gating boundary caps the whole window
        # (commits stop at the earliest gate), so if any gate precedes
        # the first completion the call cannot commit — an O(jobs)
        # rejection that keeps gate-dense phases (jittered plans) cheap
        for _m_x, i_x in L.heap:
            jx = job_of[i_x]
            if jx.gated is not None:
                _drain(jx, t0)
                if (jx.gptr < jx.g_rd.shape[0]
                        and jx.g_rd[jx.gptr] <= t_first):
                    L.bulk_skip = 4     # locally gate-dense: go scalar
                    return 0
        # no mark beyond this can commit (commit times are < t_cal), so
        # chains truncate here before the merge sort — a truncation is
        # just an earlier artificial boundary, never an arithmetic
        # change, and the next call continues the same cumsum exactly
        mark_limit = S0 + (t_cal - t0) * share
        chains = []
        mark_segs = []
        id_segs = []
        for m0, i0 in L.heap:
            jb = job_of[i0]
            if jb.link is not L:
                return 0
            if jb.wk is None:
                onp = jb.onp
                if onp is None:
                    onp = jb.onp = np.asarray(jb.order, dtype=np.intp)
                jb.wk = g_wk[onp]
                jb.rd = rd_np[onp]
                jb.hd = g_hd[onp]
                jb.lt = g_lt[onp]
            kcap = L.bulk_cap
            if jb.gated is None:
                ptr = jb.ptr
                k = len(jb.order) - ptr
                if k > kcap:
                    k = kcap
                ids = np.empty(k + 1, dtype=np.intp)
                ids[0] = i0
                ids[1:] = jb.onp[ptr:ptr + k]
                marks = np.empty(k + 1)
                marks[0] = m0
                marks[1:] = jb.wk[ptr:ptr + k]
                pos = None
            else:
                # resolved prefix: the admissible mask in service order
                # (this job was already drained by the gate pre-check)
                pos = jb.readyq.nonzero()[0]
                k = pos.shape[0]
                if k > kcap:
                    k = kcap
                    pos = pos[:k]
                ids = np.empty(k + 1, dtype=np.intp)
                ids[0] = i0
                ids[1:] = jb.onp[pos]
                marks = np.empty(k + 1)
                marks[0] = m0
                marks[1:] = jb.wk[pos]
            marks = marks.cumsum()          # exact left fold, like scalar
            if marks.shape[0] > 8:
                kk = int(marks.searchsorted(mark_limit,
                                            side="right")) + 2
                if kk < marks.shape[0]:
                    marks = marks[:kk]
                    ids = ids[:kk]
                    if pos is not None:
                        pos = pos[:kk - 1]
            chains.append((jb, m0, i0, marks, ids, pos))
            mark_segs.append(marks)
            id_segs.append(ids)
        # merge all chains into global service order (ties break on the
        # flow index, exactly like the link heap's (mark, i) tuples),
        # then chain completion times with the scalar spin's own
        # arithmetic: t_{j} = t_{j-1} + (m_j - m_{j-1}) / share
        M = np.concatenate(mark_segs)
        I = np.concatenate(id_segs)
        order_g = np.lexsort((I, M))
        Ms = M[order_g]
        d = np.empty_like(Ms)
        d[0] = t_first
        if Ms.shape[0] > 1:
            d[1:] = (Ms[1:] - Ms[:-1]) / share
        times_sorted = d.cumsum()
        times_flat = np.empty_like(times_sorted)
        times_flat[order_g] = times_sorted
        t_stop = t_cal
        metas = []
        off = 0
        for jb, m0, i0, marks, ids, pos in chains:
            n_j = marks.shape[0]
            times = times_flat[off:off + n_j]
            off += n_j
            k = n_j - 1                     # future flows in the chain
            if jb.gated is None:
                ptr = jb.ptr
                if k:
                    viol = ((jb.rd[ptr:ptr + k] > times[:k])
                            | jb.hd[ptr - 1:ptr + k - 1])
                    nz = viol.nonzero()[0]
                    v = int(nz[0]) + 1 if nz.size else k + 1
                else:
                    v = 1
                bt = times[v - 1]           # this job's boundary time
            else:
                if k:
                    hd_prev = g_hd[ids[:k]]
                    nz = hd_prev.nonzero()[0]
                    v = int(nz[0]) + 1 if nz.size else k + 1
                    bt = times[v - 1]
                    # gating boundary: a commit window reaching the
                    # next gated ready time would let a fresh flow
                    # preempt the resolved prefix
                    gp = jb.gptr
                    if gp < jb.g_rd.shape[0]:
                        tg = jb.g_rd[gp]
                        if tg < bt:
                            bt = tg
                else:
                    v = 1
                    bt = times[0]
            if bt < t_stop:
                t_stop = bt
            metas.append((jb, m0, i0, marks, times, v, ids, pos))
        total = 0
        entries = []
        for jb, m0, i0, marks, times, v, ids, pos in metas:
            c = int(times[:v].searchsorted(t_stop, side="left"))
            if c == 0:
                entries.append((m0, i0))
                continue
            tc = times[:c]
            idc = ids[:c]
            if c > 1:
                start[ids[1:c]] = tc[:-1]
            wire[idc] = tc
            if jb.gated is None:
                ptr = jb.ptr
                end[idc] = tc + jb.lt[ptr - 1:ptr + c - 1]
                ia = jb.order[ptr + c - 1]  # the job's new active flow
                jb.ptr = ptr + c
            else:
                end[idc] = tc + g_lt[idc]
                ia = int(ids[c])
                # consume the committed prefix plus the new active flow
                # (``ids[1:] = onp[pos]``, so ``ia`` sits at ``pos[c-1]``)
                jb.readyq[pos[:c]] = False
                jb.n_ready -= c
                jb.apos = int(pos[c - 1])
            contended[idc] = True
            tl = float(tc[-1])
            start[ia] = tl
            contended[ia] = True
            entries.append((float(marks[c]), ia))
            total += c
        if not total:
            return 0
        L.heap = entries
        heapify(entries)
        # final link state = exactly the scalar spin's after serving
        # the last committed completion of the merged sequence
        n_commit = int(times_sorted.searchsorted(t_stop, side="left"))
        L.S = float(Ms[n_commit - 1])
        L.t_last = float(times_sorted[n_commit - 1])
        L.version += 1
        # geometric cap adaptation: big commits earn longer chains next
        # call, near-empty windows shrink the per-call numpy work
        nc = 2 * total
        L.bulk_cap = (_BULK_CHAIN_CAP if nc > _BULK_CHAIN_CAP
                      else nc if nc > 32 else 32)
        if total < 4 * L.n:
            L.bulk_skip = 64    # window too small to pay numpy setup
        n_done += total
        stale = 0               # bulk-committed work is progress
        return total

    # -- multi-link bulk window: retire saturated stretches across all
    # eligible links per window, not one ``_try_bulk(L, t)`` at a time ------
    def _bulk_window(L: _Link, t0: float) -> int:
        """Park other links' valid projected completions at the front of
        the calendar when those links are themselves bulk-eligible and
        *self-contained* (every job in their heap runs entirely on them,
        so nothing they commit can admit work on another link — any
        cross-link effect would arrive as a calendar event, which then
        fences the window).  The shared fence ``t_cal`` is the first
        non-parkable event; ``L`` and every parked link each retire their
        stretch against it.  A parked link's first completion is served at
        the exact time its calendar entry carried (the scalar loop's
        arithmetic, clamping included); an entry whose link commits
        nothing is re-pushed *unchanged* — same seq, same tie order."""
        nonlocal seq
        parked = []
        while cal:
            ev = cal[0]
            if ev[1] != _DONE:
                break
            L2 = ev[4]
            if ev[3] != L2.version:
                heappop(cal)        # lazily-invalidated projection
                continue
            if L2 is L or L2.n < _BULK_MIN_ACTIVE or L2.bulk_skip:
                break
            contained = True
            for _m, i in L2.heap:
                if job_of[i].link is not L2:
                    contained = False
                    break
            if not contained:
                break
            heappop(cal)
            parked.append(ev)
        t_cal = cal[0][0] if cal else _INF
        total = _try_bulk(L, t0, t_cal)
        for ev in parked:
            L2 = ev[4]
            if ev[3] != L2.version:
                continue            # defensive; parked links are disjoint
            if _try_bulk(L2, L2.t_last, t_cal, ev[0]):
                # bulk preserves membership (every completion re-admits),
                # so L2 still has a next completion to project
                seq += 1
                proj2 = L2.t_last + (L2.heap[0][0] - L2.S) / L2.share
                if proj2 < L2.t_last:
                    proj2 = L2.t_last
                heappush(cal, (proj2, _DONE, seq, L2.version, L2))
            else:
                heappush(cal, ev)
        return total

    while n_done < n_total:
        if not cal:
            raise RuntimeError(
                f"event engine stalled: {n_done}/{n_total} flows done "
                "with an empty calendar")
        ev = heappop(cal)
        t = ev[0]

        if ev[1] == _DONE:
            ver, L = ev[3], ev[4]
            if ver != L.version or not L.n:
                stale += 1      # lazily-invalidated projection
                if stale > stall_limit:
                    raise RuntimeError(
                        "event engine made no progress over "
                        f"{stale} events ({n_done}/{n_total} flows done)")
                if len(cal) > sweep_at:
                    # batched stale sweep: one filter pass + heapify
                    # beats popping invalidated projections one by one
                    # (non-_DONE entries carry no link/version to check)
                    cal[:] = [e for e in cal if e[1] != _DONE
                              or e[3] == e[4].version]
                    heapify(cal)
                    sweep_at = max(256, 2 * len(cal))
                continue
            stale = 0
            # ---- completion spin: serve this link's completions while
            # they precede everything else on the calendar ------------------
            while True:
                if t > L.t_last:
                    L.S += (t - L.t_last) * L.share
                L.t_last = t
                s_top, i = heappop(L.heap)
                L.S = s_top
                L.n -= 1
                L.version += 1
                if L.n:
                    c = L.cap
                    L.share = 1.0 if c >= L.n else c / L.n
                else:
                    L.all_contended = False
                if contended[i]:
                    w = t
                    e = t + lt_col[i]
                else:
                    # exact closed form: share was 1.0 throughout
                    w = float(start[i]) + wk_col[i]
                    d = du_col[i]
                    if hd_col[i] and d == d:    # NaN = no duration
                        e = float(start[i]) + d
                    else:
                        e = w + lt_col[i]
                wire[i] = w
                end[i] = e
                n_done += 1
                jb = job_of[i]
                jb.busy = False
                jb.free = e if hd_col[i] else w
                # instant re-admission keeps the spin going (the
                # saturated steady state); anything else goes back
                # through the calendar
                readmitted = None
                if not hd_col[i]:
                    if jb.gated is None:
                        p = jb.ptr
                        if p < len(jb.order) and jb.rdy[p] <= t:
                            jb.ptr = p + 1
                            readmitted = _admit(jb.order[p], jb, t)
                    else:
                        _drain(jb, t)
                        if jb.n_ready:
                            # first set bit = best (priority, op_id)
                            p = int(jb.readyq.argmax())
                            jb.readyq[p] = False
                            jb.n_ready -= 1
                            jb.apos = p
                            readmitted = _admit(jb.order[p], jb, t)
                if readmitted is None:
                    _schedule_admit(jb, t)
                elif readmitted is not L:
                    # cross-link re-admission: project the other link
                    seq += 1
                    s2 = readmitted.heap[0][0]
                    proj2 = t + (s2 - readmitted.S) / readmitted.share
                    heappush(cal, (proj2 if proj2 > t else t, _DONE,
                                   seq, readmitted.version, readmitted))
                if not L.n:
                    break
                if L.n >= _BULK_MIN_ACTIVE:
                    if L.bulk_skip:
                        L.bulk_skip -= 1
                    elif _bulk_window(L, t):
                        t = L.t_last
                        if not L.n:
                            break
                proj = t + (L.heap[0][0] - L.S) / L.share
                if proj < t:
                    proj = t
                if cal and cal[0][0] < proj:
                    seq += 1
                    heappush(cal, (proj, _DONE, seq, L.version, L))
                    break
                t = proj
            continue

        if ev[1] == _FAULT or ev[1] == _RETX:
            # ---- membership change / retransmission timeout: apply to
            # every matched job (retx shares the fault handler — its kind
            # never matches the "drop" cancellation gate, so it reduces to
            # pull-back + backoff stall)
            for jb in ev[3]:
                _apply_fault(jb, ev[4], t)
            continue

        # ---- admission event ----------------------------------------------
        jb = ev[3]
        if jb.busy:
            stale += 1          # superseded by an instant re-admission
            if stale > stall_limit:
                raise RuntimeError(
                    "event engine made no progress over "
                    f"{stale} events ({n_done}/{n_total} flows done)")
            continue
        if jb.free > t:         # defensive: fire again once free
            stale += 1
            _schedule_admit(jb, t)
            continue
        stale = 0               # a serviced admission trigger is progress
        admitted = None
        if jb.gated is None:
            p = jb.ptr
            if p < len(jb.order):
                if jb.rdy[p] <= t:
                    jb.ptr = p + 1
                    admitted = _admit(jb.order[p], jb, t)
                else:
                    _schedule_admit(jb, t)
        else:
            _drain(jb, t)
            if jb.n_ready:
                p = int(jb.readyq.argmax())
                jb.readyq[p] = False
                jb.n_ready -= 1
                jb.apos = p
                admitted = _admit(jb.order[p], jb, t)
            elif jb.gptr < jb.g_rd.shape[0]:
                _schedule_admit(jb, t)
        if admitted is not None:
            seq += 1
            s_top = admitted.heap[0][0]
            proj = t + (s_top - admitted.S) / admitted.share
            heappush(cal, (proj if proj > t else t, _DONE, seq,
                           admitted.version, admitted))

    return start, wire, end, contended





