"""Discrete-event network engine: link resources with fair-share bandwidth.

The serialized bucket loop the simulator used to hard-code is one point in
a much larger scheduling space.  This engine executes *flows* — wire
transfers with a fixed post-wire latency (the reduction/vector-add phase of
a collective) — against named link resources:

- **links** split their bandwidth fairly among concurrent flows (progressive
  filling: each of the k active flows progresses at 1/k of full rate), which
  is what makes multi-job contention expressible;
- **jobs** serialize their own flows (one wire in flight per job): a ring
  all-reduce occupies the full NIC, so intra-job concurrency happens at
  chunk granularity via the scheduler that *ordered* the flows, not via the
  link;
- a job admits its highest-priority ready flow whenever it is free; a flow
  with ``hold=True`` keeps the job busy through its latency (Horovod's
  serialized all-reduce process), otherwise the job frees at wire end and
  the latency overlaps the next flow's transmission (pipelined chunks).

Exactness: a ``hold`` flow whose wire phase never shared its link completes
at ``start + duration`` with ``duration`` precomputed by the caller as a
single float expression — so the ``fifo`` schedule reproduces the legacy
serialized loop bit-for-bit, not just within tolerance.  A flow counts as
``contended`` only if it shared its link for a *nonzero* duration; the seed
engine also flagged zero-duration overlaps (two flows co-admitted at an
instant where one has zero residual work), which changed no completion time
beyond re-rounding but cosmetically dropped the closed form.

Times in seconds; ``work`` is wire time at full link rate (the caller bakes
bandwidth into it via the cost model).

Engine architecture (the O((n+e) log n) event calendar)
-------------------------------------------------------

The seed implementation rescanned every pending/running flow at every event
and advanced all wires step by step — quadratic once plans reach thousands
of flows.  This version is indexed end to end:

- **per-job admission state**: flows sort once into service order
  ``(priority, op_id)``.  When ready times are non-decreasing along that
  order (fifo/chunked plans), the next admissible flow is a pointer
  increment; otherwise (priority plans, where late-flushed buckets preempt)
  the job keeps a ready-time heap of *gated* flows plus a priority heap of
  admissible ones, so an admission is O(log n) instead of a rescan.
- **per-link fluid service clocks**: all flows on a link progress at the
  same fair share, so in link-service time a flow admitted when the link
  had delivered ``S`` per-flow seconds completes at exactly ``S + work`` —
  a *static* order.  Each link keeps a heap of these completion marks;
  membership changes rescale only the rate at which the clock advances,
  never the order, so projections are recomputed only when a link's
  membership (and hence share) changes, and only for the heap top.
- **versioned calendar entries**: the global ``heapq`` calendar holds each
  link's next projected completion stamped with the link's membership
  version, plus per-job admission triggers.  A membership change bumps the
  version; stale entries are lazily discarded on pop rather than searched
  for and removed.
- **completion spin + bulk commit**: when a link's next completion precedes
  everything else on the calendar, completions are served in a tight loop
  without calendar round-trips; and while membership is *constant* (every
  completion instantly re-admits the job's next flow), each job's future
  completion marks are plain prefix sums of its works, so whole saturated
  stretches are computed with vectorized numpy cumulative sums and
  committed in one pass, up to the first membership-changing boundary
  (ready gate, ``hold`` flow, job exhaustion, or calendar interrupt).
- **small-plan setup**: the columnar numpy views that pay for themselves on
  thousand-flow plans cost more than the whole event loop on the two-dozen-
  op plans the paper grids generate, so below
  :data:`_SMALL_PLAN_MAX_FLOWS` the setup runs on plain lists and the bulk
  commit (which needs the arrays, and can never engage on single-job plans
  anyway) is skipped.  The scalar event loop is identical either way, so
  single-job results are bit-identical across the two setups.

Termination is progress-based: the engine raises only when the calendar
drains with flows outstanding, or when event processing stops advancing
time, admitting, or completing — not on an iteration-count heuristic, which
could false-trip on heavily contended multi-job plans.

Multi-rail links
----------------

A physical NIC with ``r`` rails is ``r`` independent fluid links that
happen to share a name: ``NetworkEngine(rails={"nic": r})`` turns the named
link into a :class:`_LinkSet` of ``r`` per-rail service clocks, and each
flow's ``rail`` field selects which clock serves it (rail selection is part
of the *plan* — see :func:`repro.core.schedule.assign_rails` — so the
engine stays deterministic and a one-rail plan is bit-exact with a plain
link).  Rails do not fair-share with each other: contention is per rail,
which is exactly what distinguishes a 2x50G multi-rail host from a single
100G NIC.  The caller models per-rail bandwidth by scaling ``work`` (see
``plan_to_flows(..., n_rails=...)``).
"""
from __future__ import annotations

from heapq import heapify, heappop, heappush
from typing import Dict, List, NamedTuple, Optional, Sequence

import numpy as np

DEFAULT_LINK = "nic"
DEFAULT_JOB = "job0"

_DONE, _ADMIT = 0, 1       # calendar event kinds; completions sort first
_INF = float("inf")


class FlowSpec(NamedTuple):
    """One wire transfer plus a fixed post-wire latency.

    The engine's unit of work: the schedule layer lowers every
    :class:`~repro.core.schedule.CommOp` to exactly one ``FlowSpec``.

    - ``op_id`` identifies the flow in results (results come back in input
      order, but ``op_id`` survives any caller-side regrouping);
    - ``ready`` is the earliest admission time (the bucket's flush time,
      possibly perturbed by :func:`perturb_flows`);
    - ``work`` is wire seconds *at full link rate* — the caller bakes
      bandwidth into it via the cost model, so a rail at 1/n of the
      aggregate bandwidth simply carries ``n`` times the work;
    - ``latency`` is the fixed post-wire phase (vector adds + negotiation)
      that does not scale under link sharing;
    - ``priority`` orders admission within a job (smaller first; ties broken
      by ``op_id``);
    - ``job`` names the serialization resource (one wire in flight per job);
    - ``link``/``rail`` name the bandwidth resource: ``rail`` selects the
      per-rail service clock when the engine was built with
      ``rails={link: n}``, and is ignored (must be 0) otherwise;
    - ``hold`` keeps the job busy through the latency (Horovod's serialized
      all-reduce); ``duration``, when given, must equal ``work + latency``
      up to the caller's own float rounding — it is used verbatim for the
      closed-form uncontended completion of ``hold`` flows, which is what
      makes the fifo schedule bit-exact with the legacy serialized loop.
    """

    op_id: int
    ready: float                     # earliest admission time
    work: float                      # wire seconds at full link rate
    latency: float = 0.0             # fixed post-wire time (reduction etc.)
    priority: float = 0.0
    job: str = DEFAULT_JOB
    link: str = DEFAULT_LINK
    hold: bool = False               # job held busy through the latency
    duration: Optional[float] = None  # precomputed work+latency (hold flows)
    rail: int = 0                    # which rail of a multi-rail link


class FlowResult(NamedTuple):
    """Execution record of one flow, in the input list's order.

    ``start`` is the admission time (wire begins), ``wire_end`` when the
    link was released, ``end`` when the post-wire latency finished.
    ``contended`` is True only if the wire phase shared its link (or rail)
    for a *nonzero* duration — uncontended flows take exact closed forms,
    so ``start + work == wire_end`` bit-for-bit.
    """

    op_id: int
    job: str
    start: float                     # admission (wire begins)
    wire_end: float                  # link released
    end: float                       # wire + latency complete
    contended: bool                  # wire phase ever shared its link

    @property
    def occupancy(self) -> float:
        """Time this flow kept its serialization resource busy."""
        return self.end - self.start


def perturb_flows(flows: Sequence[FlowSpec], jitter: float, seed: int,
                  stream: int = 0) -> List[FlowSpec]:
    """Seeded straggler model: delay every flow's ``ready`` time.

    Each flow's flush is pushed back by an independent exponential draw
    with mean ``jitter`` seconds — the long-tailed per-flow perturbation
    that models slow workers, GC pauses, and negotiation stalls jittering
    bucket flush times.  Determinism contract:

    - the draws depend only on ``(seed, stream, len(flows))`` — never on
      process, thread, or global RNG state — so artifacts are bit-identical
      across executors (``stream`` separates jobs in a contention scenario
      so co-located jobs straggle independently);
    - with a fixed seed the delays scale *linearly* in ``jitter``
      (``jitter * standard_exponential``), so a swept jitter axis moves
      every ready time monotonically — the straggler grid's
      ``t_sync`` monotonicity validator rests on this;
    - ``jitter <= 0`` returns the flows unchanged (same objects), keeping
      the zero-jitter path bit-exact with a run that never heard of jitter.
    """
    if jitter <= 0.0 or not flows:
        return list(flows)
    rng = np.random.default_rng(
        np.random.SeedSequence(entropy=int(seed), spawn_key=(int(stream),)))
    delays = (jitter * rng.standard_exponential(len(flows))).tolist()
    return [f._replace(ready=f.ready + d) for f, d in zip(flows, delays)]


class _Link:
    """Fluid fair-share link: a service clock plus a completion-mark heap.

    ``S`` is the per-flow service delivered since the link last went idle;
    a flow admitted at service mark ``S`` completes when the clock reaches
    ``S + work``.  ``version`` stamps calendar entries for lazy
    invalidation on membership changes.
    """

    __slots__ = ("cap", "n", "share", "S", "t_last", "heap", "version",
                 "all_contended")

    def __init__(self, cap: float):
        self.cap = cap
        self.n = 0
        self.share = 1.0 if cap >= 1.0 else cap
        self.S = 0.0
        self.t_last = 0.0
        self.heap: List = []        # (service completion mark, flow index)
        self.version = 0
        self.all_contended = False


class _LinkSet:
    """One named multi-rail link: ``n_rails`` independent per-rail clocks.

    Every rail is a full :class:`_Link` (its own fluid service clock,
    completion heap, and membership version); flows are routed to
    ``rails[flow.rail]`` at setup, after which the event loop sees only
    plain links.  Rails therefore never fair-share with each other — the
    defining property of a multi-rail NIC versus one fat link.
    """

    __slots__ = ("rails",)

    def __init__(self, cap: float, n_rails: int):
        self.rails = [_Link(cap) for _ in range(n_rails)]


class _Job:
    """Serialization resource: one wire in flight, priority admission."""

    __slots__ = ("order", "rdy", "ptr", "gated", "readyq", "free", "busy",
                 "link", "onp", "wk", "rd", "hd", "lt")

    def __init__(self):
        self.order: List[int] = []   # flow indices in (priority, op_id) order
        self.rdy: List[float] = []   # ready times along ``order`` (ptr mode)
        self.ptr = 0
        self.gated: Optional[List] = None   # ready-time heap (heap mode)
        self.readyq: Optional[List] = None  # (priority, op_id, idx) heap
        self.free = 0.0
        self.busy = False
        self.link: Optional[_Link] = None   # sole link, if homogeneous
        # numpy views along ``order`` for the bulk-commit path (lazy)
        self.onp = self.wk = self.rd = self.hd = self.lt = None


# below this many flows the engine skips its columnar numpy setup (and the
# bulk-commit path that needs it): asarray/lexsort/zeros dominate the whole
# event loop on the two-dozen-op plans the paper grids generate, while the
# bulk path only ever engages on contended multi-job plans far above this
_SMALL_PLAN_MAX_FLOWS = 64


class NetworkEngine:
    """Event-calendar executor for a set of flows over shared links.

    ``capacities`` maps link name -> number of flows that can run at full
    rate before fair sharing kicks in (default 1.0 — the whole link).
    ``rails`` maps link name -> rail count: a name with ``n > 1`` becomes a
    :class:`_LinkSet` of ``n`` independent per-rail service clocks and each
    flow's ``rail`` field selects its clock (modulo ``n``).  Links absent
    from ``rails`` (or mapped to 1) behave exactly as before, bit-for-bit.
    """

    def __init__(self, capacities: Optional[Dict[str, float]] = None,
                 rails: Optional[Dict[str, int]] = None):
        self.capacities = dict(capacities or {})
        self.rails = dict(rails or {})

    def run(self, flows: Sequence[FlowSpec]) -> List[FlowResult]:
        """Execute ``flows``; returns results in input order."""
        n_total = len(flows)
        if not n_total:
            return []
        caps = self.capacities
        small = n_total < _SMALL_PLAN_MAX_FLOWS

        # -- setup: columnar views, grouping, service order, mode -----------
        (op_col, rdy_col, wk_col, lt_col, pr_col, job_col, lk_col, hd_col,
         _du_col, rl_col) = zip(*flows)

        rail_counts = self.rails
        if rail_counts and any(rail_counts.get(nm, 1) > 1
                               for nm in set(lk_col)):
            sets = {nm: _LinkSet(caps.get(nm, 1.0),
                                 max(rail_counts.get(nm, 1), 1))
                    for nm in set(lk_col)}
            link_of = [sets[nm].rails[r % len(sets[nm].rails)]
                       for nm, r in zip(lk_col, rl_col)]
            one_link = sum(len(s.rails) for s in sets.values()) == 1
        else:
            links: Dict[str, _Link] = {
                nm: _Link(caps.get(nm, 1.0)) for nm in set(lk_col)}
            link_of = list(map(links.__getitem__, lk_col))
            one_link = len(links) == 1

        by_job: Dict[str, List[int]] = {}
        for i, name in enumerate(job_col):
            try:
                by_job[name].append(i)
            except KeyError:
                by_job[name] = [i]
        jobs: Dict[str, _Job] = {name: _Job() for name in by_job}
        job_of = list(map(jobs.__getitem__, job_col))

        if small:
            pr_np = op_np = rd_np = None
        else:
            pr_np = np.asarray(pr_col)
            op_np = np.asarray(op_col)
            rd_np = np.asarray(rdy_col)
        g_wk = g_hd = g_lt = None           # global columns (lazy, for bulk)

        cal: List = []              # (time, kind, seq, ...) event calendar
        seq = 0
        for name, idxs in by_job.items():
            jb = jobs[name]
            if small:
                # plain-list service order: identical (priority, op_id)
                # total order, without paying numpy's fixed costs
                if len(idxs) > 1:
                    idxs.sort(key=lambda i: (pr_col[i], op_col[i]))
                order = jb.order = idxs
                rdy = jb.rdy = [rdy_col[i] for i in order]
                monotone = all(a <= b for a, b in zip(rdy, rdy[1:]))
            else:
                ix = np.asarray(idxs, dtype=np.intp)
                if ix.shape[0] > 1:
                    ix = ix[np.lexsort((op_np[ix], pr_np[ix]))]
                order = jb.order = ix.tolist()
                rd_ix = rd_np[ix]
                rdy = jb.rdy = rd_ix.tolist()
                monotone = (len(rdy) == 1
                            or bool((rd_ix[1:] >= rd_ix[:-1]).all()))
            first = link_of[order[0]]
            jb.link = first if one_link or all(link_of[i] is first
                                               for i in order) else None
            if monotone:
                trigger = rdy[0]
            else:
                # ready times regress along service order (e.g. priority
                # plans): gate admissions through a ready-time heap
                jb.gated = [(rdy_col[i], pr_col[i], op_col[i], i)
                            for i in order]
                heapify(jb.gated)
                jb.readyq = []
                trigger = jb.gated[0][0]
            seq += 1
            heappush(cal, (trigger if trigger > 0.0 else 0.0, _ADMIT, seq, jb))

        if small:
            start: List[float] = [0.0] * n_total
            wire: List[float] = [0.0] * n_total
            end: List[float] = [0.0] * n_total
            contended: List[bool] = [False] * n_total
        else:
            start = np.zeros(n_total)
            wire = np.zeros(n_total)
            end = np.zeros(n_total)
            contended = np.zeros(n_total, dtype=bool)
        n_done = 0
        stale = 0                   # consecutive no-progress calendar pops
        flws = flows                # local alias for the hot loops

        # -- admission: put flow ``i`` on its link at time ``t`` ------------
        def _admit(i: int, jb: _Job, t: float) -> _Link:
            L = link_of[i]
            if L.n:
                if t > L.t_last:
                    L.S += (t - L.t_last) * L.share
                L.t_last = t
                contended[i] = True
                if not L.all_contended:
                    for _, k in L.heap:
                        contended[k] = True
                    L.all_contended = True
            else:
                # fresh busy period: restart the service clock so the
                # single-flow closed form stays exact (mark == work)
                L.S = 0.0
                L.t_last = t
                if L.cap < 1.0:
                    contended[i] = True
                    L.all_contended = True
            heappush(L.heap, (L.S + wk_col[i], i))
            L.n += 1
            c = L.cap
            L.share = 1.0 if c >= L.n else c / L.n
            L.version += 1
            start[i] = t
            jb.busy = True
            return L

        # -- next-admission trigger for a job that just freed ---------------
        def _schedule_admit(jb: _Job, t: float) -> None:
            nonlocal seq
            if jb.gated is None:
                if jb.ptr < len(jb.order):
                    trig = jb.rdy[jb.ptr]
                    if trig < jb.free:
                        trig = jb.free
                    seq += 1
                    heappush(cal, (trig, _ADMIT, seq, jb))
            else:
                if jb.readyq:
                    seq += 1
                    heappush(cal, (jb.free, _ADMIT, seq, jb))
                elif jb.gated:
                    trig = jb.gated[0][0]
                    if trig < jb.free:
                        trig = jb.free
                    seq += 1
                    heappush(cal, (trig, _ADMIT, seq, jb))

        # -- bulk commit: vectorized saturated stretch on link ``L`` --------
        def _try_bulk(L: _Link, t0: float) -> int:
            """While every completion instantly re-admits (constant
            membership, constant share), each job's future completion marks
            are prefix sums of its works.  Commit every completion strictly
            before the first boundary (ready gate, hold flow, exhaustion,
            or foreign calendar event) in one vectorized pass.  Returns the
            number of flows committed."""
            nonlocal n_done, g_wk, g_hd, g_lt
            S0 = L.S
            share = L.share
            # drop lazily-invalidated projections so a stale early entry
            # cannot mask how far the bulk window really extends
            while cal and cal[0][1] == _DONE and cal[0][3] != cal[0][4].version:
                heappop(cal)
            t_cal = cal[0][0] if cal else _INF
            # O(1) pre-checks on the earliest completion: if its own job
            # cannot instantly re-admit, the very first completion is a
            # boundary and nothing can commit
            m_top, i_top = L.heap[0]
            if t_cal <= t0 + (m_top - S0) / share:
                return 0
            jb_top = job_of[i_top]
            p = jb_top.ptr
            if (jb_top.gated is not None or p >= len(jb_top.order)
                    or hd_col[jb_top.order[p - 1]]
                    or jb_top.rdy[p] > t0 + (m_top - S0) / share):
                return 0
            if g_wk is None:
                g_wk = np.asarray(wk_col)
                g_hd = np.asarray(hd_col, dtype=bool)
                g_lt = np.asarray(lt_col)
            chains = []
            t_stop = t_cal
            for m0, i0 in L.heap:
                jb = job_of[i0]
                if jb.gated is not None or jb.link is not L:
                    return 0
                if jb.wk is None:
                    onp = jb.onp = np.asarray(jb.order, dtype=np.intp)
                    jb.wk = g_wk[onp]
                    jb.rd = rd_np[onp]
                    jb.hd = g_hd[onp]
                    jb.lt = g_lt[onp]
                ptr = jb.ptr
                marks = np.empty(len(jb.order) - ptr + 1)
                marks[0] = m0
                marks[1:] = jb.wk[ptr:]
                marks = np.cumsum(marks)        # exact left fold, like scalar
                times = t0 + (marks - S0) / share
                k = marks.shape[0] - 1          # future flows in the chain
                if k:
                    viol = ((jb.rd[ptr:] > times[:k])
                            | jb.hd[ptr - 1:ptr + k - 1])
                    nz = np.nonzero(viol)[0]
                    v = int(nz[0]) + 1 if nz.size else k + 1
                else:
                    v = 1
                bt = times[v - 1]               # this job's boundary time
                if bt < t_stop:
                    t_stop = bt
                chains.append((jb, m0, i0, marks, times, v))
            total = 0
            t_final = t0
            s_final = S0
            entries = []
            for jb, m0, i0, marks, times, v in chains:
                c = int(np.searchsorted(times[:v], t_stop, side="left"))
                if c == 0:
                    entries.append((m0, i0))
                    continue
                ptr = jb.ptr
                tc = times[:c]
                ids = np.empty(c, dtype=np.intp)
                ids[0] = i0
                if c > 1:
                    ids[1:] = jb.onp[ptr:ptr + c - 1]
                    start[ids[1:]] = tc[:-1]
                wire[ids] = tc
                end[ids] = tc + jb.lt[ptr - 1:ptr + c - 1]
                contended[ids] = True
                ia = jb.order[ptr + c - 1]      # the job's new active flow
                tl = float(tc[-1])
                start[ia] = tl
                contended[ia] = True
                jb.ptr = ptr + c
                entries.append((float(marks[c]), ia))
                total += c
                if tl > t_final:
                    t_final = tl
                    s_final = float(marks[c - 1])
            if not total:
                return 0
            L.heap = entries
            heapify(entries)
            L.S = s_final
            L.t_last = t_final
            L.version += 1
            n_done += total
            return total

        while n_done < n_total:
            if not cal:
                raise RuntimeError(
                    f"event engine stalled: {n_done}/{n_total} flows done "
                    "with an empty calendar")
            ev = heappop(cal)
            t = ev[0]

            if ev[1] == _DONE:
                ver, L = ev[3], ev[4]
                if ver != L.version or not L.n:
                    stale += 1      # lazily-invalidated projection
                    if stale > 4 * n_total + 1000:
                        raise RuntimeError(
                            "event engine made no progress over "
                            f"{stale} events ({n_done}/{n_total} flows done)")
                    continue
                stale = 0
                # ---- completion spin: serve this link's completions while
                # they precede everything else on the calendar --------------
                while True:
                    if t > L.t_last:
                        L.S += (t - L.t_last) * L.share
                    L.t_last = t
                    s_top, i = heappop(L.heap)
                    L.S = s_top
                    L.n -= 1
                    L.version += 1
                    if L.n:
                        c = L.cap
                        L.share = 1.0 if c >= L.n else c / L.n
                    else:
                        L.all_contended = False
                    if contended[i]:
                        w = t
                        e = t + lt_col[i]
                    else:
                        # exact closed form: share was 1.0 throughout
                        w = float(start[i]) + wk_col[i]
                        d = flws[i].duration
                        if hd_col[i] and d is not None:
                            e = float(start[i]) + d
                        else:
                            e = w + lt_col[i]
                    wire[i] = w
                    end[i] = e
                    n_done += 1
                    jb = job_of[i]
                    jb.busy = False
                    jb.free = e if hd_col[i] else w
                    # instant re-admission keeps the spin going (the
                    # saturated steady state); anything else goes back
                    # through the calendar
                    readmitted = None
                    if not hd_col[i]:
                        if jb.gated is None:
                            p = jb.ptr
                            if p < len(jb.order) and jb.rdy[p] <= t:
                                jb.ptr = p + 1
                                readmitted = _admit(jb.order[p], jb, t)
                        else:
                            g = jb.gated
                            while g and g[0][0] <= t:
                                r, pr, op, k = heappop(g)
                                heappush(jb.readyq, (pr, op, k))
                            if jb.readyq:
                                _, _, k = heappop(jb.readyq)
                                readmitted = _admit(k, jb, t)
                    if readmitted is None:
                        _schedule_admit(jb, t)
                    elif readmitted is not L:
                        # cross-link re-admission: project the other link
                        seq += 1
                        s2 = readmitted.heap[0][0]
                        proj2 = t + (s2 - readmitted.S) / readmitted.share
                        heappush(cal, (proj2 if proj2 > t else t, _DONE,
                                       seq, readmitted.version, readmitted))
                    if not L.n:
                        break
                    if not small and L.n > 1 and _try_bulk(L, t):
                        t = L.t_last
                        if not L.n:
                            break
                    proj = t + (L.heap[0][0] - L.S) / L.share
                    if proj < t:
                        proj = t
                    if cal and cal[0][0] < proj:
                        seq += 1
                        heappush(cal, (proj, _DONE, seq, L.version, L))
                        break
                    t = proj
                continue

            # ---- admission event ------------------------------------------
            jb = ev[3]
            if jb.busy:
                stale += 1          # superseded by an instant re-admission
                if stale > 4 * n_total + 1000:
                    raise RuntimeError(
                        "event engine made no progress over "
                        f"{stale} events ({n_done}/{n_total} flows done)")
                continue
            if jb.free > t:         # defensive: fire again once free
                stale += 1
                _schedule_admit(jb, t)
                continue
            stale = 0
            admitted = None
            if jb.gated is None:
                p = jb.ptr
                if p < len(jb.order):
                    if jb.rdy[p] <= t:
                        jb.ptr = p + 1
                        admitted = _admit(jb.order[p], jb, t)
                    else:
                        _schedule_admit(jb, t)
            else:
                g = jb.gated
                while g and g[0][0] <= t:
                    r, pr, op, k = heappop(g)
                    heappush(jb.readyq, (pr, op, k))
                if jb.readyq:
                    _, _, k = heappop(jb.readyq)
                    admitted = _admit(k, jb, t)
                elif g:
                    _schedule_admit(jb, t)
            if admitted is not None:
                seq += 1
                s_top = admitted.heap[0][0]
                proj = t + (s_top - admitted.S) / admitted.share
                heappush(cal, (proj if proj > t else t, _DONE, seq,
                               admitted.version, admitted))

        if small:
            rows = zip(op_col, job_col, start, wire, end, contended)
        else:
            rows = zip(op_col, job_col, start.tolist(), wire.tolist(),
                       end.tolist(), contended.tolist())
        new = tuple.__new__
        return [new(FlowResult, row) for row in rows]


def run_flows(flows: Sequence[FlowSpec],
              capacities: Optional[Dict[str, float]] = None,
              rails: Optional[Dict[str, int]] = None) -> List[FlowResult]:
    """Convenience wrapper: execute ``flows`` on a fresh engine.

    ``capacities`` and ``rails`` are per-link-name maps — see
    :class:`NetworkEngine`.
    """
    return NetworkEngine(capacities, rails).run(flows)
