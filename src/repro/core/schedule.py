"""Comm-schedule IR: buckets -> collective ops, shared by simulator & runtime.

The paper's claim is that *scheduling* — not link capacity — is what keeps
distributed training from scaling.  This module makes the schedule a
first-class object: a :class:`CommPlan` is an ordered set of
:class:`CommOp` (bucket -> collective op with priority, chunking and
channel), produced by a registered *scheduler* from the same bucket
description the runtime's ``BucketPlan`` and the simulator's
``fuse_buckets`` both emit.  The analytic layer lowers a plan onto the
discrete-event engine (:mod:`repro.core.events`); the runtime executes its
collectives in the plan's bucket order — so the simulator predicts exactly
what the runtime does.

Schedulers:

- ``fifo``               one op per bucket, served in flush order with the
                         reduction serialized behind the wire (Horovod's
                         one-collective-in-flight semantics — the paper's
                         measured baseline, bit-exact with the legacy loop);
- ``priority``           ByteScheduler-style: k chunks per bucket, buckets
                         flushed *later* (the model's front layers — backward
                         runs last-layer-first) preempt at chunk boundaries,
                         reductions overlap the next chunk's transmission;
- ``chunked``            (alias ``chunked-pipelined``) k chunks per bucket in
                         flush order, transmission pipelined with reduction
                         — Sun et al.'s fused+pipelined all-reduce.

Rail assignment (multi-NIC hosts) is a separate pass over a finished plan:
:func:`assign_rails` maps each op's ``channel`` onto one of ``n_rails``
rails under a named policy, and :func:`plan_to_flows` lowers channels onto
the engine's per-rail links (``n_rails`` scales each flow's wire work to
the per-rail share of the aggregate bandwidth).  Keeping assignment out of
the schedulers means every scheduler composes with every rail policy, and
an ``n_rails=1`` plan is the *same object* — bit-exact with a run that
never heard of rails.

Codec assignment (gradient compression) follows the same pass idiom:
:func:`assign_codec` stamps each op's ``codec`` (uniformly, or per bucket
under the Hivemind-style ``size-adaptive`` policy), and
:func:`plan_to_flows` — given a ``codecs`` cost table — lowers each op
into an **encode -> wire -> decode** pipeline: encode serializes on the
job's GPU (a closed-form chain that shifts the wire flow's ready time;
the encoder doesn't contend for the NIC), the wire flow carries the
codec's compressed wire time, and decode rides as post-wire latency.
Each op stays one engine flow, so codecs compose with every scheduler,
rail policy, contention, and jitter unchanged.

Exactness contract: ``fifo`` lowered with ``n_rails=1`` onto an
uncontended link reproduces the legacy serialized loop bit-for-bit (the
``duration`` passed to the engine is the legacy loop's exact float
expression); all schedulers conserve bytes exactly per bucket,
:func:`assign_rails` permutes nothing — it only stamps channels — and a
``codecs=None`` (or all-``none``) lowering takes the pre-codec code path
verbatim.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import (Callable, Dict, List, Mapping, NamedTuple, Optional,
                    Sequence, Tuple)

import numpy as np

from repro.core.codec import SIZE_ADAPTIVE_THRESHOLD, Codec
from repro.core.events import (DEFAULT_JOB, FlowBatch, FlowSpec, _EMPTY_BATCH,
                               _intern, serialized_chain)
from repro.core.transport import LinkProfile

DEFAULT_CHUNKS = 4


@dataclass(frozen=True)
class CommOp:
    """One collective (or one chunk of one) over a bucket's bytes.

    ``op_id`` equals the op's position in the plan by construction, and is
    stable under rail assignment.  ``priority`` orders service within the
    plan's job (smaller first, ties by ``op_id``); ``ready`` is the
    bucket's flush time.  ``channel`` is the rail the op transmits on —
    0 (the only rail) until :func:`assign_rails` stamps a multi-rail
    assignment.  ``codec`` names the compression codec the op's bytes go
    through on the wire — ``"none"`` until :func:`assign_codec` stamps
    one; ``size`` stays the *uncompressed* byte count (the IR's conserved
    quantity), compression enters through the per-codec cost model at
    lowering time.
    """

    op_id: int
    bucket_id: int
    chunk: int                      # chunk index within the bucket
    n_chunks: int                   # total chunks of this bucket
    size: float                     # bytes moved by this op
    n_tensors: int                  # tensors whose negotiation cost this op carries
    ready: float                    # earliest start (the bucket's flush time)
    priority: float                 # smaller = served first
    channel: int = 0                # rail id (stamped by assign_rails)
    codec: str = "none"             # codec name (stamped by assign_codec)


@dataclass(frozen=True)
class CommPlan:
    """An executable communication schedule for one sync.

    Produced by a registered scheduler from flushed buckets
    (:func:`lower_buckets`); executed by the analytic layer via
    :func:`plan_to_flows` + the event engine, and by the runtime via
    :meth:`bucket_order`.  Plans are immutable: passes like
    :func:`assign_rails` return a new plan.
    """

    scheduler: str
    ops: Tuple[CommOp, ...]
    n_buckets: int

    @property
    def total_bytes(self) -> float:
        return float(sum(op.size for op in self.ops))

    def bucket_order(self) -> Tuple[int, ...]:
        """Bucket ids in first-service order — the runtime execution order."""
        order: List[int] = []
        for op in sorted(self.ops, key=lambda o: (o.priority, o.op_id)):
            if op.bucket_id not in order:
                order.append(op.bucket_id)
        return tuple(order)

    @property
    def serialized_fifo(self) -> bool:
        """True when the plan is one op per bucket, served in op order.

        This is the structural precondition for the simulator's closed-form
        fifo fast path: service order ``(priority, op_id)`` must equal op
        order, which holds when priorities are non-decreasing (ties fall
        back to ``op_id``, increasing by construction)."""
        if self.scheduler != "fifo" or len(self.ops) != self.n_buckets:
            return False
        prev = -float("inf")
        for op in self.ops:
            if op.priority < prev:
                return False
            prev = op.priority
        return True


# ---------------------------------------------------------------------------
# schedulers: (ready, size, n_tensors) buckets -> CommPlan
# ---------------------------------------------------------------------------

BucketLike = Tuple[float, float, int]        # (ready_time, bytes, n_tensors)

SchedulerFn = Callable[[Sequence[BucketLike], int, int], CommPlan]

SCHEDULERS: Dict[str, SchedulerFn] = {}

_ALIASES = {"chunked-pipelined": "chunked", "bytescheduler": "priority"}


def canonical_scheduler(name: str) -> str:
    name = _ALIASES.get(name, name)
    if name not in SCHEDULERS:
        known = sorted(SCHEDULERS) + sorted(_ALIASES)
        raise KeyError(f"unknown scheduler {name!r}; known: {', '.join(known)}")
    return name


def _register(name: str):
    def deco(fn: SchedulerFn) -> SchedulerFn:
        SCHEDULERS[name] = fn
        return fn
    return deco


def _chunk(ops: List[CommOp], bucket_id: int, ready: float, size: float,
           n_tensors: int, n_chunks: int, priority_of: Callable[[int, int], float],
           channel: int) -> None:
    """Append ``n_chunks`` equal chunks of one bucket (bytes conserved).

    The per-tensor negotiation cost is paid once per bucket, on its first
    chunk (Horovod negotiates per fused tensor, not per wire chunk).
    """
    k = max(1, min(int(n_chunks), max(int(size), 1)))
    base = size / k
    for c in range(k):
        chunk_size = size - base * (k - 1) if c == k - 1 else base
        ops.append(CommOp(
            op_id=len(ops), bucket_id=bucket_id, chunk=c, n_chunks=k,
            size=chunk_size, n_tensors=n_tensors if c == 0 else 0,
            ready=ready, priority=priority_of(bucket_id, c), channel=channel))


@_register("fifo")
def _sched_fifo(buckets: Sequence[BucketLike], n_chunks: int,
                channel: int = 0) -> CommPlan:
    """Today's Horovod semantics: flush order, no chunking."""
    ops = [CommOp(op_id=i, bucket_id=i, chunk=0, n_chunks=1, size=size,
                  n_tensors=n_tensors, ready=ready, priority=float(i),
                  channel=channel)
           for i, (ready, size, n_tensors) in enumerate(buckets)]
    return CommPlan("fifo", tuple(ops), n_buckets=len(ops))


@_register("chunked")
def _sched_chunked(buckets: Sequence[BucketLike], n_chunks: int,
                   channel: int = 0) -> CommPlan:
    """Flush order at chunk granularity; reduction overlaps transmission."""
    ops: List[CommOp] = []
    for i, (ready, size, n_tensors) in enumerate(buckets):
        _chunk(ops, i, ready, size, n_tensors, n_chunks,
               lambda b, c: float(b), channel)
    return CommPlan("chunked", tuple(ops), n_buckets=len(buckets))


@_register("priority")
def _sched_priority(buckets: Sequence[BucketLike], n_chunks: int,
                    channel: int = 0) -> CommPlan:
    """First-layer-first (ByteScheduler): backward emits the *last* layers
    first, so later-flushed buckets hold the front of the model and preempt
    earlier ones at chunk boundaries."""
    ops: List[CommOp] = []
    n = len(buckets)
    for i, (ready, size, n_tensors) in enumerate(buckets):
        _chunk(ops, i, ready, size, n_tensors, n_chunks,
               lambda b, c: float(n - 1 - b), channel)
    return CommPlan("priority", tuple(ops), n_buckets=len(buckets))


def lower_buckets(buckets: Sequence[BucketLike], *, scheduler: str = "fifo",
                  n_chunks: int = DEFAULT_CHUNKS, channel: int = 0) -> CommPlan:
    """Lower flushed buckets into a :class:`CommPlan` via a named scheduler."""
    return SCHEDULERS[canonical_scheduler(scheduler)](buckets, n_chunks,
                                                      channel)


# ---------------------------------------------------------------------------
# rail assignment: CommPlan -> CommPlan with channels stamped
# ---------------------------------------------------------------------------

RAIL_POLICIES = ("round-robin", "size-balanced")


def assign_rails(plan: CommPlan, n_rails: int,
                 policy: str = "round-robin") -> CommPlan:
    """Stamp each op's ``channel`` with one of ``n_rails`` rails.

    Policies:

    - ``round-robin``    op ``i`` transmits on rail ``i % n_rails``.  Over a
      chunked plan this *stripes* every bucket across all rails (chunks of
      one bucket alternate rails), which is how NCCL-style multi-channel
      collectives aggregate NICs; over a fifo plan it deals whole buckets
      out to rails — a serialized stream cannot stripe, which is precisely
      the multirail grid's finding.
    - ``size-balanced``  greedy: each op (in op order) goes to the rail
      with the least accumulated bytes, ties to the lowest rail index.
      Better when op sizes are skewed (e.g. a small tail bucket).

    ``n_rails <= 1`` returns ``plan`` itself (the same object): a one-rail
    run is bit-exact with a run that never heard of rails.  Assignment
    never reorders, splits, or resizes ops — only ``channel`` changes.
    """
    if n_rails <= 1:
        return plan
    if policy not in RAIL_POLICIES:
        raise KeyError(f"unknown rail policy {policy!r}; "
                       f"known: {', '.join(RAIL_POLICIES)}")
    if policy == "round-robin":
        chans = [i % n_rails for i in range(len(plan.ops))]
    else:
        load = [0.0] * n_rails
        chans = []
        for op in plan.ops:
            r = min(range(n_rails), key=load.__getitem__)
            chans.append(r)
            load[r] += op.size
    ops = tuple(replace(op, channel=c) for op, c in zip(plan.ops, chans))
    return replace(plan, ops=ops)


# ---------------------------------------------------------------------------
# codec assignment: CommPlan -> CommPlan with codecs stamped
# ---------------------------------------------------------------------------

CODEC_POLICIES = ("uniform", "size-adaptive")


def assign_codec(plan: CommPlan, codec: str = "none",
                 policy: str = "uniform", *,
                 threshold: Optional[float] = None) -> CommPlan:
    """Stamp each op's ``codec`` under a named policy.

    - ``uniform``        every op gets ``codec``;
    - ``size-adaptive``  Hivemind's idiom: a *bucket* whose total bytes
      reach ``threshold`` (default :data:`~repro.core.codec.
      SIZE_ADAPTIVE_THRESHOLD`) gets ``codec``, smaller buckets stay
      uncompressed — their wire time is negotiation-dominated and the
      encode/decode compute would be pure loss.  The decision is per
      bucket (all chunks of a bucket agree), since the runtime compresses
      the fused bucket before chunking it onto the wire.

    ``codec="none"`` under ``uniform`` returns ``plan`` itself (the same
    object): a codec-free plan is bit-exact with a run that never heard
    of codecs.  Assignment never reorders, splits, or resizes ops.
    """
    if policy not in CODEC_POLICIES:
        raise KeyError(f"unknown codec policy {policy!r}; "
                       f"known: {', '.join(CODEC_POLICIES)}")
    if policy == "uniform":
        if codec == "none":
            return plan
        ops = tuple(replace(op, codec=codec) for op in plan.ops)
        return replace(plan, ops=ops)
    thr = SIZE_ADAPTIVE_THRESHOLD if threshold is None else threshold
    bucket_bytes: Dict[int, float] = {}
    for op in plan.ops:
        bucket_bytes[op.bucket_id] = bucket_bytes.get(op.bucket_id, 0.0) \
            + op.size
    ops = tuple(replace(op, codec=codec
                        if bucket_bytes[op.bucket_id] >= thr else "none")
                for op in plan.ops)
    return replace(plan, ops=ops)


class CodecLowering(NamedTuple):
    """One codec's lowering bundle: the priced :class:`Codec` plus a cost
    model whose wire term already divides by the codec's wire ratio (built
    by the simulator via ``make_cost_model(compression_ratio=
    codec.wire_ratio)``)."""

    codec: Codec
    cost: object


def _codec_stage_seconds(op: CommOp, codec: Codec) -> Tuple[float, float]:
    """(encode, decode) seconds for one op.  Launch overheads are charged
    once per bucket per stage, on the bucket's first chunk (mirroring how
    the negotiation cost rides on chunk 0)."""
    if codec.is_free:
        return 0.0, 0.0
    launch = codec.launch_overhead if op.chunk == 0 else 0.0
    return (launch + codec.encode_seconds(op.size),
            launch + codec.decode_seconds(op.size))


def codec_compute_seconds(plan: CommPlan,
                          codecs: Optional[Mapping[str, CodecLowering]]
                          ) -> float:
    """Total encode+decode compute the plan spends on compression — the
    per-worker GPU-seconds the byte-divisor shortcut pretends are free."""
    if codecs is None:
        return 0.0
    t = 0.0
    for op in plan.ops:
        enc, dec = _codec_stage_seconds(op, codecs[op.codec].codec)
        t += enc + dec
    return t


# ---------------------------------------------------------------------------
# lowering a plan onto the event engine
# ---------------------------------------------------------------------------

def _apply_link(flows: List[FlowSpec],
                lp: Optional[LinkProfile]) -> List[FlowSpec]:
    """Deterministic lossy-link pricing over a lowered flow list.

    The fluid-model mean of a :class:`~repro.core.transport.LinkProfile`:
    wire work inflates by the expected retransmission factor
    ``1/(1-loss)`` and the propagation RTT joins the fixed post-wire
    latency (``duration`` keeps its ``work + latency`` identity).  The
    stochastic tail — RTO stalls — is priced separately by
    :func:`repro.core.transport.retx_events`.  A null (or absent) profile
    returns the *same object*: the zero-loss bypass is bitwise, which is
    what keeps every pre-WAN golden artifact stable.  The elementwise
    float64 arithmetic here and in :func:`_apply_link_batch` is identical
    op for op, preserving the tuple-vs-columnar bit-identity contract.
    """
    if lp is None or lp.is_null:
        return flows
    fac = 1.0 / (1.0 - lp.loss)
    rtt = lp.rtt
    new = tuple.__new__
    out: List[FlowSpec] = []
    for f in flows:
        w = f[2] * fac
        dur = None if f[8] is None else f[8] + (w - f[2]) + rtt
        out.append(new(FlowSpec, (f[0], f[1], w, f[3] + rtt, f[4], f[5],
                                  f[6], f[7], dur, f[9], f[10], f[11])))
    return out


def _apply_link_batch(batch: FlowBatch,
                      lp: Optional[LinkProfile]) -> FlowBatch:
    """Columnar :func:`_apply_link` — same float ops, elementwise."""
    if lp is None or lp.is_null:
        return batch
    fac = 1.0 / (1.0 - lp.loss)
    rtt = lp.rtt
    work = batch.work * fac
    return batch._replace(
        work=work, latency=batch.latency + rtt,
        duration=batch.duration + (work - batch.work) + rtt)


def plan_to_flows(plan: CommPlan, cost, per_tensor_overhead: float = 0.0, *,
                  job: str = "job0", link: str = "nic",
                  op_id_base: int = 0, n_rails: int = 1,
                  codecs: Optional[Mapping[str, CodecLowering]] = None,
                  link_profile: Optional[LinkProfile] = None
                  ) -> List[FlowSpec]:
    """CommOps -> engine flows under a cost model.

    ``cost`` is any all-reduce cost model from :mod:`repro.core.network_model`
    — ``time(size)`` is the serialized duration; ``wire_time(size)`` (when
    present) is the transmission share of it, the part that scales with link
    contention.  The remainder (vector adds + per-tensor negotiation) is a
    fixed latency.  ``fifo`` flows hold the job through the latency and
    carry the legacy loop's exact duration expression, so an uncontended
    fifo schedule is bit-identical with the pre-engine serialized loop.

    ``n_rails > 1`` lowers each op's ``channel`` (stamped by
    :func:`assign_rails`) onto a rail of an aggregate-bandwidth link: the
    cost model still prices wire time at the *aggregate* bandwidth, so each
    rail serves ``1/n_rails`` of it and the flow's wire work scales by
    ``n_rails``; each rail also gets its own serialization lane
    (``job@r<k>``) — a NIC's rails have independent DMA engines, so one
    job's flows on different rails overlap.  Run the result with
    ``run_flows(flows, rails={link: n_rails})``.

    ``codecs`` (a ``{codec name: CodecLowering}`` table covering every
    ``op.codec`` in the plan) turns each op into an encode -> wire ->
    decode pipeline while keeping it ONE engine flow:

    - **encode** runs on the job's GPU, which never contends for the NIC,
      so encode completions are a closed-form serialized chain
      (``end_i = max(ready_i, end_{i-1}) + t_enc_i`` in op order) computed
      right here; the wire flow's ``ready`` becomes its op's encode end;
    - **wire** uses the op's codec's cost model — its wire term divides
      by the codec's wire ratio;
    - **decode** is post-wire compute with no link share: it folds into
      the flow's fixed ``latency`` (and ``duration``, so fifo holds the
      job through it).

    ``codecs=None`` — or a table whose codecs are all free — takes the
    pre-codec arithmetic path for each op: a ``none`` plan is
    bit-identical with a build that never heard of codecs.

    ``link_profile`` (a non-null
    :class:`~repro.core.transport.LinkProfile`) prices the lossy-link
    mean as a final elementwise pass (:func:`_apply_link`); ``None`` or
    the null profile leaves the lowering untouched, object for object.
    """
    hold = plan.scheduler == "fifo"
    flows: List[FlowSpec] = []
    if codecs is not None:
        enc_clock: Optional[float] = None
        for op in plan.ops:
            cl = codecs[op.codec]
            enc, dec = _codec_stage_seconds(op, cl.codec)
            c = cl.cost
            total = c.time(op.size) + per_tensor_overhead * op.n_tensors
            wire = min(getattr(c, "wire_time", c.time)(op.size), total)
            if enc > 0.0:
                start = op.ready if enc_clock is None \
                    else max(op.ready, enc_clock)
                enc_clock = start + enc
                ready = enc_clock
            else:
                ready = op.ready
            lat = max(0.0, total - wire) + dec
            if n_rails <= 1:
                flows.append(FlowSpec(
                    op_id=op_id_base + op.op_id, ready=ready, work=wire,
                    latency=lat, priority=op.priority, job=job,
                    link=f"{link}{op.channel}" if op.channel else link,
                    hold=hold, duration=total + dec))
            else:
                rail_work = wire * n_rails
                flows.append(FlowSpec(
                    op_id=op_id_base + op.op_id, ready=ready,
                    work=rail_work, latency=lat, priority=op.priority,
                    job=job if op.channel == 0 else f"{job}@r{op.channel}",
                    link=link, hold=hold, duration=lat + rail_work,
                    rail=op.channel))
        return _apply_link(flows, link_profile)
    wire_time = getattr(cost, "wire_time", cost.time)
    if n_rails <= 1:
        for op in plan.ops:
            total = cost.time(op.size) + per_tensor_overhead * op.n_tensors
            wire = min(wire_time(op.size), total)
            flows.append(FlowSpec(
                op_id=op_id_base + op.op_id, ready=op.ready, work=wire,
                latency=max(0.0, total - wire), priority=op.priority,
                job=job, link=f"{link}{op.channel}" if op.channel else link,
                hold=hold, duration=total))
        return _apply_link(flows, link_profile)
    for op in plan.ops:
        total = cost.time(op.size) + per_tensor_overhead * op.n_tensors
        wire = min(wire_time(op.size), total)
        lat = max(0.0, total - wire)
        rail_work = wire * n_rails           # per-rail bw = aggregate / n
        flows.append(FlowSpec(
            op_id=op_id_base + op.op_id, ready=op.ready, work=rail_work,
            latency=lat, priority=op.priority,
            job=job if op.channel == 0 else f"{job}@r{op.channel}",
            link=link, hold=hold, duration=lat + rail_work,
            rail=op.channel))
    return _apply_link(flows, link_profile)


def _time_col(cost, sizes: np.ndarray) -> np.ndarray:
    """``cost.time`` over a size column — ``time_v`` when the model has one
    (bit-identical per element by contract), scalar loop otherwise."""
    tv = getattr(cost, "time_v", None)
    if tv is not None:
        return tv(sizes)
    return np.array([cost.time(s) for s in sizes.tolist()], dtype=np.float64)


def _wire_col(cost, sizes: np.ndarray) -> np.ndarray:
    """``getattr(cost, "wire_time", cost.time)`` over a size column."""
    wv = getattr(cost, "wire_time_v", None)
    if wv is not None:
        return wv(sizes)
    wt = getattr(cost, "wire_time", None)
    if wt is not None:
        return np.array([wt(s) for s in sizes.tolist()], dtype=np.float64)
    return _time_col(cost, sizes)


def _channel_names(chans: np.ndarray, fmt) -> Tuple[Tuple[str, ...],
                                                    np.ndarray]:
    """Intern a channel column into (name table, codes) under a naming rule,
    with the table in first-appearance order — the same order a per-op loop
    building names would produce, which :class:`FlowBatch` requires."""
    if not chans.any():
        return (fmt(0),), np.zeros(len(chans), dtype=np.intp)
    u, first, inv = np.unique(chans, return_index=True, return_inverse=True)
    order = np.argsort(first, kind="stable")
    rank = np.empty(len(u), dtype=np.intp)
    rank[order] = np.arange(len(u), dtype=np.intp)
    return tuple(fmt(int(c)) for c in u[order]), rank[inv]


def plan_to_flow_batch(plan: CommPlan, cost,
                       per_tensor_overhead: float = 0.0, *,
                       job: str = "job0", link: str = "nic",
                       op_id_base: int = 0, n_rails: int = 1,
                       codecs: Optional[Mapping[str, CodecLowering]] = None,
                       link_profile: Optional[LinkProfile] = None
                       ) -> FlowBatch:
    """Columnar :func:`plan_to_flows`: one vectorized pass over the plan
    producing a :class:`FlowBatch` instead of a FlowSpec list.

    Bit-identity is the contract, not an aspiration: every column holds
    exactly the float values the per-op loop would put in the tuples.  The
    pieces that make that true:

    - cost models expose ``time_v``/``wire_time_v`` twins whose elementwise
      numpy arithmetic performs the scalar expressions' operations in the
      same order (models without the twins fall back to a scalar loop);
    - the codec encode chain — serialized on the job's GPU with a running
      ``enc_clock`` — is the max-plus recurrence, solved exactly by
      :func:`repro.core.events.serialized_chain` over the subsequence of
      ops with nonzero encode cost (a ``np.maximum.accumulate`` cumsum
      would re-associate the adds and drift);
    - job/link name tables come out in first-appearance order, the
      :class:`FlowBatch` invariant the engine's tie-breaking relies on.

    ``plan_to_flows(...)`` and ``FlowBatch.from_flows`` round-trip through
    this equivalence; the property suite pins it element-wise.
    """
    ops = plan.ops
    if not ops:
        return _EMPTY_BATCH
    op_col, size_col, nt_col, rdy_col, pr_col, ch_col, chunk_col = zip(
        *((o.op_id, o.size, o.n_tensors, o.ready, o.priority, o.channel,
           o.chunk) for o in ops))
    n = len(ops)
    sizes = np.asarray(size_col, dtype=np.float64)
    nt = np.asarray(nt_col, dtype=np.float64)
    ready = np.asarray(rdy_col, dtype=np.float64)
    pr = np.asarray(pr_col, dtype=np.float64)
    chans = np.asarray(ch_col, dtype=np.intp)
    op_ids = np.asarray(op_col, dtype=np.intp) + op_id_base
    hold = np.full(n, plan.scheduler == "fifo")
    pto = per_tensor_overhead

    if codecs is not None:
        ctab, ccode = _intern([o.codec for o in ops])
        totals = np.empty(n)
        wires = np.empty(n)
        enc = np.zeros(n)
        dec = np.zeros(n)
        chunk0 = np.asarray(chunk_col, dtype=np.intp) == 0
        for k, cname in enumerate(ctab):
            cl = codecs[cname]
            idx = np.flatnonzero(ccode == k)
            s = sizes[idx]
            tg = _time_col(cl.cost, s) + pto * nt[idx]
            totals[idx] = tg
            wires[idx] = np.minimum(_wire_col(cl.cost, s), tg)
            cd = cl.codec
            if not cd.is_free:
                launch = np.where(chunk0[idx], cd.launch_overhead, 0.0)
                enc[idx] = launch + cd.encode_seconds(s)
                dec[idx] = launch + cd.decode_seconds(s)
        m = enc > 0.0
        if m.any():
            # the encode chain runs across ALL ops in op order, skipping
            # zero-cost ops — exactly the scalar loop's enc_clock updates
            _, ends = serialized_chain(ready[m], enc[m])
            ready = ready.copy()
            ready[m] = ends
        lat = np.maximum(0.0, totals - wires) + dec
        if n_rails <= 1:
            links, lcode = _channel_names(
                chans, lambda c: f"{link}{c}" if c else link)
            return _apply_link_batch(FlowBatch(
                op_id=op_ids, ready=ready, work=wires, latency=lat,
                priority=pr, duration=totals + dec, hold=hold,
                jobs=(job,), job=np.zeros(n, dtype=np.intp),
                links=links, link=lcode, rail=np.zeros(n, dtype=np.intp),
                worker=np.zeros(n, dtype=np.intp)), link_profile)
        rail_work = wires * n_rails
        jobs, jcode = _channel_names(
            chans, lambda c: job if c == 0 else f"{job}@r{c}")
        return _apply_link_batch(FlowBatch(
            op_id=op_ids, ready=ready, work=rail_work, latency=lat,
            priority=pr, duration=lat + rail_work, hold=hold,
            jobs=jobs, job=jcode, links=(link,),
            link=np.zeros(n, dtype=np.intp), rail=chans,
            worker=np.zeros(n, dtype=np.intp)), link_profile)

    totals = _time_col(cost, sizes) + pto * nt
    wires = np.minimum(_wire_col(cost, sizes), totals)
    lat = np.maximum(0.0, totals - wires)
    if n_rails <= 1:
        links, lcode = _channel_names(
            chans, lambda c: f"{link}{c}" if c else link)
        return _apply_link_batch(FlowBatch(
            op_id=op_ids, ready=ready, work=wires, latency=lat,
            priority=pr, duration=totals, hold=hold,
            jobs=(job,), job=np.zeros(n, dtype=np.intp),
            links=links, link=lcode, rail=np.zeros(n, dtype=np.intp),
            worker=np.zeros(n, dtype=np.intp)), link_profile)
    rail_work = wires * n_rails                # per-rail bw = aggregate / n
    jobs, jcode = _channel_names(
        chans, lambda c: job if c == 0 else f"{job}@r{c}")
    return _apply_link_batch(FlowBatch(
        op_id=op_ids, ready=ready, work=rail_work, latency=lat,
        priority=pr, duration=lat + rail_work, hold=hold,
        jobs=jobs, job=jcode, links=(link,),
        link=np.zeros(n, dtype=np.intp), rail=chans,
        worker=np.zeros(n, dtype=np.intp)), link_profile)


def clone_flows(flows: Sequence[FlowSpec], op_id_base: int, job: str, *,
                old_job: str = DEFAULT_JOB) -> List[FlowSpec]:
    """Relabel an already-lowered flow list for another identical job.

    :func:`plan_to_flows` is pure in everything except ``job`` and
    ``op_id_base``: two co-located jobs running the same plan under the
    same cost model differ only in those labels.  Cloning skips the
    cost-model calls and duration arithmetic entirely and the result is
    bit-identical to a fresh ``plan_to_flows`` call — the same float
    objects, relabeled — which is what lets ``simulate_contention`` lower
    an n-job contention cell once instead of n times.  Rail lanes
    (``job@r<k>``, stamped by :func:`plan_to_flows` under multi-rail
    lowering) are relabeled consistently; job names not starting with
    ``old_job`` are left untouched.
    """
    if op_id_base == 0 and job == old_job:
        return list(flows)
    shift = len(old_job)
    names: dict = {}
    new = tuple.__new__
    out: List[FlowSpec] = []
    for f in flows:
        nm = names.get(f[5])
        if nm is None:
            nm = job + f[5][shift:] if f[5].startswith(old_job) else f[5]
            names[f[5]] = nm
        out.append(new(FlowSpec, (f[0] + op_id_base, f[1], f[2], f[3], f[4],
                                  nm, f[6], f[7], f[8], f[9], f[10], f[11])))
    return out
