"""Pytree checkpointing without external dependencies.

Layout: a directory per step with one ``.npy`` file per leaf plus a JSON
manifest of the tree structure and dtypes.  Restore is shape/dtype checked
against a template tree.  Works for params and optimizer state alike; in a
multi-host deployment each host saves its addressable shards (here: the
single host saves everything).
"""
from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree: Any):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


def save(directory: str | Path, tree: Any, step: int) -> Path:
    d = Path(directory) / f"step_{step:08d}"
    tmp = d.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    manifest = {}
    for i, (name, leaf) in enumerate(_flatten_with_names(tree)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        dtype = str(arr.dtype)
        if dtype == "bfloat16":        # numpy can't serialize bf16: store bits
            np.save(tmp / fname, arr.view(np.uint16))
        else:
            np.save(tmp / fname, arr)
        manifest[name] = {"file": fname, "shape": list(arr.shape),
                          "dtype": dtype}
    (tmp / "manifest.json").write_text(json.dumps({"step": step,
                                                   "leaves": manifest}))
    if d.exists():
        shutil.rmtree(d)
    tmp.rename(d)
    return d


def latest_step(directory: str | Path) -> Optional[int]:
    d = Path(directory)
    if not d.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in d.glob("step_*")
                   if p.is_dir())
    return steps[-1] if steps else None


def restore(directory: str | Path, template: Any, step: Optional[int] = None
            ) -> Any:
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())["leaves"]
    named = _flatten_with_names(template)
    leaves = []
    for name, tmpl in named:
        ent = manifest[name]
        arr = np.load(d / ent["file"])
        if ent["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16.dtype)
        if list(arr.shape) != list(tmpl.shape):
            raise ValueError(f"{name}: checkpoint shape {arr.shape} != "
                             f"template {tmpl.shape}")
        leaves.append(jnp.asarray(arr, dtype=tmpl.dtype))
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)
