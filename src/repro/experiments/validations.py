"""Paper-claim checks, evaluated on experiment cells and stored in artifacts.

These are the section-1 validations that used to live inline in
``benchmarks/figures.py``; moving them into the engine means every artifact
carries its own pass/fail record and ``compare`` can flag a claim that a
refactor silently broke (a True that became False is a regression even if
no numeric tolerance trips).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence

Check = Dict[str, bool]


def _by(cells: Sequence[Dict], *axes: str, value: str = "scaling_factor"):
    return {tuple(c[a] for a in axes): c[value] for c in cells}


def _fig1(cells: Sequence[Dict]) -> Check:
    by = _by(cells, "model", "n_servers")
    # paper §2.2: RN50/RN101/VGG16 = 75/69/56 % @2 servers; none exceeds 76 %
    return {
        "rn50_2srv_in_[0.6,0.9]": 0.60 <= by[("resnet50", 2)] <= 0.90,
        "vgg16_worst": by[("vgg16", 2)] < by[("resnet50", 2)],
        "no_linear_scaling": max(by.values()) < 0.85,
    }


def _fig3(cells: Sequence[Dict]) -> Check:
    by = _by(cells, "n_servers", "bandwidth_gbps")
    # paper: 2-server RN50 grows 13 % -> ~68 % from 1 to 10 Gbps, then
    # plateaus after 25 Gbps (measured transport)
    return {
        "low_bw_poor": by[(2, 1.0)] < 0.25,
        "grows_to_10g": by[(2, 10.0)] > 3 * by[(2, 1.0)],
        "plateau_after_25g": (by[(2, 100.0)] - by[(2, 25.0)]) < 0.15,
    }


def _fig4(cells: Sequence[Dict]) -> Check:
    util = _by(cells, "model", "bandwidth_gbps", value="network_utilization")
    eff = _by(cells, "model", "bandwidth_gbps", value="effective_gbps")
    return {
        "full_util_at_1g": util[("resnet50", 1.0)] > 0.9,
        "low_util_at_100g": eff[("resnet50", 100.0)] < 32.0,
    }


def _fig6(cells: Sequence[Dict]) -> Check:
    by = _by(cells, "model", "bandwidth_gbps", "transport")
    low_bw_agree, high_bw_diverge = True, False
    for (m, bw, t), f in by.items():
        if t != "ideal":
            continue
        meas = by[(m, bw, "horovod_tcp")]
        if bw <= 10 and abs(f - meas) > 0.08:
            low_bw_agree = False       # Fig 6: the lines coincide at low bw
        if bw == 100 and f - meas > 0.15:
            high_bw_diverge = True     # ...and split at 100 Gbps
    return {"low_bw_agree": low_bw_agree, "high_bw_diverge": high_bw_diverge}


def _fig7(cells: Sequence[Dict]) -> Check:
    # paper: full-util scaling ~100 % even at 64 GPUs
    worst = min(c["scaling_factor"] for c in cells
                if c["transport"] == "ideal")
    return {"full_util_near_1_even_64gpus": worst > 0.97}


def _fig8(cells: Sequence[Dict]) -> Check:
    by = _by(cells, "model", "bandwidth_gbps", "compression_ratio")
    # paper: 2-5x suffices at 10 Gbps for ResNets; ~10x for VGG16;
    # compression unnecessary at 100 Gbps
    return {
        "rn50_5x_10g": by[("resnet50", 10.0, 5.0)] > 0.95,
        "vgg16_10x_10g": by[("vgg16", 10.0, 10.0)] > 0.95,
        "no_need_at_100g": by[("vgg16", 100.0, 1.0)] > 0.97,
        "100x_overkill": (by[("resnet50", 10.0, 100.0)]
                          - by[("resnet50", 10.0, 10.0)]) < 0.02,
    }


def _fig9(cells: Sequence[Dict]) -> Check:
    by = _by(cells, "model", "bandwidth_gbps", "topology")
    ok = all(by[(m, bw, "switchml")] >= by[(m, bw, "ring")] - 1e-9
             for (m, bw, topo) in by if topo == "ring")
    return {"switchml_never_worse": ok}


def _scheduler_suite(cells: Sequence[Dict]) -> Check:
    """The tentpole's acceptance claim: a better schedule never *adds*
    overhead.  priority and chunked reorder/pipeline the same wire work a
    work-conserving link serves, so per cell their t_overhead must be <=
    fifo's (tiny epsilon for float re-association)."""
    over = _by(cells, "model", "bandwidth_gbps", "transport", "scheduler",
               value="t_overhead")
    eps = 1e-12
    pri_ok = all(over[(m, bw, t, "priority")] <= f + eps
                 for (m, bw, t, s), f in over.items() if s == "fifo")
    chk_ok = all(over[(m, bw, t, "chunked")] <= f + eps
                 for (m, bw, t, s), f in over.items() if s == "fifo")
    # pipelining matters most where the link is the bottleneck: at 5 Gbps
    # measured-mode VGG16 the chunked schedule must show a real win
    gain = (over[("vgg16", 5.0, "horovod_tcp", "fifo")]
            - over[("vgg16", 5.0, "horovod_tcp", "chunked")])
    return {
        "priority_overhead_le_fifo": pri_ok,
        "chunked_overhead_le_fifo": chk_ok,
        "chunked_helps_vgg16_at_5g": gain > 0.0,
    }


VALIDATORS: Dict[str, Callable[[Sequence[Dict]], Check]] = {
    "paper-fig1": _fig1,
    "paper-fig3": _fig3,
    "paper-fig4": _fig4,
    "paper-fig6": _fig6,
    "paper-fig7": _fig7,
    "paper-fig8": _fig8,
    "paper-fig9": _fig9,
    "scheduler-suite": _scheduler_suite,
}


def validate(grid_name: str, cells: Sequence[Dict]) -> Check:
    fn = VALIDATORS.get(grid_name)
    # bool() strips numpy bool scalars, which are not JSON serializable
    return {k: bool(v) for k, v in fn(cells).items()} if fn else {}
