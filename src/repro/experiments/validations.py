"""Paper-claim checks, evaluated on experiment cells and stored in artifacts.

These are the section-1 validations that used to live inline in
``benchmarks/figures.py``; moving them into the engine means every artifact
carries its own pass/fail record and ``compare`` can flag a claim that a
refactor silently broke (a True that became False is a regression even if
no numeric tolerance trips).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence

Check = Dict[str, bool]


def _by(cells: Sequence[Dict], *axes: str, value: str = "scaling_factor"):
    from repro.experiments.spec import axis_value
    return {tuple(axis_value(c, a) for a in axes): c[value] for c in cells}


def _fig1(cells: Sequence[Dict]) -> Check:
    by = _by(cells, "model", "n_servers")
    # paper §2.2: RN50/RN101/VGG16 = 75/69/56 % @2 servers; none exceeds 76 %
    return {
        "rn50_2srv_in_[0.6,0.9]": 0.60 <= by[("resnet50", 2)] <= 0.90,
        "vgg16_worst": by[("vgg16", 2)] < by[("resnet50", 2)],
        "no_linear_scaling": max(by.values()) < 0.85,
    }


def _fig3(cells: Sequence[Dict]) -> Check:
    by = _by(cells, "n_servers", "bandwidth_gbps")
    # paper: 2-server RN50 grows 13 % -> ~68 % from 1 to 10 Gbps, then
    # plateaus after 25 Gbps (measured transport)
    return {
        "low_bw_poor": by[(2, 1.0)] < 0.25,
        "grows_to_10g": by[(2, 10.0)] > 3 * by[(2, 1.0)],
        "plateau_after_25g": (by[(2, 100.0)] - by[(2, 25.0)]) < 0.15,
    }


def _fig4(cells: Sequence[Dict]) -> Check:
    util = _by(cells, "model", "bandwidth_gbps", value="network_utilization")
    eff = _by(cells, "model", "bandwidth_gbps", value="effective_gbps")
    return {
        "full_util_at_1g": util[("resnet50", 1.0)] > 0.9,
        "low_util_at_100g": eff[("resnet50", 100.0)] < 32.0,
    }


def _fig6(cells: Sequence[Dict]) -> Check:
    by = _by(cells, "model", "bandwidth_gbps", "transport")
    low_bw_agree, high_bw_diverge = True, False
    for (m, bw, t), f in by.items():
        if t != "ideal":
            continue
        meas = by[(m, bw, "horovod_tcp")]
        if bw <= 10 and abs(f - meas) > 0.08:
            low_bw_agree = False       # Fig 6: the lines coincide at low bw
        if bw == 100 and f - meas > 0.15:
            high_bw_diverge = True     # ...and split at 100 Gbps
    return {"low_bw_agree": low_bw_agree, "high_bw_diverge": high_bw_diverge}


def _fig7(cells: Sequence[Dict]) -> Check:
    # paper: full-util scaling ~100 % even at 64 GPUs
    worst = min(c["scaling_factor"] for c in cells
                if c["transport"] == "ideal")
    return {"full_util_near_1_even_64gpus": worst > 0.97}


def _fig8(cells: Sequence[Dict]) -> Check:
    by = _by(cells, "model", "bandwidth_gbps", "compression_ratio")
    # paper: 2-5x suffices at 10 Gbps for ResNets; ~10x for VGG16;
    # compression unnecessary at 100 Gbps
    return {
        "rn50_5x_10g": by[("resnet50", 10.0, 5.0)] > 0.95,
        "vgg16_10x_10g": by[("vgg16", 10.0, 10.0)] > 0.95,
        "no_need_at_100g": by[("vgg16", 100.0, 1.0)] > 0.97,
        "100x_overkill": (by[("resnet50", 10.0, 100.0)]
                          - by[("resnet50", 10.0, 10.0)]) < 0.02,
    }


def _fig9(cells: Sequence[Dict]) -> Check:
    by = _by(cells, "model", "bandwidth_gbps", "topology")
    ok = all(by[(m, bw, "switchml")] >= by[(m, bw, "ring")] - 1e-9
             for (m, bw, topo) in by if topo == "ring")
    return {"switchml_never_worse": ok}


def _scheduler_suite(cells: Sequence[Dict]) -> Check:
    """The tentpole's acceptance claim: a better schedule never *adds*
    overhead.  priority and chunked reorder/pipeline the same wire work a
    work-conserving link serves, so per cell their t_overhead must be <=
    fifo's (tiny epsilon for float re-association)."""
    over = _by(cells, "model", "bandwidth_gbps", "transport", "scheduler",
               value="t_overhead")
    eps = 1e-12
    pri_ok = all(over[(m, bw, t, "priority")] <= f + eps
                 for (m, bw, t, s), f in over.items() if s == "fifo")
    chk_ok = all(over[(m, bw, t, "chunked")] <= f + eps
                 for (m, bw, t, s), f in over.items() if s == "fifo")
    # pipelining matters most where the link is the bottleneck: at 5 Gbps
    # measured-mode VGG16 the chunked schedule must show a real win
    gain = (over[("vgg16", 5.0, "horovod_tcp", "fifo")]
            - over[("vgg16", 5.0, "horovod_tcp", "chunked")])
    return {
        "priority_overhead_le_fifo": pri_ok,
        "chunked_overhead_le_fifo": chk_ok,
        "chunked_helps_vgg16_at_5g": gain > 0.0,
    }


def _xl_bandwidth(cells: Sequence[Dict]) -> Check:
    """The dense sweep must reproduce the paper's shape everywhere: scaling
    monotone in bandwidth per (model, servers, transport), ideal transport
    never below measured mode, and the measured plateau past 25 Gbps."""
    by = _by(cells, "model", "n_servers", "transport", "bandwidth_gbps")
    bws = sorted({bw for (_, _, _, bw) in by})
    mono = all(by[(m, n, t, a)] <= by[(m, n, t, b)] + 1e-9
               for (m, n, t, _) in by for a, b in zip(bws, bws[1:]))
    ideal_ge = all(f <= by[(m, n, "ideal", bw)] + 1e-9
                   for (m, n, t, bw), f in by.items() if t == "horovod_tcp")
    plateau = all(by[(m, n, "horovod_tcp", 400.0)]
                  - by[(m, n, "horovod_tcp", 25.0)] < 0.15
                  for (m, n, t, _) in by if t == "horovod_tcp")
    return {"monotone_in_bandwidth": mono, "ideal_bounds_measured": ideal_ge,
            "measured_plateau_past_25g": plateau}


def _xl_sched(cells: Sequence[Dict]) -> Check:
    """Deep chunking (64 chunks/bucket) must sharpen, not break, the
    scheduler claims: pipelined schedules never add overhead over fifo."""
    over = _by(cells, "model", "bandwidth_gbps", "transport", "scheduler",
               value="t_overhead")
    eps = 1e-12
    fifo = {k[:3]: v for k, v in over.items() if k[3] == "fifo"}
    pri_ok = all(v <= fifo[k[:3]] + eps
                 for k, v in over.items() if k[3] == "priority")
    chk_ok = all(v <= fifo[k[:3]] + eps
                 for k, v in over.items() if k[3] == "chunked")
    # at 64 chunks the pipeline must show a strict win on the bandwidth-
    # bound measured VGG16 cell
    gain = (over[("vgg16", 5.0, "horovod_tcp", "fifo")]
            - over[("vgg16", 5.0, "horovod_tcp", "chunked")])
    return {"priority64_overhead_le_fifo": pri_ok,
            "chunked64_overhead_le_fifo": chk_ok,
            "chunked64_helps_vgg16_at_5g": gain > 0.0}


def _xl_contention(cells: Sequence[Dict]) -> Check:
    """Fair-share contention semantics at sweep scale: co-located jobs can
    only hurt, monotonically in the number of jobs, and a solo 'contention'
    cell must agree with the plain simulate path bit-for-bit (the engine's
    closed forms make the degenerate case exact, not just close)."""
    by = _by(cells, "model", "bandwidth_gbps", "scheduler", "n_jobs")
    jobs = sorted({j for (_, _, _, j) in by})
    mono = all(by[(m, bw, s, b)] <= by[(m, bw, s, a)] + 1e-9
               for (m, bw, s, _) in by for a, b in zip(jobs, jobs[1:]))
    hurts = all(by[(m, bw, s, 8)] < by[(m, bw, s, 1)] - 1e-6
                for (m, bw, s, j) in by if j == 1 and bw <= 25.0)
    from repro.core.simulator import simulate
    from repro.core.timeline import from_cnn
    from repro.core.transport import GBPS
    solo = [c for c in cells if c.get("n_jobs", 1) == 1
            and c["model"] == "vgg16" and c["scheduler"] == "fifo"]
    exact = all(simulate(from_cnn(c["model"]), n_workers=c["n_workers"],
                         bandwidth=c["bandwidth_gbps"] * GBPS,
                         transport=c["transport"],
                         scheduler=c["scheduler"]).t_sync == c["t_sync"]
                for c in solo)
    return {"monotone_in_n_jobs": mono, "contention_hurts_at_low_bw": hurts,
            "solo_cell_matches_simulate_bitwise": exact}


def _xxl_contention(cells: Sequence[Dict]) -> Check:
    """The 10k-flow priority/contention regime the heap-mode bulk commit
    opens up.  Gated claims:

    - fair-share contention only hurts, monotonically in ``n_jobs``, at
      every (model, bandwidth, scheduler, jitter) point — including the
      18k-flow 16-job VGG16 cells;
    - at 64 chunks/bucket the priority schedule never *adds* overhead
      over the chunked pipeline (same chunking, reordered): solo it may
      win slightly, and under saturation the work-conserving link makes
      them coincide up to the final-tail reordering;
    - flush jitter is monotone for a *solo* job (the straggler-grid
      claim at 64-chunk scale).  Under contention independent job
      streams can delay competitors and *help* job 0, so monotonicity
      is deliberately not claimed for n_jobs > 1;
    - a solo unjittered cell is bit-exact with plain ``simulate`` — the
      degenerate contention path stays on the engine's closed forms.
    """
    from repro.experiments.spec import axis_value
    by = {(c["model"], c["bandwidth_gbps"], c["scheduler"],
           axis_value(c, "n_jobs"), axis_value(c, "jitter_ms")): c
          for c in cells}
    jobs = sorted({k[3] for k in by})
    over = {k: c["t_overhead"] for k, c in by.items()}
    mono_jobs = all(over[(m, bw, s, a, jm)] <= over[(m, bw, s, b, jm)] + 1e-9
                    for (m, bw, s, _, jm) in by
                    for a, b in zip(jobs, jobs[1:]))
    hurts = all(by[(m, bw, s, jobs[-1], jm)]["scaling_factor"]
                < by[(m, bw, s, 1, jm)]["scaling_factor"] - 1e-6
                for (m, bw, s, j, jm) in by if j == 1)
    pri_le_chk = all(over[(m, bw, "priority", j, jm)]
                     <= over[(m, bw, "chunked", j, jm)] + 1e-4
                     for (m, bw, s, j, jm) in by if s == "chunked")
    jits = sorted({k[4] for k in by})
    solo_jit = all(over[(m, bw, s, 1, a)] <= over[(m, bw, s, 1, b)] + 1e-9
                   for (m, bw, s, j, _) in by if j == 1
                   for a, b in zip(jits, jits[1:]))
    from repro.core.simulator import simulate
    from repro.core.timeline import from_cnn
    from repro.core.transport import GBPS
    solo = [c for c in cells if axis_value(c, "n_jobs") == 1
            and axis_value(c, "jitter_ms") == 0.0]
    exact = all(simulate(from_cnn(c["model"]), n_workers=c["n_workers"],
                         bandwidth=c["bandwidth_gbps"] * GBPS,
                         transport=c["transport"], scheduler=c["scheduler"],
                         n_chunks=64).t_sync == c["t_sync"]
                for c in solo)
    return {"overhead_monotone_in_n_jobs": mono_jobs,
            "contention_hurts_at_16_jobs": hurts,
            "priority64_overhead_le_chunked64": pri_le_chk,
            "solo_overhead_monotone_in_jitter": solo_jit,
            "solo_cell_matches_simulate_bitwise": exact}


def _multirail(cells: Sequence[Dict]) -> Check:
    """The multi-rail claims the scenario golden suite gates.

    At *equal aggregate bandwidth*: the chunked pipeline stripes every
    bucket across rails, so splitting the link never costs more than the
    tail-bucket negotiation skew (the negotiation-carrying chunk's wire
    runs at 1/n rate — an absolute, sub-millisecond effect); the
    serialized fifo stream cannot stripe, so rails strictly *help*
    latency-bound models (lanes run reductions in parallel) and strictly
    *hurt* the bandwidth-bound VGG16 (whole buckets sit on a slower rail).
    A fifo cell on one rail must be bit-exact with a ``simulate`` call
    that never heard of the axis.
    """
    over = _by(cells, "model", "bandwidth_gbps", "scheduler", "n_rails",
               value="t_overhead")
    skew = 1e-3                      # seconds; see docstring
    chunked_ok = all(v <= over[(m, bw, s, 1)] + skew
                     for (m, bw, s, r), v in over.items()
                     if s == "chunked" and r > 1)
    fifo_helps = all(over[(m, bw, "fifo", 2)] < over[(m, bw, "fifo", 1)]
                     for m in ("resnet50", "resnet101")
                     for bw in (25.0, 100.0))
    fifo_hurts = all(over[("vgg16", bw, "fifo", 2)]
                     > over[("vgg16", bw, "fifo", 1)]
                     for bw in (10.0, 25.0, 100.0))
    from repro.core.simulator import simulate
    from repro.core.timeline import from_cnn
    from repro.core.transport import GBPS
    from repro.experiments.spec import axis_value
    solo = [c for c in cells if axis_value(c, "n_rails") == 1
            and c["scheduler"] == "fifo" and c["model"] == "vgg16"]
    exact = all(simulate(from_cnn(c["model"]), n_workers=c["n_workers"],
                         bandwidth=c["bandwidth_gbps"] * GBPS,
                         transport=c["transport"], scheduler=c["scheduler"],
                         n_chunks=8).t_sync == c["t_sync"]
                for c in solo)
    return {"chunked_rails_never_slower_within_skew": chunked_ok,
            "fifo_rails2_help_latency_bound_models": fifo_helps,
            "fifo_rails2_hurt_bandwidth_bound_vgg16": fifo_hurts,
            "fifo_rails1_matches_simulate_bitwise": exact}


def _straggler(cells: Sequence[Dict]) -> Check:
    """The straggler claims the scenario golden suite gates.

    Delays are drawn once per (seed, flow) and scale linearly in the
    jitter axis, so overhead must be monotone in jitter everywhere.  At
    full bandwidth the straggler tail passes straight into t_overhead
    (the sync was ready-time-bound already); in the bandwidth-bound
    regime the transmission queue absorbs most of it — the overlap
    argument the gradient-compression follow-up turns on.  Zero-jitter
    cells must be bit-exact with a ``simulate`` that never saw the axis.
    """
    over = _by(cells, "model", "bandwidth_gbps", "scheduler", "jitter_ms",
               value="t_overhead")
    jits = sorted({k[3] for k in over})
    hi = jits[-1]
    mono = all(over[(m, bw, s, a)] <= over[(m, bw, s, b)] + 1e-9
               for (m, bw, s, _) in over for a, b in zip(jits, jits[1:]))
    tail = all(over[(m, 100.0, s, hi)] > over[(m, 100.0, s, 0.0)] + 1e-4
               for (m, bw, s, _) in over if bw == 100.0)
    damp = all(over[(m, 10.0, "chunked", hi)]
               - over[(m, 10.0, "chunked", 0.0)]
               < over[(m, 100.0, "chunked", hi)]
               - over[(m, 100.0, "chunked", 0.0)]
               for m in ("resnet50", "resnet101"))
    from repro.core.simulator import simulate
    from repro.core.timeline import from_cnn
    from repro.core.transport import GBPS
    from repro.experiments.spec import axis_value
    base = [c for c in cells if axis_value(c, "jitter_ms") == 0.0
            and c["model"] == "vgg16"]
    exact = all(simulate(from_cnn(c["model"]), n_workers=c["n_workers"],
                         bandwidth=c["bandwidth_gbps"] * GBPS,
                         transport=c["transport"], scheduler=c["scheduler"],
                         n_chunks=8).t_sync == c["t_sync"]
                for c in base)
    return {"overhead_monotone_in_jitter": mono,
            "jitter_tail_hits_full_bw_overhead": tail,
            "queue_absorbs_jitter_when_bw_bound": damp,
            "jitter0_matches_simulate_bitwise": exact}


def _compression(cells: Sequence[Dict]) -> Check:
    """The compression-regime claims the golden suite gates.

    Compression is priced (encode -> wire -> decode with kernel-calibrated
    compute), not a free byte divisor, so the gated claims are exactly the
    ones the divisor cannot express:

    - a ``codec=none`` cell is bit-exact with a ``simulate`` call that
      never heard of the axis (the codec path is a pass, not a rewrite);
    - wire bytes are monotone non-increasing in the codec's wire ratio
      (none >= int8 >= topk:8 >= ternary), per cell twin;
    - every real-codec cell spends strictly positive encode+decode GPU
      time (``codec_compute_s > 0``) — nothing is free;
    - the size-adaptive policy's wire bytes land between the none and
      int8 twins (it compresses only the large buckets);
    - the fig13 regimes come out as the paper + follow-ups predict:
      compression *wins* at 10 Gbps (network-bound) and is *pure
      overhead* at 100 Gbps (compute-bound baseline).
    """
    from repro.core.codec import (REGIME_PURE_OVERHEAD, REGIME_WINS,
                                  classify_regime)
    from repro.experiments.spec import axis_value
    by = {(c["model"], c["bandwidth_gbps"], c["scheduler"],
           axis_value(c, "n_jobs"), axis_value(c, "codec")): c
          for c in cells}
    # wire ratio order: none (1x) < int8 (~3.9x) < topk:8 (8x) < ternary
    order = ("none", "int8", "topk:8", "ternary")
    wire = {k: c["wire_bytes_per_worker"] for k, c in by.items()}
    mono = all(wire[(m, bw, s, j, a)] >= wire[(m, bw, s, j, b)] - 1e-9
               for (m, bw, s, j, cd) in by if cd == "none"
               for a, b in zip(order, order[1:]))
    compute_pos = all(c.get("codec_compute_s", 0.0) > 0.0
                      for k, c in by.items() if k[4] != "none")
    adaptive_between = all(
        wire[(m, bw, s, j, "int8")] - 1e-9
        <= wire[(m, bw, s, j, "size-adaptive")]
        <= wire[(m, bw, s, j, "none")] + 1e-9
        for (m, bw, s, j, cd) in by if cd == "size-adaptive")

    def regime(model: str, bw: float, codec: str) -> str:
        none = by[(model, bw, "fifo", 1, "none")]
        c = by[(model, bw, "fifo", 1, codec)]
        return classify_regime(c["t_overhead"], none["t_overhead"],
                               none["t_batch"], c["codec_compute_s"])

    wins_10g = all(regime(m, 10.0, "int8") == REGIME_WINS
                   for m in ("resnet50", "vgg16"))
    pure_100g = all(regime(m, 100.0, cd) == REGIME_PURE_OVERHEAD
                    for m in ("resnet50", "vgg16")
                    for cd in ("int8", "ternary"))

    from repro.core.simulator import simulate
    from repro.core.timeline import from_cnn
    from repro.core.transport import GBPS
    base = [c for c in cells if axis_value(c, "codec") == "none"
            and axis_value(c, "n_jobs") == 1]
    exact = all(simulate(from_cnn(c["model"]), n_workers=c["n_workers"],
                         bandwidth=c["bandwidth_gbps"] * GBPS,
                         transport=c["transport"], scheduler=c["scheduler"],
                         n_chunks=8).t_sync == c["t_sync"]
                for c in base)
    return {"codec_none_matches_simulate_bitwise": exact,
            "wire_bytes_monotone_in_ratio": mono,
            "codec_compute_strictly_positive": compute_pos,
            "size_adaptive_wire_between_none_and_int8": adaptive_between,
            "compression_wins_at_10g": wins_10g,
            "pure_overhead_at_100g": pure_100g}


def _churn(cells: Sequence[Dict]) -> Check:
    """The unreliable-world claims the churn golden suite gates.

    - a zero-fault cell (``fault_model="none"``, no churn, no skew) is
      bit-exact with a ``simulate`` call that never heard of the fault
      axes — the null model is a bypass, not a rewrite;
    - at fixed seed and no churn, overhead is monotone in the slowdown
      scale (``none <= slowdown:1 <= slowdown:5``): the exponential
      draws are shared across the sweep and scale linearly.  Under
      active churn the claim is deliberately *not* gated — the slowdown
      stretches the iteration horizon, which moves the churn draw times
      and can reorder which flows a dropout catches in flight;
    - bandwidth skew only adds wire work (factors are ``1 + skew *
      Exp(1) >= 1``), so at no churn overhead is monotone in the skew
      axis too;
    - churn never helps: every churned cell's t_sync is >= its
      churn-free twin's (drops cancel pending flows, but the re-bucket
      stalls and restarts dominate at the gated seed);
    - priority never loses to fifo on t_overhead, fault axes included —
      the engine re-admits survivors in IR order under either schedule;
    - a fully-faulted cell replays bit-exact through a direct
      ``simulate`` call with the same fault kwargs (the determinism
      contract: draws depend only on ``(fault_seed, stream, n)``).
    """
    from repro.experiments.spec import axis_value
    by = {(c["model"], c["bandwidth_gbps"], axis_value(c, "scheduler"),
           axis_value(c, "n_rails"), axis_value(c, "fault_model"),
           axis_value(c, "churn_rate"), axis_value(c, "worker_bw_skew")): c
          for c in cells}
    over = {k: c["t_overhead"] for k, c in by.items()}
    fms = ("none", "slowdown:1", "slowdown:5")
    mono_slow = all(
        over[(m, bw, s, r, a, 0.0, sk)] <= over[(m, bw, s, r, b, 0.0, sk)]
        + 1e-9
        for (m, bw, s, r, fm, cr, sk) in by if fm == "none" and cr == 0.0
        for a, b in zip(fms, fms[1:]))
    mono_skew = all(
        over[(m, bw, s, r, fm, 0.0, 0.0)] <= over[(m, bw, s, r, fm, 0.0, 0.5)]
        + 1e-9
        for (m, bw, s, r, fm, cr, sk) in by if sk == 0.0 and cr == 0.0)
    churn_hurts = all(
        by[(m, bw, s, r, fm, 0.64, sk)]["t_sync"]
        >= by[(m, bw, s, r, fm, 0.0, sk)]["t_sync"] - 1e-9
        for (m, bw, s, r, fm, cr, sk) in by if cr == 0.0)
    pri_ok = all(over[(m, bw, "priority", r, fm, cr, sk)]
                 <= over[(m, bw, "fifo", r, fm, cr, sk)] + 1e-9
                 for (m, bw, s, r, fm, cr, sk) in by if s == "fifo")
    from repro.core.simulator import simulate
    from repro.core.timeline import from_cnn
    from repro.core.transport import GBPS
    base = [c for c in cells if axis_value(c, "fault_model") == "none"
            and axis_value(c, "churn_rate") == 0.0
            and axis_value(c, "worker_bw_skew") == 0.0
            and c["model"] == "vgg16"]
    exact = all(simulate(from_cnn(c["model"]), n_workers=c["n_workers"],
                         bandwidth=c["bandwidth_gbps"] * GBPS,
                         transport=c["transport"],
                         scheduler=axis_value(c, "scheduler"), n_chunks=8,
                         n_rails=axis_value(c, "n_rails")).t_sync
                == c["t_sync"]
                for c in base)
    # fault_seed=2027 is the registered churn grid's seed (grids.py), same
    # convention as the other validators hardcoding their grid's n_chunks
    hot = by[("vgg16", 10.0, "priority", 2, "slowdown:5", 0.64, 0.5)]
    replay = simulate(from_cnn("vgg16"), n_workers=hot["n_workers"],
                      bandwidth=hot["bandwidth_gbps"] * GBPS,
                      transport=hot["transport"], scheduler="priority",
                      n_chunks=8, n_rails=2, fault_model="slowdown:5",
                      churn_rate=0.64, worker_bw_skew=0.5,
                      fault_seed=2027).t_sync == hot["t_sync"]
    return {"zero_fault_matches_simulate_bitwise": exact,
            "overhead_monotone_in_slowdown_no_churn": mono_slow,
            "overhead_monotone_in_bw_skew_no_churn": mono_skew,
            "churn_never_helps_t_sync": churn_hurts,
            "priority_overhead_le_fifo_under_faults": pri_ok,
            "faulted_cell_replays_bitwise": replay}


def _fabric(cells: Sequence[Dict]) -> Check:
    """The fabric-lowering claims the fabric golden suite gates.

    - a 1:1 cell is *bitwise* identical to a ``simulate`` call that never
      heard of the fabric axes — at 1:1 the uplink can never bind, so
      :meth:`repro.core.fabric.Fabric.path` elides it and the original
      single-link engine runs verbatim (the elision contract);
    - scaling is monotone non-increasing in oversubscription at every
      (model, bandwidth, topology) point: a thinner uplink can only slow
      the collective down;
    - hierarchical never loses to the flat ring at 4:1 — rack-local
      reduction puts only the leader on the spine (uplink multiplicity
      1 <= capacity 1 at 4:1 with 4 hosts/ToR), so it dodges the
      oversubscription the striped ring pays 4x for.
    """
    from repro.experiments.spec import axis_value
    by = {(c["model"], c["bandwidth_gbps"], c["topology"],
           axis_value(c, "oversubscription")): c for c in cells}
    ovs = sorted({k[3] for k in by})
    sf = {k: c["scaling_factor"] for k, c in by.items()}
    mono = all(sf[(m, bw, t, b)] <= sf[(m, bw, t, a)] + 1e-9
               for (m, bw, t, _) in by for a, b in zip(ovs, ovs[1:]))
    hier_ok = all(by[(m, bw, "hierarchical", 4.0)]["t_overhead"]
                  <= by[(m, bw, "ring", 4.0)]["t_overhead"] + 1e-9
                  for (m, bw, t, ov) in by if t == "ring" and ov == 4.0)
    from repro.core.simulator import simulate
    from repro.core.timeline import from_cnn
    from repro.core.transport import GBPS
    flat = [c for c in cells if axis_value(c, "oversubscription") == 1.0]
    exact = all(simulate(from_cnn(c["model"]), n_workers=c["n_workers"],
                         bandwidth=c["bandwidth_gbps"] * GBPS,
                         transport=c["transport"],
                         topology=c["topology"]).t_sync == c["t_sync"]
                for c in flat)
    return {"oversub1_matches_flat_simulate_bitwise": exact,
            "scaling_monotone_nonincreasing_in_oversub": mono,
            "hierarchical_overhead_le_ring_at_4to1": hier_ok}


def _wan(cells: Sequence[Dict]) -> Check:
    """The lossy-transport claims the wan golden suite gates.

    - a ``link_profile="none"`` cell is *bitwise* identical to a
      ``simulate`` call that never heard of the axis — the null profile
      returns the untouched flow objects and draws no retx events;
    - t_sync is monotone non-decreasing in the loss axis at fixed rtt:
      the deterministic wire inflation 1/(1-loss) grows with loss, and
      the retx thinning gate keeps a loss-*superset* of the same timed
      candidate events (see :func:`repro.core.transport.retx_events`);
    - stalls are monotone in the backoff multiplier at fixed timeout
      (``backoff=1 <= backoff=4``): the event set is identical, only
      ``timeout * backoff**k`` scales;
    - the compression-wins region only *widens* with loss: lost bytes
      are retransmitted bytes, so every wire byte a codec saves is
      saved ``1/(1-loss)`` times — the count of (bandwidth, scheduler)
      points where int8 beats its codec=none twin on t_sync is
      non-decreasing along the loss ladder;
    - the lossiest cell replays bit-exact through a direct ``simulate``
      call with the same ``link_profile``/``fault_seed`` (the
      determinism contract: draws depend only on ``(seed, stream, n)``).
    """
    from repro.experiments.spec import axis_value
    by = {(c["model"], c["bandwidth_gbps"], c["scheduler"],
           axis_value(c, "codec"), axis_value(c, "link_profile")): c
          for c in cells}
    ts = {k: c["t_sync"] for k, c in by.items()}
    # the loss ladder at fixed rtt, clean link first
    ladder = ("none",
              "wan:loss=0.001,rtt=20",
              "wan:loss=0.01,rtt=20",
              "wan:loss=0.05,rtt=20")
    mono_loss = all(
        ts[(m, bw, s, cd, a)] <= ts[(m, bw, s, cd, b)] + 1e-9
        for (m, bw, s, cd, lp) in by if lp == "none"
        for a, b in zip(ladder, ladder[1:]))
    b1 = "wan:loss=0.01,rtt=20:timeout=100,backoff=1"
    b4 = "wan:loss=0.01,rtt=20:timeout=100,backoff=4"
    mono_backoff = all(
        ts[(m, bw, s, cd, b1)] <= ts[(m, bw, s, cd, b4)] + 1e-9
        for (m, bw, s, cd, lp) in by if lp == b1)

    def wins(profile: str) -> int:
        return sum(1 for (m, bw, s, cd, lp) in by
                   if cd == "none" and lp == profile
                   and ts[(m, bw, s, "int8", lp)]
                   < ts[(m, bw, s, "none", lp)] - 1e-12)

    w = [wins(p) for p in ladder]
    wins_widen = all(a <= b for a, b in zip(w, w[1:]))

    from repro.core.simulator import simulate
    from repro.core.timeline import from_cnn
    from repro.core.transport import GBPS
    clean = [c for c in cells if axis_value(c, "link_profile") == "none"
             and axis_value(c, "codec") == "none"]
    exact = all(simulate(from_cnn(c["model"]), n_workers=c["n_workers"],
                         bandwidth=c["bandwidth_gbps"] * GBPS,
                         transport=c["transport"], scheduler=c["scheduler"],
                         n_chunks=8).t_sync == c["t_sync"]
                for c in clean)
    # fault_seed=2029 is the registered wan grid's seed (grids.py), same
    # convention as _churn hardcoding its grid's seed and n_chunks
    hot = by[("resnet50", 10.0, "priority", "int8",
              "wan:loss=0.05,rtt=20")]
    replay = simulate(from_cnn("resnet50"), n_workers=hot["n_workers"],
                      bandwidth=hot["bandwidth_gbps"] * GBPS,
                      transport=hot["transport"], scheduler="priority",
                      n_chunks=8, codec="int8", fault_seed=2029,
                      link_profile="wan:loss=0.05,rtt=20"
                      ).t_sync == hot["t_sync"]
    return {"zero_loss_matches_simulate_bitwise": exact,
            "t_sync_monotone_in_loss": mono_loss,
            "stalls_monotone_in_backoff": mono_backoff,
            "compression_wins_region_widens_with_loss": wins_widen,
            "lossiest_cell_replays_bitwise": replay}


VALIDATORS: Dict[str, Callable[[Sequence[Dict]], Check]] = {
    "paper-fig1": _fig1,
    "paper-fig3": _fig3,
    "paper-fig4": _fig4,
    "paper-fig6": _fig6,
    "paper-fig7": _fig7,
    "paper-fig8": _fig8,
    "paper-fig9": _fig9,
    "scheduler-suite": _scheduler_suite,
    "xl-bandwidth": _xl_bandwidth,
    "xl-sched": _xl_sched,
    "xl-contention": _xl_contention,
    "xxl-contention": _xxl_contention,
    "multirail": _multirail,
    "straggler": _straggler,
    "compression": _compression,
    "churn": _churn,
    "fabric": _fabric,
    "wan": _wan,
}


def validate(grid_name: str, cells: Sequence[Dict]) -> Check:
    # a hardened sweep records retry-exhausted cells as {"failed": true,
    # ...} instead of aborting; validators see only the completed cells,
    # and the degradation itself lands as an always-False check so compare
    # flags the artifact rather than trusting partial claims.  Complete
    # sweeps (every golden) never hit this branch, so their validation
    # dicts — and hashes — are untouched.
    ok = [c for c in cells if not c.get("failed")]
    fn = VALIDATORS.get(grid_name)
    degraded = len(ok) != len(cells)
    try:
        # bool() strips numpy bool scalars, which are not JSON serializable
        out = {k: bool(v) for k, v in fn(ok).items()} if fn else {}
    except Exception:
        # a validator indexing a twin cell that failed: only tolerable on
        # a degraded sweep — on a complete one it is a real bug
        if not degraded:
            raise
        out = {"validator_completed": False}
    if degraded:
        out["no_failed_cells"] = False
    return out
