"""Artifact diffing — the regression gate.

``compare(old, new, tolerances)`` walks two artifacts experiment by
experiment and cell by cell: spec hashes must match, every numeric result
field must agree within its tolerance (|a-b| <= atol + rtol*max(|a|,|b|)),
and no paper-claim validation may flip from passing to failing.  CI runs
this between the committed golden artifact and a fresh sweep; any violation
exits non-zero.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.experiments.runner import index_cells

# default: essentially bit-exactness modulo float formatting (1e-9 abs+rel).
# n_buckets is structural and must match exactly.
NUMERIC_FIELDS = ("scaling_factor", "t_sync", "t_overhead", "t_batch",
                  "t_back", "effective_bw", "effective_gbps",
                  "network_utilization", "wire_bytes_per_worker",
                  "codec_compute_s")
DEFAULT_ATOL = 1e-9
DEFAULT_RTOL = 1e-9


@dataclass(frozen=True)
class Violation:
    experiment: str
    kind: str          # spec | cells | field | validation
    where: str
    detail: str

    def __str__(self) -> str:
        return f"[{self.experiment}] {self.kind} {self.where}: {self.detail}"


@dataclass
class CompareReport:
    n_experiments: int = 0
    n_cells: int = 0
    violations: List[Violation] = field(default_factory=list)
    notes: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> str:
        head = (f"compared {self.n_experiments} experiment(s), "
                f"{self.n_cells} cell(s): "
                f"{'OK' if self.ok else f'{len(self.violations)} violation(s)'}")
        lines = [head] + [f"  {v}" for v in self.violations]
        lines += [f"  note: {n}" for n in self.notes]
        return "\n".join(lines)


def _tol(tolerances: Optional[Dict[str, float]], fieldname: str) -> float:
    if tolerances and fieldname in tolerances:
        return tolerances[fieldname]
    return DEFAULT_ATOL


def _compare_cells(name: str, old: Sequence[Dict], new: Sequence[Dict],
                   tolerances: Optional[Dict[str, float]],
                   report: CompareReport) -> None:
    old_ix, new_ix = index_cells(old), index_cells(new)
    for key in old_ix.keys() - new_ix.keys():
        report.violations.append(Violation(name, "cells", str(key),
                                           "missing from new artifact"))
    for key in new_ix.keys() - old_ix.keys():
        report.violations.append(Violation(name, "cells", str(key),
                                           "absent from old artifact"))
    for key in sorted(old_ix.keys() & new_ix.keys(), key=str):
        a, b = old_ix[key], new_ix[key]
        report.n_cells += 1
        # hardened sweeps record retry-exhausted cells with failure
        # metadata instead of numerics: a new-side failure where the old
        # artifact has real numbers is a regression; an old-side failure
        # has nothing to diff against, so skip-and-report
        if b.get("failed") and not a.get("failed"):
            report.violations.append(Violation(
                name, "cells", str(key),
                f"cell failed in new artifact: {b.get('error', '?')}"))
            continue
        if a.get("failed"):
            report.notes.append(
                f"[{name}] {key}: old-side cell failed "
                f"({a.get('error', '?')}); numerics skipped")
            continue
        if a.get("n_buckets") != b.get("n_buckets"):
            report.violations.append(Violation(
                name, "field", f"{key}.n_buckets",
                f"{a.get('n_buckets')} != {b.get('n_buckets')}"))
        for f in NUMERIC_FIELDS:
            if f not in a and f not in b:
                continue
            if f not in a or f not in b:
                # a field present on one side only is a schema regression,
                # not a silent skip — drift checking for it would vanish
                report.violations.append(Violation(
                    name, "field", f"{key}.{f}",
                    f"present only in {'old' if f in a else 'new'} artifact"))
                continue
            va, vb = float(a[f]), float(b[f])
            atol = _tol(tolerances, f)
            bound = atol + DEFAULT_RTOL * max(abs(va), abs(vb))
            if abs(va - vb) > bound:
                report.violations.append(Violation(
                    name, "field", f"{key}.{f}",
                    f"old={va!r} new={vb!r} |diff|={abs(va - vb):.3e} "
                    f"> tol={bound:.3e}"))


def compare(old_art: Dict, new_art: Dict,
            tolerances: Optional[Dict[str, float]] = None) -> CompareReport:
    """Diff two artifact dicts (as returned by ``artifacts.read``)."""
    report = CompareReport()
    old_ex = {e["name"]: e for e in old_art.get("experiments", [])}
    new_ex = {e["name"]: e for e in new_art.get("experiments", [])}

    for name in sorted(old_ex.keys() - new_ex.keys()):
        report.violations.append(Violation(name, "cells", "-",
                                           "experiment missing from new"))
    for name in sorted(new_ex.keys() - old_ex.keys()):
        report.notes.append(f"experiment {name!r} only in new artifact")

    for name in sorted(old_ex.keys() & new_ex.keys()):
        a, b = old_ex[name], new_ex[name]
        report.n_experiments += 1
        if a.get("spec_hash") != b.get("spec_hash"):
            report.violations.append(Violation(
                name, "spec", "spec_hash",
                f"{a.get('spec_hash')} != {b.get('spec_hash')} "
                f"(grids differ; refresh the golden artifact deliberately)"))
            continue
        _compare_cells(name, a.get("cells", []), b.get("cells", []),
                       tolerances, report)
        old_val = a.get("validations", {})
        new_val = b.get("validations", {})
        for check, passed in sorted(old_val.items()):
            if passed and not new_val.get(check, False):
                report.violations.append(Violation(
                    name, "validation", check,
                    "paper claim passed in old artifact, fails in new"))
    return report
