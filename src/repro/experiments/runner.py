"""Grid execution: fan an expanded spec out over the what-if simulator.

``run_spec`` maps every :class:`~repro.experiments.spec.Cell` through
``repro.core.simulator.simulate`` (or ``simulate_contention`` when the
cell's ``n_jobs`` axis is > 1) via ``concurrent.futures`` and returns one
*experiment record*: spec + spec hash + per-cell ``SimResult`` fields +
paper-claim validations.  Records are plain dicts so ``artifacts.write``
can dump them untouched.

Executor selection (``executor="auto"``, the CLI default): grids below
:data:`PROCESS_THRESHOLD` cells run on threads — each cell is a few ms of
pure Python, so thread fan-out only hides the artifact I/O — while larger
grids use a process pool, since the GIL serializes pure-Python cells and
threads cannot scale them.  ``serial`` stays available for debugging (and
is what tiny grids degenerate to).

Process pools have two per-worker costs this module amortizes:

- the ``_timeline`` LRU cache is cold in every worker, so each pool worker
  runs :func:`_warm_timelines` as an initializer, building the timelines
  the spec names exactly once per process instead of once per cell;
- cells are submitted in :data:`CELLS_PER_TASK`-sized batches so argument
  pickling and future bookkeeping are paid per batch, not per cell.

Hardened mode (any of ``journal`` / ``resume`` / ``cell_timeout`` /
``retries`` set) trades the batched fast path for crash-safety: every
completed cell is flushed to a JSONL journal as it lands, a wedged cell is
killed at its wall-clock budget and retried with exponential backoff, a
cell that exhausts its retries is *recorded* with failure metadata instead
of aborting the sweep, and ``resume=True`` replays the journal — re-running
only missing/failed cells — to an artifact byte-identical to a single-shot
run (cells are assembled in ``spec.expand()`` index order either way).
With none of those knobs set, the historical code path runs untouched.
"""
from __future__ import annotations

import json
import multiprocessing
import os
import time
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from functools import lru_cache
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Union

from repro.core.addest import AddEst
from repro.core.simulator import simulate, simulate_contention
from repro.core.transport import GBPS
from repro.configs.base import CommConfig
from repro.experiments.spec import Cell, ExperimentSpec

ENGINE_VERSION = 1

# auto executor: processes once the grid is big enough that the GIL (not
# I/O) is the bottleneck; below it, threads keep the artifact write warm
# without fork/spawn overhead
PROCESS_THRESHOLD = 64
# cells per process-pool task: amortizes pickling without starving workers
CELLS_PER_TASK = 8

# crash-safe journal identity (validated on --resume so a journal written
# by a different grid can never silently seed another sweep's artifact)
JOURNAL_KIND = "repro-journal"
JOURNAL_SCHEMA_VERSION = 1
# base of the round-level exponential retry backoff (seconds); bounded so
# a sweep with many flaky cells degrades in minutes, not hours
_RETRY_BACKOFF_S = 0.05
_RETRY_BACKOFF_MAX_S = 2.0

_ADDEST = {"v100": AddEst.v100, "tpu_v5e": AddEst.tpu_v5e}


@lru_cache(maxsize=32)
def _timeline(model: str):
    from repro.core.timeline import from_cnn
    return from_cnn(model)


def _warm_timelines(models: Sequence[str]) -> None:
    """Process-pool initializer: pre-build the timelines a spec sweeps.

    ``_timeline``'s ``lru_cache`` lives per process; without this, every
    worker would rebuild each model's timeline on its first cell."""
    for m in models:
        _timeline(m)


def run_cell(spec: ExperimentSpec, cell: Cell) -> Dict:
    """Simulate one grid cell.  Must match ``whatif.sim_scaling`` exactly:
    same timeline, worker count, AddEst, and CommConfig as the historical
    per-figure loops, so golden artifacts are comparable at 1e-9.

    A cell with ``n_jobs > 1`` runs :func:`simulate_contention` with
    ``n_jobs`` copies of the same training job sharing one fair-share link;
    the jobs are symmetric, so the first job's result is the cell's record.

    The scenario axes ride along as plain keyword arguments: ``n_rails``
    splits the cell's (aggregate) bandwidth into rails under
    ``spec.rail_policy``, ``jitter_ms`` perturbs flush times under
    ``spec.jitter_seed``, and ``codec`` prices gradient compression as
    encode -> wire -> decode stages (``spec.error_feedback`` adds the
    EF-SGD residual cost to lossy-codec cells; ``codec="none"`` cells
    ignore it, so a grid can sweep codecs with EF on without its baseline
    cells rejecting the knob) — all default-off, leaving the historical
    cells' code path (and bits) untouched.  ``fabric``/``oversubscription``
    lower the cell onto NIC -> ToR-uplink paths (:mod:`repro.core.fabric`)
    priced at the engine's max-min fair share; ``fabric="none"`` (and the
    elided 1:1 case) is bitwise the flat link.  ``link_profile`` prices a
    lossy WAN link (:mod:`repro.core.transport`): retransmission wire
    inflation + RTT deterministically, seeded RTO stalls stochastically
    (drawn from ``spec.fault_seed``); ``"none"`` is bitwise the clean link.
    """
    kwargs = dict(
        n_workers=cell.n_servers * spec.gpus_per_server,
        bandwidth=cell.bandwidth_gbps * GBPS,
        transport=cell.transport,
        compression_ratio=cell.compression_ratio,
        scheduler=cell.scheduler,
        n_chunks=spec.sched_chunks,
        n_rails=cell.n_rails,
        rail_policy=spec.rail_policy,
        jitter=cell.jitter_ms / 1e3,
        jitter_seed=spec.jitter_seed,
        codec=cell.codec,
        error_feedback=spec.error_feedback and cell.codec != "none",
        fault_model=cell.fault_model,
        churn_rate=cell.churn_rate,
        worker_bw_skew=cell.worker_bw_skew,
        fault_seed=spec.fault_seed,
        fabric=cell.fabric,
        oversubscription=cell.oversubscription,
        link_profile=cell.link_profile,
        comm=CommConfig(fusion_buffer_mb=spec.fusion_buffer_mb,
                        timeout_ms=spec.timeout_ms),
        addest=_ADDEST[spec.addest]())
    tl = _timeline(cell.model)
    if cell.n_jobs > 1:
        if cell.topology != "ring":
            raise ValueError(
                f"contention cells model the flat ring only, got topology "
                f"{cell.topology!r} with n_jobs={cell.n_jobs}")
        r = simulate_contention([tl] * cell.n_jobs, **kwargs)[0]
    else:
        r = simulate(tl, topology=cell.topology, **kwargs)
    out = cell.to_dict()
    out.update(r.to_dict())
    # effective bandwidth in the sweep's own unit, for readable artifacts
    out["effective_gbps"] = r.effective_bw / GBPS
    # numpy scalars (np.float64 creeps in via the timeline arrays) become
    # plain Python types so artifacts are pure JSON
    return {k: float(v) if isinstance(v, float) else v
            for k, v in out.items()}


def _run_cell_from_dicts(spec_d: Dict, cell_d: Dict) -> Dict:
    # module-level picklable entry point for ProcessPoolExecutor
    return run_cell(ExperimentSpec.from_dict(spec_d), Cell.from_dict(cell_d))


def _run_cell_batch(spec_d: Dict, cell_ds: Sequence[Dict]) -> List[Dict]:
    """Picklable batch entry point: one submission, many cells."""
    spec = ExperimentSpec.from_dict(spec_d)
    return [run_cell(spec, Cell.from_dict(d)) for d in cell_ds]


def resolve_executor(executor: str, n_cells: int,
                     workload: Optional[int] = None) -> str:
    """``auto`` -> threads for small grids, processes for big ones.

    ``workload`` (default: the plain cell count) is the grid's
    :attr:`~repro.experiments.spec.ExperimentSpec.workload_units` — a
    contention cell weighs ``n_jobs``-fold, since one n_jobs=16 cell runs
    sixteen jobs' worth of flows through the engine.  Without the
    weighting, a 48-cell grid of 10k-flow contention cells would be
    GIL-serialized on threads purely because its *count* is small."""
    if executor != "auto":
        return executor
    load = n_cells if workload is None else workload
    return "process" if load >= PROCESS_THRESHOLD else "thread"


def _batches(items: Sequence, size: int) -> List[Sequence]:
    return [items[i:i + size] for i in range(0, len(items), size)]


# -- hardened path: journal / resume / timeout / retry -----------------------

def _failure_record(cell: Cell, error: str) -> Dict:
    """Graceful degradation: the cell's identity plus failure metadata,
    shaped so ``index_cells`` still indexes it and ``validate``/``compare``
    can skip-and-report instead of crashing on missing numerics."""
    d = cell.to_dict()
    d["failed"] = True
    d["error"] = error
    return d


def _journal_append(fh, index: int, record: Dict) -> None:
    fh.write(json.dumps({"index": index, "cell": record},
                        sort_keys=True) + "\n")
    fh.flush()  # past the user-space buffer: SIGKILL loses at most one line


def _load_journal(path: Union[str, Path],
                  spec: ExperimentSpec) -> Dict[int, Dict]:
    """Replay a journal -> {expand() index: completed cell record}.

    Tolerates a truncated final line (the crash boundary); refuses a
    journal whose header names a different grid.  Failed cells are
    *dropped* so ``--resume`` re-runs them."""
    done: Dict[int, Dict] = {}
    p = Path(path)
    if not p.exists():
        return done
    with p.open() as fh:
        header = None
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError:
                break  # torn tail from a mid-write kill: keep what precedes
            if header is None:
                header = d
                if (d.get("kind") != JOURNAL_KIND
                        or d.get("schema_version") != JOURNAL_SCHEMA_VERSION):
                    raise ValueError(f"{p} is not a sweep journal")
                if d.get("spec_hash") != spec.spec_hash():
                    raise ValueError(
                        f"journal {p} was written by spec "
                        f"{d.get('spec_hash')!r}, not {spec.spec_hash()!r} "
                        f"({spec.name}) — refusing to resume across grids")
                continue
            rec = d.get("cell", {})
            if rec.get("failed"):
                continue
            done[int(d["index"])] = rec
    return done


def _run_hardened_serial(spec: ExperimentSpec, pending: Dict[int, Cell], *,
                         retries: int, jfh) -> Dict[int, Dict]:
    out: Dict[int, Dict] = {}
    for i in sorted(pending):
        cell, rec, err = pending[i], None, ""
        for attempt in range(retries + 1):
            try:
                rec = run_cell(spec, cell)
                break
            except Exception as e:  # noqa: BLE001 — degrade, don't abort
                err = f"{type(e).__name__}: {e}"
                if attempt < retries:
                    time.sleep(min(_RETRY_BACKOFF_S * 2.0 ** attempt,
                                   _RETRY_BACKOFF_MAX_S))
        if rec is None:
            rec = _failure_record(cell, err)
        out[i] = rec
        if jfh is not None:
            _journal_append(jfh, i, rec)
    return out


def _run_hardened_process(spec: ExperimentSpec, pending: Dict[int, Cell], *,
                          max_workers: Optional[int],
                          cell_timeout: Optional[float],
                          retries: int, jfh) -> Dict[int, Dict]:
    """Round-based pool execution with per-cell wall-clock budgets.

    Each round submits every still-pending cell via ``apply_async`` and
    collects in index order.  A cell that blows ``cell_timeout`` cannot be
    recalled from its worker, so the round charges it one attempt,
    harvests whatever later cells already finished, terminates the pool,
    and loops; a worker exception likewise burns an attempt.  Every round
    either drains cells or charges attempts (which are capped), so the
    sweep always terminates — exhausted cells land as failure records."""
    spec_d = spec.to_dict()
    out: Dict[int, Dict] = {}
    attempts = dict.fromkeys(pending, 0)
    left = dict(pending)
    rnd = 0
    while left:
        order = sorted(left)
        workers = max_workers or min(len(order), os.cpu_count() or 1)
        pool = multiprocessing.Pool(processes=workers,
                                    initializer=_warm_timelines,
                                    initargs=(tuple(spec.models),))
        harvested: Dict[int, tuple] = {}
        timed_out = None
        try:
            asyncs = {i: pool.apply_async(_run_cell_from_dicts,
                                          (spec_d, left[i].to_dict()))
                      for i in order}
            for pos, i in enumerate(order):
                try:
                    harvested[i] = ("ok", asyncs[i].get(cell_timeout))
                except multiprocessing.TimeoutError:
                    timed_out = i
                    for j in order[pos + 1:]:
                        if asyncs[j].ready():
                            try:
                                harvested[j] = ("ok", asyncs[j].get(0))
                            except Exception as e:  # noqa: BLE001
                                harvested[j] = (
                                    "err", f"{type(e).__name__}: {e}")
                    break
                except Exception as e:  # noqa: BLE001
                    harvested[i] = ("err", f"{type(e).__name__}: {e}")
        finally:
            pool.terminate()  # also the close() path: nothing left queued
            pool.join()

        charged = False
        if timed_out is not None:
            attempts[timed_out] += 1
            charged = True
            if attempts[timed_out] > retries:
                rec = _failure_record(
                    left[timed_out],
                    f"TimeoutError: cell exceeded {cell_timeout}s wall "
                    f"clock ({attempts[timed_out]} attempts)")
                out[timed_out] = rec
                del left[timed_out]
                if jfh is not None:
                    _journal_append(jfh, timed_out, rec)
        for i, (kind, val) in sorted(harvested.items()):
            if kind == "ok":
                out[i] = val
                del left[i]
                if jfh is not None:
                    _journal_append(jfh, i, val)
            else:
                attempts[i] += 1
                charged = True
                if attempts[i] > retries:
                    rec = _failure_record(left[i], val)
                    out[i] = rec
                    del left[i]
                    if jfh is not None:
                        _journal_append(jfh, i, rec)
        if charged and left:
            time.sleep(min(_RETRY_BACKOFF_S * 2.0 ** rnd,
                           _RETRY_BACKOFF_MAX_S))
        rnd += 1
    return out


def _run_hardened(spec: ExperimentSpec, cells: Sequence[Cell], *, mode: str,
                  max_workers: Optional[int],
                  journal: Optional[Union[str, Path]], resume: bool,
                  cell_timeout: Optional[float],
                  retries: int) -> List[Dict]:
    done: Dict[int, Dict] = {}
    jpath = Path(journal) if journal is not None else None
    if resume:
        if jpath is None:
            raise ValueError("resume=True needs a journal path")
        done = _load_journal(jpath, spec)
    jfh = None
    if jpath is not None:
        jpath.parent.mkdir(parents=True, exist_ok=True)
        # rewrite-from-scratch on every run: drops any torn tail line and
        # the failed entries being re-run, so the journal is always a clean
        # prefix of the final artifact
        jfh = jpath.open("w")
        jfh.write(json.dumps(
            {"kind": JOURNAL_KIND,
             "schema_version": JOURNAL_SCHEMA_VERSION,
             "name": spec.name, "spec_hash": spec.spec_hash()},
            sort_keys=True) + "\n")
        jfh.flush()
        for i in sorted(done):
            _journal_append(jfh, i, done[i])
    pending = {i: c for i, c in enumerate(cells) if i not in done}
    try:
        if not pending:
            fresh: Dict[int, Dict] = {}
        elif mode == "process":
            fresh = _run_hardened_process(
                spec, pending, max_workers=max_workers,
                cell_timeout=cell_timeout, retries=retries, jfh=jfh)
        else:
            # thread mode degenerates to serial here: a wedged thread
            # cannot be recalled, and retry bookkeeping wants one owner
            fresh = _run_hardened_serial(spec, pending, retries=retries,
                                         jfh=jfh)
    finally:
        if jfh is not None:
            jfh.close()
    done.update(fresh)
    return [done[i] for i in range(len(cells))]


def run_spec(spec: ExperimentSpec, *, executor: str = "auto",
             max_workers: Optional[int] = None,
             journal: Optional[Union[str, Path]] = None,
             resume: bool = False,
             cell_timeout: Optional[float] = None,
             retries: int = 0) -> Dict:
    """Expand and run one grid; returns the experiment record.

    ``journal`` (a JSONL path) flushes every completed cell as it lands;
    ``resume=True`` replays that journal and re-runs only missing/failed
    cells — the assembled record is byte-identical to a single-shot run.
    ``cell_timeout`` (seconds, process pool only) bounds each cell's wall
    clock; ``retries`` bounds re-attempts per cell, with exponential
    backoff between rounds.  A cell that exhausts its retries is recorded
    with ``{"failed": true, "error": ...}`` instead of aborting the sweep.
    All four default off, leaving the historical path byte-untouched."""
    cells = spec.expand()
    mode = resolve_executor(executor, len(cells), spec.workload_units)
    hardened = (journal is not None or resume
                or cell_timeout is not None or retries > 0)
    if hardened:
        results = _run_hardened(
            spec, cells, mode=mode, max_workers=max_workers,
            journal=journal, resume=resume, cell_timeout=cell_timeout,
            retries=retries)
        from repro.experiments.validations import validate
        return {
            "name": spec.name,
            "engine_version": ENGINE_VERSION,
            "spec": spec.to_dict(),
            "spec_hash": spec.spec_hash(),
            "cells": results,
            "validations": validate(spec.name, results),
        }
    if mode == "serial" or len(cells) <= 1:
        results = [run_cell(spec, c) for c in cells]
    elif mode == "process":
        spec_d = spec.to_dict()
        workers = max_workers or min(len(cells), os.cpu_count() or 1)
        batches = _batches([c.to_dict() for c in cells], CELLS_PER_TASK)
        with ProcessPoolExecutor(
                max_workers=workers,
                initializer=_warm_timelines,
                initargs=(tuple(spec.models),)) as pool:
            results = [r for batch in pool.map(_run_cell_batch,
                                               [spec_d] * len(batches),
                                               batches)
                       for r in batch]
    elif mode == "thread":
        with ThreadPoolExecutor(max_workers=max_workers) as pool:
            results = list(pool.map(lambda c: run_cell(spec, c), cells))
    else:
        raise ValueError(f"unknown executor {executor!r}")

    from repro.experiments.validations import validate
    return {
        "name": spec.name,
        "engine_version": ENGINE_VERSION,
        "spec": spec.to_dict(),
        "spec_hash": spec.spec_hash(),
        "cells": results,
        "validations": validate(spec.name, results),
    }


def run_suite(specs: Sequence[ExperimentSpec], *, executor: str = "auto",
              max_workers: Optional[int] = None,
              journal_dir: Optional[Union[str, Path]] = None,
              resume: bool = False,
              cell_timeout: Optional[float] = None,
              retries: int = 0) -> List[Dict]:
    """Run several grids; ``journal_dir`` keeps one journal per spec
    (``<dir>/<name>.jsonl``), which is what ``--resume`` replays."""
    out = []
    for s in specs:
        journal = (Path(journal_dir) / f"{s.name}.jsonl"
                   if journal_dir is not None else None)
        out.append(run_spec(s, executor=executor, max_workers=max_workers,
                            journal=journal, resume=resume,
                            cell_timeout=cell_timeout, retries=retries))
    return out


def index_cells(cells: Sequence[Dict]) -> Dict[tuple, Dict]:
    """Cell list -> {(model, n_servers, bw, transport, ratio, topo,
    scheduler, n_jobs): cell}.  Axes added after an artifact was written
    fall back to their recorded defaults, so old artifacts index
    consistently."""
    from repro.experiments.spec import CELL_AXES, axis_value
    return {tuple(axis_value(c, a) for a in CELL_AXES): c for c in cells}
