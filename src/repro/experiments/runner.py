"""Grid execution: fan an expanded spec out over the what-if simulator.

``run_spec`` maps every :class:`~repro.experiments.spec.Cell` through
``repro.core.simulator.simulate`` via ``concurrent.futures`` (threads by
default — each cell is a few ms of pure Python — or processes for large
grids) and returns one *experiment record*: spec + spec hash + per-cell
``SimResult`` fields + paper-claim validations.  Records are plain dicts so
``artifacts.write`` can dump them untouched.
"""
from __future__ import annotations

from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from functools import lru_cache
from typing import Dict, List, Optional, Sequence

from repro.core.addest import AddEst
from repro.core.simulator import simulate
from repro.core.transport import GBPS
from repro.configs.base import CommConfig
from repro.experiments.spec import Cell, ExperimentSpec

ENGINE_VERSION = 1

_ADDEST = {"v100": AddEst.v100, "tpu_v5e": AddEst.tpu_v5e}


@lru_cache(maxsize=32)
def _timeline(model: str):
    from repro.core.timeline import from_cnn
    return from_cnn(model)


def run_cell(spec: ExperimentSpec, cell: Cell) -> Dict:
    """Simulate one grid cell.  Must match ``whatif.sim_scaling`` exactly:
    same timeline, worker count, AddEst, and CommConfig as the historical
    per-figure loops, so golden artifacts are comparable at 1e-9."""
    r = simulate(
        _timeline(cell.model),
        n_workers=cell.n_servers * spec.gpus_per_server,
        bandwidth=cell.bandwidth_gbps * GBPS,
        transport=cell.transport,
        compression_ratio=cell.compression_ratio,
        topology=cell.topology,
        scheduler=cell.scheduler,
        n_chunks=spec.sched_chunks,
        comm=CommConfig(fusion_buffer_mb=spec.fusion_buffer_mb,
                        timeout_ms=spec.timeout_ms),
        addest=_ADDEST[spec.addest]())
    out = cell.to_dict()
    out.update(r.to_dict())
    # effective bandwidth in the sweep's own unit, for readable artifacts
    out["effective_gbps"] = r.effective_bw / GBPS
    # numpy scalars (np.float64 creeps in via the timeline arrays) become
    # plain Python types so artifacts are pure JSON
    return {k: float(v) if isinstance(v, float) else v
            for k, v in out.items()}


def _run_cell_from_dicts(spec_d: Dict, cell_d: Dict) -> Dict:
    # module-level picklable entry point for ProcessPoolExecutor
    return run_cell(ExperimentSpec.from_dict(spec_d), Cell.from_dict(cell_d))


def run_spec(spec: ExperimentSpec, *, executor: str = "thread",
             max_workers: Optional[int] = None) -> Dict:
    """Expand and run one grid; returns the experiment record."""
    cells = spec.expand()
    if executor == "serial" or len(cells) <= 1:
        results = [run_cell(spec, c) for c in cells]
    elif executor == "process":
        spec_d = spec.to_dict()
        with ProcessPoolExecutor(max_workers=max_workers) as pool:
            results = list(pool.map(_run_cell_from_dicts,
                                    [spec_d] * len(cells),
                                    [c.to_dict() for c in cells]))
    elif executor == "thread":
        with ThreadPoolExecutor(max_workers=max_workers) as pool:
            results = list(pool.map(lambda c: run_cell(spec, c), cells))
    else:
        raise ValueError(f"unknown executor {executor!r}")

    from repro.experiments.validations import validate
    return {
        "name": spec.name,
        "engine_version": ENGINE_VERSION,
        "spec": spec.to_dict(),
        "spec_hash": spec.spec_hash(),
        "cells": results,
        "validations": validate(spec.name, results),
    }


def run_suite(specs: Sequence[ExperimentSpec], *, executor: str = "thread",
              max_workers: Optional[int] = None) -> List[Dict]:
    return [run_spec(s, executor=executor, max_workers=max_workers)
            for s in specs]


def index_cells(cells: Sequence[Dict]) -> Dict[tuple, Dict]:
    """Cell list -> {(model, n_servers, bw, transport, ratio, topo,
    scheduler): cell}.  Axes added after an artifact was written fall back
    to their recorded defaults, so old artifacts index consistently."""
    from repro.experiments.spec import AXIS_DEFAULTS, CELL_AXES
    return {tuple(c.get(a, AXIS_DEFAULTS[a]) if a in AXIS_DEFAULTS else c[a]
                  for a in CELL_AXES): c
            for c in cells}
