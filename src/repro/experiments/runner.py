"""Grid execution: fan an expanded spec out over the what-if simulator.

``run_spec`` maps every :class:`~repro.experiments.spec.Cell` through
``repro.core.simulator.simulate`` (or ``simulate_contention`` when the
cell's ``n_jobs`` axis is > 1) via ``concurrent.futures`` and returns one
*experiment record*: spec + spec hash + per-cell ``SimResult`` fields +
paper-claim validations.  Records are plain dicts so ``artifacts.write``
can dump them untouched.

Executor selection (``executor="auto"``, the CLI default): grids below
:data:`PROCESS_THRESHOLD` cells run on threads — each cell is a few ms of
pure Python, so thread fan-out only hides the artifact I/O — while larger
grids use a process pool, since the GIL serializes pure-Python cells and
threads cannot scale them.  ``serial`` stays available for debugging (and
is what tiny grids degenerate to).

Process pools have two per-worker costs this module amortizes:

- the ``_timeline`` LRU cache is cold in every worker, so each pool worker
  runs :func:`_warm_timelines` as an initializer, building the timelines
  the spec names exactly once per process instead of once per cell;
- cells are submitted in :data:`CELLS_PER_TASK`-sized batches so argument
  pickling and future bookkeeping are paid per batch, not per cell.
"""
from __future__ import annotations

import os
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from functools import lru_cache
from typing import Dict, List, Optional, Sequence

from repro.core.addest import AddEst
from repro.core.simulator import simulate, simulate_contention
from repro.core.transport import GBPS
from repro.configs.base import CommConfig
from repro.experiments.spec import Cell, ExperimentSpec

ENGINE_VERSION = 1

# auto executor: processes once the grid is big enough that the GIL (not
# I/O) is the bottleneck; below it, threads keep the artifact write warm
# without fork/spawn overhead
PROCESS_THRESHOLD = 64
# cells per process-pool task: amortizes pickling without starving workers
CELLS_PER_TASK = 8

_ADDEST = {"v100": AddEst.v100, "tpu_v5e": AddEst.tpu_v5e}


@lru_cache(maxsize=32)
def _timeline(model: str):
    from repro.core.timeline import from_cnn
    return from_cnn(model)


def _warm_timelines(models: Sequence[str]) -> None:
    """Process-pool initializer: pre-build the timelines a spec sweeps.

    ``_timeline``'s ``lru_cache`` lives per process; without this, every
    worker would rebuild each model's timeline on its first cell."""
    for m in models:
        _timeline(m)


def run_cell(spec: ExperimentSpec, cell: Cell) -> Dict:
    """Simulate one grid cell.  Must match ``whatif.sim_scaling`` exactly:
    same timeline, worker count, AddEst, and CommConfig as the historical
    per-figure loops, so golden artifacts are comparable at 1e-9.

    A cell with ``n_jobs > 1`` runs :func:`simulate_contention` with
    ``n_jobs`` copies of the same training job sharing one fair-share link;
    the jobs are symmetric, so the first job's result is the cell's record.

    The scenario axes ride along as plain keyword arguments: ``n_rails``
    splits the cell's (aggregate) bandwidth into rails under
    ``spec.rail_policy``, ``jitter_ms`` perturbs flush times under
    ``spec.jitter_seed``, and ``codec`` prices gradient compression as
    encode -> wire -> decode stages (``spec.error_feedback`` adds the
    EF-SGD residual cost to lossy-codec cells; ``codec="none"`` cells
    ignore it, so a grid can sweep codecs with EF on without its baseline
    cells rejecting the knob) — all default-off, leaving the historical
    cells' code path (and bits) untouched.  ``fabric``/``oversubscription``
    lower the cell onto NIC -> ToR-uplink paths (:mod:`repro.core.fabric`)
    priced at the engine's max-min fair share; ``fabric="none"`` (and the
    elided 1:1 case) is bitwise the flat link.
    """
    kwargs = dict(
        n_workers=cell.n_servers * spec.gpus_per_server,
        bandwidth=cell.bandwidth_gbps * GBPS,
        transport=cell.transport,
        compression_ratio=cell.compression_ratio,
        scheduler=cell.scheduler,
        n_chunks=spec.sched_chunks,
        n_rails=cell.n_rails,
        rail_policy=spec.rail_policy,
        jitter=cell.jitter_ms / 1e3,
        jitter_seed=spec.jitter_seed,
        codec=cell.codec,
        error_feedback=spec.error_feedback and cell.codec != "none",
        fault_model=cell.fault_model,
        churn_rate=cell.churn_rate,
        worker_bw_skew=cell.worker_bw_skew,
        fault_seed=spec.fault_seed,
        fabric=cell.fabric,
        oversubscription=cell.oversubscription,
        comm=CommConfig(fusion_buffer_mb=spec.fusion_buffer_mb,
                        timeout_ms=spec.timeout_ms),
        addest=_ADDEST[spec.addest]())
    tl = _timeline(cell.model)
    if cell.n_jobs > 1:
        if cell.topology != "ring":
            raise ValueError(
                f"contention cells model the flat ring only, got topology "
                f"{cell.topology!r} with n_jobs={cell.n_jobs}")
        r = simulate_contention([tl] * cell.n_jobs, **kwargs)[0]
    else:
        r = simulate(tl, topology=cell.topology, **kwargs)
    out = cell.to_dict()
    out.update(r.to_dict())
    # effective bandwidth in the sweep's own unit, for readable artifacts
    out["effective_gbps"] = r.effective_bw / GBPS
    # numpy scalars (np.float64 creeps in via the timeline arrays) become
    # plain Python types so artifacts are pure JSON
    return {k: float(v) if isinstance(v, float) else v
            for k, v in out.items()}


def _run_cell_from_dicts(spec_d: Dict, cell_d: Dict) -> Dict:
    # module-level picklable entry point for ProcessPoolExecutor
    return run_cell(ExperimentSpec.from_dict(spec_d), Cell.from_dict(cell_d))


def _run_cell_batch(spec_d: Dict, cell_ds: Sequence[Dict]) -> List[Dict]:
    """Picklable batch entry point: one submission, many cells."""
    spec = ExperimentSpec.from_dict(spec_d)
    return [run_cell(spec, Cell.from_dict(d)) for d in cell_ds]


def resolve_executor(executor: str, n_cells: int,
                     workload: Optional[int] = None) -> str:
    """``auto`` -> threads for small grids, processes for big ones.

    ``workload`` (default: the plain cell count) is the grid's
    :attr:`~repro.experiments.spec.ExperimentSpec.workload_units` — a
    contention cell weighs ``n_jobs``-fold, since one n_jobs=16 cell runs
    sixteen jobs' worth of flows through the engine.  Without the
    weighting, a 48-cell grid of 10k-flow contention cells would be
    GIL-serialized on threads purely because its *count* is small."""
    if executor != "auto":
        return executor
    load = n_cells if workload is None else workload
    return "process" if load >= PROCESS_THRESHOLD else "thread"


def _batches(items: Sequence, size: int) -> List[Sequence]:
    return [items[i:i + size] for i in range(0, len(items), size)]


def run_spec(spec: ExperimentSpec, *, executor: str = "auto",
             max_workers: Optional[int] = None) -> Dict:
    """Expand and run one grid; returns the experiment record."""
    cells = spec.expand()
    mode = resolve_executor(executor, len(cells), spec.workload_units)
    if mode == "serial" or len(cells) <= 1:
        results = [run_cell(spec, c) for c in cells]
    elif mode == "process":
        spec_d = spec.to_dict()
        workers = max_workers or min(len(cells), os.cpu_count() or 1)
        batches = _batches([c.to_dict() for c in cells], CELLS_PER_TASK)
        with ProcessPoolExecutor(
                max_workers=workers,
                initializer=_warm_timelines,
                initargs=(tuple(spec.models),)) as pool:
            results = [r for batch in pool.map(_run_cell_batch,
                                               [spec_d] * len(batches),
                                               batches)
                       for r in batch]
    elif mode == "thread":
        with ThreadPoolExecutor(max_workers=max_workers) as pool:
            results = list(pool.map(lambda c: run_cell(spec, c), cells))
    else:
        raise ValueError(f"unknown executor {executor!r}")

    from repro.experiments.validations import validate
    return {
        "name": spec.name,
        "engine_version": ENGINE_VERSION,
        "spec": spec.to_dict(),
        "spec_hash": spec.spec_hash(),
        "cells": results,
        "validations": validate(spec.name, results),
    }


def run_suite(specs: Sequence[ExperimentSpec], *, executor: str = "auto",
              max_workers: Optional[int] = None) -> List[Dict]:
    return [run_spec(s, executor=executor, max_workers=max_workers)
            for s in specs]


def index_cells(cells: Sequence[Dict]) -> Dict[tuple, Dict]:
    """Cell list -> {(model, n_servers, bw, transport, ratio, topo,
    scheduler, n_jobs): cell}.  Axes added after an artifact was written
    fall back to their recorded defaults, so old artifacts index
    consistently."""
    from repro.experiments.spec import CELL_AXES, axis_value
    return {tuple(axis_value(c, a) for a in CELL_AXES): c for c in cells}
