"""``python -m repro.experiments`` — run sweeps, gate regressions.

Commands:
  run      expand a named grid/suite, simulate it, write a JSON artifact
  compare  diff two artifacts under tolerances; exit 1 on any violation
  report   pretty-print an artifact (validations + CSV cells)
  list     show the known grids and suites

Examples:
  python -m repro.experiments run --grid paper-fig3
  python -m repro.experiments run --grid paper --out /tmp/new.json
  python -m repro.experiments compare artifacts/golden/paper_suite.json /tmp/new.json
  python -m repro.experiments report artifacts/golden/paper_suite.json
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import Dict, List, Optional

from repro.experiments import artifacts, grids
from repro.experiments.compare import compare
from repro.experiments.runner import ENGINE_VERSION, run_suite
from repro.experiments.spec import CELL_AXES, axis_value


def _cmd_run(args: argparse.Namespace) -> int:
    specs = grids.resolve(args.grid)
    out = Path(args.out or f"artifacts/experiments/{args.grid}.json")
    journal_dir = args.journal
    if args.resume and journal_dir is None:
        raise SystemExit("--resume needs --journal DIR (the journal to "
                         "replay)")
    experiments = run_suite(specs, executor=args.executor,
                            max_workers=args.jobs,
                            journal_dir=journal_dir, resume=args.resume,
                            cell_timeout=args.cell_timeout,
                            retries=args.retries)
    artifacts.write(out, experiments, meta={"grid": args.grid,
                                            "engine_version": ENGINE_VERSION})
    n_cells = sum(len(e["cells"]) for e in experiments)
    n_failed_cells = sum(1 for e in experiments
                         for c in e["cells"] if c.get("failed"))
    failed = [f"{e['name']}:{k}" for e in experiments
              for k, v in e["validations"].items() if not v]
    print(f"wrote {out} ({len(experiments)} experiment(s), {n_cells} cells)")
    if n_failed_cells:
        print(f"WARNING: {n_failed_cells} cell(s) exhausted retries and "
              f"were recorded with failure metadata")
    if failed:
        print("FAILED paper-claim checks: " + ", ".join(failed))
        return 1
    print("all paper-claim checks pass")
    return 0


def _parse_tols(pairs: Optional[List[str]]) -> Dict[str, float]:
    tols: Dict[str, float] = {}
    for p in pairs or []:
        if "=" not in p:
            raise SystemExit(f"--tol expects field=value, got {p!r}")
        k, v = p.split("=", 1)
        tols[k] = float(v)
    return tols


def _cmd_compare(args: argparse.Namespace) -> int:
    old = artifacts.read(args.old)
    new = artifacts.read(args.new)
    report = compare(old, new, _parse_tols(args.tol))
    print(report.summary())
    return 0 if report.ok else 1


def _cmd_report(args: argparse.Namespace) -> int:
    art = artifacts.read(args.artifact)
    for e in art["experiments"]:
        if args.grid and e["name"] != args.grid:
            continue
        vals = e.get("validations", {})
        ok = all(vals.values())
        print(f"\n== {e['name']} ({len(e['cells'])} cells, "
              f"spec {e['spec_hash']}) {'PASS' if ok else 'FAIL'}")
        for k, v in vals.items():
            print(f"  check {k}: {'ok' if v else 'FAIL'}")
        cols = list(CELL_AXES) + ["scaling_factor", "t_overhead",
                                  "network_utilization"]
        print("  " + ",".join(cols))
        rows = e["cells"] if args.all else e["cells"][:8]
        for c in rows:
            # axes added after an artifact was written (or elided at their
            # default) fall back to AXIS_DEFAULTS
            vals = [axis_value(c, k) for k in cols]
            print("  " + ",".join(
                f"{v:.6g}" if isinstance(v, float) else str(v)
                for v in vals))
        if not args.all and len(e["cells"]) > 8:
            print(f"  ... ({len(e['cells'])} cells total; --all to list)")
    return 0


def _cmd_list(_args: argparse.Namespace) -> int:
    print("grids:")
    for name, spec in sorted(grids.GRIDS.items()):
        print(f"  {name:<14} {spec.n_cells:>4} cells  "
              f"(hash {spec.spec_hash()})")
    print("suites:")
    for name, members in sorted(grids.SUITES.items()):
        print(f"  {name:<14} -> {', '.join(members)}")
    return 0


def build_parser() -> argparse.ArgumentParser:
    """The CLI's argument parser, importable without running anything.

    ``tools/check_docs.py`` parses every documented
    ``python -m repro.experiments ...`` line through this parser, so a
    README example that drifts from the real flags fails CI.
    """
    ap = argparse.ArgumentParser(prog="python -m repro.experiments",
                                 description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("run", help="run a grid/suite and write an artifact")
    p.add_argument("--grid", required=True,
                   help="grid or suite name (see `list`)")
    p.add_argument("--out", help="artifact path "
                   "(default artifacts/experiments/<grid>.json)")
    p.add_argument("--executor",
                   choices=("auto", "thread", "process", "serial"),
                   default="auto",
                   help="auto = threads for small grids, a process pool "
                        "once the grid reaches 64 cells (pure-Python cells "
                        "are GIL-bound on threads); serial for debugging")
    p.add_argument("--jobs", type=int, default=None,
                   help="max workers for the executor")
    p.add_argument("--journal", metavar="DIR", default=None,
                   help="crash-safe journal directory: every completed "
                        "cell is flushed to DIR/<grid>.jsonl as it lands")
    p.add_argument("--resume", action="store_true",
                   help="replay the journal and re-run only missing/failed "
                        "cells; the artifact is byte-identical to a "
                        "single-shot run")
    p.add_argument("--cell-timeout", type=float, default=None,
                   metavar="SECONDS", dest="cell_timeout",
                   help="per-cell wall-clock budget (process pool): a "
                        "wedged cell is killed and retried")
    p.add_argument("--retries", type=int, default=0,
                   help="re-attempts per failed/timed-out cell (with "
                        "exponential backoff); an exhausted cell is "
                        "recorded with failure metadata, not fatal")
    p.set_defaults(fn=_cmd_run)

    p = sub.add_parser("compare", help="diff two artifacts (regression gate)")
    p.add_argument("old")
    p.add_argument("new")
    p.add_argument("--tol", action="append", metavar="FIELD=ATOL",
                   help="override the absolute tolerance for one field")
    p.set_defaults(fn=_cmd_compare)

    p = sub.add_parser("report", help="pretty-print an artifact")
    p.add_argument("artifact")
    p.add_argument("--grid", help="only this experiment")
    p.add_argument("--all", action="store_true", help="print every cell")
    p.set_defaults(fn=_cmd_report)

    p = sub.add_parser("list", help="list known grids and suites")
    p.set_defaults(fn=_cmd_list)
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
