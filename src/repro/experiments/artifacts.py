"""Versioned JSON experiment artifacts.

One artifact holds an ordered list of experiment records (see
``runner.run_spec``) under a schema version, written with sorted keys and
full float repr so a byte-identical rerun produces a byte-identical file —
the property the golden-artifact CI gate relies on.
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Union

SCHEMA_VERSION = 1
KIND = "repro-experiment-artifact"

PathLike = Union[str, Path]


class ArtifactError(ValueError):
    pass


def make_artifact(experiments: Sequence[Dict],
                  meta: Optional[Dict] = None) -> Dict:
    return {
        "kind": KIND,
        "schema_version": SCHEMA_VERSION,
        "meta": dict(meta or {}),
        "experiments": list(experiments),
    }


def write(path: PathLike, experiments: Sequence[Dict],
          meta: Optional[Dict] = None) -> Dict:
    art = make_artifact(experiments, meta)
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    # sort_keys + fixed separators => canonical bytes; json floats use
    # repr() which round-trips IEEE doubles exactly.  Written to a temp
    # file in the same directory and renamed into place: a crashed or
    # colliding writer can never leave a truncated file that read() (and
    # hence compare) would mistake for a complete artifact.
    fd, tmp = tempfile.mkstemp(dir=p.parent, prefix=p.name + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as fh:
            fh.write(json.dumps(art, sort_keys=True, indent=1) + "\n")
        os.replace(tmp, p)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return art


def read(path: PathLike) -> Dict:
    p = Path(path)
    try:
        art = json.loads(p.read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise ArtifactError(f"cannot read artifact {p}: {e}") from e
    if not isinstance(art, dict) or art.get("kind") != KIND:
        raise ArtifactError(f"{p} is not a {KIND}")
    version = art.get("schema_version")
    if version != SCHEMA_VERSION:
        raise ArtifactError(
            f"{p}: schema_version {version} != supported {SCHEMA_VERSION}")
    return art


def experiments_by_name(art: Dict) -> Dict[str, Dict]:
    return {e["name"]: e for e in art.get("experiments", [])}
