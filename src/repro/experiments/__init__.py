"""Declarative experiment engine for the paper's sweep methodology.

- :mod:`repro.experiments.spec`      — ``ExperimentSpec`` / ``Cell`` grids
- :mod:`repro.experiments.grids`     — named paper grids + suites
- :mod:`repro.experiments.runner`    — concurrent fan-out over the simulator
- :mod:`repro.experiments.artifacts` — versioned JSON artifact I/O
- :mod:`repro.experiments.compare`   — tolerance-gated artifact diffing
- :mod:`repro.experiments.cli`       — ``python -m repro.experiments``
"""
from repro.experiments.spec import CELL_AXES, Cell, ExperimentSpec
from repro.experiments.grids import GRIDS, SUITES, resolve
from repro.experiments.runner import (ENGINE_VERSION, index_cells, run_cell,
                                      run_spec, run_suite)
from repro.experiments.compare import CompareReport, Violation, compare
from repro.experiments import artifacts

__all__ = [
    "CELL_AXES", "Cell", "ExperimentSpec", "GRIDS", "SUITES", "resolve",
    "ENGINE_VERSION", "index_cells", "run_cell", "run_spec", "run_suite",
    "CompareReport", "Violation", "compare", "artifacts",
]
