"""Named sweep grids — one per paper figure, plus suite aliases.

These are the declarative replacements for the old imperative loops in
``repro.core.whatif``: each grid is exactly the figure's sweep, and the
``paper`` suite is what the committed golden artifact (and the CI
sim-regression job) runs.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.experiments.spec import ExperimentSpec

PAPER_MODELS = ("resnet50", "resnet101", "vgg16")

GRIDS: Dict[str, ExperimentSpec] = {}


def _register(spec: ExperimentSpec) -> ExperimentSpec:
    GRIDS[spec.name] = spec
    return spec


# Fig 1: measured-mode scaling factor vs number of servers at 100 Gbps.
_register(ExperimentSpec(
    name="paper-fig1", models=PAPER_MODELS, n_servers=(2, 4, 8),
    bandwidth_gbps=(100.0,), transport=("horovod_tcp",)))

# Fig 3: ResNet-50 scaling vs bandwidth, per server count (measured mode).
_register(ExperimentSpec(
    name="paper-fig3", models=("resnet50",), n_servers=(2, 4, 8),
    bandwidth_gbps=(1.0, 2.0, 5.0, 10.0, 25.0, 50.0, 75.0, 100.0),
    transport=("horovod_tcp",)))

# Fig 4: network utilization during the communication phase, 8 servers.
_register(ExperimentSpec(
    name="paper-fig4", models=PAPER_MODELS, n_servers=(8,),
    bandwidth_gbps=(1.0, 10.0, 25.0, 50.0, 100.0),
    transport=("horovod_tcp",)))

# Fig 6: simulated-full-utilization vs measured-mode lines, 8 servers.
_register(ExperimentSpec(
    name="paper-fig6", models=PAPER_MODELS, n_servers=(8,),
    bandwidth_gbps=(1.0, 10.0, 25.0, 50.0, 100.0),
    transport=("ideal", "horovod_tcp")))

# Fig 7: scaling vs worker count at 100 Gbps, both transports.
_register(ExperimentSpec(
    name="paper-fig7", models=PAPER_MODELS, n_servers=(1, 2, 4, 8),
    bandwidth_gbps=(100.0,), transport=("ideal", "horovod_tcp")))

# Fig 8: gradient compression under full utilization.
_register(ExperimentSpec(
    name="paper-fig8", models=PAPER_MODELS, n_servers=(8,),
    bandwidth_gbps=(10.0, 100.0), transport=("ideal",),
    compression_ratio=(1.0, 2.0, 5.0, 10.0, 100.0)))

# §4 other systems: ring vs SwitchML vs sharded parameter server (what-if).
_register(ExperimentSpec(
    name="paper-fig9", models=PAPER_MODELS, n_servers=(8,),
    bandwidth_gbps=(10.0, 25.0, 100.0), transport=("ideal",),
    topology=("ring", "switchml", "param_server")))

# Scheduler axis (tentpole of the event-engine refactor): the paper grid's
# interesting bandwidths under each comm schedule.  fifo is the measured
# Horovod baseline; priority (ByteScheduler-style first-layer-first) and
# chunked (pipelined transmission+reduction) must never add overhead —
# the `scheduler_suite` golden artifact gates exactly that in CI.
_register(ExperimentSpec(
    name="scheduler-suite", models=PAPER_MODELS, n_servers=(8,),
    bandwidth_gbps=(5.0, 10.0, 25.0, 100.0),
    transport=("ideal", "horovod_tcp"),
    scheduler=("fifo", "priority", "chunked")))

# paper-xl (the event-engine rewrite's payoff): the scenario space the
# follow-up papers show is needed before the interesting conclusions emerge
# — a dense bandwidth axis, deep chunking, and a multi-job contention axis.
# Only tractable with the indexed engine + process-pool runner: the xl-sched
# grid alone lowers to ~10^3 flows per cell at sched_chunks=64.

# Dense bandwidth sweep: every server count x a 14-point bandwidth axis,
# both transports (fig3/fig6 were 8 and 5 points on one model each).
_register(ExperimentSpec(
    name="xl-bandwidth", models=PAPER_MODELS, n_servers=(2, 4, 8),
    bandwidth_gbps=(1.0, 2.0, 5.0, 7.5, 10.0, 15.0, 20.0, 25.0, 40.0, 50.0,
                    75.0, 100.0, 200.0, 400.0),
    transport=("ideal", "horovod_tcp")))

# Deep chunking: the pipelined schedulers at 64 chunks/bucket, where the
# chunk pipeline saturates and the t_overhead <= fifo claim is sharpest.
_register(ExperimentSpec(
    name="xl-sched", models=PAPER_MODELS, n_servers=(8,),
    bandwidth_gbps=(5.0, 10.0, 25.0, 50.0, 100.0),
    transport=("ideal", "horovod_tcp"),
    scheduler=("fifo", "priority", "chunked"), sched_chunks=64))

# Contention: 1/2/4/8 copies of the same training job fair-sharing one
# link (simulate_contention), under fifo and the chunked pipeline.
_register(ExperimentSpec(
    name="xl-contention", models=PAPER_MODELS, n_servers=(8,),
    bandwidth_gbps=(10.0, 25.0, 100.0), transport=("horovod_tcp",),
    scheduler=("fifo", "chunked"), n_jobs=(1, 2, 4, 8), sched_chunks=32))

# xxl-contention (the heap-mode bulk-commit payoff): the large, contended,
# scheduler-sensitive regime the gradient-compression follow-up identifies
# as where scheduling actually matters — priority *and* chunked pipelines
# at 64 chunks/bucket, up to 16 co-located jobs, with and without flush
# jitter.  The 16-job VGG16 cells lower to >18k flows each (>10k/cell is
# the grid's defining scale), which is only sweepable because heap-mode
# (priority) jobs ride the same numpy bulk-commit fast path as pointer
# mode.  Gated by artifacts/golden/xxl_contention_suite.json in CI.
_register(ExperimentSpec(
    name="xxl-contention", models=("resnet50", "vgg16"), n_servers=(8,),
    bandwidth_gbps=(10.0, 25.0), transport=("horovod_tcp",),
    scheduler=("priority", "chunked"), n_jobs=(1, 4, 16), sched_chunks=64,
    jitter_ms=(0.0, 2.0), jitter_seed=2026))

# Scenario axes (the follow-up literature's territory — what the paper's
# single-NIC, no-straggler testbed could not measure).

# Multi-rail hosts: the cell's bandwidth is the *aggregate*; n_rails splits
# it into equal rails and assign_rails deals the plan's ops across them
# (round-robin).  The claims the golden suite gates: the chunked pipeline
# stripes, so rails leave its overhead unchanged up to the tail-bucket
# negotiation skew; the serialized fifo stream cannot stripe, so rails
# *help* latency-bound models (parallel reductions) and *hurt*
# bandwidth-bound ones (a whole bucket is stuck on one slower rail).
_register(ExperimentSpec(
    name="multirail", models=PAPER_MODELS, n_servers=(8,),
    bandwidth_gbps=(10.0, 25.0, 100.0), transport=("horovod_tcp",),
    scheduler=("fifo", "chunked"), sched_chunks=8, n_rails=(1, 2, 4)))

# Stragglers: each flow's flush is delayed by an exponential draw with
# mean jitter_ms (seeded, so the grid is reproducible bit-for-bit).  The
# gated claims: overhead is monotone in jitter; at full bandwidth the
# straggler tail passes straight into t_overhead, while in the
# bandwidth-bound regime the transmission queue absorbs it.
_register(ExperimentSpec(
    name="straggler", models=PAPER_MODELS, n_servers=(8,),
    bandwidth_gbps=(10.0, 100.0), transport=("horovod_tcp",),
    scheduler=("fifo", "chunked"), sched_chunks=8,
    jitter_ms=(0.0, 2.0, 10.0), jitter_seed=2020))

# Compression as a priced axis (the Agarwal et al. critique of fig 8's
# free byte divisor): every codec carries kernel-calibrated encode/decode
# compute, so each cell answers "does this codec win, lose, or just burn
# GPU time here?" against its codec=none twin.  Ideal transport isolates
# the wire-vs-compute tradeoff (under horovod_tcp the transport cap, not
# the network, dominates at 100 Gbps): at 10 Gbps the network is the
# bottleneck and compression wins; at 100 Gbps the baseline overhead is
# already negligible and any codec is pure overhead.  Gated by
# artifacts/golden/compression_suite.json in CI (fig13 renders it).
_register(ExperimentSpec(
    name="compression", models=PAPER_MODELS, n_servers=(8,),
    bandwidth_gbps=(1.0, 10.0, 100.0), transport=("ideal",),
    scheduler=("fifo", "chunked"), sched_chunks=8, n_jobs=(1, 4),
    codec=("none", "int8", "ternary", "topk:8", "size-adaptive")))

# Unreliable-world axes (the Hivemind / flaky-fleet territory): worker-
# correlated slowdowns, dropout/rejoin churn with a priced re-bucketing
# stall, and asymmetric per-worker bandwidth — all seeded via core.faults
# and composed with the scheduler and rails axes.  The gated claims:
# fault_model="none" x churn_rate=0 x skew=0 cells are *bitwise* identical
# to plain simulate (the null model never touches a flow); fifo overhead
# is monotone in the slowdown scale at fixed seed (shared exponential
# draws, linear scaling); priority never loses to fifo on t_overhead
# under churn (the engine re-admits survivors in IR order either way).
# Gated by artifacts/golden/churn_suite.json in CI.
_register(ExperimentSpec(
    name="churn", models=("resnet50", "vgg16"), n_servers=(8,),
    bandwidth_gbps=(10.0, 100.0), transport=("horovod_tcp",),
    scheduler=("fifo", "priority"), sched_chunks=8, n_rails=(1, 2),
    fault_model=("none", "slowdown:1", "slowdown:5"),
    churn_rate=(0.0, 0.64), worker_bw_skew=(0.0, 0.5), fault_seed=2027))

# Fabric axes (the tentpole of the multi-link max-min engine): the same
# collectives priced on a Clos fabric with oversubscribed ToR uplinks
# instead of one flat link.  Striped ring/tree collectives push all
# hosts_per_tor NICs of a rack through the uplink at once, so their solo
# rate is min(1, 1/oversubscription); hierarchical reduces rack-locally
# and only a leader crosses the spine, riding out oversubscription.  The
# gated claims: 1:1 cells are *bitwise* the flat topology (the uplink is
# elided from the path, so the original engine runs verbatim); scaling is
# monotone non-increasing in oversubscription; hierarchical never loses
# to the flat ring at 4:1.  Gated by artifacts/golden/fabric_suite.json.
_register(ExperimentSpec(
    name="fabric", models=("resnet50", "vgg16"), n_servers=(8,),
    bandwidth_gbps=(10.0, 100.0), transport=("ideal",),
    topology=("ring", "tree", "hierarchical"),
    fabric=("clos",), oversubscription=(1.0, 2.0, 4.0)))

# WAN / lossy-link axes (the tentpole of the lossy-transport engine): the
# transport-regime territory the Agarwal et al. and Han et al. follow-ups
# show flips end-to-end utility judgments.  link_profile prices Bernoulli
# segment loss two ways: deterministically (wire work inflates by
# 1/(1-loss), RTT joins the post-wire latency) and stochastically (seeded
# RTO stalls of timeout * backoff^k riding the _RETX calendar kind).  The
# gated claims: link_profile="none" cells are *bitwise* plain simulate
# (the null profile never touches a flow); t_sync is monotone
# non-decreasing in loss at fixed rtt (thinning keeps a loss-superset of
# the same timed events); stalls are monotone in the backoff multiplier
# at fixed timeout; and the compression-wins region (int8 beating its
# codec=none twin on t_sync) only widens as loss grows — lost bytes are
# retransmitted bytes, so compression pays double under loss.  Gated by
# artifacts/golden/wan_suite.json in CI (fig16 renders the regime map).
_register(ExperimentSpec(
    name="wan", models=("resnet50",), n_servers=(8,),
    bandwidth_gbps=(1.0, 10.0), transport=("horovod_tcp",),
    scheduler=("fifo", "priority"), sched_chunks=8,
    codec=("none", "int8"), fault_seed=2029,
    link_profile=("none",
                  "wan:loss=0.001,rtt=20",
                  "wan:loss=0.01,rtt=20",
                  "wan:loss=0.05,rtt=20",
                  "wan:loss=0.01,rtt=20:timeout=100,backoff=1",
                  "wan:loss=0.01,rtt=20:timeout=100,backoff=4")))

# Suites: ordered grid groups runnable/comparable as one artifact.
SUITES: Dict[str, Tuple[str, ...]] = {
    "paper": ("paper-fig1", "paper-fig3", "paper-fig4", "paper-fig6",
              "paper-fig7", "paper-fig8", "paper-fig9"),
    "scheduler": ("scheduler-suite",),
    "paper-xl": ("xl-bandwidth", "xl-sched", "xl-contention"),
    "scenario": ("multirail", "straggler"),
    "xxl": ("xxl-contention",),
    "compression": ("compression",),
    "churn": ("churn",),
    "fabric": ("fabric",),
    "wan": ("wan",),
}


def resolve(name: str) -> Tuple[ExperimentSpec, ...]:
    """A grid name resolves to one spec; a suite name to its ordered specs."""
    if name in SUITES:
        return tuple(GRIDS[g] for g in SUITES[name])
    if name in GRIDS:
        return (GRIDS[name],)
    known = sorted(GRIDS) + sorted(SUITES)
    raise KeyError(f"unknown grid/suite {name!r}; known: {', '.join(known)}")
