"""Declarative experiment specs — the paper's sweep methodology as data.

An :class:`ExperimentSpec` names a cartesian grid over the simulator's axes
(model x servers x bandwidth x transport x compression x topology); the
runner fans the expanded cells out over ``repro.core.simulator.simulate``.
Specs are canonically serializable (sorted-key JSON) and content-addressed
via :meth:`ExperimentSpec.spec_hash`, so an artifact records exactly which
grid produced it and ``compare`` can refuse to diff mismatched sweeps.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field, fields
from itertools import product
from typing import Dict, Sequence, Tuple

SPEC_VERSION = 1

# axis order is part of the stable cell identity — never reorder (new axes
# append at the end, with a default recorded in AXIS_DEFAULTS so artifacts
# written before the axis existed still index consistently)
CELL_AXES = ("model", "n_servers", "bandwidth_gbps", "transport",
             "compression_ratio", "topology", "scheduler", "n_jobs",
             "n_rails", "jitter_ms", "codec", "fault_model", "churn_rate",
             "worker_bw_skew", "fabric", "oversubscription", "link_profile")

AXIS_DEFAULTS = {"scheduler": "fifo", "n_jobs": 1, "n_rails": 1,
                 "jitter_ms": 0.0, "codec": "none", "fault_model": "none",
                 "churn_rate": 0.0, "worker_bw_skew": 0.0,
                 "fabric": "none", "oversubscription": 1.0,
                 "link_profile": "none"}

# axes added after the first golden artifacts shipped: omitted from
# serialized cells/specs while at their default, so pre-axis artifacts stay
# byte-identical and spec hashes (the CI regression gate) never drift for
# grids that do not sweep them
_ELIDED_AT_DEFAULT = {"n_jobs": 1, "n_rails": 1, "jitter_ms": 0.0,
                      "codec": "none", "fault_model": "none",
                      "churn_rate": 0.0, "worker_bw_skew": 0.0,
                      "fabric": "none", "oversubscription": 1.0,
                      "link_profile": "none"}


def axis_value(cell: Dict, axis: str):
    """Read ``axis`` from a serialized cell, defaulting elided/new axes.

    The one sanctioned way to index recorded cells: axes appended after an
    artifact was written (or elided at their default) fall back to
    ``AXIS_DEFAULTS`` instead of raising."""
    if axis in AXIS_DEFAULTS:
        return cell.get(axis, AXIS_DEFAULTS[axis])
    return cell[axis]


@dataclass(frozen=True)
class Cell:
    """One point of an expanded grid (the arguments of a single simulate)."""

    model: str
    n_servers: int
    bandwidth_gbps: float
    transport: str
    compression_ratio: float
    topology: str
    scheduler: str = "fifo"
    n_jobs: int = 1                 # co-located jobs contending for the link
    n_rails: int = 1                # rails splitting the aggregate bandwidth
    jitter_ms: float = 0.0          # mean per-flow flush delay (stragglers)
    codec: str = "none"             # gradient-compression codec (core.codec)
    fault_model: str = "none"       # worker-correlated slowdown (core.faults)
    churn_rate: float = 0.0         # expected dropout events per iteration
    worker_bw_skew: float = 0.0     # per-worker bandwidth asymmetry scale
    fabric: str = "none"            # datacenter fabric (core.fabric)
    oversubscription: float = 1.0   # ToR uplink oversubscription ratio
    link_profile: str = "none"      # lossy-link regime (core.transport)

    def key(self) -> Tuple:
        return tuple(getattr(self, a) for a in CELL_AXES)

    @property
    def weight(self) -> int:
        """Scheduling weight: a contention cell runs ``n_jobs`` co-located
        jobs through one engine call, so it costs roughly ``n_jobs`` plain
        cells.  The runner's auto-executor sums these instead of counting
        cells, so a small-by-count grid of 10k-flow contention cells still
        lands on the process pool."""
        return max(int(self.n_jobs), 1)

    def to_dict(self) -> Dict:
        return {a: getattr(self, a) for a in CELL_AXES
                if _ELIDED_AT_DEFAULT.get(a, ...) != getattr(self, a)}

    @staticmethod
    def from_dict(d: Dict) -> "Cell":
        return Cell(**{a: d.get(a, AXIS_DEFAULTS[a]) if a in AXIS_DEFAULTS
                       else d[a] for a in CELL_AXES})


@dataclass(frozen=True)
class ExperimentSpec:
    """A named sweep grid plus the fixed simulator context.

    Axis fields hold the *values to sweep* (tuples); the remaining fields
    (GPUs per server, add-estimator, fusion-buffer config) are held constant
    across the grid, matching the paper's setup (p3dn.24xlarge, V100).
    """

    name: str
    models: Tuple[str, ...] = ("resnet50", "resnet101", "vgg16")
    n_servers: Tuple[int, ...] = (8,)
    bandwidth_gbps: Tuple[float, ...] = (100.0,)
    transport: Tuple[str, ...] = ("ideal",)
    compression_ratio: Tuple[float, ...] = (1.0,)
    topology: Tuple[str, ...] = ("ring",)
    scheduler: Tuple[str, ...] = ("fifo",)
    n_jobs: Tuple[int, ...] = (1,)      # contention axis (fair-share link)
    n_rails: Tuple[int, ...] = (1,)     # multi-rail axis (aggregate bw split)
    jitter_ms: Tuple[float, ...] = (0.0,)   # straggler axis (mean flush delay)
    codec: Tuple[str, ...] = ("none",)  # compression-codec axis (core.codec)
    fault_model: Tuple[str, ...] = ("none",)    # fault axis (core.faults)
    churn_rate: Tuple[float, ...] = (0.0,)  # dropout/rejoin rate axis
    worker_bw_skew: Tuple[float, ...] = (0.0,)  # asymmetric-bw axis
    fabric: Tuple[str, ...] = ("none",)     # fabric axis (core.fabric)
    oversubscription: Tuple[float, ...] = (1.0,)    # ToR uplink oversub
    link_profile: Tuple[str, ...] = ("none",)   # lossy-link axis (transport)
    gpus_per_server: int = 8            # p3dn.24xlarge
    addest: str = "v100"                # v100 | tpu_v5e
    fusion_buffer_mb: float = 64.0      # paper's fusion buffer
    timeout_ms: float = 5.0             # paper's fusion timeout
    sched_chunks: int = 4               # chunks/bucket for pipelined scheds
    rail_policy: str = "round-robin"    # CommOp -> rail assignment policy
    jitter_seed: int = 0                # seed of the straggler perturbation
    error_feedback: bool = False        # EF-SGD residual cost on lossy codecs
    fault_seed: int = 0                 # seed of the fault-model draws

    # spec fields added after the first golden artifacts shipped, elided
    # from canonical JSON at their default (same contract as the elided
    # axes: pre-existing spec hashes never drift)
    _ELIDED_FIELDS = (("n_jobs", (1,)), ("n_rails", (1,)),
                      ("jitter_ms", (0.0,)), ("rail_policy", "round-robin"),
                      ("jitter_seed", 0), ("codec", ("none",)),
                      ("error_feedback", False), ("fault_model", ("none",)),
                      ("churn_rate", (0.0,)), ("worker_bw_skew", (0.0,)),
                      ("fault_seed", 0), ("fabric", ("none",)),
                      ("oversubscription", (1.0,)),
                      ("link_profile", ("none",)))

    def __post_init__(self):
        # tolerate lists (e.g. straight from JSON) by freezing to tuples
        for f in ("models", "n_servers", "bandwidth_gbps", "transport",
                  "compression_ratio", "topology", "scheduler", "n_jobs",
                  "n_rails", "jitter_ms", "codec", "fault_model",
                  "churn_rate", "worker_bw_skew", "fabric",
                  "oversubscription", "link_profile"):
            v = getattr(self, f)
            if not isinstance(v, tuple):
                object.__setattr__(self, f, tuple(v))

    # -- grid expansion ------------------------------------------------------

    def expand(self) -> Tuple[Cell, ...]:
        """Cartesian product in stable axis order (model outermost)."""
        return tuple(Cell(m, int(n), float(bw), t, float(r), topo, s, int(j),
                          int(nr), float(jm), cd, fml, float(cr), float(sk),
                          fb, float(ov), lp)
                     for m, n, bw, t, r, topo, s, j, nr, jm, cd, fml, cr, sk,
                     fb, ov, lp
                     in product(
                         self.models, self.n_servers, self.bandwidth_gbps,
                         self.transport, self.compression_ratio,
                         self.topology, self.scheduler, self.n_jobs,
                         self.n_rails, self.jitter_ms, self.codec,
                         self.fault_model, self.churn_rate,
                         self.worker_bw_skew, self.fabric,
                         self.oversubscription, self.link_profile))

    @property
    def n_cells(self) -> int:
        return (len(self.models) * len(self.n_servers)
                * len(self.bandwidth_gbps) * len(self.transport)
                * len(self.compression_ratio) * len(self.topology)
                * len(self.scheduler) * len(self.n_jobs)
                * len(self.n_rails) * len(self.jitter_ms)
                * len(self.codec) * len(self.fault_model)
                * len(self.churn_rate) * len(self.worker_bw_skew)
                * len(self.fabric) * len(self.oversubscription)
                * len(self.link_profile))

    @property
    def workload_units(self) -> int:
        """Sum of :attr:`Cell.weight` over the grid, without expanding it.

        The executor-dispatch measure: every axis combination repeats once
        per ``n_jobs`` value, so the sum factors into (combinations
        without the contention axis) x (sum of per-value weights)."""
        per_combo = sum(max(int(j), 1) for j in self.n_jobs)
        return (self.n_cells // max(len(self.n_jobs), 1)) * per_combo

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> Dict:
        d = asdict(self)
        for f, default in self._ELIDED_FIELDS:
            # elided while at its default: specs written before the axis
            # (or knob) existed keep their canonical JSON — and hence spec
            # hash, the golden-artifact gate — unchanged
            if getattr(self, f) == default:
                del d[f]
        d["spec_version"] = SPEC_VERSION
        return d

    @staticmethod
    def from_dict(d: Dict) -> "ExperimentSpec":
        d = dict(d)
        d.pop("spec_version", None)
        known = {f.name for f in fields(ExperimentSpec)}
        return ExperimentSpec(**{k: v for k, v in d.items() if k in known})

    def canonical_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    def spec_hash(self) -> str:
        return hashlib.sha256(self.canonical_json().encode()).hexdigest()[:16]
