"""Uniform model API over all architecture families.

``get_model(cfg)`` returns a ``ModelApi`` whose members close over ``cfg``:

    init(key) -> params
    loss_fn(params, batch) -> (loss, metrics)          # batch: tokens/labels(+frontend)
    prefill(params, batch) -> (logits, caches)
    decode_step(params, batch, cache, cache_index) -> (logits, new_cache)
    cache_spec(batch_size, cache_len) -> pytree of (shape, dtype) tuples
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


class ModelApi(NamedTuple):
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    cache_spec: Callable


def _transformer_api(cfg: ModelConfig) -> ModelApi:
    from repro.models import transformer as t

    def loss_fn(params, batch):
        return t.loss_fn(params, batch, cfg)

    def prefill(params, batch):
        return t.prefill(params, batch["tokens"], cfg,
                         prefix_embeds=batch.get("prefix_embeds"))

    def decode_step(params, batch, cache, cache_index):
        return t.decode_step(params, batch["tokens"], cache, cache_index, cfg)

    return ModelApi(cfg, lambda key: t.init_decoder(key, cfg), loss_fn,
                    prefill, decode_step,
                    lambda b, w: t.cache_spec(cfg, b, w))


def _ssm_api(cfg: ModelConfig) -> ModelApi:
    from repro.models import rwkv as r

    def loss_fn(params, batch):
        return r.loss_fn(params, batch, cfg)

    def prefill(params, batch):
        return r.prefill(params, batch["tokens"], cfg)

    def decode_step(params, batch, cache, cache_index):
        return r.decode_step(params, batch["tokens"], cache, cache_index, cfg)

    return ModelApi(cfg, lambda key: r.init_model(key, cfg), loss_fn,
                    prefill, decode_step, lambda b, w: r.cache_spec(cfg, b))


def _hybrid_api(cfg: ModelConfig) -> ModelApi:
    from repro.models import jamba as j

    def loss_fn(params, batch):
        return j.loss_fn(params, batch, cfg)

    def prefill(params, batch):
        return j.prefill(params, batch["tokens"], cfg)

    def decode_step(params, batch, cache, cache_index):
        return j.decode_step(params, batch["tokens"], cache, cache_index, cfg)

    return ModelApi(cfg, lambda key: j.init_model(key, cfg), loss_fn,
                    prefill, decode_step, lambda b, w: j.cache_spec(cfg, b, w))


def _encdec_api(cfg: ModelConfig) -> ModelApi:
    from repro.models import whisper as w

    def loss_fn(params, batch):
        return w.loss_fn(params, batch, cfg)

    def prefill(params, batch):
        return w.prefill(params, batch["tokens"], batch["frames"], cfg)

    def decode_step(params, batch, cache, cache_index):
        return w.decode_step(params, batch["tokens"], cache, cache_index, cfg)

    return ModelApi(cfg, lambda key: w.init_model(key, cfg), loss_fn,
                    prefill, decode_step, lambda b, wl: w.cache_spec(cfg, b, wl))


# cache leaves whose dim-2 is the ring-buffer/sequence axis
_SEQ_CACHE_LEAVES = {"k", "v", "c_kv", "k_rope"}


def pad_cache(cache: Any, new_len: int) -> Any:
    """Grow the ring-buffer (W) axis of a prefill cache to ``new_len`` so
    decode can append tokens.  Recurrent-state leaves (SSM/RWKV) and
    cross-attention K/V are untouched (they have no growing axis)."""

    def one(path, leaf):
        name = None
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                name = str(p.key)
        if name in _SEQ_CACHE_LEAVES and leaf.ndim >= 3:
            axis = 2 if leaf.ndim >= 4 else 1
            cur = leaf.shape[axis]
            if cur < new_len:
                pad = [(0, 0)] * leaf.ndim
                pad[axis] = (0, new_len - cur)
                return jnp.pad(leaf, pad)
        return leaf

    return jax.tree_util.tree_map_with_path(one, cache)


def get_model(cfg: ModelConfig) -> ModelApi:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return _transformer_api(cfg)
    if fam == "ssm":
        return _ssm_api(cfg)
    if fam == "hybrid":
        return _hybrid_api(cfg)
    if fam == "encdec":
        return _encdec_api(cfg)
    raise ValueError(f"unknown family: {fam}")
