"""Attention variants: GQA with chunked online-softmax ("flash" in pure jnp),
MLA (DeepSeek-V2 latent attention), sliding-window masking, and single-token
decode against (optionally ring-buffer) KV caches.

Memory discipline: training/prefill never materializes an (Sq, Skv) score
matrix larger than (attn_chunk, attn_chunk) per (batch, kv-head, group).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, apply_rope, dense_init, split_keys

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# chunked online-softmax attention core
# ---------------------------------------------------------------------------

def _flash_core(q, k, v, q_pos, kv_pos, *, causal: bool, window: int, chunk: int):
    """q: (B, Hkv, G, Sq, d); k, v: (B, Hkv, Skv, d).

    q_pos: (Sq,) absolute positions of queries; kv_pos: (Skv,).
    Returns (B, Hkv, G, Sq, d).  Scans over KV chunks with a running
    (max, denominator, accumulator) triple; fp32 accumulation.
    """
    B, Hkv, G, Sq, d = q.shape
    dv = v.shape[-1]                                     # may differ from d (MLA)
    Skv = k.shape[2]
    chunk = min(chunk, Skv)
    if Skv % chunk != 0:
        chunk = Skv
    n_blocks = Skv // chunk

    kb = k.reshape(B, Hkv, n_blocks, chunk, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, Hkv, n_blocks, chunk, dv).transpose(2, 0, 1, 3, 4)
    pb = kv_pos.reshape(n_blocks, chunk)

    scale = 1.0 / math.sqrt(d)
    qf = q.astype(jnp.float32) * scale

    def body(carry, inp):
        m, l, acc = carry
        kc, vc, pc = inp                                     # (B,Hkv,chunk,d), (chunk,)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kc.astype(jnp.float32))
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask &= pc[None, :] <= q_pos[:, None]
        if window:
            mask &= pc[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def use_pallas(cfg) -> bool:
    """Kernel dispatch policy: Pallas on TPU (or when forced for tests)."""
    mode = getattr(cfg, "use_pallas", "auto")
    if mode == "always":
        return True
    if mode == "never":
        return False
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_pallas_cv(q, k, v, causal, n_heads, n_kv_heads):
    """Pallas forward with the pure-jnp path's gradients (recompute in
    backward) — the standard pattern until a bwd kernel lands."""
    from repro.kernels.flash_attn import flash_attention_pallas
    B, Hq, Sq, d = q.shape
    Hkv = k.shape[1]
    out = flash_attention_pallas(
        q.reshape(B * Hq, Sq, d), k.reshape(B * Hkv, k.shape[2], d),
        v.reshape(B * Hkv, v.shape[2], d), causal=causal,
        n_heads=Hq, n_kv_heads=Hkv,
        interpret=jax.default_backend() != "tpu")
    return out.reshape(B, Hq, Sq, d)


def _flash_cv_fwd(q, k, v, causal, n_heads, n_kv_heads):
    return _flash_pallas_cv(q, k, v, causal, n_heads, n_kv_heads), (q, k, v)


def _flash_cv_bwd(causal, n_heads, n_kv_heads, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _flash_reference(q_, k_, v_, causal), q, k, v)
    return vjp(g)


def _flash_reference(q, k, v, causal):
    return flash_attention(q, k, v, causal=causal, chunk=1024,
                           _allow_pallas=False)


_flash_pallas_cv.defvjp(_flash_cv_fwd, _flash_cv_bwd)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    chunk: int = 1024, q_offset: int = 0,
                    cfg=None, _allow_pallas: bool = True) -> jnp.ndarray:
    """GQA-aware chunked attention.

    q: (B, Hq, Sq, d); k, v: (B, Hkv, Skv, d); Hq % Hkv == 0.
    ``q_offset`` shifts query positions (prefill continuation).
    Queries are processed in blocks of ``chunk`` via lax.map so prefill_32k
    never holds more than one (chunk x chunk) score tile per head-group.

    When ``cfg.use_pallas`` resolves true and the shape qualifies (no
    window/offset, same qk/v dims, 128-aligned), dispatches to the Pallas
    online-softmax kernel (repro.kernels.flash_attn).
    """
    if (_allow_pallas and cfg is not None and use_pallas(cfg)
            and window == 0 and q_offset == 0
            and q.shape[-1] == v.shape[-1]
            and q.shape[2] % 128 == 0 and k.shape[2] % 128 == 0):
        return _flash_pallas_cv(q, k, v, causal, q.shape[1], k.shape[1])
    B, Hq, Sq, d = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Sq, d)
    kv_pos = jnp.arange(k.shape[2])

    qchunk = min(chunk, Sq)
    if Sq % qchunk != 0:
        qchunk = Sq
    nq = Sq // qchunk
    if nq == 1:
        q_pos = q_offset + jnp.arange(Sq)
        out = _flash_core(qg, k, v, q_pos, kv_pos, causal=causal,
                          window=window, chunk=chunk)
    else:
        qb = qg.reshape(B, Hkv, G, nq, qchunk, d).transpose(3, 0, 1, 2, 4, 5)

        def one(args):
            qc, i = args
            q_pos = q_offset + i * qchunk + jnp.arange(qchunk)
            return _flash_core(qc, k, v, q_pos, kv_pos, causal=causal,
                               window=window, chunk=chunk)

        outs = jax.lax.map(one, (qb, jnp.arange(nq)))
        out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hkv, G, Sq, v.shape[-1])
    return out.reshape(B, Hq, Sq, v.shape[-1])


def decode_attention(q, k, v, valid_mask) -> jnp.ndarray:
    """Single-token attention.  q: (B, Hq, 1, d); k, v: (B, Hkv, S, d);
    valid_mask: (B, S) bool (ring-buffer slots that hold real tokens)."""
    B, Hq, _, d = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, d).astype(jnp.float32) / math.sqrt(d)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k.astype(jnp.float32))
    s = jnp.where(valid_mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, 1, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ModelConfig, n_layers: int = 0) -> Params:
    ks = split_keys(key, 4)
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    lead = (n_layers,) if n_layers else ()
    dtype = jnp.dtype(cfg.dtype)
    return {
        "wq": dense_init(ks[0], lead + (D, H * hd), dtype),
        "wk": dense_init(ks[1], lead + (D, KV * hd), dtype),
        "wv": dense_init(ks[2], lead + (D, KV * hd), dtype),
        "wo": dense_init(ks[3], lead + (H * hd, D), dtype),
    }


def gqa_forward(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                q_offset: int = 0) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Training / prefill path.  x: (B, S, D) -> (out, cache)."""
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = (x @ params["wk"]).reshape(B, S, KV, hd).transpose(0, 2, 1, 3)
    v = (x @ params["wv"]).reshape(B, S, KV, hd).transpose(0, 2, 1, 3)
    pos = q_offset + jnp.arange(S)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    out = flash_attention(q, k, v, causal=True, window=cfg.sliding_window,
                          chunk=cfg.attn_chunk, q_offset=q_offset, cfg=cfg)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    cache = {"k": k.transpose(0, 2, 1, 3), "v": v.transpose(0, 2, 1, 3)}  # (B,S,KV,hd)
    return out @ params["wo"], cache


def gqa_decode(params: Params, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
               cache_index: jnp.ndarray, cfg: ModelConfig
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode.  x: (B, 1, D); cache k/v: (B, W, KV, hd) ring buffer
    (W = sliding window if set, else max seq); cache_index: () int32 count of
    tokens already written."""
    B, _, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    W = cache["k"].shape[1]
    q = (x @ params["wq"]).reshape(B, 1, H, hd).transpose(0, 2, 1, 3)
    k = (x @ params["wk"]).reshape(B, 1, KV, hd)
    v = (x @ params["wv"]).reshape(B, 1, KV, hd)
    pos = cache_index[None]                       # absolute position of new token
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k.transpose(0, 2, 1, 3), pos, cfg.rope_theta).transpose(0, 2, 1, 3)
    slot = jnp.mod(cache_index, W)
    new_k = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    n_valid = jnp.minimum(cache_index + 1, W)
    valid = (jnp.arange(W)[None, :] < n_valid) | jnp.zeros((B, 1), bool)
    out = decode_attention(q, new_k.transpose(0, 2, 1, 3),
                           new_v.transpose(0, 2, 1, 3), valid)
    out = out.transpose(0, 2, 1, 3).reshape(B, 1, H * hd)
    return out @ params["wo"], {"k": new_k, "v": new_v}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, n_layers: int = 0) -> Params:
    ks = split_keys(key, 7)
    D, H, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    L, R = cfg.mla_kv_lora, cfg.mla_rope_dim
    lead = (n_layers,) if n_layers else ()
    dtype = jnp.dtype(cfg.dtype)
    return {
        "w_dkv": dense_init(ks[0], lead + (D, L), dtype),      # down-proj to latent
        "w_kr": dense_init(ks[1], lead + (D, R), dtype),       # shared rope key
        "w_uk": dense_init(ks[2], lead + (L, H * hd), dtype),  # up-proj keys
        "w_uv": dense_init(ks[3], lead + (L, H * hd), dtype),  # up-proj values
        "w_q": dense_init(ks[4], lead + (D, H * (hd + R)), dtype),
        "w_o": dense_init(ks[5], lead + (H * hd, D), dtype),
        "ln_kv": jnp.ones(lead + (L,), dtype),
    }


def _mla_qkv(params, x, cfg, pos):
    """Shared projection logic.  Returns q_nope,(B,H,S,hd) q_rope,(B,H,S,R)
    latent c_kv (B,S,L), k_rope (B,S,R)."""
    from repro.models.layers import rms_norm
    B, S, D = x.shape
    H, hd, R = cfg.num_heads, cfg.head_dim, cfg.mla_rope_dim
    q = (x @ params["w_q"]).reshape(B, S, H, hd + R).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    c_kv = rms_norm(x @ params["w_dkv"], params["ln_kv"], cfg.norm_eps)
    k_rope = apply_rope((x @ params["w_kr"])[:, None], pos, cfg.rope_theta)[:, 0]
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                q_offset: int = 0) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    B, S, D = x.shape
    H, hd, R = cfg.num_heads, cfg.head_dim, cfg.mla_rope_dim
    pos = q_offset + jnp.arange(S)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, x, cfg, pos)
    k_nope = (c_kv @ params["w_uk"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    v = (c_kv @ params["w_uv"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    # fold the shared rope-key into every head by concatenation
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, None], (B, H, S, R))], axis=-1)
    out = flash_attention(q_full, k_full, v, causal=True,
                          window=cfg.sliding_window, chunk=cfg.attn_chunk,
                          q_offset=q_offset)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    return out @ params["w_o"], {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode(params: Params, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
               cache_index: jnp.ndarray, cfg: ModelConfig
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Decode with the latent cache: c_kv (B, W, L), k_rope (B, W, R)."""
    B, _, D = x.shape
    H, hd, R = cfg.num_heads, cfg.head_dim, cfg.mla_rope_dim
    W = cache["c_kv"].shape[1]
    pos = cache_index[None]
    q_nope, q_rope, c_new, kr_new = _mla_qkv(params, x, cfg, pos)
    slot = jnp.mod(cache_index, W)
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new, (0, slot, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new, (0, slot, 0))
    n_valid = jnp.minimum(cache_index + 1, W)
    valid = jnp.arange(W)[None, :] < n_valid                      # (1, W)
    # score via the latent space: q_nope projected back through w_uk
    # (B,H,1,hd) x (L,H*hd) -> absorb: q_lat (B,H,L)
    w_uk = params["w_uk"].reshape(-1, H, hd)
    q_lat = jnp.einsum("bhd,lhd->bhl", q_nope[:, :, 0], w_uk)
    s = jnp.einsum("bhl,bwl->bhw", q_lat.astype(jnp.float32),
                   c_kv.astype(jnp.float32))
    s += jnp.einsum("bhr,bwr->bhw", q_rope[:, :, 0].astype(jnp.float32),
                    k_rope.astype(jnp.float32))
    s = s / math.sqrt(hd + R)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhw,bwl->bhl", p, c_kv.astype(jnp.float32))  # latent ctx
    w_uv = params["w_uv"].reshape(-1, H, hd)
    out = jnp.einsum("bhl,lhd->bhd", ctx, w_uv.astype(jnp.float32))
    out = out.reshape(B, 1, H * hd).astype(x.dtype)
    return out @ params["w_o"], {"c_kv": c_kv, "k_rope": k_rope}
