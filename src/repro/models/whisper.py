"""Whisper-style encoder-decoder transformer backbone [arXiv:2212.04356].

Per the assignment carve-out, the mel-spectrogram + conv frontend is a STUB:
``input_specs`` supplies precomputed frame embeddings (B, encoder_seq, D).
This module implements everything downstream — the bidirectional audio
encoder, the causal text decoder with cross-attention, and the decode path
whose cache holds both the self-attention ring buffer and the cross-attention
K/V computed once at prefill.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models.attention import decode_attention, flash_attention
from repro.models.layers import (Params, chunked_softmax_xent, dense_init,
                                 embed_init, init_mlp, mlp, rms_norm,
                                 split_keys)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_xattn(key, cfg: ModelConfig, n_layers: int) -> Params:
    ks = split_keys(key, 4)
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    lead = (n_layers,) if n_layers else ()
    dtype = jnp.dtype(cfg.dtype)
    return {
        "wq": dense_init(ks[0], lead + (D, H * hd), dtype),
        "wk": dense_init(ks[1], lead + (D, KV * hd), dtype),
        "wv": dense_init(ks[2], lead + (D, KV * hd), dtype),
        "wo": dense_init(ks[3], lead + (H * hd, D), dtype),
    }


def init_model(key, cfg: ModelConfig) -> Params:
    ks = split_keys(key, 8)
    dtype = jnp.dtype(cfg.dtype)
    Le, Ld = cfg.encoder_layers, cfg.num_layers
    return {
        "embed": {"w": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model), dtype)},
        "enc_blocks": {
            "attn": attn_lib.init_gqa(ks[1], cfg, Le),
            "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype, Le),
            "ln1": {"w": jnp.ones((Le, cfg.d_model), dtype)},
            "ln2": {"w": jnp.ones((Le, cfg.d_model), dtype)},
        },
        "enc_norm": {"w": jnp.ones((cfg.d_model,), dtype)},
        "dec_blocks": {
            "attn": attn_lib.init_gqa(ks[3], cfg, Ld),
            "xattn": _init_xattn(ks[4], cfg, Ld),
            "mlp": init_mlp(ks[5], cfg.d_model, cfg.d_ff, dtype, Ld),
            "ln1": {"w": jnp.ones((Ld, cfg.d_model), dtype)},
            "lnx": {"w": jnp.ones((Ld, cfg.d_model), dtype)},
            "ln2": {"w": jnp.ones((Ld, cfg.d_model), dtype)},
        },
        "final_norm": {"w": jnp.ones((cfg.d_model,), dtype)},
        "lm_head": {"w": dense_init(ks[6], (cfg.d_model, cfg.padded_vocab),
                                    dtype, scale=0.02)},
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(params: Params, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frames: (B, enc_seq, D) stub embeddings -> encoder hidden states."""
    def body(x, bp):
        h = rms_norm(x, bp["ln1"]["w"], cfg.norm_eps)
        B, S, D = h.shape
        H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        q = (h @ bp["attn"]["wq"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
        k = (h @ bp["attn"]["wk"]).reshape(B, S, KV, hd).transpose(0, 2, 1, 3)
        v = (h @ bp["attn"]["wv"]).reshape(B, S, KV, hd).transpose(0, 2, 1, 3)
        a = flash_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
        a = a.transpose(0, 2, 1, 3).reshape(B, S, H * hd) @ bp["attn"]["wo"]
        x = x + a
        x = x + mlp(bp["mlp"], rms_norm(x, bp["ln2"]["w"], cfg.norm_eps))
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, frames, params["enc_blocks"])
    return rms_norm(x, params["enc_norm"]["w"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------

def _cross_kv(bp: Params, enc_out: jnp.ndarray, cfg: ModelConfig):
    B, Se, D = enc_out.shape
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    xk = (enc_out @ bp["wk"]).reshape(B, Se, KV, hd)
    xv = (enc_out @ bp["wv"]).reshape(B, Se, KV, hd)
    return xk, xv


def _cross_attend(bp: Params, h, xk, xv, cfg: ModelConfig):
    """h: (B, Sq, D); xk/xv: (B, Se, KV, hd) — bidirectional, no rope."""
    B, Sq, D = h.shape
    H, hd = cfg.num_heads, cfg.head_dim
    q = (h @ bp["wq"]).reshape(B, Sq, H, hd).transpose(0, 2, 1, 3)
    k = xk.transpose(0, 2, 1, 3)
    v = xv.transpose(0, 2, 1, 3)
    a = flash_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    return a.transpose(0, 2, 1, 3).reshape(B, Sq, H * hd) @ bp["wo"]


def _dec_block(bp: Params, x, enc_out, cfg: ModelConfig, want_cache: bool):
    a, cache = attn_lib.gqa_forward(bp["attn"],
                                    rms_norm(x, bp["ln1"]["w"], cfg.norm_eps), cfg)
    x = x + a
    xk, xv = _cross_kv(bp["xattn"], enc_out, cfg)
    x = x + _cross_attend(bp["xattn"], rms_norm(x, bp["lnx"]["w"], cfg.norm_eps),
                          xk, xv, cfg)
    x = x + mlp(bp["mlp"], rms_norm(x, bp["ln2"]["w"], cfg.norm_eps))
    full_cache = {**cache, "xk": xk, "xv": xv} if want_cache else None
    return x, full_cache


def decode_stack(params: Params, tokens: jnp.ndarray, enc_out: jnp.ndarray,
                 cfg: ModelConfig, want_cache: bool = False):
    x = params["embed"]["w"][tokens]

    def body(h, bp):
        h, cache = _dec_block(bp, h, enc_out, cfg, want_cache)
        return h, cache

    body_fn = jax.checkpoint(body) if (cfg.remat and not want_cache) else body
    x, caches = jax.lax.scan(body_fn, x, params["dec_blocks"])
    return rms_norm(x, params["final_norm"]["w"], cfg.norm_eps), caches


# ---------------------------------------------------------------------------
# model API
# ---------------------------------------------------------------------------

def loss_fn(params: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig):
    enc_out = encode(params, batch["frames"], cfg)
    x, _ = decode_stack(params, batch["tokens"], enc_out, cfg)
    xent = chunked_softmax_xent(x, params["lm_head"]["w"], batch["labels"],
                                cfg.logit_chunk, valid_vocab=cfg.vocab_size)
    return xent, {"xent": xent}


def prefill(params: Params, tokens: jnp.ndarray, frames: jnp.ndarray,
            cfg: ModelConfig):
    enc_out = encode(params, frames, cfg)
    x, caches = decode_stack(params, tokens, enc_out, cfg, want_cache=True)
    logits = x[:, -1:] @ params["lm_head"]["w"]
    # self-attn cache: (L, B, S, KV, hd); cross: (L, B, Se, KV, hd)
    return logits, caches


def cache_spec(cfg: ModelConfig, batch: int, cache_len: int) -> Dict[str, Any]:
    L = cfg.num_layers
    dtype = jnp.dtype(cfg.dtype)
    W = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    kv = (L, batch, W, cfg.num_kv_heads, cfg.head_dim)
    xkv = (L, batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim)
    return {"k": (kv, dtype), "v": (kv, dtype),
            "xk": (xkv, dtype), "xv": (xkv, dtype)}


def decode_step(params: Params, token: jnp.ndarray, cache, cache_index,
                cfg: ModelConfig):
    """token: (B, 1); cache: stacked {k, v, xk, xv} from prefill/cache_spec."""
    x = params["embed"]["w"][token]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    def body(h, inp):
        bp, bc = inp
        a, new_kv = attn_lib.gqa_decode(
            bp["attn"], rms_norm(h, bp["ln1"]["w"], cfg.norm_eps),
            {"k": bc["k"], "v": bc["v"]}, cache_index, cfg)
        h = h + a
        hq = rms_norm(h, bp["lnx"]["w"], cfg.norm_eps)
        B = hq.shape[0]
        q = (hq @ bp["xattn"]["wq"]).reshape(B, 1, H, hd).transpose(0, 2, 1, 3)
        valid = jnp.ones((B, bc["xk"].shape[1]), bool)
        a = decode_attention(q, bc["xk"].transpose(0, 2, 1, 3),
                             bc["xv"].transpose(0, 2, 1, 3), valid)
        h = h + a.transpose(0, 2, 1, 3).reshape(B, 1, H * hd) @ bp["xattn"]["wo"]
        h = h + mlp(bp["mlp"], rms_norm(h, bp["ln2"]["w"], cfg.norm_eps))
        return h, {**new_kv, "xk": bc["xk"], "xv": bc["xv"]}

    x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))
    x = rms_norm(x, params["final_norm"]["w"], cfg.norm_eps)
    logits = x @ params["lm_head"]["w"]
    return logits, new_cache
