"""RWKV-6 "Finch" — attention-free time-mix with data-dependent decay.

The WKV recurrence per head (state S in R^{hd x hd}):

    y_t = r_t @ (S_{t-1} + (u * k_t) v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          w_t = exp(-exp(w0 + lora(x)))

TPU-native chunked form: a ``lax.scan`` over chunks carries S; inside a
chunk the pairwise decay tensor ``exp(Lx[t]-L[j])`` (always <= 1, so fp32
underflow is the *correct* limit — no logspace ratio explosions) gives an
intra-chunk "decay-weighted attention" einsum that maps onto the MXU.

Token-shift is the static-mix variant (the data-dependent *decay* — the
Finch headline feature — is kept; the dynamic token-shift LoRA is
simplified to learned static interpolation, noted in DESIGN.md).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (Params, chunked_softmax_xent, dense_init,
                                 embed_init, rms_norm, split_keys)

DECAY_LORA = 64


def head_dims(cfg: ModelConfig) -> Tuple[int, int]:
    hd = cfg.ssm.head_dim
    return cfg.d_model // hd, hd


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_time_mix(key, cfg: ModelConfig, n_layers: int = 0) -> Params:
    D = cfg.d_model
    ks = split_keys(key, 7)
    lead = (n_layers,) if n_layers else ()
    dtype = jnp.dtype(cfg.dtype)
    # per-channel decay-speed init (RWKV convention): slow channels keep
    # long-range state, fast channels decay within a few tokens
    ratio = jnp.arange(D, dtype=jnp.float32) / max(D - 1, 1)
    w0 = -6.0 + 5.0 * ratio ** 0.7
    return {
        "w_r": dense_init(ks[0], lead + (D, D), dtype),
        "w_k": dense_init(ks[1], lead + (D, D), dtype),
        "w_v": dense_init(ks[2], lead + (D, D), dtype),
        "w_g": dense_init(ks[3], lead + (D, D), dtype),
        "w_o": dense_init(ks[4], lead + (D, D), dtype),
        "w_decay": w0 * jnp.ones(lead + (D,), jnp.float32),
        "w_decay_lora_a": dense_init(ks[5], lead + (D, DECAY_LORA), dtype, scale=0.01),
        "w_decay_lora_b": dense_init(ks[6], lead + (DECAY_LORA, D), dtype, scale=0.01),
        "u_bonus": jnp.zeros(lead + (D,), jnp.float32),
        "mix": 0.5 * jnp.ones(lead + (5, D), jnp.float32),   # r,k,v,w,g
        "ln_x": jnp.ones(lead + (D,), dtype),                # per-head group norm
    }


def init_channel_mix(key, cfg: ModelConfig, n_layers: int = 0) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 3)
    lead = (n_layers,) if n_layers else ()
    dtype = jnp.dtype(cfg.dtype)
    return {
        "wr_ch": dense_init(ks[0], lead + (D, D), dtype),
        "wk_ch": dense_init(ks[1], lead + (D, F), dtype),
        "wv_ch": dense_init(ks[2], lead + (F, D), dtype),
        "mix_ch": 0.5 * jnp.ones(lead + (2, D), jnp.float32),  # r,k
    }


def init_model(key, cfg: ModelConfig) -> Params:
    ks = split_keys(key, 5)
    dtype = jnp.dtype(cfg.dtype)
    L = cfg.num_layers
    return {
        "embed": {"w": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model), dtype)},
        "blocks": {
            "ln1": {"w": jnp.ones((L, cfg.d_model), dtype)},
            "ln2": {"w": jnp.ones((L, cfg.d_model), dtype)},
            "rwkv": init_time_mix(ks[1], cfg, L),
            "cmix": init_channel_mix(ks[2], cfg, L),
        },
        "final_norm": {"w": jnp.ones((cfg.d_model,), dtype)},
        "lm_head": {"w": dense_init(ks[3], (cfg.d_model, cfg.padded_vocab),
                                    dtype, scale=0.02)},
    }


# ---------------------------------------------------------------------------
# WKV chunked kernel (pure jnp reference; Pallas version in repro.kernels.wkv)
# ---------------------------------------------------------------------------

def wkv_chunked(r, k, v, logw, u, s0, chunk: int):
    """r,k,v,logw: (B, S, H, hd) fp32 (logw <= 0); u: (H, hd);
    s0: (B, H, hd, hd).  Returns (y (B,S,H,hd), s_final)."""
    B, S, H, hd = r.shape
    if S % chunk != 0:
        chunk = S
    nc = S // chunk
    rc = r.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    wc = logw.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)          # j < t

    def step(s, inp):
        rr, kk, vv, ww = inp                         # (B, C, H, hd)
        L = jnp.cumsum(ww, axis=1)                   # inclusive
        Lx = L - ww                                  # exclusive
        # pairwise decay exp(Lx[t]-L[j]) <= 1 for j < t  (B,H,t,j,hd)
        dec = jnp.exp(jnp.clip(
            Lx.transpose(0, 2, 1, 3)[:, :, :, None, :]
            - L.transpose(0, 2, 1, 3)[:, :, None, :, :], -60.0, 0.0))
        scores = jnp.einsum("bthd,bjhd,bhtjd->bhtj",
                            rr, kk, dec, optimize=True)
        scores = scores * tri[None, None]
        diag = jnp.einsum("bthd,hd,bthd->bth", rr, u, kk)
        y = jnp.einsum("bhtj,bjhd->bthd", scores, vv)
        y += diag[..., None] * vv
        # carried-state contribution and state update
        y += jnp.einsum("bthd,bhde->bthe", rr * jnp.exp(Lx), s)
        k_dec = kk * jnp.exp(L[:, -1:] - L)          # exp <= 1
        s_new = s * jnp.exp(L[:, -1])[..., None] \
            + jnp.einsum("bjhd,bjhe->bhde", k_dec, vv)
        return s_new, y

    s_final, yc = jax.lax.scan(step, s0, (rc, kc, vc, wc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return y, s_final


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _wkv_pallas_cv(r, k, v, logw, u, s0, chunk):
    """Pallas WKV forward with the chunked-jnp path's gradients."""
    from repro.kernels.wkv import wkv_pallas
    tr = lambda x: x.transpose(0, 2, 1, 3)
    y, s_f = wkv_pallas(tr(r), tr(k), tr(v), tr(logw), u, s0, chunk=chunk,
                        interpret=jax.default_backend() != "tpu")
    return tr(y), s_f


def _wkv_cv_fwd(r, k, v, logw, u, s0, chunk):
    return _wkv_pallas_cv(r, k, v, logw, u, s0, chunk), (r, k, v, logw, u, s0)


def _wkv_cv_bwd(chunk, res, g):
    r, k, v, logw, u, s0 = res
    _, vjp = jax.vjp(
        lambda *a: wkv_chunked(*a, chunk), r, k, v, logw, u, s0)
    return vjp(g)


_wkv_pallas_cv.defvjp(_wkv_cv_fwd, _wkv_cv_bwd)


def _token_shift(x, prev):
    """x: (B, S, D); prev: (B, D) last token of the previous segment."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _decay(params, xw):
    lora = jnp.tanh(xw @ params["w_decay_lora_a"]) @ params["w_decay_lora_b"]
    return -jnp.exp(params["w_decay"] + lora.astype(jnp.float32))  # logw <= 0


def _group_norm(y, weight, H, eps=1e-5):
    """Per-head RMS norm over hd; y: (B, S, H, hd) fp32."""
    B, S, _, hd = y.shape
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + eps)
    return y.reshape(B, S, H * hd) * weight


def time_mix(params: Params, x: jnp.ndarray, cfg: ModelConfig,
             state: Dict[str, jnp.ndarray] | None = None):
    """x: (B, S, D) -> (out, {state, tm_x})."""
    B, S, D = x.shape
    H, hd = head_dims(cfg)
    prev = state["tm_x"] if state is not None else jnp.zeros((B, D), x.dtype)
    xs = _token_shift(x, prev)
    mu = params["mix"].astype(x.dtype)                  # (5, D)
    mr, mk, mv, mw, mg = (x + mu[i] * (xs - x) for i in range(5))
    r = (mr @ params["w_r"]).reshape(B, S, H, hd).astype(jnp.float32)
    k = (mk @ params["w_k"]).reshape(B, S, H, hd).astype(jnp.float32)
    v = (mv @ params["w_v"]).reshape(B, S, H, hd).astype(jnp.float32)
    g = jax.nn.silu(mg @ params["w_g"])
    logw = _decay(params, mw).reshape(B, S, H, hd)
    u = params["u_bonus"].reshape(H, hd)
    s0 = (state["state"] if state is not None
          else jnp.zeros((B, H, hd, hd), jnp.float32))
    from repro.models.attention import use_pallas
    if use_pallas(cfg) and S > 1 and S % cfg.ssm.chunk_size == 0:
        y, s_final = _wkv_pallas_cv(r, k, v, logw, u, s0, cfg.ssm.chunk_size)
    else:
        y, s_final = wkv_chunked(r, k, v, logw, u, s0, cfg.ssm.chunk_size)
    y = _group_norm(y, params["ln_x"].astype(jnp.float32), H)
    out = (y.astype(x.dtype) * g) @ params["w_o"]
    return out, {"state": s_final, "tm_x": x[:, -1]}


def channel_mix(params: Params, x: jnp.ndarray,
                state: Dict[str, jnp.ndarray] | None = None):
    B, S, D = x.shape
    prev = state["cm_x"] if state is not None else jnp.zeros((B, D), x.dtype)
    xs = _token_shift(x, prev)
    mu = params["mix_ch"].astype(x.dtype)
    mr, mk = (x + mu[i] * (xs - x) for i in range(2))
    r = jax.nn.sigmoid(mr @ params["wr_ch"])
    kk = jnp.square(jax.nn.relu(mk @ params["wk_ch"]))
    return r * (kk @ params["wv_ch"]), {"cm_x": x[:, -1]}


def _block(bp: Params, x, cfg: ModelConfig, state=None):
    tm_state = ({"state": state["state"], "tm_x": state["tm_x"]}
                if state is not None else None)
    a, tm_new = time_mix(bp["rwkv"], rms_norm(x, bp["ln1"]["w"], cfg.norm_eps),
                         cfg, tm_state)
    x = x + a
    cm_state = {"cm_x": state["cm_x"]} if state is not None else None
    c, cm_new = channel_mix(bp["cmix"], rms_norm(x, bp["ln2"]["w"], cfg.norm_eps),
                            cm_state)
    return x + c, {**tm_new, **cm_new}


# ---------------------------------------------------------------------------
# model API
# ---------------------------------------------------------------------------

def forward(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
            want_state: bool = False, state=None):
    x = params["embed"]["w"][tokens]

    def body(carry, inp):
        h = carry
        lp, lst = inp
        h, new_state = _block(lp, h, cfg, lst)
        return h, (new_state if want_state else None)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if state is None:
        B = tokens.shape[0]
        H, hd = head_dims(cfg)
        L, D = cfg.num_layers, cfg.d_model
        state = {"state": jnp.zeros((L, B, H, hd, hd), jnp.float32),
                 "tm_x": jnp.zeros((L, B, D), x.dtype),
                 "cm_x": jnp.zeros((L, B, D), x.dtype)}
    x, new_state = jax.lax.scan(body_fn, x, (params["blocks"], state))
    x = rms_norm(x, params["final_norm"]["w"], cfg.norm_eps)
    return x, new_state


def loss_fn(params: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig):
    x, _ = forward(params, batch["tokens"], cfg)
    xent = chunked_softmax_xent(x, params["lm_head"]["w"], batch["labels"],
                                cfg.logit_chunk, valid_vocab=cfg.vocab_size)
    return xent, {"xent": xent}


def prefill(params: Params, tokens: jnp.ndarray, cfg: ModelConfig):
    x, state = forward(params, tokens, cfg, want_state=True)
    logits = x[:, -1:] @ params["lm_head"]["w"]
    return logits, state


def decode_step(params: Params, token: jnp.ndarray, cache, cache_index,
                cfg: ModelConfig):
    """token: (B, 1).  The recurrent state is O(1) in sequence length —
    cache_index is unused (kept for API uniformity)."""
    x, new_state = forward(params, token, cfg, want_state=True, state=cache)
    logits = x[:, -1:] @ params["lm_head"]["w"]
    return logits, new_state


def cache_spec(cfg: ModelConfig, batch: int):
    H, hd = head_dims(cfg)
    L, D = cfg.num_layers, cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    return {"state": ((L, batch, H, hd, hd), jnp.dtype(jnp.float32)),
            "tm_x": ((L, batch, D), dtype),
            "cm_x": ((L, batch, D), dtype)}
