"""Jamba — hybrid Mamba + attention (1:7 interleave) with MoE every other
layer [arXiv:2403.19887].

The 32-layer stack is 4 *super-blocks* of ``hybrid_block_layers`` (8)
layers.  Layer kinds inside a super-block are heterogeneous (one attention
layer at position ``hybrid_attn_period // 2``, Mamba elsewhere; MoE FFN on
odd positions), so parameters are stored per-position and stacked over the
super-block axis, and ``lax.scan`` runs over super-blocks with the eight
heterogeneous layers unrolled in the body — 60-layer-class models lower to
a compact HLO while keeping the 1:7 mixer pattern exact.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models.layers import (Params, chunked_softmax_xent, dense_init,
                                 embed_init, init_mlp, mlp, rms_norm,
                                 split_keys)


def block_layout(cfg: ModelConfig):
    """[(mixer, use_moe)] for one super-block (matches core.flops)."""
    out = []
    for i in range(cfg.hybrid_block_layers):
        mixer = "attn" if i == cfg.hybrid_attn_period // 2 else "mamba"
        use_moe = cfg.moe is not None and (i % cfg.moe.every == 1)
        out.append((mixer, use_moe))
    return out


def n_super_blocks(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.hybrid_block_layers


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, mixer: str, use_moe: bool, nb: int) -> Params:
    ks = split_keys(key, 2)
    dtype = jnp.dtype(cfg.dtype)
    p: Params = {
        "ln1": {"w": jnp.ones((nb, cfg.d_model), dtype)},
        "ln2": {"w": jnp.ones((nb, cfg.d_model), dtype)},
    }
    if mixer == "attn":
        p["attn"] = attn_lib.init_gqa(ks[0], cfg, nb)
    else:
        p["ssm"] = mamba_lib.init_mamba(ks[0], cfg, nb)
    if use_moe:
        p["moe"] = moe_lib.init_moe(ks[1], cfg, nb)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype, nb)
    return p


def init_model(key, cfg: ModelConfig) -> Params:
    nb = n_super_blocks(cfg)
    layout = block_layout(cfg)
    ks = split_keys(key, len(layout) + 3)
    dtype = jnp.dtype(cfg.dtype)
    blocks = {f"l{i}": _init_layer(ks[i], cfg, m, moe, nb)
              for i, (m, moe) in enumerate(layout)}
    return {
        "embed": {"w": embed_init(ks[-3], (cfg.padded_vocab, cfg.d_model), dtype)},
        "blocks": blocks,
        "final_norm": {"w": jnp.ones((cfg.d_model,), dtype)},
        "lm_head": {"w": dense_init(ks[-2], (cfg.d_model, cfg.padded_vocab),
                                    dtype, scale=0.02)},
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer_forward(lp: Params, x, cfg: ModelConfig, mixer: str, use_moe: bool,
                   q_offset: int = 0):
    h = rms_norm(x, lp["ln1"]["w"], cfg.norm_eps)
    if mixer == "attn":
        a, cache = attn_lib.gqa_forward(lp["attn"], h, cfg, q_offset)
    else:
        a, cache = mamba_lib.mamba_mixer(lp["ssm"], h, cfg)
    x = x + a
    h = rms_norm(x, lp["ln2"]["w"], cfg.norm_eps)
    if use_moe:
        m, aux = moe_lib.moe_block(lp["moe"], h, cfg)
    else:
        m, aux = mlp(lp["mlp"], h), {}
    return x + m, aux, cache


def forward(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
            want_cache: bool = False):
    x = params["embed"]["w"][tokens]
    layout = block_layout(cfg)

    def body(carry, bp):
        h, aux_acc = carry
        caches = {}
        for i, (mixer, use_moe) in enumerate(layout):
            h, aux, cache = _layer_forward(bp[f"l{i}"], h, cfg, mixer, use_moe)
            if aux:
                aux_acc = aux_acc + sum(aux.values())
            if want_cache:
                caches[f"l{i}"] = cache
        return (h, aux_acc), (caches if want_cache else None)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), caches = jax.lax.scan(
        body_fn, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    x = rms_norm(x, params["final_norm"]["w"], cfg.norm_eps)
    return x, aux, caches


def loss_fn(params: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig):
    x, aux, _ = forward(params, batch["tokens"], cfg)
    xent = chunked_softmax_xent(x, params["lm_head"]["w"], batch["labels"],
                                cfg.logit_chunk, valid_vocab=cfg.vocab_size)
    return xent + aux, {"xent": xent, "aux": aux}


def prefill(params: Params, tokens: jnp.ndarray, cfg: ModelConfig):
    """Returns last-position logits + decode cache.

    Attention caches from the chunked forward hold full-sequence K/V; Mamba
    caches are the O(1) (conv, ssm) states.
    """
    x, _, caches = forward(params, tokens, cfg, want_cache=True)
    logits = x[:, -1:] @ params["lm_head"]["w"]
    # attn caches come back as (B, S, KV, hd) per super-block laye stacked
    return logits, caches


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def cache_spec(cfg: ModelConfig, batch: int, cache_len: int) -> Dict[str, Any]:
    nb = n_super_blocks(cfg)
    dtype = jnp.dtype(cfg.dtype)
    W = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    spec: Dict[str, Any] = {}
    for i, (mixer, _) in enumerate(block_layout(cfg)):
        if mixer == "attn":
            spec[f"l{i}"] = {
                "k": ((nb, batch, W, cfg.num_kv_heads, cfg.head_dim), dtype),
                "v": ((nb, batch, W, cfg.num_kv_heads, cfg.head_dim), dtype)}
        else:
            s = mamba_lib.state_spec(cfg, batch)
            spec[f"l{i}"] = {k: ((nb,) + v[0], v[1]) for k, v in s.items()}
    return spec


def _layer_decode(lp: Params, x, cache, cache_index, cfg: ModelConfig,
                  mixer: str, use_moe: bool):
    h = rms_norm(x, lp["ln1"]["w"], cfg.norm_eps)
    if mixer == "attn":
        a, new_cache = attn_lib.gqa_decode(lp["attn"], h, cache, cache_index, cfg)
    else:
        a, new_cache = mamba_lib.mamba_decode(lp["ssm"], h, cache, cfg)
    x = x + a
    h = rms_norm(x, lp["ln2"]["w"], cfg.norm_eps)
    if use_moe:
        m, _ = moe_lib.moe_block(lp["moe"], h, cfg)
    else:
        m = mlp(lp["mlp"], h)
    return x + m, new_cache


def decode_step(params: Params, token: jnp.ndarray, cache, cache_index,
                cfg: ModelConfig):
    x = params["embed"]["w"][token]
    layout = block_layout(cfg)

    def body(h, inp):
        bp, bc = inp
        new_caches = {}
        for i, (mixer, use_moe) in enumerate(layout):
            h, nc = _layer_decode(bp[f"l{i}"], h, bc[f"l{i}"], cache_index,
                                  cfg, mixer, use_moe)
            new_caches[f"l{i}"] = nc
        return h, new_caches

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = rms_norm(x, params["final_norm"]["w"], cfg.norm_eps)
    logits = x @ params["lm_head"]["w"]
    return logits, new_cache
