"""Core pure-JAX building blocks shared by every architecture.

All parameters are plain nested dicts of ``jnp.ndarray`` so they compose with
``jax.tree_util``, pjit partitioning and the bucketed grad-sync in
``repro.parallel.grad_sync``.  Initializers take an explicit PRNG key.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (LeCun style)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, d) with d even; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2].astype(jnp.float32), x[..., 1::2].astype(jnp.float32)
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype, n_layers: int = 0) -> Params:
    """SwiGLU MLP; stacked over a leading layer dim when n_layers > 0."""
    ks = split_keys(key, 3)
    lead = (n_layers,) if n_layers else ()
    return {
        "wi": dense_init(ks[0], lead + (d_model, d_ff), dtype),
        "wg": dense_init(ks[1], lead + (d_model, d_ff), dtype),
        "wo": dense_init(ks[2], lead + (d_ff, d_model), dtype),
    }


def mlp(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])
    return h @ params["wo"]


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def chunked_softmax_xent(x: jnp.ndarray, lm_head: jnp.ndarray,
                         labels: jnp.ndarray, chunk: int = 512,
                         valid_vocab: int = 0) -> jnp.ndarray:
    """Cross-entropy over a huge vocab without materializing (B,S,V).

    x: (B, S, D) final hidden states; lm_head: (D, V); labels: (B, S) int32.
    Scans over S in blocks of ``chunk``; each block computes logits, the
    logsumexp and the target logit, discarding the block logits afterwards.
    ``valid_vocab``: mask logits for padded vocab rows >= this (0 = all valid).
    """
    B, S, D = x.shape
    V = lm_head.shape[-1]
    vocab_mask = (jnp.arange(V) >= valid_vocab) if (valid_vocab and valid_vocab < V) else None
    if S % chunk != 0:
        chunk = S  # fall back to one block for odd smoke shapes
    n_blocks = S // chunk
    xb = x.reshape(B, n_blocks, chunk, D).transpose(1, 0, 2, 3)
    lb = labels.reshape(B, n_blocks, chunk).transpose(1, 0, 2)

    def block(carry, inp):
        xc, lc = inp                                  # (B, chunk, D), (B, chunk)
        logits = (xc @ lm_head).astype(jnp.float32)   # (B, chunk, V)
        if vocab_mask is not None:
            logits = jnp.where(vocab_mask, -1e30, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - tgt), None

    total, _ = jax.lax.scan(block, jnp.zeros((), jnp.float32), (xb, lb))
    return total / (B * S)


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def count_params(params: Params) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


def param_bytes(params: Params) -> int:
    return sum(int(p.size) * p.dtype.itemsize for p in jax.tree_util.tree_leaves(params))


def cast_tree(params: Params, dtype) -> Params:
    return jax.tree_util.tree_map(
        lambda p: p.astype(dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
