"""Mamba (S6) selective-state-space mixer — the SSM half of Jamba.

TPU-native adaptation: the recurrence is *chunked* — a ``lax.scan`` over
chunks of ``cfg.ssm.chunk_size`` tokens carries the (d_inner, d_state)
state, and inside each chunk a ``lax.associative_scan`` (logarithmic depth,
maps onto the VPU) computes the per-token states.  This bounds the live
activation to one (B, C, d_inner, d_state) block instead of the full
sequence, which is what lets ``long_500k`` lower.

Parameter names match the sharding rules in ``repro.parallel.sharding``
(everything hangs off an ``"ssm"`` subtree; d_inner is the `model`-sharded
axis).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_init, split_keys


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm.expand * cfg.d_model
    dt_rank = cfg.ssm.dt_rank or max(cfg.d_model // 16, 1)
    return d_inner, dt_rank, cfg.ssm.d_state, cfg.ssm.d_conv


def init_mamba(key, cfg: ModelConfig, n_layers: int = 0) -> Params:
    di, dt_rank, n, d_conv = dims(cfg)
    D = cfg.d_model
    ks = split_keys(key, 6)
    lead = (n_layers,) if n_layers else ()
    dtype = jnp.dtype(cfg.dtype)
    # S4D-real initialization for A; dt bias spread over [1e-3, 1e-1]
    a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
    dt_init = jnp.exp(
        jax.random.uniform(ks[4], lead + (di,), jnp.float32)
        * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))      # inverse softplus
    return {
        "w_in": dense_init(ks[0], lead + (D, 2 * di), dtype),
        "conv_w": dense_init(ks[1], lead + (d_conv, di), dtype, scale=0.5),
        "conv_b": jnp.zeros(lead + (di,), dtype),
        "w_bcdt": dense_init(ks[2], lead + (di, dt_rank + 2 * n), dtype),
        "w_dt": dense_init(ks[3], lead + (dt_rank, di), dtype),
        "dt_bias": dt_bias.astype(jnp.float32) * jnp.ones(lead + (di,), jnp.float32),
        "a_log": jnp.log(a) * jnp.ones(lead + (di, n), jnp.float32),
        "d_skip": jnp.ones(lead + (di,), jnp.float32),
        "w_out": dense_init(ks[5], lead + (di, D), dtype),
    }


# ---------------------------------------------------------------------------
# selective scan (chunked)
# ---------------------------------------------------------------------------

def _ssm_scan_chunked(decay, bx, h0, chunk: int):
    """h_t = decay_t * h_{t-1} + bx_t, computed chunk-at-a-time.

    decay, bx: (B, S, di, n) fp32; h0: (B, di, n).
    Returns (y_states (B, S, di, n), h_final).
    """
    B, S, di, n = decay.shape
    if S % chunk != 0:
        chunk = S
    nc = S // chunk
    dc = decay.reshape(B, nc, chunk, di, n).transpose(1, 0, 2, 3, 4)
    bc = bx.reshape(B, nc, chunk, di, n).transpose(1, 0, 2, 3, 4)

    def combine(a, b):
        return (a[0] * b[0], a[1] * b[0] + b[1])

    def step(h, inp):
        d, b = inp                                     # (B, chunk, di, n)
        cum_d, inner = jax.lax.associative_scan(combine, (d, b), axis=1)
        states = inner + cum_d * h[:, None]
        return states[:, -1], states

    h_final, states = jax.lax.scan(step, h0, (dc, bc))
    states = states.transpose(1, 0, 2, 3, 4).reshape(B, S, di, n)
    return states, h_final


def _ssm_scan_chunked_fused_y(decay, bx, c_t, h0, chunk: int):
    """§Perf variant (``cfg.mamba_fused_y``): contract the d_state axis
    against C inside the chunk step, so the scan emits y chunks
    (B, C, di) instead of state chunks (B, C, di, n) — an n-fold (16x)
    reduction of the scan's stacked output and its backward residual.

    decay, bx: (B, S, di, n); c_t: (B, S, n); h0: (B, di, n).
    Returns (y (B, S, di), h_final).
    """
    B, S, di, n = decay.shape
    if S % chunk != 0:
        chunk = S
    nc = S // chunk
    dc = decay.reshape(B, nc, chunk, di, n).transpose(1, 0, 2, 3, 4)
    bc = bx.reshape(B, nc, chunk, di, n).transpose(1, 0, 2, 3, 4)
    cc = c_t.reshape(B, nc, chunk, n).transpose(1, 0, 2, 3)

    def combine(a, b):
        return (a[0] * b[0], a[1] * b[0] + b[1])

    def step(h, inp):
        d, b, ct = inp
        cum_d, inner = jax.lax.associative_scan(combine, (d, b), axis=1)
        states = inner + cum_d * h[:, None]
        y = jnp.einsum("bcdn,bcn->bcd", states, ct)
        return states[:, -1], y

    h_final, yc = jax.lax.scan(step, h0, (dc, bc, cc))
    y = yc.transpose(1, 0, 2, 3).reshape(B, S, di)
    return y, h_final


def _ssm_scan_seq_fused_y(decay, bx, c_t, h0):
    """§Perf variant (``mamba_scan_impl="seq"`` + fused y): one sequential
    ``lax.scan`` over time with the (B, di, n) state carried in
    VMEM/registers.  ~3 HBM passes over (B, S, di, n) (read decay, read bx,
    write y/di-only) vs ~2*log2(C) for the associative scan's pad/slice
    cascade.  The Pallas deployment kernel (repro.kernels.ssm_scan) is the
    same dataflow with explicit VMEM tiling.

    Returns (y (B, S, di), h_final).
    """
    def step(h, inp):
        d, b, ct = inp                        # (B, di, n), (B, di, n), (B, n)
        h = d * h + b
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    h_final, ys = jax.lax.scan(
        step, h0, (decay.transpose(1, 0, 2, 3), bx.transpose(1, 0, 2, 3),
                   c_t.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2), h_final


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _ssm_pallas_cv(decay, bx, c_t, h0, chunk):
    """Pallas selective scan with the chunked-jnp path's gradients
    (recompute in backward) — same pattern as the flash-attention dispatch."""
    from repro.kernels.ssm_scan import ssm_scan_pallas
    y, h = ssm_scan_pallas(decay.transpose(0, 1, 3, 2),
                           bx.transpose(0, 1, 3, 2), c_t,
                           h0.transpose(0, 2, 1), chunk=chunk,
                           interpret=jax.default_backend() != "tpu")
    return y, h.transpose(0, 2, 1)


def _ssm_cv_fwd(decay, bx, c_t, h0, chunk):
    return _ssm_pallas_cv(decay, bx, c_t, h0, chunk), (decay, bx, c_t, h0)


def _ssm_cv_bwd(chunk, res, g):
    decay, bx, c_t, h0 = res
    _, vjp = jax.vjp(
        lambda d, b, c, h: _ssm_scan_chunked_fused_y(d, b, c, h, chunk),
        decay, bx, c_t, h0)
    return vjp(g)


_ssm_pallas_cv.defvjp(_ssm_cv_fwd, _ssm_cv_bwd)


def _use_pallas_scan(cfg, S, di) -> bool:
    from repro.models.attention import use_pallas
    return (use_pallas(cfg) and S > 1 and S % cfg.ssm.chunk_size == 0
            and di % 128 == 0)


def _depthwise_conv(x, w, b, prev=None):
    """Causal depthwise conv.  x: (B, S, di); w: (d_conv, di); prev: (B, d_conv-1, di)
    left-context (zeros for a fresh sequence).  Returns (y, new_prev)."""
    d_conv = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], d_conv - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(d_conv)) + b
    return y, xp[:, -(d_conv - 1):]


def mamba_mixer(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                state: Dict[str, jnp.ndarray] | None = None
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence mixer.  x: (B, S, D) -> (out (B, S, D), final state)."""
    B, S, D = x.shape
    di, dt_rank, n, d_conv = dims(cfg)
    xz = x @ params["w_in"]                              # (B, S, 2*di)
    xs, z = xz[..., :di], xz[..., di:]
    prev = state["conv"] if state is not None else None
    xs, conv_state = _depthwise_conv(xs, params["conv_w"], params["conv_b"], prev)
    xs = jax.nn.silu(xs)

    bcdt = xs @ params["w_bcdt"]                         # (B, S, dt_rank+2n)
    dt = jax.nn.softplus(
        (bcdt[..., :dt_rank] @ params["w_dt"]).astype(jnp.float32)
        + params["dt_bias"])                             # (B, S, di)
    b_t = bcdt[..., dt_rank:dt_rank + n].astype(jnp.float32)
    c_t = bcdt[..., dt_rank + n:].astype(jnp.float32)

    a = -jnp.exp(params["a_log"])                        # (di, n)
    decay = jnp.exp(dt[..., None] * a)                   # (B, S, di, n)
    bx = (dt * xs.astype(jnp.float32))[..., None] * b_t[:, :, None, :]
    h0 = (state["ssm"] if state is not None
          else jnp.zeros((B, di, n), jnp.float32))
    if cfg.bf16_stream:
        # §Perf: halve the scan's HBM traffic; decays are products of
        # values <= 1 (bf16-safe) and bx accumulates over one chunk only
        decay, bx, c_t = (t.astype(jnp.bfloat16) for t in (decay, bx, c_t))
        h0 = h0.astype(jnp.bfloat16)
    if _use_pallas_scan(cfg, S, di):
        y, h_final = _ssm_pallas_cv(decay, bx, c_t, h0, cfg.ssm.chunk_size)
    elif cfg.mamba_scan_impl == "seq":
        y, h_final = _ssm_scan_seq_fused_y(decay, bx, c_t, h0)
    elif cfg.mamba_fused_y:
        y, h_final = _ssm_scan_chunked_fused_y(decay, bx, c_t, h0,
                                               cfg.ssm.chunk_size)
    else:
        states, h_final = _ssm_scan_chunked(decay, bx, h0, cfg.ssm.chunk_size)
        y = jnp.einsum("bsdn,bsn->bsd", states, c_t)
    y = y.astype(jnp.float32) + params["d_skip"] * xs.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ params["w_out"]
    return out, {"conv": conv_state, "ssm": h_final.astype(jnp.float32)}


def mamba_decode(params: Params, x: jnp.ndarray, state: Dict[str, jnp.ndarray],
                 cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token step.  x: (B, 1, D); state: conv (B, d_conv-1, di), ssm (B, di, n)."""
    out, new_state = mamba_mixer(params, x, cfg, state=state)
    return out, new_state


def state_spec(cfg: ModelConfig, batch: int):
    di, _, n, d_conv = dims(cfg)
    dtype = jnp.dtype(cfg.dtype)
    return {"conv": ((batch, d_conv - 1, di), dtype),
            "ssm": ((batch, di, n), jnp.dtype(jnp.float32))}
