"""Decoder-only transformer stack covering the dense and MoE families.

Layers are stacked along a leading axis and driven by ``jax.lax.scan`` so
that 60-layer configs lower to a compact HLO.  The same stack is reused by
the VLM wrapper (prefix embeddings) and — with its own mixers — by the
hybrid/SSM stacks.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models.layers import (Params, chunked_softmax_xent, dense_init,
                                 embed_init, init_mlp, mlp, rms_norm,
                                 split_keys)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: ModelConfig, n_layers: int):
    if cfg.attention == "mla":
        return attn_lib.init_mla(key, cfg, n_layers)
    return attn_lib.init_gqa(key, cfg, n_layers)


def _init_block_stack(key, cfg: ModelConfig, n_layers: int, use_moe: bool) -> Params:
    ks = split_keys(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    lead = (n_layers,) if n_layers else ()
    p = {
        "attn": _init_attn(ks[0], cfg, n_layers),
        "ln1": {"w": jnp.ones(lead + (cfg.d_model,), dtype)},
        "ln2": {"w": jnp.ones(lead + (cfg.d_model,), dtype)},
    }
    if use_moe:
        p["moe"] = moe_lib.init_moe(ks[1], cfg, n_layers)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype, n_layers)
    return p


def init_decoder(key, cfg: ModelConfig) -> Params:
    ks = split_keys(key, 5)
    dtype = jnp.dtype(cfg.dtype)
    n_dense_first = cfg.moe.first_dense if cfg.moe else 0
    n_scan = cfg.num_layers - n_dense_first
    params: Params = {
        "embed": {"w": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model), dtype)},
        "final_norm": {"w": jnp.ones((cfg.d_model,), dtype)},
        "blocks": _init_block_stack(ks[1], cfg, n_scan, use_moe=cfg.moe is not None),
    }
    if n_dense_first:
        params["first_blocks"] = _init_block_stack(ks[2], cfg, n_dense_first,
                                                   use_moe=False)
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": dense_init(ks[3], (cfg.d_model, cfg.padded_vocab),
                                             dtype, scale=0.02)}
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _block_forward(bp: Params, x, cfg: ModelConfig, use_moe: bool, q_offset: int = 0):
    """One transformer layer.  Returns (x, aux, cache)."""
    if cfg.attention == "mla":
        a, cache = attn_lib.mla_forward(bp["attn"], rms_norm(x, bp["ln1"]["w"], cfg.norm_eps),
                                        cfg, q_offset)
    else:
        a, cache = attn_lib.gqa_forward(bp["attn"], rms_norm(x, bp["ln1"]["w"], cfg.norm_eps),
                                        cfg, q_offset)
    x = x + a
    h = rms_norm(x, bp["ln2"]["w"], cfg.norm_eps)
    if use_moe:
        m, aux = moe_lib.moe_block(bp["moe"], h, cfg)
    else:
        m, aux = mlp(bp["mlp"], h), {}
    return x + m, aux, cache


def forward(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
            prefix_embeds: Optional[jnp.ndarray] = None, want_cache: bool = False
            ) -> Tuple[jnp.ndarray, jnp.ndarray, Any]:
    """tokens: (B, S) int32 -> (hidden (B,S,D), aux_loss scalar, caches)."""
    x = params["embed"]["w"][tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    aux_total = jnp.zeros((), jnp.float32)
    caches = {}

    # sequence parallelism (§Perf): keep the residual stream sharded
    # (batch over data, S over model) between layers so norms/MLP run on
    # S-shards and the remat residual is saved sharded — kills the
    # full-D all-gathers in backward.
    sp_axes = tuple(a for a in cfg.seq_parallel.split(",") if a)
    sp_spec = P(sp_axes if sp_axes else None, "model", None)

    def run_stack(x, stack, n_layers, use_moe, name):
        nonlocal aux_total, caches

        def body(carry, lp):
            h, aux_acc = carry
            if cfg.seq_parallel:
                h = jax.lax.with_sharding_constraint(h, sp_spec)
            h, aux, cache = _block_forward(lp, h, cfg, use_moe)
            if cfg.seq_parallel:
                h = jax.lax.with_sharding_constraint(h, sp_spec)
            aux_acc = aux_acc + sum(aux.values()) if aux else aux_acc
            out = cache if want_cache else None
            return (h, aux_acc), out

        if cfg.remat and cfg.remat_policy == "dots":
            body_fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        elif cfg.remat:
            body_fn = jax.checkpoint(body)
        else:
            body_fn = body
        if cfg.scan_layers and n_layers > 1:
            (x, aux_acc), cache = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)), stack)
        else:
            aux_acc = jnp.zeros((), jnp.float32)
            cache_list = []
            for i in range(n_layers):
                lp = jax.tree_util.tree_map(lambda p: p[i], stack) if n_layers > 1 else (
                    jax.tree_util.tree_map(lambda p: p[0], stack))
                (x, aux_acc), c = body_fn((x, aux_acc), lp)
                cache_list.append(c)
            cache = (jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *cache_list)
                     if want_cache else None)
        aux_total = aux_total + aux_acc
        if want_cache:
            caches[name] = cache
        return x

    if "first_blocks" in params:
        n_first = cfg.moe.first_dense
        x = run_stack(x, params["first_blocks"], n_first, False, "first_blocks")
    n_scan = cfg.num_layers - (cfg.moe.first_dense if cfg.moe else 0)
    x = run_stack(x, params["blocks"], n_scan, cfg.moe is not None, "blocks")
    x = rms_norm(x, params["final_norm"]["w"], cfg.norm_eps)
    return x, aux_total, caches


def lm_head_weight(params: Params, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return params["embed"]["w"].T
    return params["lm_head"]["w"]


def loss_fn(params: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    prefix = batch.get("prefix_embeds")
    x, aux, _ = forward(params, batch["tokens"], cfg, prefix_embeds=prefix)
    if prefix is not None:
        x = x[:, prefix.shape[1]:]
    xent = chunked_softmax_xent(x, lm_head_weight(params, cfg),
                                batch["labels"], cfg.logit_chunk,
                                valid_vocab=cfg.vocab_size)
    return xent + aux, {"xent": xent, "aux": aux}


def prefill(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
            prefix_embeds: Optional[jnp.ndarray] = None):
    """Prefill: hidden states of the final position -> next-token logits,
    plus per-layer KV caches."""
    x, _, caches = forward(params, tokens, cfg, prefix_embeds=prefix_embeds,
                           want_cache=True)
    logits = x[:, -1:] @ lm_head_weight(params, cfg)
    return logits, caches


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def cache_spec(cfg: ModelConfig, batch: int, cache_len: int) -> Dict[str, Any]:
    """Shapes of the decode cache (ring buffer of ``cache_len`` slots)."""
    W = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    dtype = jnp.dtype(cfg.dtype)
    n_first = cfg.moe.first_dense if cfg.moe else 0
    n_scan = cfg.num_layers - n_first

    def layer_cache(n):
        if cfg.attention == "mla":
            return {"c_kv": ((n, batch, W, cfg.mla_kv_lora), dtype),
                    "k_rope": ((n, batch, W, cfg.mla_rope_dim), dtype)}
        return {"k": ((n, batch, W, cfg.num_kv_heads, cfg.head_dim), dtype),
                "v": ((n, batch, W, cfg.num_kv_heads, cfg.head_dim), dtype)}

    spec = {"blocks": layer_cache(n_scan)}
    if n_first:
        spec["first_blocks"] = layer_cache(n_first)
    return spec


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> Dict[str, Any]:
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s[0], s[1]), cache_spec(cfg, batch, cache_len),
        is_leaf=lambda s: isinstance(s, tuple))


def _block_decode(bp: Params, x, cache, cache_index, cfg: ModelConfig, use_moe: bool):
    if cfg.attention == "mla":
        a, new_cache = attn_lib.mla_decode(bp["attn"],
                                           rms_norm(x, bp["ln1"]["w"], cfg.norm_eps),
                                           cache, cache_index, cfg)
    else:
        a, new_cache = attn_lib.gqa_decode(bp["attn"],
                                           rms_norm(x, bp["ln1"]["w"], cfg.norm_eps),
                                           cache, cache_index, cfg)
    x = x + a
    h = rms_norm(x, bp["ln2"]["w"], cfg.norm_eps)
    if use_moe:
        m, _ = moe_lib.moe_block(bp["moe"], h, cfg)
    else:
        m = mlp(bp["mlp"], h)
    return x + m, new_cache


def decode_step(params: Params, token: jnp.ndarray, cache: Dict[str, Any],
                cache_index: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """token: (B, 1) int32; cache_index: () int32 tokens already cached.

    Returns (logits (B, 1, V), new_cache)."""
    x = params["embed"]["w"][token]
    new_caches = {}

    def run_stack(x, stack, stack_cache, n_layers, use_moe, name):
        def body(h, inp):
            lp, lc = inp
            h, nc = _block_decode(lp, h, lc, cache_index, cfg, use_moe)
            return h, nc

        if cfg.scan_layers and n_layers > 1:
            x, nc = jax.lax.scan(body, x, (stack, stack_cache))
        else:
            ncs = []
            for i in range(n_layers):
                lp = jax.tree_util.tree_map(lambda p: p[i] if n_layers > 1 else p[0], stack)
                lc = jax.tree_util.tree_map(lambda p: p[i] if n_layers > 1 else p[0], stack_cache)
                x, c = body(x, (lp, lc))
                ncs.append(c)
            nc = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ncs)
        new_caches[name] = nc
        return x

    if "first_blocks" in params:
        n_first = cfg.moe.first_dense
        x = run_stack(x, params["first_blocks"], cache["first_blocks"],
                      n_first, False, "first_blocks")
    n_scan = cfg.num_layers - (cfg.moe.first_dense if cfg.moe else 0)
    x = run_stack(x, params["blocks"], cache["blocks"], n_scan,
                  cfg.moe is not None, "blocks")
    x = rms_norm(x, params["final_norm"]["w"], cfg.norm_eps)
    logits = x @ lm_head_weight(params, cfg)
    return logits, new_caches
