"""Executable JAX implementations of the paper's three workloads
(ResNet-50/101, VGG-16) for image classification.

The what-if simulator uses the analytic profiles in ``core.cnn_profiles``;
these executable models close the loop: ``timeline.measure``-style
white-box timing can run against the real computation, and the data-parallel
training path (grad-sync, compression) is exercised on the exact workloads
the paper measured.  Layer structure mirrors torchvision so parameter
counts match the paper's 97/170/527 MB.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init, split_keys

Conv = jax.lax.conv_general_dilated
_DN = ("NHWC", "HWIO", "NHWC")


def _conv_init(key, k, cin, cout, dtype=jnp.float32):
    fan_in = k * k * cin
    w = jax.random.truncated_normal(key, -2, 2, (k, k, cin, cout)) \
        * (2.0 / fan_in) ** 0.5
    return w.astype(dtype)


def conv2d(x, w, stride=1, padding="SAME"):
    return Conv(x, w, (stride, stride), padding, dimension_numbers=_DN)


def batch_norm(x, scale, bias, eps=1e-5):
    """Per-batch normalization (training mode; no running stats — the
    simulator's subject is throughput, not eval accuracy)."""
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


# ---------------------------------------------------------------------------
# VGG-16
# ---------------------------------------------------------------------------

VGG_CFG = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]


def init_vgg16(key, num_classes: int = 1000, width_mult: float = 1.0) -> Params:
    ks = iter(split_keys(key, 32))
    params: Params = {"convs": [], "fcs": []}
    cin = 3
    for v in VGG_CFG:
        if v == "M":
            continue
        cout = max(int(v * width_mult), 8)
        params["convs"].append({
            "w": _conv_init(next(ks), 3, cin, cout),
            "b": jnp.zeros((cout,)),
        })
        cin = cout
    fc_dim = max(int(4096 * width_mult), 16)
    in_dim = cin * 7 * 7
    params["fcs"] = [
        {"w": dense_init(next(ks), (in_dim, fc_dim), jnp.float32),
         "b": jnp.zeros((fc_dim,))},
        {"w": dense_init(next(ks), (fc_dim, fc_dim), jnp.float32),
         "b": jnp.zeros((fc_dim,))},
        {"w": dense_init(next(ks), (fc_dim, num_classes), jnp.float32),
         "b": jnp.zeros((num_classes,))},
    ]
    return params


def vgg16_forward(params: Params, images: jnp.ndarray) -> jnp.ndarray:
    """images: (B, H, W, 3) -> logits (B, classes).  H=W=224 canonically."""
    x = images
    i = 0
    for v in VGG_CFG:
        if v == "M":
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
            continue
        c = params["convs"][i]
        x = jax.nn.relu(conv2d(x, c["w"]) + c["b"])
        i += 1
    # adaptive 7x7 (canonical input already lands at 7x7)
    B = x.shape[0]
    x = x.reshape(B, -1)
    for j, fc in enumerate(params["fcs"]):
        x = x @ fc["w"] + fc["b"]
        if j < 2:
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------------------------
# ResNet-50 / 101
# ---------------------------------------------------------------------------

def _init_bn(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _init_bottleneck(key, cin, width, stride, downsample):
    ks = split_keys(key, 4)
    p = {
        "conv1": _conv_init(ks[0], 1, cin, width), "bn1": _init_bn(width),
        "conv2": _conv_init(ks[1], 3, width, width), "bn2": _init_bn(width),
        "conv3": _conv_init(ks[2], 1, width, width * 4),
        "bn3": _init_bn(width * 4),
    }
    if downsample:
        p["down"] = _conv_init(ks[3], 1, cin, width * 4)
        p["down_bn"] = _init_bn(width * 4)
    return p


def init_resnet(key, blocks: Sequence[int], num_classes: int = 1000,
                width_mult: float = 1.0) -> Params:
    ks = iter(split_keys(key, sum(blocks) + 3))
    base = max(int(64 * width_mult), 8)
    params: Params = {
        "stem": {"w": _conv_init(next(ks), 7, 3, base), "bn": _init_bn(base)},
        "stages": [],
    }
    cin = base
    for stage, n in enumerate(blocks):
        width = base * (2 ** stage)
        stage_p = []
        for b in range(n):
            stride = 2 if (b == 0 and stage > 0) else 1
            stage_p.append(_init_bottleneck(next(ks), cin, width, stride,
                                            downsample=(b == 0)))
            cin = width * 4
        params["stages"].append(stage_p)
    params["fc"] = {"w": dense_init(next(ks), (cin, num_classes), jnp.float32),
                    "b": jnp.zeros((num_classes,))}
    return params


def _bottleneck_forward(p, x, stride):
    """stride is static (derived from block position, not stored in the
    param pytree — pytree leaves must all be arrays)."""
    h = jax.nn.relu(batch_norm(conv2d(x, p["conv1"]),
                               p["bn1"]["scale"], p["bn1"]["bias"]))
    h = jax.nn.relu(batch_norm(conv2d(h, p["conv2"], stride=stride),
                               p["bn2"]["scale"], p["bn2"]["bias"]))
    h = batch_norm(conv2d(h, p["conv3"]), p["bn3"]["scale"], p["bn3"]["bias"])
    if "down" in p:
        x = batch_norm(conv2d(x, p["down"], stride=stride),
                       p["down_bn"]["scale"], p["down_bn"]["bias"])
    return jax.nn.relu(x + h)


def resnet_forward(params: Params, images: jnp.ndarray) -> jnp.ndarray:
    x = jax.nn.relu(batch_norm(conv2d(images, params["stem"]["w"], stride=2),
                               params["stem"]["bn"]["scale"],
                               params["stem"]["bn"]["bias"]))
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                              (1, 3, 3, 1), (1, 2, 2, 1), "SAME")
    for si, stage in enumerate(params["stages"]):
        for bi, block in enumerate(stage):
            stride = 2 if (bi == 0 and si > 0) else 1
            x = _bottleneck_forward(block, x, stride)
    x = jnp.mean(x, axis=(1, 2))
    return x @ params["fc"]["w"] + params["fc"]["b"]


# ---------------------------------------------------------------------------
# uniform API
# ---------------------------------------------------------------------------

def get_cnn(name: str, key, num_classes: int = 1000, width_mult: float = 1.0):
    """Returns (params, forward) for resnet50 | resnet101 | vgg16."""
    if name == "vgg16":
        return init_vgg16(key, num_classes, width_mult), vgg16_forward
    if name == "resnet50":
        return init_resnet(key, (3, 4, 6, 3), num_classes, width_mult), resnet_forward
    if name == "resnet101":
        return init_resnet(key, (3, 4, 23, 3), num_classes, width_mult), resnet_forward
    raise ValueError(name)


def cnn_loss(forward, params, batch) -> jnp.ndarray:
    logits = forward(params, batch["images"])
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["labels"][:, None],
                                         axis=1))
