"""Pure-JAX model zoo: dense GQA/MLA transformers, GShard MoE, Mamba,
RWKV6, hybrid Jamba, Whisper enc-dec, VLM wrapper, and the paper's CNNs."""
