"""Mixture-of-Experts with GShard-style einsum dispatch.

TPU-native choices:
- dense one-hot dispatch/combine einsums (SPMD-friendly; the dispatch tensor
  shards over (data, model) axes, experts shard over the `model` axis),
- per-batch-row groups with a capacity factor (tokens over capacity drop
  through the residual connection),
- router computed in fp32; load-balance + router-z auxiliary losses.

The gather/scatter ("sort-based") dispatch is intentionally NOT the baseline:
the einsum form is what the roofline baseline measures, and replacing it is
one of the §Perf hillclimb candidates.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import Params, dense_init, split_keys


def expert_capacity(moe: MoEConfig, group_tokens: int) -> int:
    cap = int(moe.top_k * group_tokens * moe.capacity_factor / moe.num_experts)
    return max(cap, 1)


def init_moe(key, cfg: ModelConfig, n_layers: int = 0) -> Params:
    moe = cfg.moe
    assert moe is not None
    d_ff = moe.d_ff_expert or cfg.d_ff
    ks = split_keys(key, 5)
    lead = (n_layers,) if n_layers else ()
    dtype = jnp.dtype(cfg.dtype)
    E = moe.num_experts
    p: Params = {
        "router": dense_init(ks[0], lead + (cfg.d_model, E), dtype, scale=0.02),
        "wi": dense_init(ks[1], lead + (E, cfg.d_model, d_ff), dtype),
        "wg": dense_init(ks[2], lead + (E, cfg.d_model, d_ff), dtype),
        "wo": dense_init(ks[3], lead + (E, d_ff, cfg.d_model), dtype),
    }
    if moe.num_shared_experts:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(ks[4], cfg.d_model,
                               d_ff * moe.num_shared_experts, dtype,
                               n_layers=n_layers)
    return p


def moe_block(params: Params, x: jnp.ndarray, cfg: ModelConfig
              ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, S, D) -> (out, aux_losses).

    Groups are batch rows: capacity is computed over S tokens per row.
    """
    moe = cfg.moe
    B, S, D = x.shape
    E, K = moe.num_experts, moe.top_k
    C = expert_capacity(moe, S)
    C = min(C, S)

    logits = (x @ params["router"]).astype(jnp.float32)          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # position-in-expert for capacity masking ------------------------------
    # sel: (B,S,K,E) one-hot of chosen experts, ranked by (s, k) priority
    sel = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)
    flat = sel.reshape(B, S * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                        # tokens ahead
    pos = pos.reshape(B, S, K, E)
    within = pos < C
    sel = sel * within
    pos_idx = jnp.sum(pos * sel, axis=-1).astype(jnp.int32)      # (B,S,K)

    # dispatch/combine tensors (B,S,E,C) -----------------------------------
    # (bf16_stream: one-hots exact in bf16; gate rounding <0.4% — halves the
    # largest MoE intermediates' HBM traffic)
    oh_dt = jnp.bfloat16 if getattr(cfg, "bf16_stream", False) else jnp.float32
    pos_oh = jax.nn.one_hot(pos_idx, C, dtype=oh_dt)             # (B,S,K,C)
    disp = jnp.einsum("bske,bskc->bsec", sel.astype(oh_dt), pos_oh)
    comb = jnp.einsum("bske,bskc,bsk->bsec", sel.astype(oh_dt), pos_oh,
                      gate_vals.astype(oh_dt))

    dt = x.dtype
    xin = jnp.einsum("bsec,bsd->ebcd", disp.astype(dt), x)       # (E,B,C,D)
    h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", xin, params["wg"]))
    h = h * jnp.einsum("ebcd,edf->ebcf", xin, params["wi"])
    out_e = jnp.einsum("ebcf,efd->ebcd", h, params["wo"])        # (E,B,C,D)
    out = jnp.einsum("bsec,ebcd->bsd", comb.astype(dt), out_e)

    if moe.num_shared_experts and "shared" in params:
        from repro.models.layers import mlp
        out = out + mlp(params["shared"], x)

    # auxiliary losses ------------------------------------------------------
    # load balance: E * sum_e f_e * p_e  (Switch Transformer eq. 4-6)
    top1 = jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32)
    f = jnp.mean(top1, axis=(0, 1))
    p = jnp.mean(probs, axis=(0, 1))
    lb = E * jnp.sum(f * p) * moe.load_balance_loss
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * moe.router_z_loss
    aux = {"load_balance": lb, "router_z": z}
    return out, aux
