"""Gradient-compression training demo (paper §3.2 as a runtime feature).

Trains the same smoke model under every compression mode of the explicit
(Horovod-style) communication phase and compares loss trajectories: the
paper's point is that compression trades model quality for wire time, so
you should only pay for it on slow networks.

Run:  PYTHONPATH=src python examples/gradient_compression.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch import train as train_mod


def main():
    results = {}
    for compression in ("none", "fp16", "int8", "topk"):
        res = train_mod.main([
            "--arch", "stablelm-3b", "--smoke", "--steps", "10",
            "--comm-mode", "explicit", "--compression", compression,
            "--topk-ratio", "0.25", "--log-every", "100"])
        results[compression] = res

    print(f"\n{'mode':<8} {'loss_0':>8} {'loss_N':>8} {'decreased':>10}")
    base = results["none"]["last_loss"]
    for mode, r in results.items():
        print(f"{mode:<8} {r['first_loss']:>8.4f} {r['last_loss']:>8.4f} "
              f"{str(r['loss_decreased']):>10}")
        assert r["loss_decreased"], f"{mode}: loss must decrease"
    # lossless/lossy ordering sanity: fp16 tracks none closely
    assert abs(results["fp16"]["last_loss"] - base) < 0.15
    print("\nAll compression modes converge; fp16 tracks the uncompressed "
          "trajectory (paper: lossy compression is the only mode that "
          "risks model quality — use it only when the wire demands it).")


if __name__ == "__main__":
    main()
