"""White-box measured what-if: the paper's §3.1 methodology end-to-end on
an *executable* workload.

1. Time a real training step of (a width-reduced) VGG-16 on this device.
2. Build the gradient-ready timeline from the measured batch time with
   per-layer FLOPs-proportional backward shares (the paper distributes
   hook timings the same way).
3. Run the two-process simulator across bandwidths.

Run:  PYTHONPATH=src python examples/measured_whatif.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core.simulator import simulate
from repro.core.timeline import from_cnn
from repro.core.transport import GBPS
from repro.models.cnn import cnn_loss, get_cnn


def measure_step(name="vgg16", width_mult=0.25, batch=4, repeats=3) -> float:
    params, forward = get_cnn(name, jax.random.key(0), num_classes=100,
                              width_mult=width_mult)
    batch_data = {
        "images": jax.random.normal(jax.random.key(1), (batch, 224, 224, 3)),
        "labels": jnp.zeros((batch,), jnp.int32),
    }
    step = jax.jit(jax.grad(lambda p: cnn_loss(forward, p, batch_data)))
    jax.block_until_ready(step(params))          # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(step(params))
    return (time.perf_counter() - t0) / repeats


def main():
    t_local = measure_step()
    print(f"measured reduced-VGG16 step on {jax.default_backend()}: "
          f"{t_local*1e3:.0f} ms")
    # the timeline uses the *shape* of the measurement (the paper's V100
    # batch time for absolute numbers; our measured time demonstrates the
    # white-box pipeline on live hardware)
    for label, t_batch in [("paper-V100", None), ("this-device", t_local)]:
        tl = from_cnn("vgg16", t_batch=t_batch)
        line = f"  {label:<12}"
        for bw in (10, 25, 100):
            r = simulate(tl, n_workers=64, bandwidth=bw * GBPS,
                         transport="ideal")
            line += f"  {bw:>3}Gbps={r.scaling_factor:.1%}"
        print(line)
    print("\nSlower compute (this device) hides more communication -> higher "
          "scaling factor at equal bandwidth,\nexactly the compute/comm "
          "balance the paper's what-if captures.")

    # the scheduling axis the event engine opens: the same timeline under
    # each comm schedule.  fifo is Horovod's serialized loop; priority
    # preempts for the front layers at chunk boundaries; chunked pipelines
    # transmission with reduction.  (V100 batch time: on this host's
    # measured step the compute is slow enough to hide all comm, so every
    # scheduler reads 100 % — the fast-compute regime is where the
    # schedule matters.)
    print("\nscheduler x bandwidth (VGG16 V100 timeline, horovod_tcp "
          "transport, 64 GPUs):")
    tl = from_cnn("vgg16")
    print(f"  {'scheduler':<10}" + "".join(f"  {bw:>3}Gbps" for bw in (10, 25, 100)))
    for sched in ("fifo", "priority", "chunked"):
        line = f"  {sched:<10}"
        for bw in (10, 25, 100):
            r = simulate(tl, n_workers=64, bandwidth=bw * GBPS,
                         transport="horovod_tcp", scheduler=sched)
            line += f"  {r.scaling_factor:6.1%}"
        print(line)
    print("\nA better schedule recovers bandwidth the serialized loop "
          "leaves idle -- the paper's point\nthat scheduling, not the "
          "network, is the bottleneck.")


if __name__ == "__main__":
    main()
