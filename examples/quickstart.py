"""Quickstart: the paper's question — *is the network the bottleneck?* —
answered end-to-end with this framework in under a minute on CPU.

1. Build the paper's three CNN workloads' gradient timelines.
2. Run the what-if simulator at measured-transport vs full utilization.
3. Reproduce the headline numbers: scaling plateaus at high bandwidth under
   the measured transport, but reaches ~100 % under full utilization, and
   2-5x compression suffices at 10 Gbps.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import whatif
from repro.core.whatif import sim_scaling


def main():
    print("=" * 72)
    print("Paper reproduction: 'Is Network the Bottleneck of Distributed "
          "Training?'")
    print("=" * 72)

    print("\n-- transmission time of all parameters at 100 Gbps "
          "(paper: 7.8 / 13.6 / 42.2 ms) --")
    for row in whatif.transmission_table():
        print(f"  {row['model']:<10} {row['size_mb']:6.1f} MB  "
              f"{row['time_ms']:5.1f} ms")

    print("\n-- scaling factor, 8 servers (64 GPUs) --")
    print(f"  {'model':<10} {'bw':>6} {'measured-mode':>14} {'full-util':>10}")
    for model in whatif.PAPER_MODELS:
        for bw in (10, 25, 100):
            meas = sim_scaling(model, bandwidth_gbps=bw,
                               transport="horovod_tcp").scaling_factor
            ideal = sim_scaling(model, bandwidth_gbps=bw,
                                transport="ideal").scaling_factor
            print(f"  {model:<10} {bw:>4}G {meas:>13.1%} {ideal:>10.1%}")

    print("\n-- gradient compression at 10 Gbps, full utilization "
          "(paper: 2-5x is enough; VGG16 needs ~10x) --")
    for model in whatif.PAPER_MODELS:
        line = f"  {model:<10}"
        for ratio in (1, 2, 5, 10):
            f = sim_scaling(model, bandwidth_gbps=10, transport="ideal",
                            compression_ratio=ratio).scaling_factor
            line += f"  {ratio}x={f:.1%}"
        print(line)

    print("\nConclusion (paper §4): with the network fully utilized the "
          "scaling factor is ~100% at 100 Gbps —\nthe bottleneck is the "
          "transport implementation, not the network speed.")


if __name__ == "__main__":
    main()
