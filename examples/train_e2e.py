"""End-to-end training driver: train a ~100M-param dense model with the
paper's communication phase enabled (bucketed grad-sync + optional
compression), checkpointing, and scaling-factor instrumentation.

Defaults are sized for this CPU container (~100M params, short run); pass
``--steps 300`` for the full few-hundred-step run on real hardware.

Run:  PYTHONPATH=src python examples/train_e2e.py --steps 30
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import register  # noqa: E402
from repro.configs.base import ModelConfig  # noqa: E402


@register("repro-100m")
def repro_100m() -> ModelConfig:
    """~100M-param llama-style dense config for the e2e example."""
    return ModelConfig(
        name="repro-100m", family="dense", num_layers=8, d_model=768,
        num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32000,
        attn_chunk=256, logit_chunk=256, dtype="float32", remat=False,
        sharding="dp_tp", source="examples/train_e2e.py")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--compression", default="none")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    from repro.launch import train as train_mod

    argv = ["--arch", "repro-100m", "--shape", "train_4k",
            "--steps", str(args.steps), "--batch", str(args.batch),
            "--comm-mode", "explicit", "--compression", args.compression,
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "10",
            "--log-every", "1"]
    # shrink seq len for CPU by overriding the shape via smoke=False + batch:
    from repro.configs import INPUT_SHAPES, InputShape
    INPUT_SHAPES["train_4k"] = InputShape("train_4k", args.seq_len,
                                          args.batch, "train")
    result = train_mod.main(argv)
    assert result["loss_decreased"], "loss must decrease over the run"
    print(f"[e2e] OK — loss {result['first_loss']:.3f} -> "
          f"{result['last_loss']:.3f}")


if __name__ == "__main__":
    main()
