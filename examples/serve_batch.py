"""Batched serving example: prefill a prompt batch and decode continuations
for three architecture families (dense GQA, attention-free RWKV6, enc-dec
Whisper) through the same ModelApi.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_mod


def main():
    for arch in ("stablelm-3b", "rwkv6-1.6b", "whisper-base"):
        serve_mod.main(["--arch", arch, "--smoke", "--batch", "2",
                        "--prompt-len", "32", "--gen", "8"])


if __name__ == "__main__":
    main()
