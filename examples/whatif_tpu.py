"""Beyond-paper example: the paper's what-if analysis transplanted to the
TPU-v5e production mesh for the assigned architectures.

Asks the paper's question about *our* system: for data-parallel training of
each architecture on a 16x16 pod (and 2 pods over DCN), is the interconnect
the bottleneck, and what compression ratio (if any) would full utilization
need?

Run:  PYTHONPATH=src python examples/whatif_tpu.py
"""
import sys

sys.path.insert(0, "src")

from repro.configs import INPUT_SHAPES, get_config
from repro.core.whatif import tpu_whatif


def main():
    shape = INPUT_SHAPES["train_4k"]
    archs = ["stablelm-3b", "command-r-35b", "deepseek-coder-33b",
             "rwkv6-1.6b", "moonshot-v1-16b-a3b"]
    print(f"{'arch':<22} {'pods':>4} {'comp':>5} {'f_sim':>7} {'overhead':>9}")
    for arch in archs:
        cfg = get_config(arch)
        for n_pods in (1, 2):
            for ratio in (1.0, 4.0):
                r = tpu_whatif(cfg, shape, n_pods=n_pods,
                               compression_ratio=ratio)
                print(f"{arch:<22} {n_pods:>4} {ratio:>4.0f}x "
                      f"{r.scaling_factor:>6.1%} {r.t_overhead*1e3:>7.2f}ms")
    print("\nReading: ICI at 400 Gbps keeps data-parallel gradient sync "
          "near-invisible for <=35B dense\nmodels; the cross-pod DCN stage "
          "is where compression starts to matter (paper's 10 Gbps regime).")


if __name__ == "__main__":
    main()
