"""§Perf hillclimb driver: lower one (arch x shape) on the single-pod mesh
with config-knob overrides, analyze the compiled HLO, and print the three
roofline terms — the measurement half of the hypothesis -> change ->
measure -> validate loop.

  PYTHONPATH=src python -m benchmarks.perf_lab --arch jamba-v0.1-52b \
      --shape train_4k --set mamba_fused_y=True --tag fused_y

Results are appended to artifacts/perf/<arch>_<shape>.json so iterations
accumulate into the EXPERIMENTS.md §Perf log.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import gzip
import json
import time
from pathlib import Path

import jax

from repro.configs import INPUT_SHAPES, get_config
from repro.launch import specs as specs_lib
from repro.launch.dryrun import resolve_cfg
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models.registry import get_model
from repro.optim.optimizers import get_optimizer
from repro.utils.hlo import analyze

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts" / "perf"

_WIRE = {"all-reduce": 2.0}


def parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        out[k] = v
    return out


def run_variant(arch: str, shape_name: str, overrides: dict, tag: str,
                verbose: bool = True) -> dict:
    cfg, note = resolve_cfg(arch, shape_name)
    assert cfg is not None, f"{arch} x {shape_name} skipped by design"
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh()
    api = get_model(cfg)
    opt = get_optimizer("adamw") if shape.kind == "train" else None
    spec = specs_lib.step_spec(api, shape, mesh, opt)
    fn = specs_lib.make_step_fn(api, spec.kind, opt)
    t0 = time.time()
    with mesh:
        compiled = jax.jit(fn, in_shardings=spec.in_shardings,
                           out_shardings=spec.out_shardings,
                           donate_argnums=spec.donate_argnums
                           ).lower(*spec.args).compile()
    text = compiled.as_text()
    ana = analyze(text)
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    with gzip.open(ARTIFACTS / f"{arch}_{shape_name}_{tag}.hlo.gz", "wt") as f:
        f.write(text)
    mem = compiled.memory_analysis()
    wire = sum(_WIRE.get(k, 1.0) * v for k, v in ana.collective_bytes.items())
    result = {
        "tag": tag, "arch": arch, "shape": shape_name,
        "overrides": overrides, "note": note,
        "compile_s": round(time.time() - t0, 1),
        "compute_s": ana.flops / PEAK_FLOPS_BF16,
        "memory_s": ana.bytes / HBM_BW,
        "collective_s": wire / ICI_BW,
        "flops": ana.flops, "bytes": ana.bytes,
        "collective_bytes": ana.collective_bytes,
        "temp_gib": mem.temp_size_in_bytes / 2**30,
    }
    if verbose:
        print(f"[perf] {arch} x {shape_name} [{tag}] "
              f"compute={result['compute_s']*1e3:.1f}ms "
              f"memory={result['memory_s']*1e3:.1f}ms "
              f"collective={result['collective_s']*1e3:.1f}ms "
              f"temp={result['temp_gib']:.1f}GiB")
        print(f"       collectives: "
              f"{ {k: f'{v/1e9:.1f}GB' for k, v in ana.collective_bytes.items()} }")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override, e.g. mamba_fused_y=True")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()
    result = run_variant(args.arch, args.shape, parse_overrides(args.set),
                         args.tag)
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    out = ARTIFACTS / f"{args.arch}_{args.shape}.json"
    hist = json.loads(out.read_text()) if out.exists() else []
    hist = [h for h in hist if h["tag"] != args.tag] + [result]
    out.write_text(json.dumps(hist, indent=1))
    print(f"-> {out}")


if __name__ == "__main__":
    main()
