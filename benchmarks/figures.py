"""One benchmark per paper figure/table.

Each function runs the corresponding analysis and returns (rows, validation)
where ``validation`` is a dict of claim-checks against the paper's numbers.
``benchmarks.run`` prints them as CSV and a pass/fail summary.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

from repro.core import whatif
from repro.core.whatif import sim_scaling

Rows = List[Dict]


def _timed(fn, *a, **kw):
    t0 = time.perf_counter()
    out = fn(*a, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def fig1_scaling_vs_servers() -> Tuple[Rows, Dict]:
    rows, us = _timed(whatif.fig1_scaling_vs_servers)
    by = {(r["model"], r["servers"]): r["scaling"] for r in rows}
    # paper §2.2: RN50/RN101/VGG16 = 75/69/56 % @2 servers; none exceeds 76 %
    val = {
        "rn50_2srv_in_[0.6,0.9]": 0.60 <= by[("resnet50", 2)] <= 0.90,
        "vgg16_worst": by[("vgg16", 2)] < by[("resnet50", 2)],
        "no_linear_scaling": max(by.values()) < 0.85,
        "us": us,
    }
    return rows, val


def fig2_computation_time() -> Tuple[Rows, Dict]:
    """Computation time is flat in worker count (it's the same timeline —
    we verify the simulator treats it as such and report t_back/t_batch)."""
    rows = []
    for m in whatif.PAPER_MODELS:
        tl = whatif.paper_timeline(m)
        for n in (1, 2, 4, 8):
            rows.append(dict(model=m, servers=n, t_back_ms=tl.t_back * 1e3,
                             t_batch_ms=tl.t_batch * 1e3))
    val = {"flat_by_construction": True, "us": 0.0}
    return rows, val


def fig3_scaling_vs_bandwidth() -> Tuple[Rows, Dict]:
    rows, us = _timed(whatif.fig3_scaling_vs_bandwidth)
    by = {(r["servers"], r["bandwidth_gbps"]): r["scaling"] for r in rows}
    # paper: 2-server RN50 grows 13 % -> ~68 % from 1 to 10 Gbps, then
    # plateaus after 25 Gbps (measured transport)
    val = {
        "low_bw_poor": by[(2, 1)] < 0.25,
        "grows_to_10g": by[(2, 10)] > 3 * by[(2, 1)],
        "plateau_after_25g": (by[(2, 100)] - by[(2, 25)]) < 0.15,
        "us": us,
    }
    return rows, val


def fig4_utilization() -> Tuple[Rows, Dict]:
    rows, us = _timed(whatif.fig4_utilization)
    by = {(r["model"], r["bandwidth_gbps"]): r for r in rows}
    val = {
        "full_util_at_1g": by[("resnet50", 1)]["utilization"] > 0.9,
        "low_util_at_100g": by[("resnet50", 100)]["effective_gbps"] < 32.0,
        "us": us,
    }
    return rows, val


def fig6_sim_vs_measured() -> Tuple[Rows, Dict]:
    rows, us = _timed(whatif.fig6_sim_vs_measured)
    val = {"us": us}
    for r in rows:
        if r["bandwidth_gbps"] <= 10:
            # low bw: simulated and measured-mode lines coincide (Fig 6)
            val.setdefault("low_bw_agree", True)
            if abs(r["simulated_full_util"] - r["measured_mode"]) > 0.08:
                val["low_bw_agree"] = False
        if r["bandwidth_gbps"] == 100:
            val.setdefault("high_bw_diverge", False)
            if r["simulated_full_util"] - r["measured_mode"] > 0.15:
                val["high_bw_diverge"] = True
    return rows, val


def fig7_scaling_vs_workers() -> Tuple[Rows, Dict]:
    rows, us = _timed(whatif.fig7_scaling_vs_workers)
    # paper: full-util scaling ~100 % even at 64 GPUs
    worst = min(r["simulated"] for r in rows)
    val = {"full_util_near_1_even_64gpus": worst > 0.97, "us": us}
    return rows, val


def fig8_compression() -> Tuple[Rows, Dict]:
    rows, us = _timed(whatif.fig8_compression)
    by = {(r["model"], r["bandwidth_gbps"], r["ratio"]): r["scaling"]
          for r in rows}
    val = {
        # paper: 2-5x suffices at 10 Gbps for ResNets; ~10x for VGG16;
        # compression unnecessary at 100 Gbps
        "rn50_5x_10g": by[("resnet50", 10, 5)] > 0.95,
        "vgg16_10x_10g": by[("vgg16", 10, 10)] > 0.95,
        "no_need_at_100g": by[("vgg16", 100, 1)] > 0.97,
        "100x_overkill": by[("resnet50", 10, 100)] - by[("resnet50", 10, 10)] < 0.02,
        "us": us,
    }
    return rows, val


def table_transmission() -> Tuple[Rows, Dict]:
    rows, us = _timed(whatif.transmission_table)
    by = {r["model"]: r["time_ms"] for r in rows}
    # paper §4: 7.8 / 13.6 / 42.2 ms (paper's sizes 97/170/527 MB; ours are
    # the exact torchvision parameter counts, slightly larger)
    val = {
        "resnet50_ms": abs(by["resnet50"] - 7.8) < 1.5,
        "resnet101_ms": abs(by["resnet101"] - 13.6) < 2.0,
        "vgg16_ms": abs(by["vgg16"] - 42.2) < 4.0,
        "us": us,
    }
    return rows, val


def fig9_other_systems() -> Tuple[Rows, Dict]:
    """Paper §4: the same what-if applied to SwitchML / parameter-server /
    ByteScheduler (see repro.core.whatif)."""
    rows, us = _timed(whatif.fig9_other_systems)
    val = {"us": us}
    for r in rows:
        val.setdefault("switchml_never_worse", True)
        if r["switchml"] < r["ring"] - 1e-9:
            val["switchml_never_worse"] = False
    bs = whatif.bytescheduler_whatif("vgg16", 10)
    rows.append(bs)
    val["bytescheduler_bound_helps"] = (
        bs["bytescheduler_bound"] >= bs["baseline"])
    return rows, val


ALL_FIGURES = {
    "fig1_scaling_vs_servers": fig1_scaling_vs_servers,
    "fig2_computation_time": fig2_computation_time,
    "fig3_scaling_vs_bandwidth": fig3_scaling_vs_bandwidth,
    "fig4_utilization": fig4_utilization,
    "fig6_sim_vs_measured": fig6_sim_vs_measured,
    "fig7_scaling_vs_workers": fig7_scaling_vs_workers,
    "fig8_compression": fig8_compression,
    "fig9_other_systems": fig9_other_systems,
    "table_transmission": table_transmission,
}
