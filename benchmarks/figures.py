"""Non-sweep paper analyses for the benchmark harness.

The sweep figures (fig1/3/4/6/7/8/9) live in the experiment engine now:
grids in ``repro.experiments.grids``, claim checks in
``repro.experiments.validations``, row-shaped access in
``repro.core.whatif`` — ``benchmarks.run`` consumes the engine's artifact
directly.  What remains here are the analyses with no sweep grid: the
computation-time sanity check (fig2), the parameter-transmission table,
and the ByteScheduler overlap bound.

Each function returns (rows, validation) where ``validation`` carries the
claim-check booleans plus ``us`` (wall-clock microseconds).
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

from repro.core import whatif

Rows = List[Dict]


def _timed(fn, *a, **kw):
    t0 = time.perf_counter()
    out = fn(*a, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def fig2_computation_time() -> Tuple[Rows, Dict]:
    """Computation time is flat in worker count (it's the same timeline —
    we verify the simulator treats it as such and report t_back/t_batch)."""
    rows = []
    for m in whatif.PAPER_MODELS:
        tl = whatif.paper_timeline(m)
        for n in (1, 2, 4, 8):
            rows.append(dict(model=m, servers=n, t_back_ms=tl.t_back * 1e3,
                             t_batch_ms=tl.t_batch * 1e3))
    val = {"flat_by_construction": True, "us": 0.0}
    return rows, val


def table_transmission() -> Tuple[Rows, Dict]:
    rows, us = _timed(whatif.transmission_table)
    by = {r["model"]: r["time_ms"] for r in rows}
    # paper §4: 7.8 / 13.6 / 42.2 ms (paper's sizes 97/170/527 MB; ours are
    # the exact torchvision parameter counts, slightly larger)
    val = {
        "resnet50_ms": abs(by["resnet50"] - 7.8) < 1.5,
        "resnet101_ms": abs(by["resnet101"] - 13.6) < 2.0,
        "vgg16_ms": abs(by["vgg16"] - 42.2) < 4.0,
        "us": us,
    }
    return rows, val


def bytescheduler_bound() -> Tuple[Dict, bool]:
    """The §4 ByteScheduler upper bound and its single pass criterion."""
    bs = whatif.bytescheduler_whatif("vgg16", 10)
    return bs, bs["bytescheduler_bound"] >= bs["baseline"]


def scheduler_contention() -> Tuple[Rows, Dict]:
    """Two jobs on one link (the event engine's fair-share what-if): each
    job must be no faster than when it owns the link, and the pipelined
    scheduler must not make contention worse than fifo."""
    rows, us = _timed(whatif.contention_whatif)
    fifo = {r["model"]: r for r in rows}
    rows_c, us2 = _timed(whatif.contention_whatif, scheduler="chunked")
    chk = {r["model"]: r for r in rows_c}
    val = {
        "contention_never_speeds_up": all(
            r["contended"] <= r["alone"] + 1e-9 for r in rows + rows_c),
        "chunked_no_worse_under_contention": all(
            chk[m]["contended"] >= fifo[m]["contended"] - 1e-9 for m in fifo),
        "us": us + us2,
    }
    return rows + rows_c, val
