"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Sections:
  1. Paper figures/tables (fig1-fig8 + transmission table) with validation
     checks against the paper's own numbers.
  2. Kernel micro-benchmarks (Pallas interpret-mode vs jnp ref).
  3. TPU what-if for the assigned architectures (beyond-paper).
  4. Roofline table from the dry-run artifacts, if present.

Prints ``name,us_per_call,derived`` CSV per benchmark plus a validation
summary; exits non-zero if a paper-claim check fails.
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def main() -> int:
    failures = 0

    # -- 1. paper figures (experiment engine -> artifact -> report) ---------
    import time

    from repro.experiments import artifacts, compare, grids, run_suite

    print("=" * 72)
    print("SECTION 1: paper figure reproductions (experiment engine)")
    print("=" * 72)
    art_path = Path(__file__).resolve().parent.parent / "artifacts" / \
        "experiments" / "paper.json"
    t0 = time.perf_counter()
    records = run_suite(grids.resolve("paper"))
    suite_us = (time.perf_counter() - t0) * 1e6
    art = artifacts.write(art_path, records, meta={"grid": "paper"})
    print(f"suite artifact: {art_path} ({suite_us:.0f} us total)")
    for ex in art["experiments"]:
        val = ex["validations"]
        ok = all(val.values())
        failures += 0 if ok else 1
        print(f"\n{ex['name']},{len(ex['cells'])}cells,"
              f"{'PASS' if ok else 'FAIL'}")
        for k, v in val.items():
            print(f"  check {k}: {'ok' if v else 'FAIL'}")
        for c in ex["cells"][:6]:
            print(f"  {c['model']},srv={c['n_servers']},"
                  f"bw={c['bandwidth_gbps']:g},{c['transport']},"
                  f"r={c['compression_ratio']:g},{c['topology']}: "
                  f"f_sim={c['scaling_factor']:.4f} "
                  f"util={c['network_utilization']:.3f}")
        if len(ex["cells"]) > 6:
            print(f"  ... ({len(ex['cells'])} cells total)")

    golden = Path(__file__).resolve().parent.parent / "artifacts" / \
        "golden" / "paper_suite.json"
    if golden.exists():
        report = compare(artifacts.read(golden), art)
        failures += 0 if report.ok else 1
        print(f"\ngolden-artifact gate: {report.summary()}")

    # the scheduling axis the event engine opened (tentpole): fifo vs
    # priority vs chunked over the paper bandwidths, gated by its own golden
    sched_records = run_suite(grids.resolve("scheduler"))
    sched_art = artifacts.make_artifact(sched_records)
    for ex in sched_art["experiments"]:
        val = ex["validations"]
        ok = all(val.values())
        failures += 0 if ok else 1
        print(f"\n{ex['name']},{len(ex['cells'])}cells,"
              f"{'PASS' if ok else 'FAIL'}")
        for k, v in val.items():
            print(f"  check {k}: {'ok' if v else 'FAIL'}")
    sched_golden = golden.parent / "scheduler_suite.json"
    if sched_golden.exists():
        report = compare(artifacts.read(sched_golden), sched_art)
        failures += 0 if report.ok else 1
        print(f"scheduler-golden gate: {report.summary()}")

    from benchmarks.figures import scheduler_contention
    rows, cval = scheduler_contention()
    cok = all(bool(v) for k, v in cval.items() if k != "us")
    failures += 0 if cok else 1
    print(f"\nscheduler_contention,{cval.get('us', 0):.0f},"
          f"{'PASS' if cok else 'FAIL'}")
    for r in rows:
        print(f"  {r}")

    # non-sweep figures keep their direct analyses
    from benchmarks.figures import fig2_computation_time, table_transmission
    for name, fn in (("fig2_computation_time", fig2_computation_time),
                     ("table_transmission", table_transmission)):
        rows, val = fn()
        us = val.pop("us", 0.0)
        ok = all(bool(v) for v in val.values())
        failures += 0 if ok else 1
        print(f"\n{name},{us:.0f},{'PASS' if ok else 'FAIL'}")
        for k, v in val.items():
            print(f"  check {k}: {'ok' if v else 'FAIL'}")
        for r in rows[:6]:
            print(f"  {r}")
        if len(rows) > 6:
            print(f"  ... ({len(rows)} rows total)")
    from benchmarks.figures import bytescheduler_bound
    bs, bs_ok = bytescheduler_bound()
    failures += 0 if bs_ok else 1
    print(f"\nbytescheduler_whatif,0,{'PASS' if bs_ok else 'FAIL'}")
    print(f"  {bs}")

    # -- 2. kernels -----------------------------------------------------------
    print("\n" + "=" * 72)
    print("SECTION 2: kernel micro-benchmarks (interpret mode on CPU)")
    print("=" * 72)
    from benchmarks.kernel_bench import run as kernel_run
    print("name,us_per_call,derived")
    for r in kernel_run():
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")

    # -- 3. TPU what-if -------------------------------------------------------
    print("\n" + "=" * 72)
    print("SECTION 3: TPU what-if for assigned architectures (beyond-paper)")
    print("=" * 72)
    from repro.configs import INPUT_SHAPES, get_config
    from repro.core.whatif import tpu_whatif
    shape = INPUT_SHAPES["train_4k"]
    print("name,us_per_call,derived")
    for arch in ("stablelm-3b", "command-r-35b", "deepseek-coder-33b",
                 "rwkv6-1.6b", "jamba-v0.1-52b", "moonshot-v1-16b-a3b"):
        for n_pods in (1, 2):
            r = tpu_whatif(get_config(arch), shape, n_pods=n_pods)
            print(f"tpu_whatif[{arch},pods={n_pods}],0,"
                  f"f_sim={r.scaling_factor:.3f};overhead_ms="
                  f"{r.t_overhead*1e3:.2f}")

    # -- 4. roofline ----------------------------------------------------------
    print("\n" + "=" * 72)
    print("SECTION 4: roofline from dry-run artifacts")
    print("=" * 72)
    art = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"
    from benchmarks.roofline import load_table
    for fname in ("results.json", "results_multipod.json"):
        path = art / fname
        if not path.exists():
            print(f"({fname} not present — run repro.launch.dryrun first)")
            continue
        rows = load_table(path)
        print(f"\n-- {fname}: {len(rows)} combos --")
        print("name,us_per_call,derived")
        for r in rows:
            if r.get("kind") == "skipped":
                print(f"roofline[{r['arch']},{r['shape']}],0,skipped")
                continue
            print(f"roofline[{r['arch']},{r['shape']},{r['mesh']}],0,"
                  f"dominant={r['dominant']};compute_ms={r['compute_s']*1e3:.2f};"
                  f"memory_ms={r['memory_s']*1e3:.2f};"
                  f"collective_ms={r['collective_s']*1e3:.2f};"
                  f"useful_ratio={r['model_flops_ratio']:.2f}")

    print(f"\n{'ALL BENCHMARKS PASS' if failures == 0 else f'{failures} FAILURES'}")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
