"""Sweep-engine performance benchmark — the repo's perf trajectory seed.

Writes these metrics to ``BENCH_sweep.json``:

- **sweep_cells_per_sec** — end-to-end simulator throughput over a fixed
  mixed grid (models x bandwidths x schedulers x contention), run serially
  so the number is executor-independent and comparable across commits;
- **engine_events_per_sec / stress_speedup_vs_seed** — the discrete-event
  engine on the stress workload the PR-3 acceptance pins (8 contending
  jobs x chunked ``n_chunks=32`` -> thousands of flows on one fair-share
  link), against the retained seed engine
  (``tests/_reference_engine.py``); the engine consumes its native
  columnar input (``run_flow_batch``, no tuple materialization);
- **lowering_ms / xxl_lowering_ms** — the columnar lowering phase alone
  (``plan_to_flow_batch`` + per-job relabel/jitter + concat) for the
  stress workload and the xxl cell: the stage the structure-of-arrays
  pipeline collapsed from per-op NamedTuple rebuilds to column copies;
- **heap_stress_speedup_vs_seed** — the same 8-job stress under the
  *priority* scheduler, whose regressed ready order forces every job into
  heap mode: this pins the heap-mode bulk-commit fast path (resolved
  prefixes), which the CI gate holds to a hard speedup floor;
- **xxl_cell_ms** — one full ``simulate_contention`` call on the heaviest
  ``xxl-contention`` golden cell (16 VGG16 jobs x priority ``k=64`` with
  flush jitter, >18k flows), end to end through the lowering;
- **fabric_cell_ms** — one contended fabric cell (4 VGG16 jobs on a 4:1
  Clos fabric) end to end: the multi-link max-min event loop
  (``NetworkEngine._run_maxmin``) re-solving the rate vector at every
  membership change, through the same ``simulate_contention`` entry the
  ``fabric`` golden grid uses;
- **wan_cell_ms** — the lossiest hot cell of the gated ``wan`` grid
  (ResNet-50, priority + int8 at 10 Gbps over ``loss=0.05, rtt=20``):
  the lossy-transport lowering (goodput inflation + RTT) plus seeded
  retransmission stalls through the ``_RETX`` calendar machinery,
  end to end through ``simulate``;
- **fastpath_speedup** — the closed-form fifo path in
  ``repro.core.simulator`` against the event engine on a long serialized
  plan;
- **small_plan_us** — one engine call on a paper-sized (two-dozen-flow)
  plan, the regime where per-run setup cost dominates: this is what the
  plain-list small-plan setup in ``repro.core.events`` optimizes, and what
  every sub-fastpath-threshold cell of a sweep pays per call.

Usage::

    python -m benchmarks.sweep_bench                 # full, writes JSON
    python -m benchmarks.sweep_bench --quick         # CI: fewer reps
    python -m benchmarks.sweep_bench --quick \
        --baseline artifacts/bench/BENCH_sweep.json  # regression gate

With ``--baseline``, exits non-zero when sweep throughput regresses more
than :data:`REGRESSION_FACTOR` x against the committed baseline, the
heap-mode stress speedup falls below :data:`HEAP_SPEEDUP_FLOOR`, the xxl
worst cell exceeds :data:`XXL_CELL_MS_CEILING`, or chunked-stress engine
throughput falls below :data:`ENGINE_EVENTS_FLOOR` (the CI ``bench``
job's gates).  Absolute cells/sec is machine-dependent, so the
throughput gate compares *machine-normalized* numbers: the retained seed
engine is frozen code, so its measured stress time on the same run is a
pure machine-speed probe, and ``cells_per_sec * stress_seed_ms`` (cells
per unit of seed-engine work) cancels hardware speed out of the
comparison.  The speedup floors are same-run ratios and need no
normalization.
"""
from __future__ import annotations

import argparse
import gc
import json
import platform
import statistics
import sys
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))
sys.path.insert(0, str(REPO_ROOT / "tests"))   # the retained seed engine

SCHEMA_VERSION = 1
KIND = "repro-sweep-bench"
REGRESSION_FACTOR = 2.0
# hard floor on the heap-mode (priority) stress speedup vs the seed engine:
# a same-run ratio, so machine speed cancels out of the gate
HEAP_SPEEDUP_FLOOR = 3.5
# columnar-pipeline acceptance bars, anchored to the baseline host: the
# gate scales the measured numbers by the same-run seed-engine probe ratio
# (stress_seed_ms is frozen code — a pure machine-speed probe), so a slow
# CI runner is judged as if it ran on the machine that wrote the baseline
XXL_CELL_MS_CEILING = 100.0     # worst xxl-contention cell, end to end
ENGINE_EVENTS_FLOOR = 5e6       # chunked-stress events/sec through run_batch
FABRIC_CELL_MS_CEILING = 50.0   # 4-job 4:1-fabric contention cell
WAN_CELL_MS_CEILING = 50.0      # lossiest wan-grid cell, end to end
DEFAULT_OUT = "BENCH_sweep.json"
DEFAULT_BASELINE = REPO_ROOT / "artifacts" / "bench" / "BENCH_sweep.json"


# each timed rep runs the workload enough times to span this much wall
# time, so per-call and timer overheads amortize to noise
MIN_REP_SECONDS = 0.1


# a sample more than this factor above the run's fastest is a load burst
# (another tenant, a throttle step), not the code under test: wall-clock
# medians must reject those or a busy host flunks the speedup floors
_SPIKE_FACTOR = 1.5


def _measure(fn: Callable[[], None], reps: int) -> float:
    """Median-of-N per-call wall time via ``time.perf_counter_ns``.

    The previous ``process_time`` timer ticks as coarsely as 10 ms on
    some kernels, which visibly quantized the published metrics (e.g.
    ``engine_fifo_ms: 1.2999...`` — a lattice point, not a measurement)
    and made the CI gate compare rounding artifacts.  ``perf_counter_ns``
    is nanosecond-granular; a timeit-style autorange still grows an inner
    loop until one rep spans :data:`MIN_REP_SECONDS` so call overhead
    amortizes.  Wall clock sees noisy neighbours, so the estimator is a
    *spike-rejected median*: samples more than :data:`_SPIKE_FACTOR` x
    the run's fastest are discarded as external load (they measure the
    machine, not the code), and the median of the rest absorbs what
    remains — a mean would inherit the spikes, a plain best-of would
    under-report a machine that throttles mid-run."""
    was_enabled = gc.isenabled()
    gc.disable()                    # like timeit: GC pauses are not the code
    try:
        inner = 1
        while True:
            t0 = time.perf_counter_ns()
            for _ in range(inner):
                fn()
            dt = (time.perf_counter_ns() - t0) / 1e9
            if dt >= MIN_REP_SECONDS:
                break
            inner *= 10 if dt <= 0.0 else min(10, max(
                2, int(MIN_REP_SECONDS / dt) + 1))
        samples = [dt / inner]
        for _ in range(max(reps, 5) - 1):
            t0 = time.perf_counter_ns()
            for _ in range(inner):
                fn()
            samples.append((time.perf_counter_ns() - t0) / 1e9 / inner)
        floor = min(samples)
        kept = [s for s in samples if s <= floor * _SPIKE_FACTOR]
        return statistics.median(kept)
    finally:
        if was_enabled:
            gc.enable()
        gc.collect()


def _stress_plan(n_chunks: int = 32, scheduler: str = "chunked"):
    """One VGG16 job's plan + cost for the stress workload."""
    from repro.configs.base import CommConfig
    from repro.core.addest import AddEst
    from repro.core.network_model import RingAllReduce
    from repro.core.schedule import lower_buckets
    from repro.core.simulator import fuse_buckets
    from repro.core.timeline import from_cnn
    from repro.core.transport import GBPS, get_transport

    tl = from_cnn("vgg16")
    tr = get_transport("horovod_tcp")
    cost = RingAllReduce(64, tr.effective(25 * GBPS), AddEst.v100())
    buckets = [(b.flush_time, b.size, b.n_tensors)
               for b in fuse_buckets(tl, CommConfig())]
    plan = lower_buckets(buckets, scheduler=scheduler, n_chunks=n_chunks)
    return plan, cost, tr


def _stress_flows(jobs: int = 8, n_chunks: int = 32,
                  scheduler: str = "chunked"):
    """The acceptance stress workload: ``jobs`` identical VGG16 trainings
    under ``scheduler`` at ``n_chunks`` chunks/bucket, contending for one
    fair-share link.  ``chunked`` keeps every job in pointer mode;
    ``priority`` regresses each job's ready order and forces heap mode."""
    from repro.core.schedule import plan_to_flows

    plan, cost, tr = _stress_plan(n_chunks, scheduler)
    flows, base = [], 0
    for j in range(jobs):
        fl = plan_to_flows(plan, cost, tr.per_tensor_overhead,
                           job=f"job{j}", op_id_base=base)
        base += len(fl)
        flows.extend(fl)
    return flows


def _lower_stress_batch(plan, cost, tr, jobs: int = 8):
    """The stress workload lowered columnar from a prebuilt plan: one
    ``plan_to_flow_batch`` call, relabeled per job and concatenated — the
    exact shape ``simulate_contention`` feeds the engine.  This is the
    stage ``lowering_ms`` prices."""
    from repro.core.events import concat_batches
    from repro.core.schedule import plan_to_flow_batch

    b0 = plan_to_flow_batch(plan, cost, tr.per_tensor_overhead)
    parts, base = [], 0
    for j in range(jobs):
        parts.append(b0.relabel(base, f"job{j}"))
        base += b0.n
    return concat_batches(parts)


def _engine_vs_seed(flows, batch, reps: int, prefix: str) -> Dict[str, float]:
    """Engine (columnar input, its native shape since the SoA lowering)
    vs the retained seed engine (tuple input — frozen code) on the same
    workload.  ``<prefix>_lowering_ms`` prices producing that columnar
    input from the already-built plan (lower + relabel + concat), the
    other half of a contention cell's cost."""
    from repro.core.events import run_flow_batch, run_flows
    from _reference_engine import run_reference_flows

    assert len(flows) >= 2000, "stress workload must be >= 2000 flows"
    # correctness cross-check before timing anything: seed engine vs the
    # columnar engine on the columnar input
    ref = run_reference_flows(flows, max_iters_factor=100)
    new = run_flow_batch(batch).to_results()
    worst = max(abs(a.end - b.end) / max(abs(a.end), 1e-12)
                for a, b in zip(ref, new))
    if worst > 1e-9:
        raise RuntimeError(f"engine diverges from seed by {worst:.2e}")
    tuple_results = run_flows(flows)
    if any(a.end != b.end for a, b in zip(tuple_results, new)):
        raise RuntimeError("tuple-input engine path diverges from columnar")
    t_new = _measure(lambda: run_flow_batch(batch), reps)
    t_ref = _measure(lambda: run_reference_flows(flows,
                                                 max_iters_factor=100), reps)
    n = len(flows)
    return {
        f"{prefix}_flows": float(n),
        f"{prefix}_seed_ms": t_ref * 1e3,
        f"{prefix}_engine_ms": t_new * 1e3,
        f"{prefix}_speedup_vs_seed": t_ref / t_new,
    }


def bench_engine(reps: int) -> Dict[str, float]:
    flows = _stress_flows()
    plan, cost, tr = _stress_plan()
    batch = _lower_stress_batch(plan, cost, tr)
    m = _engine_vs_seed(flows, batch, reps, "stress")
    n = len(flows)
    t_new = m["stress_engine_ms"] / 1e3
    m["engine_flows_per_sec"] = n / t_new
    # each flow is one admission plus one completion event
    m["engine_events_per_sec"] = 2 * n / t_new
    m["lowering_ms"] = _measure(
        lambda: _lower_stress_batch(plan, cost, tr), reps) * 1e3
    return m


def bench_heap_engine(reps: int) -> Dict[str, float]:
    """Heap-mode stress: the same 8 jobs at priority k=32.

    The priority scheduler regresses ready times along each job's service
    order, so every job runs gated/heap admission — the path the heap-mode
    bulk commit vectorizes.  The CI gate pins
    ``heap_stress_speedup_vs_seed >= HEAP_SPEEDUP_FLOOR``."""
    flows = _stress_flows(scheduler="priority")
    plan, cost, tr = _stress_plan(scheduler="priority")
    batch = _lower_stress_batch(plan, cost, tr)
    m = _engine_vs_seed(flows, batch, reps, "heap_stress")
    n = len(flows)
    m["heap_engine_events_per_sec"] = 2 * n / (m["heap_stress_engine_ms"]
                                               / 1e3)
    return m


def bench_xxl_cell(reps: int) -> Dict[str, float]:
    """One full xxl-contention worst cell, end to end.

    16 co-located VGG16 jobs, priority at 64 chunks/bucket, 2 ms flush
    jitter, 25 Gbps measured transport — the heaviest cell of the gated
    ``xxl-contention`` grid (>18k flows through one fair-share link),
    including bucket fusion, lowering, and result assembly.
    ``xxl_lowering_ms`` isolates the cell's columnar lowering phase (one
    ``plan_to_flow_batch`` + 16 relabel/jitter passes + concat), the part
    the structure-of-arrays pipeline took from ~40% of cell time to
    column copies."""
    from repro.core.events import concat_batches, perturb_batch
    from repro.core.schedule import plan_to_flow_batch
    from repro.core.simulator import simulate_contention
    from repro.core.timeline import from_cnn
    from repro.core.transport import GBPS

    tl = from_cnn("vgg16")

    def cell():
        simulate_contention([tl] * 16, n_workers=64, bandwidth=25 * GBPS,
                            scheduler="priority", n_chunks=64,
                            jitter=0.002, jitter_seed=2026)

    t = _measure(cell, reps)

    plan, cost, tr = _stress_plan(n_chunks=64, scheduler="priority")

    def lower_cell():
        b0 = plan_to_flow_batch(plan, cost, tr.per_tensor_overhead)
        parts, base = [], 0
        for j in range(16):
            bj = perturb_batch(b0.relabel(base, f"job{j}"), 0.002, 2026,
                               stream=j)
            base += bj.n
            parts.append(bj)
        concat_batches(parts)

    t_lower = _measure(lower_cell, reps)
    return {"xxl_cell_ms": t * 1e3, "xxl_lowering_ms": t_lower * 1e3}


def bench_fabric_cell(reps: int) -> Dict[str, float]:
    """One contended fabric cell: 4 VGG16 jobs on a 4:1 Clos fabric.

    Every job's flows carry the nic + 4x-uplink path, so the engine runs
    the multi-link max-min loop (rate vector re-solved at each
    admission/completion) instead of the indexed single-link calendar —
    the priced regime the ``fabric`` golden grid gates.  The CI bar
    holds ``fabric_cell_ms`` under :data:`FABRIC_CELL_MS_CEILING` on the
    baseline host (seed-probe normalized, like the xxl ceiling)."""
    from repro.core.simulator import simulate_contention
    from repro.core.timeline import from_cnn
    from repro.core.transport import GBPS

    tl = from_cnn("vgg16")

    def cell():
        simulate_contention([tl] * 4, n_workers=64, bandwidth=10 * GBPS,
                            transport="ideal", fabric="clos",
                            oversubscription=4.0)

    return {"fabric_cell_ms": _measure(cell, reps) * 1e3}


def bench_wan_cell(reps: int) -> Dict[str, float]:
    """The lossiest hot cell of the gated ``wan`` grid, end to end.

    ResNet-50 under priority + int8 at 10 Gbps over a
    ``loss=0.05, rtt=20`` link (the grid's ``fault_seed=2029``): every
    flow pays the ``1/(1-loss)`` goodput inflation and the RTT through
    the lossy lowering, and the seeded retransmission draws land as
    ``_RETX`` calendar stalls — the bulk-commit fences the fault axes
    introduced, now on the loss path.  The CI bar holds ``wan_cell_ms``
    under :data:`WAN_CELL_MS_CEILING` on the baseline host (seed-probe
    normalized, like the xxl and fabric ceilings)."""
    from repro.core.simulator import simulate
    from repro.core.timeline import from_cnn
    from repro.core.transport import GBPS

    tl = from_cnn("resnet50")

    def cell():
        simulate(tl, n_workers=64, bandwidth=10 * GBPS,
                 transport="horovod_tcp", scheduler="priority", n_chunks=8,
                 codec="int8", fault_seed=2029,
                 link_profile="wan:loss=0.05,rtt=20")

    return {"wan_cell_ms": _measure(cell, reps) * 1e3}


def bench_sweep(reps: int) -> Dict[str, float]:
    from repro.experiments import run_spec
    from repro.experiments.spec import ExperimentSpec

    spec = ExperimentSpec(
        name="bench-sweep", models=("resnet50", "vgg16"),
        n_servers=(2, 8), bandwidth_gbps=(5.0, 25.0, 100.0),
        transport=("ideal", "horovod_tcp"),
        scheduler=("fifo", "priority", "chunked"), sched_chunks=16)
    contention = ExperimentSpec(
        name="bench-contention", models=("vgg16",), n_servers=(8,),
        bandwidth_gbps=(25.0,), transport=("horovod_tcp",),
        scheduler=("chunked",), n_jobs=(1, 2, 4, 8), sched_chunks=32)
    n_cells = spec.n_cells + contention.n_cells
    t = _measure(lambda: (run_spec(spec, executor="serial"),
                          run_spec(contention, executor="serial")), reps)
    return {
        "sweep_cells": float(n_cells),
        "sweep_seconds": t,
        "sweep_cells_per_sec": n_cells / t,
    }


def bench_fastpath(reps: int) -> Dict[str, float]:
    from repro.configs.base import CommConfig
    from repro.core.addest import AddEst
    from repro.core.events import run_flows
    from repro.core.network_model import RingAllReduce
    from repro.core.schedule import lower_buckets, plan_to_flows
    from repro.core.simulator import _fifo_fast_results, fuse_buckets
    from repro.core.timeline import from_cnn
    from repro.core.transport import GBPS, get_transport

    # a small fusion buffer makes a long serialized fifo plan
    tl = from_cnn("vgg16")
    tr = get_transport("horovod_tcp")
    cost = RingAllReduce(64, tr.effective(10 * GBPS), AddEst.v100())
    buckets = fuse_buckets(tl, CommConfig(fusion_buffer_mb=2.0))
    plan = lower_buckets([(b.flush_time, b.size, b.n_tensors)
                          for b in buckets], scheduler="fifo")
    flows = plan_to_flows(plan, cost, tr.per_tensor_overhead)
    fast = _fifo_fast_results(plan, flows)
    slow = run_flows(flows)
    if fast is None or any(a.end != b.end for a, b in zip(fast, slow)):
        raise RuntimeError("fifo fast path is not bit-exact with the engine")
    t_fast = _measure(lambda: _fifo_fast_results(plan, flows), reps)
    t_engine = _measure(lambda: run_flows(flows), reps)
    return {
        "fastpath_plan_ops": float(len(flows)),
        "fastpath_ms": t_fast * 1e3,
        "engine_fifo_ms": t_engine * 1e3,
        "fastpath_speedup": t_engine / t_fast,
    }


def bench_small_plan(reps: int) -> Dict[str, float]:
    from repro.configs.base import CommConfig
    from repro.core.addest import AddEst
    from repro.core.events import run_flows
    from repro.core.network_model import RingAllReduce
    from repro.core.schedule import lower_buckets, plan_to_flows
    from repro.core.simulator import fuse_buckets
    from repro.core.timeline import from_cnn
    from repro.core.transport import GBPS, get_transport

    # a real paper cell's plan: vgg16 fifo at the default fusion buffer is
    # ~18 ops — below the simulator's closed-form threshold, so sweeps pay
    # one engine call (and its setup) for every such cell
    tl = from_cnn("vgg16")
    tr = get_transport("horovod_tcp")
    cost = RingAllReduce(64, tr.effective(100 * GBPS), AddEst.v100())
    plan = lower_buckets([(b.flush_time, b.size, b.n_tensors)
                          for b in fuse_buckets(tl, CommConfig())],
                         scheduler="fifo")
    flows = plan_to_flows(plan, cost, tr.per_tensor_overhead)
    # the columnar setup must never engage down here: paper-size plans
    # stay on the plain-list small-plan path (and below the simulator's
    # columnar dispatch threshold, which shares the same knob)
    from repro.core.events import _SMALL_PLAN_MAX_FLOWS
    assert len(flows) < _SMALL_PLAN_MAX_FLOWS, (
        f"small-plan bench grew to {len(flows)} flows — no longer exercises"
        f" the sub-{_SMALL_PLAN_MAX_FLOWS} list path")
    t = _measure(lambda: run_flows(flows), reps)
    return {
        "small_plan_flows": float(len(flows)),
        "small_plan_us": t * 1e6,
    }


def run_bench(quick: bool) -> Dict:
    reps = 5 if quick else 9        # median-of-N; _measure floors N at 5
    metrics: Dict[str, float] = {}
    metrics.update(bench_sweep(reps))
    metrics.update(bench_engine(reps))
    metrics.update(bench_heap_engine(reps))
    metrics.update(bench_xxl_cell(reps))
    metrics.update(bench_fabric_cell(reps))
    metrics.update(bench_wan_cell(reps))
    metrics.update(bench_fastpath(reps))
    metrics.update(bench_small_plan(reps))
    return {
        "kind": KIND,
        "schema_version": SCHEMA_VERSION,
        "quick": quick,
        "python": platform.python_version(),
        "machine": platform.machine(),
        "metrics": metrics,
    }


def _normalized_throughput(metrics: Dict[str, float]) -> Optional[float]:
    """Sweep cells per unit of seed-engine work — machine-independent.

    ``stress_seed_ms`` measures frozen code, so it scales with the host's
    single-core speed exactly as the (serial, CPU-bound) sweep does;
    multiplying cancels the hardware out and the gate compares only what
    the *changed* code costs."""
    cells = metrics.get("sweep_cells_per_sec")
    probe = metrics.get("stress_seed_ms")
    if not cells or not probe:
        return None
    return cells * probe


def check_regression(result: Dict, baseline_path: Path) -> List[str]:
    try:
        base = json.loads(baseline_path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"cannot read baseline {baseline_path}: {e}"]
    if base.get("kind") != KIND:
        return [f"{baseline_path} is not a {KIND}"]
    failures = []
    old = _normalized_throughput(base["metrics"])
    new = _normalized_throughput(result["metrics"])
    if old and new and new < old / REGRESSION_FACTOR:
        failures.append(
            f"machine-normalized sweep throughput regressed "
            f">{REGRESSION_FACTOR}x: baseline {old:.0f} -> {new:.0f} "
            f"cells/sec x seed-ms (raw: "
            f"{base['metrics']['sweep_cells_per_sec']:.1f} -> "
            f"{result['metrics']['sweep_cells_per_sec']:.1f} cells/sec)")
    # speedup floors: same-run ratios, immune to host speed; the heap floor
    # is the heap-mode bulk-commit acceptance bar
    heap = result["metrics"].get("heap_stress_speedup_vs_seed")
    if heap is not None and heap < HEAP_SPEEDUP_FLOOR:
        failures.append(
            f"heap-mode stress speedup {heap:.2f}x fell below the "
            f"{HEAP_SPEEDUP_FLOOR}x floor (priority k=32, 8 jobs)")
    # columnar-pipeline bars: absolute on the baseline host, scaled to this
    # host by the frozen seed-engine probe so CI runner speed cancels out
    base_probe = base["metrics"].get("stress_seed_ms")
    new_probe = result["metrics"].get("stress_seed_ms")
    speed = (base_probe / new_probe) if base_probe and new_probe else 1.0
    xxl = result["metrics"].get("xxl_cell_ms")
    if xxl is not None and xxl * speed > XXL_CELL_MS_CEILING:
        failures.append(
            f"xxl worst cell {xxl:.1f} ms ({xxl * speed:.1f} ms normalized "
            f"to the baseline host) exceeds the "
            f"{XXL_CELL_MS_CEILING:.0f} ms ceiling")
    ev = result["metrics"].get("engine_events_per_sec")
    if ev is not None and ev / speed < ENGINE_EVENTS_FLOOR:
        failures.append(
            f"chunked-stress engine throughput {ev / 1e6:.2f} M events/s "
            f"({ev / speed / 1e6:.2f} M normalized to the baseline host) "
            f"fell below the {ENGINE_EVENTS_FLOOR / 1e6:.0f} M floor")
    fab = result["metrics"].get("fabric_cell_ms")
    if fab is not None and fab * speed > FABRIC_CELL_MS_CEILING:
        failures.append(
            f"fabric contention cell {fab:.1f} ms ({fab * speed:.1f} ms "
            f"normalized to the baseline host) exceeds the "
            f"{FABRIC_CELL_MS_CEILING:.0f} ms ceiling")
    wan = result["metrics"].get("wan_cell_ms")
    if wan is not None and wan * speed > WAN_CELL_MS_CEILING:
        failures.append(
            f"wan lossy cell {wan:.1f} ms ({wan * speed:.1f} ms "
            f"normalized to the baseline host) exceeds the "
            f"{WAN_CELL_MS_CEILING:.0f} ms ceiling")
    return failures


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.sweep_bench",
                                 description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="single-rep timings (CI)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help=f"output JSON path (default {DEFAULT_OUT})")
    ap.add_argument("--baseline", default=None,
                    help="baseline BENCH_sweep.json to gate against "
                         f"(e.g. {DEFAULT_BASELINE.relative_to(REPO_ROOT)})")
    args = ap.parse_args(argv)

    result = run_bench(args.quick)
    m = result["metrics"]
    print(f"sweep:   {m['sweep_cells']:.0f} cells in {m['sweep_seconds']:.2f}s"
          f" -> {m['sweep_cells_per_sec']:.1f} cells/sec")
    print(f"engine:  {m['stress_flows']:.0f} stress flows: seed "
          f"{m['stress_seed_ms']:.1f} ms -> engine {m['stress_engine_ms']:.1f}"
          f" ms ({m['stress_speedup_vs_seed']:.1f}x, "
          f"{m['engine_events_per_sec'] / 1e3:.0f}k events/sec)")
    print(f"heap:    {m['heap_stress_flows']:.0f} priority flows: seed "
          f"{m['heap_stress_seed_ms']:.1f} ms -> engine "
          f"{m['heap_stress_engine_ms']:.1f} ms "
          f"({m['heap_stress_speedup_vs_seed']:.1f}x, floor "
          f"{HEAP_SPEEDUP_FLOOR}x)")
    print(f"lower:   stress lowering {m['lowering_ms']:.2f} ms; xxl "
          f"lowering {m['xxl_lowering_ms']:.2f} ms (columnar)")
    print(f"xxl:     16-job priority k=64 jittered cell: "
          f"{m['xxl_cell_ms']:.1f} ms end to end "
          f"(ceiling {XXL_CELL_MS_CEILING:.0f} ms on the baseline host)")
    print(f"fabric:  4-job 4:1-fabric contention cell: "
          f"{m['fabric_cell_ms']:.1f} ms end to end "
          f"(ceiling {FABRIC_CELL_MS_CEILING:.0f} ms on the baseline host)")
    print(f"wan:     lossy hot cell (loss=0.05, priority+int8): "
          f"{m['wan_cell_ms']:.1f} ms end to end "
          f"(ceiling {WAN_CELL_MS_CEILING:.0f} ms on the baseline host)")
    print(f"fastpath: {m['fastpath_plan_ops']:.0f}-op fifo plan: engine "
          f"{m['engine_fifo_ms']:.2f} ms -> closed form "
          f"{m['fastpath_ms']:.2f} ms ({m['fastpath_speedup']:.1f}x)")
    print(f"small:   {m['small_plan_flows']:.0f}-flow paper plan: "
          f"{m['small_plan_us']:.1f} us/engine call")

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=1, sort_keys=True) + "\n")
    print(f"wrote {out}")

    if args.baseline:
        failures = check_regression(result, Path(args.baseline))
        if failures:
            for f in failures:
                print(f"FAIL: {f}")
            return 1
        print(f"no perf regression vs {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
