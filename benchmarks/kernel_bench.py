"""Kernel micro-benchmarks and the codec cost calibration.

Two modes:

- default: the original micro-bench table (interpret-mode on CPU:
  correctness-scale only; the derived column reports achieved GB/s to
  compare against the ref path);
- ``--calibrate``: measure the compression kernels against a *same-tiling
  Pallas copy probe* and emit the codec calibration table consumed by
  ``repro.core.codec`` (committed as ``artifacts/bench/BENCH_codec.json``).

Calibration records **probe-normalized passes**, not wall time: each codec
stage's time is divided by the copy probe's time on the same input, run
through the same ``pallas_call`` tiling in the same mode — machine speed,
interpret-mode overhead, and grid bookkeeping all cancel in the ratio.
The simulator then prices a stage as ``passes`` sweeps of memory traffic
at the modeled device's bandwidth (see ``Codec.encode_seconds``), the same
analytic idiom as ``AddEst``.  Never compare interpret-mode Pallas against
jitted XLA here: that ratio measures the interpreter (1000x), not the
kernel.

Usage::

    python -m benchmarks.kernel_bench                    # micro-bench table
    python -m benchmarks.kernel_bench --calibrate \
        --out artifacts/bench/BENCH_codec.json           # refresh the table
    python -m benchmarks.kernel_bench --calibrate --quick \
        --check artifacts/bench/BENCH_codec.json         # CI gate

With ``--check``, exits non-zero when a freshly measured pass count drifts
more than :data:`DRIFT_FACTOR` x from the committed table, or a codec
kernel in ``repro.kernels.quantize`` has no table entry.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ops, ref
from repro.kernels import quantize as _q

KIND = "repro-codec-bench"
SCHEMA_VERSION = 1
DRIFT_FACTOR = 2.0
DEFAULT_OUT = "BENCH_codec.json"
DEFAULT_TABLE = REPO_ROOT / "artifacts" / "bench" / "BENCH_codec.json"

_CODEC_KERNEL_RE = re.compile(r"^quantize_(\w+)_2d$")


def _bench(fn, *args, repeats: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeats * 1e6     # us


def run() -> List[Dict]:
    n = 1 << 20
    x = jax.random.normal(jax.random.key(0), (n,), jnp.float32)
    bufs = jax.random.normal(jax.random.key(1), (8, n // 8), jnp.float32)
    rows = []
    cases = [
        ("quantize_int8_pallas", lambda: ops.quantize_int8(x)[0]),
        ("quantize_int8_ref", lambda: ref.quantize_int8(x)[0]),
        ("ternarize_pallas", lambda: ops.ternarize(x)[0]),
        ("ternarize_ref", lambda: ref.ternarize(x)[0]),
        ("topk_sparsify_pallas", lambda: ops.topk_sparsify(x, 0.01, sample=4096)),
        ("fused_add_pallas", lambda: ops.fused_add(bufs)),
        ("fused_add_ref", lambda: ref.fused_add(bufs)),
    ]
    for name, fn in cases:
        jfn = jax.jit(fn)
        us = _bench(jfn)
        gbps = n * 4 / (us / 1e6) / 1e9
        rows.append(dict(name=name, us_per_call=us, derived=f"{gbps:.2f}GB/s"))
    return rows


# ---------------------------------------------------------------------------
# codec cost calibration
# ---------------------------------------------------------------------------

def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def probe_copy_2d(x: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """The calibration probe: a Pallas copy with the exact tiling of the
    quantize kernels (one read + one write per element, same grid)."""
    R = x.shape[0]
    grid = (R // _q.ROW_TILE,)
    return pl.pallas_call(
        _copy_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((_q.ROW_TILE, _q.BLOCK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((_q.ROW_TILE, _q.BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, _q.BLOCK), jnp.float32),
        interpret=interpret,
    )(x)


def kernel_codec_names() -> List[str]:
    """Codec names implied by the kernels in ``repro.kernels.quantize``:
    every ``quantize_<name>_2d`` entry point plus ``ternarize_2d``.  The
    ``--check`` gate fails if any of these is missing from the committed
    table, so a new compression kernel cannot land unpriced."""
    names = []
    for attr in dir(_q):
        m = _CODEC_KERNEL_RE.match(attr)
        if m and not attr.startswith("dequantize"):
            names.append(m.group(1))
    if hasattr(_q, "ternarize_2d"):
        names.append("ternary")
    return sorted(set(names))


def calibrate(quick: bool = False) -> Dict:
    """Measure probe-normalized pass counts for every codec stage.

    Quick and full mode use the SAME input size and differ only in
    repeats: interpret-mode per-grid-step overhead is not linear in the
    grid, so a pass ratio is only comparable against the committed table
    when measured on the same shape — the ``--check`` drift gate depends
    on that.  Fixed per-call costs (the DGC threshold estimate, kernel
    launch) are deliberately excluded from the streaming passes; the
    simulator prices them as the per-bucket launch overhead.
    """
    from repro.core.addest import V100_LAUNCH_OVERHEAD, V100_MEM_BW
    from repro.core.codec import PROBE_BYTES_PER_BYTE
    from repro.kernels import topk_mask as _tm

    n = 1 << 18                                 # multiple of BLOCK*ROW_TILE
    repeats = 3 if quick else 9
    interpret = ops._interpret()
    x = jax.random.normal(jax.random.key(0), (n,), jnp.float32)
    rows, _ = ops._to_rows(x)

    probe = jax.jit(lambda r: probe_copy_2d(r, interpret=interpret))
    probe_us = _bench(probe, rows, repeats=repeats)

    q8 = jax.jit(lambda r: _q.quantize_int8_2d(r, interpret=interpret))
    qv, sv = q8(rows)
    dq8 = jax.jit(lambda q, s: _q.dequantize_int8_2d(q, s,
                                                     interpret=interpret))
    tern = jax.jit(lambda r: _q.ternarize_2d(r, interpret=interpret))
    tv, tsv = tern(rows)
    # top-k encode = the streaming Pallas mask kernel; the threshold is
    # estimated once per bucket from samples (fixed cost, not a pass)
    thr = ref.topk_threshold(x[::16], 1.0 / 8.0)
    topk = jax.jit(lambda r: _tm.topk_mask_2d(r, thr, interpret=interpret))

    stages = {
        "int8": {
            "encode_us": _bench(q8, rows, repeats=repeats),
            "decode_us": _bench(dq8, qv, sv, repeats=repeats),
        },
        "ternary": {
            "encode_us": _bench(tern, rows, repeats=repeats),
            # decode is a scale-multiply; ops.deternarize reuses the
            # int8 dequant kernel, so measure exactly that
            "decode_us": _bench(dq8, tv, tsv, repeats=repeats),
        },
        "topk": {
            "encode_us": _bench(topk, rows, repeats=repeats),
            # decode scatters kept values into a zeroed buffer — one
            # streaming pass; the probe itself is that kernel
            "decode_us": probe_us,
        },
    }
    codecs = {}
    for name, t in stages.items():
        codecs[name] = {
            "encode_us": round(t["encode_us"], 1),
            "decode_us": round(t["decode_us"], 1),
            "encode_passes": round(t["encode_us"] / probe_us, 3),
            "decode_passes": round(t["decode_us"] / probe_us, 3),
        }
    return {
        "kind": KIND,
        "schema_version": SCHEMA_VERSION,
        "quick": quick,
        "interpret": interpret,
        "n_bytes": n * 4,
        "probe_us": round(probe_us, 1),
        "device_model": {
            "name": "v100",
            "mem_bw": V100_MEM_BW,
            "launch_overhead": V100_LAUNCH_OVERHEAD,
            "probe_bytes_per_byte": PROBE_BYTES_PER_BYTE,
        },
        "codecs": codecs,
    }


def check_table(fresh: Dict, table_path: Path) -> List[str]:
    """CI gate: committed table vs a fresh measurement + kernel coverage."""
    try:
        committed = json.loads(table_path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"cannot read table {table_path}: {e}"]
    if committed.get("kind") != KIND:
        return [f"{table_path} is not a {KIND}"]
    failures = []
    have = committed.get("codecs", {})
    for name in kernel_codec_names():
        if name not in have:
            failures.append(
                f"kernel codec {name!r} (repro.kernels.quantize) has no "
                f"entry in {table_path.name} — re-run --calibrate")
    for name, entry in fresh["codecs"].items():
        if name not in have:
            failures.append(
                f"measured codec {name!r} missing from {table_path.name}")
            continue
        for stage in ("encode_passes", "decode_passes"):
            old, new = have[name][stage], entry[stage]
            lo, hi = sorted((old, new))
            if lo <= 0 or hi / lo > DRIFT_FACTOR:
                failures.append(
                    f"{name}.{stage} drifted >{DRIFT_FACTOR}x: committed "
                    f"{old} vs measured {new} — kernels changed without "
                    f"re-running --calibrate?")
    return failures


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.kernel_bench",
                                 description=__doc__)
    ap.add_argument("--calibrate", action="store_true",
                    help="emit the codec cost table instead of micro-bench")
    ap.add_argument("--quick", action="store_true",
                    help="smaller input / fewer reps (CI)")
    ap.add_argument("--out", default=None,
                    help=f"calibration JSON path (default {DEFAULT_OUT})")
    ap.add_argument("--check", default=None,
                    help="committed table to gate against "
                         f"(e.g. {DEFAULT_TABLE.relative_to(REPO_ROOT)})")
    args = ap.parse_args(argv)

    if not args.calibrate:
        for row in run():
            print(f"{row['name']:24s} {row['us_per_call']:10.1f} us  "
                  f"{row['derived']}")
        return 0

    result = calibrate(quick=args.quick)
    print(f"probe: {result['probe_us']:.1f} us over "
          f"{result['n_bytes'] >> 20} MiB "
          f"(interpret={result['interpret']})")
    for name, c in sorted(result["codecs"].items()):
        print(f"{name:8s} encode {c['encode_passes']:.3f} passes "
              f"({c['encode_us']:.1f} us)  decode {c['decode_passes']:.3f} "
              f"passes ({c['decode_us']:.1f} us)")

    if args.out:
        out = Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(result, indent=1, sort_keys=True) + "\n")
        print(f"wrote {out}")

    if args.check:
        failures = check_table(result, Path(args.check))
        if failures:
            for f in failures:
                print(f"FAIL: {f}")
            return 1
        print(f"calibration OK vs {args.check} "
              f"(drift gate {DRIFT_FACTOR}x, codecs "
              f"{', '.join(kernel_codec_names())} covered)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
