"""Kernel micro-benchmarks (interpret-mode on CPU: correctness-scale only;
the derived column reports achieved GB/s to compare against the ref path).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _bench(fn, *args, repeats: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeats * 1e6     # us


def run() -> List[Dict]:
    n = 1 << 20
    x = jax.random.normal(jax.random.key(0), (n,), jnp.float32)
    bufs = jax.random.normal(jax.random.key(1), (8, n // 8), jnp.float32)
    rows = []
    cases = [
        ("quantize_int8_pallas", lambda: ops.quantize_int8(x)[0]),
        ("quantize_int8_ref", lambda: ref.quantize_int8(x)[0]),
        ("ternarize_pallas", lambda: ops.ternarize(x)[0]),
        ("ternarize_ref", lambda: ref.ternarize(x)[0]),
        ("topk_sparsify_pallas", lambda: ops.topk_sparsify(x, 0.01, sample=4096)),
        ("fused_add_pallas", lambda: ops.fused_add(bufs)),
        ("fused_add_ref", lambda: ref.fused_add(bufs)),
    ]
    for name, fn in cases:
        jfn = jax.jit(fn)
        us = _bench(jfn)
        gbps = n * 4 / (us / 1e6) / 1e9
        rows.append(dict(name=name, us_per_call=us, derived=f"{gbps:.2f}GB/s"))
    return rows
