"""Roofline analysis from the dry-run artifacts (deliverable g).

Reads ``artifacts/dryrun/results.json`` (written by repro.launch.dryrun) and
derives, per (arch x shape x mesh):

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s          [s]
    memory term     = HLO_bytes_per_device / HBM_bw               [s]
    collective term = wire_bytes_per_device / ICI link bw         [s]

``cost_analysis`` of an SPMD-partitioned module is per-device, so no
division by chip count is needed.  Collective wire bytes per device are
derived from the summed *output* shapes of collective ops in the compiled
HLO: an all-reduce moves ~2x its output over the ring, everything else ~1x
(the (N-1)/N factor is ~1 at N=16/256).

Also reports MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference)
against HLO FLOPs — the "useful-compute" ratio that catches remat and
redundancy waste.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"

_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def wire_bytes(coll: Dict[str, float]) -> float:
    return sum(_WIRE_FACTOR.get(k, 1.0) * v for k, v in coll.items())


def roofline_row(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    cfg = get_config(rec["arch"])
    shape = INPUT_SHAPES[rec["shape"]]
    from repro.core.flops import model_flops

    n_dev = rec["devices"]
    # prefer the loop-trip-aware analysis (repro.utils.hlo.analyze); the raw
    # cost_analysis numbers count while bodies once and under-report by ~L
    ana = rec.get("analyzed")
    if ana:
        flops, nbytes = ana["flops"], ana["bytes"]
        coll = ana["collective_bytes"]
    else:
        flops, nbytes = rec["flops"], rec["bytes_accessed"]
        coll = rec.get("collective_bytes", {})
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = nbytes / HBM_BW
    t_coll = wire_bytes(coll) / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape) / n_dev          # useful FLOPs per device
    ratio = mf / flops if flops else 0.0
    hbm_gib = rec["memory"].get("temp_size_in_bytes", 0) / 2**30
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec.get("kind", ""),
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops_ratio": ratio,
        "temp_hbm_gib": hbm_gib,
        "note": rec.get("note", ""),
    }


def load_table(path: Optional[Path] = None) -> List[Dict]:
    path = path or (ARTIFACTS / "results.json")
    recs = json.loads(Path(path).read_text())
    rows = []
    for rec in recs:
        row = roofline_row(rec)
        if row:
            rows.append(row)
        elif rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec.get("mesh", ""), "kind": "skipped",
                         "note": rec.get("note", "")})
    return rows


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute ms | memory ms | collective ms "
           "| dominant | useful-FLOP ratio | temp GiB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r.get("kind") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                       f"| — | skipped | — | — |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} "
            f"| {r['collective_s']*1e3:.2f} | **{r['dominant']}** "
            f"| {r['model_flops_ratio']:.2f} | {r['temp_hbm_gib']:.1f} |\n")
    return "".join(out)


def main(path: Optional[str] = None):
    rows = load_table(Path(path) if path else None)
    print("arch,shape,mesh,compute_ms,memory_ms,collective_ms,dominant,"
          "useful_flop_ratio,temp_hbm_gib")
    for r in rows:
        if r.get("kind") == "skipped":
            print(f"{r['arch']},{r['shape']},{r['mesh']},,,,skipped,,")
            continue
        print(f"{r['arch']},{r['shape']},{r['mesh']},"
              f"{r['compute_s']*1e3:.3f},{r['memory_s']*1e3:.3f},"
              f"{r['collective_s']*1e3:.3f},{r['dominant']},"
              f"{r['model_flops_ratio']:.3f},{r['temp_hbm_gib']:.2f}")
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else None)
