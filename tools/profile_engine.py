#!/usr/bin/env python
"""cProfile one grid cell and dump the hottest functions.

The sweep engine's perf trajectory is tracked by
``benchmarks/sweep_bench.py``; when a number there moves, this tool says
*where* the time went.  It resolves a registered grid (or suite), expands
its cells, runs one cell under ``cProfile``, and prints the top functions
by cumulative time — the view that pins whether a regression lives in the
event engine, the lowering, or the experiment layer.

Usage::

    python tools/profile_engine.py --grid xxl-contention --cell 47
    python tools/profile_engine.py --grid paper-fig3 --cell 0 --top 30
    python tools/profile_engine.py --grid xxl-contention --cell 47 --phases
    python tools/profile_engine.py --grid xxl-contention --list

``--cell`` indexes the concatenation of every spec's expanded cells when
the name resolves to a suite.  ``--repeat`` runs the cell several times
under one profile so short cells rise above interpreter noise; the first
(unprofiled) run warms timeline caches, so the profile shows steady-state
cost, not import/build cost.

``--phases`` skips cProfile and prints a wall-clock breakdown of the
cell into the pipeline's stages — lower (plan -> flows/batch), perturb
(jitter), engine (event loop / closed form), collect (results -> bucket
spans) and other (fusion, plan build, assembly) — so a hillclimb sees
where time went without reading profiler output.
"""
from __future__ import annotations

import argparse
import cProfile
import pstats
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))


def _cells(grid: str) -> List[Tuple]:
    from repro.experiments import grids
    out = []
    for spec in grids.resolve(grid):
        out.extend((spec, cell) for cell in spec.expand())
    return out


def _run_phases(spec, cell, repeat: int) -> Tuple[Dict[str, float], float]:
    """Time one cell with the simulator's pipeline stages instrumented.

    Wraps the functions the simulator module actually calls (its own
    globals, so ``from ... import`` binding is respected) with exclusive
    wall-clock accumulators; the serve wrappers subtract time already
    booked to nested stages, so the four phases plus ``other`` partition
    the cell's wall time."""
    from repro.core import simulator as sim

    acc = {"lower": 0.0, "perturb": 0.0, "engine": 0.0, "collect": 0.0}
    saved = []

    def wrap(name: str, phase: str):
        orig = getattr(sim, name)

        def timed(*a, **k):
            t0 = time.perf_counter_ns()
            try:
                return orig(*a, **k)
            finally:
                acc[phase] += (time.perf_counter_ns() - t0) / 1e9

        saved.append((name, orig))
        setattr(sim, name, timed)

    def wrap_serve(name: str):
        orig = getattr(sim, name)

        def timed(*a, **k):
            t0 = time.perf_counter_ns()
            before = sum(acc.values())
            try:
                return orig(*a, **k)
            finally:
                nested = sum(acc.values()) - before
                acc["collect"] += ((time.perf_counter_ns() - t0) / 1e9
                                   - nested)

        saved.append((name, orig))
        setattr(sim, name, timed)

    for name in ("plan_to_flows", "plan_to_flow_batch", "clone_flows",
                 "concat_batches"):
        wrap(name, "lower")
    for name in ("perturb_flows", "perturb_batch"):
        wrap(name, "perturb")
    for name in ("run_flows", "run_flow_batch", "_fifo_fast_results",
                 "_fifo_fast_batch"):
        wrap(name, "engine")
    wrap_serve("_serve_from_batch")
    wrap_serve("_serve_plan")

    from repro.experiments.runner import run_cell
    try:
        t0 = time.perf_counter_ns()
        for _ in range(max(repeat, 1)):
            run_cell(spec, cell)
        total = (time.perf_counter_ns() - t0) / 1e9
    finally:
        for name, orig in saved:
            setattr(sim, name, orig)
    return acc, total


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python tools/profile_engine.py",
        description="cProfile one cell of a registered grid")
    ap.add_argument("--grid", required=True,
                    help="registered grid or suite name (see "
                         "`python -m repro.experiments list`)")
    ap.add_argument("--cell", type=int, default=0,
                    help="cell index into the expanded grid (default 0)")
    ap.add_argument("--top", type=int, default=20,
                    help="how many functions to print (default 20)")
    ap.add_argument("--sort", default="cumulative",
                    choices=("cumulative", "tottime", "ncalls"),
                    help="pstats sort key (default cumulative)")
    ap.add_argument("--repeat", type=int, default=3,
                    help="profiled repetitions of the cell (default 3)")
    ap.add_argument("--phases", action="store_true",
                    help="print a lower/perturb/engine/collect wall-clock "
                         "breakdown instead of a cProfile listing")
    ap.add_argument("--list", action="store_true",
                    help="print the grid's cells with indices and exit")
    args = ap.parse_args(argv)

    cells = _cells(args.grid)
    if args.list:
        for i, (spec, cell) in enumerate(cells):
            print(f"{i:4d}  {spec.name}  {cell.to_dict()}")
        return 0
    if not 0 <= args.cell < len(cells):
        print(f"--cell {args.cell} out of range: {args.grid} has "
              f"{len(cells)} cells (use --list)")
        return 2

    from repro.experiments.runner import run_cell
    spec, cell = cells[args.cell]
    print(f"profiling {spec.name} cell {args.cell}: {cell.to_dict()} "
          f"(x{args.repeat})")
    run_cell(spec, cell)            # warm timeline/transport caches
    if args.phases:
        acc, total = _run_phases(spec, cell, args.repeat)
        reps = max(args.repeat, 1)
        other = max(total - sum(acc.values()), 0.0)
        print(f"{'phase':<10}{'ms/cell':>10}{'share':>8}")
        for phase in ("lower", "perturb", "engine", "collect"):
            print(f"{phase:<10}{acc[phase] / reps * 1e3:>10.2f}"
                  f"{acc[phase] / total:>7.0%}")
        print(f"{'other':<10}{other / reps * 1e3:>10.2f}"
              f"{other / total:>7.0%}")
        print(f"{'total':<10}{total / reps * 1e3:>10.2f}")
        return 0
    prof = cProfile.Profile()
    prof.enable()
    for _ in range(max(args.repeat, 1)):
        run_cell(spec, cell)
    prof.disable()
    stats = pstats.Stats(prof)
    stats.sort_stats(args.sort).print_stats(args.top)
    return 0


if __name__ == "__main__":
    sys.exit(main())
