#!/usr/bin/env python
"""cProfile one grid cell and dump the hottest functions.

The sweep engine's perf trajectory is tracked by
``benchmarks/sweep_bench.py``; when a number there moves, this tool says
*where* the time went.  It resolves a registered grid (or suite), expands
its cells, runs one cell under ``cProfile``, and prints the top functions
by cumulative time — the view that pins whether a regression lives in the
event engine, the lowering, or the experiment layer.

Usage::

    python tools/profile_engine.py --grid xxl-contention --cell 47
    python tools/profile_engine.py --grid paper-fig3 --cell 0 --top 30
    python tools/profile_engine.py --grid xxl-contention --list

``--cell`` indexes the concatenation of every spec's expanded cells when
the name resolves to a suite.  ``--repeat`` runs the cell several times
under one profile so short cells rise above interpreter noise; the first
(unprofiled) run warms timeline caches, so the profile shows steady-state
cost, not import/build cost.
"""
from __future__ import annotations

import argparse
import cProfile
import pstats
import sys
from pathlib import Path
from typing import List, Optional, Tuple

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))


def _cells(grid: str) -> List[Tuple]:
    from repro.experiments import grids
    out = []
    for spec in grids.resolve(grid):
        out.extend((spec, cell) for cell in spec.expand())
    return out


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python tools/profile_engine.py",
        description="cProfile one cell of a registered grid")
    ap.add_argument("--grid", required=True,
                    help="registered grid or suite name (see "
                         "`python -m repro.experiments list`)")
    ap.add_argument("--cell", type=int, default=0,
                    help="cell index into the expanded grid (default 0)")
    ap.add_argument("--top", type=int, default=20,
                    help="how many functions to print (default 20)")
    ap.add_argument("--sort", default="cumulative",
                    choices=("cumulative", "tottime", "ncalls"),
                    help="pstats sort key (default cumulative)")
    ap.add_argument("--repeat", type=int, default=3,
                    help="profiled repetitions of the cell (default 3)")
    ap.add_argument("--list", action="store_true",
                    help="print the grid's cells with indices and exit")
    args = ap.parse_args(argv)

    cells = _cells(args.grid)
    if args.list:
        for i, (spec, cell) in enumerate(cells):
            print(f"{i:4d}  {spec.name}  {cell.to_dict()}")
        return 0
    if not 0 <= args.cell < len(cells):
        print(f"--cell {args.cell} out of range: {args.grid} has "
              f"{len(cells)} cells (use --list)")
        return 2

    from repro.experiments.runner import run_cell
    spec, cell = cells[args.cell]
    print(f"profiling {spec.name} cell {args.cell}: {cell.to_dict()} "
          f"(x{args.repeat})")
    run_cell(spec, cell)            # warm timeline/transport caches
    prof = cProfile.Profile()
    prof.enable()
    for _ in range(max(args.repeat, 1)):
        run_cell(spec, cell)
    prof.disable()
    stats = pstats.Stats(prof)
    stats.sort_stats(args.sort).print_stats(args.top)
    return 0


if __name__ == "__main__":
    sys.exit(main())
