#!/usr/bin/env python
"""Docs drift gate (CI `docs` job): documented sweeps must stay real.

Two checks over README.md, ROADMAP.md, and docs/*.md:

1. every ``--grid NAME`` mentioned anywhere must name a registered grid
   or suite (``repro.experiments.grids``);
2. every documented ``python -m repro.experiments ...`` command line —
   in fenced code blocks or inline code spans — must parse against the
   real CLI parser (``repro.experiments.cli.build_parser``), i.e. a
   ``--help``-level smoke test with no simulation run.

Plus one cross-reference check: every committed golden artifact
(``artifacts/golden/*.json``) must be named in both the CI workflow
(``.github/workflows/ci.yml`` — so it actually gates something) and
``docs/GOLDEN_ARTIFACTS.md`` (so its refresh procedure is documented).

And one bench cross-reference: every backticked snake_case metric name
in the README *Performance* table must be a key of the committed bench
baseline (``artifacts/bench/BENCH_sweep.json`` ``metrics``), so the
perf table cannot quote numbers the bench no longer produces.

Snippets containing an obvious placeholder (``<suite>``, ``...``,
``{run,...}``) are skipped as templates.  The gate also enforces a floor
on how many lines/names it found, so a regex regression cannot silently
turn the check into a no-op.

Usage: python tools/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import List, Tuple

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

DOC_FILES = ["README.md", "ROADMAP.md",
             *sorted(p.relative_to(REPO).as_posix()
                     for p in (REPO / "docs").glob("*.md"))]

# a documented line found fewer times than this means the extractor broke
MIN_CLI_LINES = 5
MIN_GRID_MENTIONS = 5

_GRID_RE = re.compile(r"--grid[= ]+(\S+)")
_CLI_RE = re.compile(r"python -m repro\.experiments(?:\s|$)")
_FENCE_RE = re.compile(r"^```")
_INLINE_RE = re.compile(r"`([^`]+)`", re.S)


def _is_template(snippet: str) -> bool:
    return any(tok in snippet for tok in ("<", ">", "...", "…", "{", "}"))


def _code_snippets(text: str) -> List[str]:
    """Lines of fenced code blocks + whitespace-normalized inline spans.

    Shell comments are stripped from fenced lines, and fenced blocks are
    removed before inline-span matching so a ``` fence cannot masquerade
    as a giant inline span.
    """
    out: List[str] = []
    prose: List[str] = []
    in_fence = False
    for line in text.splitlines():
        if _FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            stripped = line.split(" #")[0].strip()
            # shell continuations: fold `cmd \` + its next line(s) into one
            if out and out[-1].endswith("\\"):
                out[-1] = out[-1][:-1].rstrip() + " " + stripped
            else:
                out.append(stripped)
        else:
            prose.append(line)
    for m in _INLINE_RE.finditer("\n".join(prose)):
        out.append(" ".join(m.group(1).split()))
    return out


def check_file(path: Path, known: set, parser) -> Tuple[List[str], int, int]:
    text = path.read_text()
    failures: List[str] = []
    n_grids = n_lines = 0

    for m in _GRID_RE.finditer(text):
        tok = m.group(1)
        if _is_template(tok):
            continue
        word = re.match(r"[\w.-]+", tok)
        name = word.group(0) if word else tok
        n_grids += 1
        if name not in known:
            failures.append(
                f"{path.name}: `--grid {name}` is not a registered "
                f"grid or suite")

    for snippet in _code_snippets(text):
        m = _CLI_RE.search(snippet)
        if not m or _is_template(snippet):
            continue
        argv = snippet[m.end():].split()
        if not argv:
            continue
        n_lines += 1
        try:
            parser.parse_args(argv)
        except SystemExit as e:
            if e.code not in (0, None):
                failures.append(
                    f"{path.name}: CLI line does not parse: {snippet}")
    return failures, n_grids, n_lines


_METRIC_RE = re.compile(r"`([a-z0-9]+(?:_[a-z0-9]+)+)`")


def check_perf_table_metrics() -> Tuple[List[str], int]:
    """README Performance-table metric names must exist in the baseline.

    The perf table labels every number with its ``BENCH_sweep.json``
    metric key in backticks; a renamed or dropped metric must take its
    README row with it, or the table quotes numbers nothing produces."""
    import json

    failures: List[str] = []
    readme = (REPO / "README.md").read_text()
    bench = REPO / "artifacts" / "bench" / "BENCH_sweep.json"
    if not bench.exists():
        return ["artifacts/bench/BENCH_sweep.json is missing (the README "
                "Performance table references its metrics)"], 0
    metrics = set(json.loads(bench.read_text())["metrics"])

    m = re.search(r"^## Performance$(.*?)(?=^## )", readme,
                  re.M | re.S)
    if not m:
        return ["README.md: no `## Performance` section found; the perf "
                "table metric check may have rotted"], 0
    names = set()
    for line in m.group(1).splitlines():
        if line.lstrip().startswith("|"):
            names.update(_METRIC_RE.findall(line))
    for name in sorted(names - metrics):
        failures.append(
            f"README.md: perf table references `{name}` but it is not a "
            f"metric in artifacts/bench/BENCH_sweep.json")
    return failures, len(names)


def check_golden_references() -> Tuple[List[str], int]:
    """Every artifacts/golden/*.json must be gated in CI and documented.

    An artifact that CI never compares is dead weight that silently rots;
    one missing from docs/GOLDEN_ARTIFACTS.md has no refresh procedure."""
    failures: List[str] = []
    goldens = sorted((REPO / "artifacts" / "golden").glob("*.json"))
    refs = {
        ".github/workflows/ci.yml": "gated by the sim-regression job",
        "docs/GOLDEN_ARTIFACTS.md": "documented with a refresh command",
    }
    texts = {rel: (REPO / rel).read_text() if (REPO / rel).exists() else None
             for rel in refs}
    for rel, text in texts.items():
        if text is None:
            failures.append(f"{rel}: file is missing (golden artifacts "
                            f"must be {refs[rel]})")
    for path in goldens:
        for rel, text in texts.items():
            if text is not None and path.name not in text:
                failures.append(
                    f"artifacts/golden/{path.name}: not named in {rel} "
                    f"(every golden artifact must be {refs[rel]})")
    return failures, len(goldens)


def main() -> int:
    from repro.experiments import grids
    from repro.experiments.cli import build_parser

    known = set(grids.GRIDS) | set(grids.SUITES)
    parser = build_parser()
    failures: List[str] = []
    total_grids = total_lines = 0

    for rel in DOC_FILES:
        path = REPO / rel
        if not path.exists():
            failures.append(f"{rel}: documented file is missing")
            continue
        fails, n_grids, n_lines = check_file(path, known, parser)
        failures.extend(fails)
        total_grids += n_grids
        total_lines += n_lines
        print(f"{rel}: {n_grids} --grid mention(s), "
              f"{n_lines} CLI line(s) checked")

    golden_fails, n_goldens = check_golden_references()
    failures.extend(golden_fails)
    print(f"artifacts/golden: {n_goldens} golden artifact(s) "
          f"cross-referenced against ci.yml and docs/GOLDEN_ARTIFACTS.md")
    if n_goldens == 0:
        failures.append("extractor found no artifacts/golden/*.json; "
                        "the golden cross-reference check may have rotted")

    perf_fails, n_metrics = check_perf_table_metrics()
    failures.extend(perf_fails)
    print(f"README.md: {n_metrics} perf-table metric name(s) checked "
          f"against artifacts/bench/BENCH_sweep.json")
    if n_metrics < 5:
        failures.append(
            f"extractor found only {n_metrics} perf-table metric names "
            f"(< 5); the perf-table metric check may have rotted")

    if total_lines < MIN_CLI_LINES:
        failures.append(
            f"extractor found only {total_lines} CLI lines "
            f"(< {MIN_CLI_LINES}); the docs check may have rotted")
    if total_grids < MIN_GRID_MENTIONS:
        failures.append(
            f"extractor found only {total_grids} --grid mentions "
            f"(< {MIN_GRID_MENTIONS}); the docs check may have rotted")

    if failures:
        print()
        for f in failures:
            print(f"FAIL: {f}")
        return 1
    print(f"docs OK: {total_grids} grid mentions, {total_lines} CLI "
          f"lines, and {n_goldens} golden artifacts all resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
