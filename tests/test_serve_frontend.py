"""ServeFrontend: readiness probe, per-request timeout, graceful drain.

Exercises the HTTP wrapper with a plain handler (no model build) so the
contract — 200/503 healthz, 504 past the budget, drain flips the probe
and stops the listener — is pinned without accelerator work.
"""
import json
import threading
import time
import urllib.error
import urllib.request

import pytest

from repro.launch.serve import ServeFrontend


def _get(port, path):
    try:
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}{path}", timeout=5) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def _post(port, path, payload):
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}{path}",
        data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=5) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


@pytest.fixture
def frontend():
    def handler(payload):
        if payload.get("sleep"):
            time.sleep(float(payload["sleep"]))
        if payload.get("boom"):
            raise RuntimeError("boom")
        return {"echo": payload.get("x", 0)}

    front = ServeFrontend(handler, request_timeout=0.2, grace=2.0)
    t = threading.Thread(target=front.serve_forever, daemon=True)
    t.start()
    yield front
    if not front.draining.is_set():
        front.drain()
    t.join(5)
    assert not t.is_alive()


def test_healthz_and_run(frontend):
    assert _get(frontend.port, "/healthz") == (200, {"status": "ok"})
    code, body = _post(frontend.port, "/run", {"x": 42})
    assert (code, body) == (200, {"echo": 42})


def test_unknown_routes_and_bad_json(frontend):
    assert _get(frontend.port, "/nope")[0] == 404
    req = urllib.request.Request(
        f"http://127.0.0.1:{frontend.port}/run", data=b"{not json")
    with pytest.raises(urllib.error.HTTPError) as exc:
        urllib.request.urlopen(req, timeout=5)
    assert exc.value.code == 400


def test_handler_exception_is_500(frontend):
    code, body = _post(frontend.port, "/run", {"boom": True})
    assert code == 500 and "boom" in body["error"]


def test_slow_request_times_out_504(frontend):
    code, body = _post(frontend.port, "/run", {"sleep": 2.0})
    assert code == 504 and "exceeded" in body["error"]
    # the server stays healthy after abandoning the worker
    assert _get(frontend.port, "/healthz")[0] == 200


def test_drain_flips_probe_and_stops_listener(frontend):
    port = frontend.port
    done = threading.Event()
    results = {}

    def inflight():
        results["resp"] = _post(port, "/run", {"sleep": 0.1, "x": 1})
        done.set()

    threading.Thread(target=inflight, daemon=True).start()
    time.sleep(0.03)  # let the request reach the handler
    frontend.drain()
    # the in-flight request finished before the listener stopped
    assert done.wait(5) and results["resp"] == (200, {"echo": 1})
    assert frontend.draining.is_set()
    with pytest.raises(urllib.error.URLError):
        urllib.request.urlopen(f"http://127.0.0.1:{port}/healthz",
                               timeout=0.5)


def test_draining_rejects_new_work():
    front = ServeFrontend(lambda p: {"ok": True}, request_timeout=1.0,
                          grace=1.0)
    t = threading.Thread(target=front.serve_forever, daemon=True)
    t.start()
    front.draining.set()  # probe flips before the listener dies
    assert _get(front.port, "/healthz") == (503, {"status": "draining"})
    assert _post(front.port, "/run", {})[0] == 503
    front.drain()
    t.join(5)
    assert not t.is_alive()
