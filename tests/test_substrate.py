"""Substrate tests: data pipeline determinism, checkpoint roundtrip,
optimizers descend, train/serve launchers run end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.data.pipeline import Prefetcher, SyntheticLM

# interpret-mode Pallas / full-model tests: minutes of wall clock on CPU
pytestmark = pytest.mark.slow



# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_data_deterministic_per_step():
    cfg = get_config("stablelm-3b").smoke()
    shape = INPUT_SHAPES["train_4k"].smoke()
    a = SyntheticLM(cfg, shape, seed=7).batch(3)
    b = SyntheticLM(cfg, shape, seed=7).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(cfg, shape, seed=8).batch(3)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_labels_are_shifted_stream():
    cfg = get_config("stablelm-3b").smoke()
    shape = INPUT_SHAPES["train_4k"].smoke()
    b = SyntheticLM(cfg, shape).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert b["tokens"].max() < cfg.vocab_size


def test_data_frontend_stubs():
    vlm = get_config("internvl2-2b").smoke()
    b = SyntheticLM(vlm, INPUT_SHAPES["train_4k"].smoke()).batch(0)
    assert b["prefix_embeds"].shape == (2, vlm.prefix_embeds, vlm.d_model)
    enc = get_config("whisper-base").smoke()
    b = SyntheticLM(enc, INPUT_SHAPES["train_4k"].smoke()).batch(0)
    assert b["frames"].shape == (2, enc.encoder_seq, enc.d_model)


def test_prefetcher():
    cfg = get_config("stablelm-3b").smoke()
    shape = INPUT_SHAPES["train_4k"].smoke()
    it = Prefetcher(iter(SyntheticLM(cfg, shape)), depth=2)
    b0, b1 = next(it), next(it)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    it.close()


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.store import latest_step, restore, save
    tree = {"w": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "c": jnp.asarray(3)}}
    save(tmp_path, tree, step=10)
    save(tmp_path, jax.tree_util.tree_map(lambda x: x * 0, tree), step=20)
    assert latest_step(tmp_path) == 20
    r10 = restore(tmp_path, tree, step=10)
    for a, b in zip(jax.tree_util.tree_leaves(r10),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    from repro.checkpoint.store import restore, save
    save(tmp_path, {"w": jnp.ones((2, 3))}, step=1)
    with pytest.raises(ValueError):
        restore(tmp_path, {"w": jnp.ones((3, 2))})


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["sgd", "momentum", "adamw"])
def test_optimizer_descends_quadratic(name):
    from repro.optim.optimizers import get_optimizer
    opt = get_optimizer(name)
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(jnp.square(p["x"]))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(params, state, g, 0.05)
    assert float(loss(params)) < 0.05


def test_adamw_moments_are_f32():
    from repro.optim.optimizers import get_optimizer
    opt = get_optimizer("adamw")
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = opt.init(params)
    assert state.mu["w"].dtype == jnp.float32
    assert state.nu["w"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# launchers (integration)
# ---------------------------------------------------------------------------

def test_train_loss_decreases():
    from repro.launch import train as train_mod
    res = train_mod.main(["--arch", "stablelm-3b", "--smoke", "--steps", "8",
                          "--log-every", "100"])
    assert res["loss_decreased"], res


def test_train_explicit_comm_matches_auto():
    from repro.launch import train as train_mod
    r_auto = train_mod.main(["--arch", "stablelm-3b", "--smoke", "--steps",
                             "5", "--comm-mode", "auto", "--log-every", "100"])
    r_exp = train_mod.main(["--arch", "stablelm-3b", "--smoke", "--steps",
                            "5", "--comm-mode", "explicit", "--log-every",
                            "100"])
    # on one device explicit sync is a no-op: identical loss trajectories
    assert abs(r_auto["last_loss"] - r_exp["last_loss"]) < 1e-4


def test_serve_generates():
    from repro.launch import serve as serve_mod
    res = serve_mod.main(["--arch", "stablelm-3b", "--smoke", "--batch", "2",
                          "--prompt-len", "16", "--gen", "4"])
    assert res["decode_tok_per_s"] > 0


# ---------------------------------------------------------------------------
# flops model sanity
# ---------------------------------------------------------------------------

def test_param_count_matches_init():
    """Analytic per-layer params within 10% of the real initialized tree
    (analytic model skips norms/padding; both are sub-percent at scale)."""
    from repro.core.flops import param_count
    from repro.models.registry import get_model
    for arch in ("stablelm-3b", "rwkv6-1.6b", "moonshot-v1-16b-a3b"):
        cfg = get_config(arch).smoke()
        api = get_model(cfg)
        p = jax.eval_shape(api.init, jax.random.key(0))
        real = sum(int(l.size) for l in jax.tree_util.tree_leaves(p))
        analytic = param_count(cfg)
        assert abs(real - analytic) / real < 0.10, (arch, real, analytic)


def test_model_flops_scaling():
    from repro.core.flops import model_flops
    cfg = get_config("deepseek-coder-33b")
    t = model_flops(cfg, INPUT_SHAPES["train_4k"])
    p = model_flops(cfg, INPUT_SHAPES["prefill_32k"])
    # train = 6ND vs prefill = 2ND at equal token counts
    assert t / p == pytest.approx(3.0, rel=0.01)
    d = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert d < p / 1000     # one token vs 32k tokens


def test_moe_active_params():
    from repro.core.flops import active_param_count, param_count
    cfg = get_config("arctic-480b")
    assert active_param_count(cfg) < 0.2 * param_count(cfg)


# ---------------------------------------------------------------------------
# schedules / clipping
# ---------------------------------------------------------------------------

def test_warmup_cosine_schedule():
    from repro.optim.schedule import warmup_cosine
    lr = warmup_cosine(1e-3, warmup_steps=10, total_steps=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(5)) == pytest.approx(5e-4, rel=1e-5)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-3)   # final_frac
    # monotone decay after warmup
    vals = [float(lr(s)) for s in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_clip_by_global_norm():
    from repro.optim.schedule import clip_by_global_norm, global_norm
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # under the limit: untouched
    same, _ = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(g["a"]))
