"""Model-level Pallas kernels (WKV recurrence, flash attention) vs their
pure-jnp oracles, swept over shapes."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention_pallas
from repro.kernels.wkv import wkv_pallas
from repro.models.attention import flash_attention as flash_jnp
from repro.models.rwkv import wkv_chunked

# interpret-mode Pallas / full-model tests: minutes of wall clock on CPU
pytestmark = pytest.mark.slow



def _tr(x):
    return x.transpose(0, 2, 1, 3)


@pytest.mark.parametrize("B,H,S,hd,chunk", [
    (2, 3, 128, 64, 64), (1, 1, 64, 64, 64), (2, 2, 256, 32, 32),
    (1, 4, 192, 64, 64),
])
def test_wkv_pallas_matches_chunked_ref(B, H, S, hd, chunk):
    ks = jax.random.split(jax.random.key(B * 1000 + S), 6)
    r = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32) * 0.5
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, hd)) * 0.5 - 2.0)
    u = jax.random.normal(ks[4], (H, hd), jnp.float32) * 0.1
    s0 = jax.random.normal(ks[5], (B, H, hd, hd), jnp.float32) * 0.1
    y_ref, s_ref = wkv_chunked(r, k, v, logw, u, s0, chunk)
    y_p, s_p = wkv_pallas(_tr(r), _tr(k), _tr(v), _tr(logw), u, s0,
                          chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(_tr(y_ref)), np.asarray(y_p),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_p),
                               rtol=1e-4, atol=1e-4)


def test_wkv_pallas_state_chain():
    """Splitting a sequence into two pallas calls (carrying the state)
    equals one call over the concatenation."""
    B, H, S, hd = 1, 2, 128, 64
    ks = jax.random.split(jax.random.key(7), 5)
    mk = lambda i, scale=0.5: jax.random.normal(ks[i], (B, H, S, hd)) * scale
    r, k, v = mk(0), mk(1), mk(2)
    logw = -jnp.exp(mk(3) * 0.3 - 2.0)
    u = jnp.zeros((H, hd))
    s0 = jnp.zeros((B, H, hd, hd))
    y_full, s_full = wkv_pallas(r, k, v, logw, u, s0, chunk=64,
                                interpret=True)
    half = S // 2
    y1, s1 = wkv_pallas(r[:, :, :half], k[:, :, :half], v[:, :, :half],
                        logw[:, :, :half], u, s0, chunk=64, interpret=True)
    y2, s2 = wkv_pallas(r[:, :, half:], k[:, :, half:], v[:, :, half:],
                        logw[:, :, half:], u, s1, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y_full[:, :, half:]),
                               np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("BH,Sq,hd,causal", [
    (4, 256, 64, True), (2, 128, 64, False), (1, 512, 32, True),
    (3, 128, 128, True),
])
def test_flash_pallas_matches_softmax(BH, Sq, hd, causal):
    ks = jax.random.split(jax.random.key(BH * 31 + Sq), 3)
    q = jax.random.normal(ks[0], (BH, Sq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (BH, Sq, hd), jnp.float32)
    v = jax.random.normal(ks[2], (BH, Sq, hd), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=causal, interpret=True)
    # dense reference
    s = jnp.einsum("bqd,bkd->bqk", q, k) / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sq), bool))
        s = jnp.where(mask, s, -1e30)
    ref = jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(s, axis=-1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_flash_pallas_matches_model_flash():
    """Pallas kernel agrees with the pure-jnp chunked attention used by the
    model stack (same semantics, different implementations)."""
    B, H, S, hd = 2, 4, 256, 64
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, hd), jnp.float32)
    ref = flash_jnp(q, k, v, causal=True, chunk=128)
    out = flash_attention_pallas(q.reshape(B * H, S, hd),
                                 k.reshape(B * H, S, hd),
                                 v.reshape(B * H, S, hd),
                                 causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(ref).reshape(B * H, S, hd),
                               np.asarray(out), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("B,S,di,n,chunk,di_block", [
    (2, 256, 256, 16, 64, 128), (1, 128, 128, 8, 128, 128),
    (2, 192, 512, 16, 64, 256),
])
def test_ssm_scan_pallas_matches_ref(B, S, di, n, chunk, di_block):
    from repro.kernels.ssm_scan import ssm_scan_pallas
    from repro.models.mamba import _ssm_scan_chunked
    ks = jax.random.split(jax.random.key(S + di), 4)
    decay = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, di, n)))
    bx = jax.random.normal(ks[1], (B, S, di, n)) * 0.3
    c_t = jax.random.normal(ks[2], (B, S, n)) * 0.5
    h0 = jax.random.normal(ks[3], (B, di, n)) * 0.1
    states, h_ref = _ssm_scan_chunked(decay, bx, h0, chunk)
    y_ref = jnp.einsum("bsdn,bsn->bsd", states, c_t)
    tr = lambda x: x.transpose(0, 1, 3, 2)
    y_p, h_p = ssm_scan_pallas(tr(decay), tr(bx), c_t,
                               h0.transpose(0, 2, 1), chunk=chunk,
                               di_block=di_block, interpret=True)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_p.transpose(0, 2, 1)),
                               np.asarray(h_ref), rtol=1e-4, atol=1e-4)


def test_pallas_dispatch_in_model():
    """cfg.use_pallas='always' routes gqa_forward through the Pallas kernel
    (custom_vjp: kernel forward, reference backward) with matching grads."""
    from repro.configs import get_config
    from repro.models import attention as a
    cfg = get_config("stablelm-3b").smoke().replace(attn_chunk=128,
                                                    head_dim=32)
    cfg_p = cfg.replace(use_pallas="always")
    p = a.init_gqa(jax.random.key(0), cfg, 0)
    x = jax.random.normal(jax.random.key(1), (2, 128, cfg.d_model)) * 0.3
    jaxpr = jax.make_jaxpr(lambda xx: a.gqa_forward(p, xx, cfg_p)[0])(x)
    assert "pallas_call" in str(jaxpr)
    out_ref, _ = a.gqa_forward(p, x, cfg)
    out_pal, _ = a.gqa_forward(p, x, cfg_p)
    np.testing.assert_allclose(np.asarray(out_ref), np.asarray(out_pal),
                               rtol=2e-4, atol=2e-4)
    g_ref = jax.grad(lambda xx: a.gqa_forward(p, xx, cfg)[0].sum())(x)
    g_pal = jax.grad(lambda xx: a.gqa_forward(p, xx, cfg_p)[0].sum())(x)
    np.testing.assert_allclose(np.asarray(g_ref), np.asarray(g_pal),
                               rtol=2e-4, atol=2e-4)


def test_flash_pallas_gqa_index_map():
    """GQA via kv index map equals explicit kv repetition."""
    from repro.kernels.flash_attn import flash_attention_pallas
    B, Hq, Hkv, S, hd = 2, 4, 2, 128, 64
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (B * Hq, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B * Hkv, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B * Hkv, S, hd), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, n_heads=Hq,
                                 n_kv_heads=Hkv, interpret=True)
    # reference: repeat kv heads explicitly
    G = Hq // Hkv
    k_rep = jnp.repeat(k.reshape(B, Hkv, S, hd), G, axis=1).reshape(B * Hq, S, hd)
    v_rep = jnp.repeat(v.reshape(B, Hkv, S, hd), G, axis=1).reshape(B * Hq, S, hd)
    ref = flash_attention_pallas(q, k_rep, v_rep, causal=True,
                                 n_heads=Hq, n_kv_heads=Hq, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


def test_pallas_dispatch_mamba():
    """use_pallas routes the mamba scan through kernels/ssm_scan with
    matching forward and (reference-backward) gradients."""
    from repro.configs import get_config
    from repro.models import mamba as m
    cfg = get_config("jamba-v0.1-52b").smoke().replace(mamba_fused_y=True)
    cfg_p = cfg.replace(use_pallas="always")
    p = m.init_mamba(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model)) * 0.4
    y0, _ = m.mamba_mixer(p, x, cfg)
    y1, _ = m.mamba_mixer(p, x, cfg_p)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-4, atol=1e-5)
    g0 = jax.grad(lambda xx: m.mamba_mixer(p, xx, cfg)[0].sum())(x)
    g1 = jax.grad(lambda xx: m.mamba_mixer(p, xx, cfg_p)[0].sum())(x)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1),
                               rtol=1e-4, atol=1e-5)
    jaxpr = jax.make_jaxpr(lambda xx: m.mamba_mixer(p, xx, cfg_p)[0])(x)
    assert "pallas_call" in str(jaxpr)


def test_pallas_dispatch_rwkv():
    """use_pallas routes WKV through kernels/wkv end-to-end (loss parity;
    grads within fp32 reordering noise)."""
    from repro.configs import get_config
    from repro.models.registry import get_model
    cfg = get_config("rwkv6-1.6b").smoke()
    api = get_model(cfg)
    api_p = get_model(cfg.replace(use_pallas="always"))
    params = api.init(jax.random.key(0))
    batch = {"tokens": jnp.ones((2, 64), jnp.int32),
             "labels": jnp.ones((2, 64), jnp.int32)}
    l0, _ = api.loss_fn(params, batch)
    l1, _ = api_p.loss_fn(params, batch)
    assert abs(float(l0) - float(l1)) < 1e-5
    g0 = jax.grad(lambda p: api.loss_fn(p, batch)[0])(params)
    g1 = jax.grad(lambda p: api_p.loss_fn(p, batch)[0])(params)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-3)
