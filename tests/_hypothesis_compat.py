"""Graceful degradation when ``hypothesis`` is not installed.

Five test modules use hypothesis for property tests but also contain many
plain pytest tests.  Importing this shim instead of hypothesis directly keeps
those plain tests collectable everywhere: with hypothesis present the real
``given``/``settings``/``st`` are re-exported; without it, ``given`` marks the
decorated test as skipped (via :func:`pytest.importorskip` at call time) and
``settings``/``st`` become inert stand-ins so module-level decorator
expressions still evaluate.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_a, **_kw):
        def deco(fn):
            def skipper(*args, **kwargs):
                pytest.importorskip("hypothesis")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_a, **_kw):
        return lambda fn: fn

    class _AnyStrategy:
        """Accepts any strategies.* attribute access / call chain."""

        def __getattr__(self, name):
            return _AnyStrategy()

        def __call__(self, *a, **kw):
            return _AnyStrategy()

    st = _AnyStrategy()
