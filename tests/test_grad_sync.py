"""Bucketed grad-sync: packing plan properties (hypothesis), single-device
semantics, and real multi-device collective semantics in a subprocess with 8
fake XLA devices."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import CommConfig
from repro.parallel.grad_sync import (BucketPlan, make_plan, pack, sync_grads,
                                      unpack)

REPO = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# packing plan
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(sizes=st.lists(st.integers(1, 10_000), min_size=1, max_size=40),
       limit_kb=st.integers(1, 256))
def test_plan_respects_limit_and_covers(sizes, limit_kb):
    shapes = [(s,) for s in sizes]
    plan = BucketPlan(shapes, [jnp.float32] * len(shapes), limit_kb * 1024)
    assert sum(plan.bucket_sizes) == sum(sizes)
    # no bucket exceeds the limit unless a single tensor does
    limit_elems = limit_kb * 1024 // 4
    for b, bsize in enumerate(plan.bucket_sizes):
        members = [s for s, (bb, _) in zip(plan.sizes, plan.assignments)
                   if bb == b]
        assert bsize <= max(limit_elems, max(members))
    # offsets are consistent
    for (b, off), size in zip(plan.assignments, plan.sizes):
        assert off + size <= plan.bucket_sizes[b]


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), limit_kb=st.integers(1, 64))
def test_pack_unpack_roundtrip(seed, limit_kb):
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.standard_normal((rng.integers(1, 50),
                                                  rng.integers(1, 50)))),
            "b": [jnp.asarray(rng.standard_normal(int(rng.integers(1, 999))),
                              dtype=jnp.float32),
                  jnp.asarray(rng.standard_normal(1).astype(np.float32))[0]]}
    plan, treedef = make_plan(tree, limit_kb / 1024.0)
    leaves = jax.tree_util.tree_leaves(tree)
    out = unpack(plan, pack(plan, leaves))
    for a, b in zip(out, leaves):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-6)


# ---------------------------------------------------------------------------
# single-device semantics (collectives degenerate to identity/mean-of-one)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("compression", ["none", "fp16", "int8"])
def test_sync_identity_on_one_device(compression):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    grads = {"w": jnp.arange(300, dtype=jnp.float32).reshape(20, 15) / 300.0,
             "b": jnp.ones((7,), jnp.bfloat16)}
    comm = CommConfig(compression=compression, hierarchical=False)
    out = sync_grads(grads, mesh, comm)
    tol = {"none": 1e-7, "fp16": 1e-2, "int8": 1e-2}[compression]
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(grads)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=tol)


# ---------------------------------------------------------------------------
# multi-device semantics (8 fake devices, subprocess)
# ---------------------------------------------------------------------------

_MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
sys.path.insert(0, "src")
from repro.configs.base import CommConfig
from repro.parallel.grad_sync import sync_grads

mesh = jax.make_mesh((2, 4), ("pod", "data"))
results = {}
for compression, hier in [("none", False), ("none", True), ("fp16", False),
                          ("int8", False), ("ternary", False)]:
    # per-device distinct gradients; the sync must produce their mean
    def make(shape, seed):
        vals = [jax.random.normal(jax.random.key(seed + i), shape)
                for i in range(8)]
        stacked = jnp.stack(vals)          # (8, ...)
        arr = jax.device_put(
            stacked.reshape(2, 4, *shape),
            NamedSharding(mesh, P("pod", "data")))
        return vals, arr

    vals_w, w = make((16, 8), 0)
    vals_b, b = make((40,), 100)
    expect_w = np.mean([np.asarray(v) for v in vals_w], axis=0)
    expect_b = np.mean([np.asarray(v) for v in vals_b], axis=0)

    comm = CommConfig(compression=compression, hierarchical=hier)
    # grads replicated per device: shard_map sees per-device blocks; here we
    # feed the (2,4,...)-stacked tree and read back block 0 via reshard
    import functools
    from jax.experimental.shard_map import shard_map
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P("pod", "data"), P("pod", "data")),
                       out_specs=(P("pod", "data"), P("pod", "data")),
                       check_rep=False)
    def run(wb, bb):
        g = {"w": wb[0, 0], "b": bb[0, 0]}
        from repro.parallel.grad_sync import _sync_bucket, make_plan, pack, unpack
        plan, tdef = make_plan(g, comm.fusion_buffer_mb)
        buckets = pack(plan, jax.tree_util.tree_leaves(g))
        axes = ("pod", "data")
        synced = [_sync_bucket(x, comm, axes, (2, 4)) for x in buckets]
        out = unpack(plan, synced)
        return out[1][None, None], out[0][None, None]   # leaves sorted: b, w

    out_w, out_b = run(w, b)   # run returns (w, b): leaves sort as (b, w)
    got_w = np.asarray(out_w)[0, 0]
    got_b = np.asarray(out_b)[0, 0]
    err_w = float(np.abs(got_w - expect_w).max())
    err_b = float(np.abs(got_b - expect_b).max())
    results[f"{compression}/{'hier' if hier else 'flat'}"] = [err_w, err_b]
print(json.dumps(results))
"""


@pytest.mark.slow
def test_multidevice_mean_semantics(tmp_path):
    script = tmp_path / "multidev.py"
    script.write_text(_MULTIDEV_SCRIPT)
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, str(script)], cwd=REPO, env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    results = json.loads(proc.stdout.strip().splitlines()[-1])
    # exact for none, small for fp16/int8, bounded for ternary
    tol = {"none/flat": 1e-6, "none/hier": 1e-6, "fp16/flat": 2e-2,
           "int8/flat": 2e-2, "ternary/flat": 1.5}
    for k, (ew, eb) in results.items():
        assert ew <= tol[k] and eb <= tol[k], (k, ew, eb)
