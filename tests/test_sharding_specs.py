"""Sharding rules produce divisibility-valid PartitionSpecs for every
architecture on the production mesh shapes — validated abstractly (no
devices needed): every sharded dim must divide by the mesh axis size."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.archs import ALL_ARCHS, FULL_ATTENTION, LONG_SKIP
from repro.models.registry import get_model
from repro.parallel import sharding as shd

MESH_AXES = {"data": 16, "model": 16}          # single-pod 16x16
MESH_AXES_MP = {"pod": 2, "data": 16, "model": 16}


def _axis_size(axes, name):
    if isinstance(name, tuple):
        n = 1
        for a in name:
            n *= axes.get(a, 1)
        return n
    return axes.get(name, 1)


def _check_specs(tree_sds, spec_tree, axes, what):
    leaves = jax.tree_util.tree_leaves_with_path(tree_sds)
    specs = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert len(leaves) == len(specs)
    for (path, leaf), spec in zip(leaves, specs):
        for dim, name in enumerate(spec):
            if name is None:
                continue
            size = _axis_size(axes, name)
            assert leaf.shape[dim] % size == 0, (
                f"{what}: {jax.tree_util.keystr(path)} dim {dim} "
                f"({leaf.shape}) not divisible by {name}={size}")


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_specs_divisible(arch):
    cfg = get_config(arch)
    api = get_model(cfg)
    p_sds = jax.eval_shape(api.init, jax.random.key(0))
    specs = shd.param_specs(p_sds, cfg)
    _check_specs(p_sds, specs, MESH_AXES, f"{arch} params")
    _check_specs(p_sds, specs, MESH_AXES_MP, f"{arch} params (mp)")


@pytest.mark.parametrize("arch", ALL_ARCHS)
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, shape_name):
    if shape_name == "long_500k" and arch in LONG_SKIP:
        pytest.skip("long_500k skipped for this arch by design")
    cfg = get_config(arch)
    if shape_name == "long_500k" and arch in FULL_ATTENTION:
        cfg = cfg.replace(sliding_window=4096)
    shape = INPUT_SHAPES[shape_name]
    api = get_model(cfg)
    spec_tree = api.cache_spec(shape.global_batch, shape.seq_len)
    is_leaf = lambda s: isinstance(s, tuple) and len(s) == 2
    sds = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s[0], s[1]), spec_tree, is_leaf=is_leaf)

    class FakeMesh:
        axis_names = tuple(MESH_AXES)
        class devices:
            shape = tuple(MESH_AXES.values())
    mesh = FakeMesh()

    leaves = jax.tree_util.tree_leaves_with_path(sds)
    for path, leaf in leaves:
        names = shd._path_names([p for p in path])
        spec = shd.cache_pspec(cfg, mesh, shape.global_batch, names,
                               len(leaf.shape))
        for dim, name in enumerate(spec):
            if name is None:
                continue
            size = _axis_size(MESH_AXES, name)
            assert leaf.shape[dim] % size == 0, (
                f"{arch}/{shape_name}: {names} dim {dim} {leaf.shape} "
                f"% {name}={size}")


def test_fsdp_changes_param_specs():
    cfg = get_config("command-r-35b")
    api = get_model(cfg)
    p_sds = jax.eval_shape(api.init, jax.random.key(0))
    fsdp = shd.param_specs(p_sds, cfg)
    dp = shd.param_specs(p_sds, cfg.replace(sharding="dp_tp"))
    fsdp_flat = jax.tree_util.tree_leaves(
        fsdp, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    dp_flat = jax.tree_util.tree_leaves(
        dp, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    n_data = sum(1 for s in fsdp_flat if "data" in jax.tree_util.tree_leaves(tuple(s)))
    assert n_data > 0, "fsdp must shard some params over data"
    n_data_dp = sum(1 for s in dp_flat if "data" in jax.tree_util.tree_leaves(tuple(s)))
    assert n_data_dp == 0, "dp_tp must not shard params over data"
