# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device.  Only repro/launch/dryrun.py (and the dedicated
# multi-device subprocess tests) force a fake device count, in their own
# processes.
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)
