"""Hardened sweep runner: crash-safe journal, resume byte-identity,
per-cell timeout/retry, graceful degradation of retry-exhausted cells,
atomic artifact writes, and compare's skip-and-report on failed cells."""
import json

import pytest

from repro.experiments import artifacts
from repro.experiments.compare import compare
from repro.experiments.runner import (_load_journal, run_cell, run_spec,
                                      run_suite)
from repro.experiments.spec import Cell, ExperimentSpec

TINY = ExperimentSpec(name="tiny-hardening", models=("resnet50",),
                      n_servers=(2,), bandwidth_gbps=(10.0,),
                      transport=("ideal",), scheduler=("fifo", "priority"))

# a grid whose second model cannot be built: the failure-injection vehicle
BROKEN = ExperimentSpec(name="tiny-broken",
                        models=("resnet50", "no-such-model"),
                        n_servers=(2,), bandwidth_gbps=(10.0,),
                        transport=("ideal",))


def test_hardened_serial_matches_default_bytewise(tmp_path):
    plain = run_spec(TINY, executor="serial")
    hard = run_spec(TINY, executor="serial", retries=2,
                    journal=tmp_path / "j.jsonl")
    assert json.dumps(plain, sort_keys=True) == \
        json.dumps(hard, sort_keys=True)


def test_journal_written_and_replayable(tmp_path):
    j = tmp_path / "tiny.jsonl"
    rec = run_spec(TINY, executor="serial", journal=j)
    lines = j.read_text().splitlines()
    head = json.loads(lines[0])
    assert head["kind"] == "repro-journal"
    assert head["spec_hash"] == TINY.spec_hash()
    assert len(lines) == 1 + len(rec["cells"])
    done = _load_journal(j, TINY)
    assert [done[i] for i in range(len(rec["cells"]))] == rec["cells"]


def test_resume_is_byte_identical_after_partial_journal(tmp_path):
    """The SIGKILL contract: keep the journal's prefix (plus a torn tail
    line, the crash boundary) and --resume must reproduce the single-shot
    artifact byte for byte."""
    j = tmp_path / "tiny.jsonl"
    single = run_spec(TINY, executor="serial", journal=j)
    lines = j.read_text().splitlines(keepends=True)
    # crash after the first completed cell, mid-write of the second
    (tmp_path / "tiny.jsonl").write_text(
        "".join(lines[:2]) + lines[2][: len(lines[2]) // 2])
    resumed = run_spec(TINY, executor="serial", journal=j, resume=True)
    a = tmp_path / "a.json"
    b = tmp_path / "b.json"
    artifacts.write(a, [single])
    artifacts.write(b, [resumed])
    assert a.read_bytes() == b.read_bytes()


def test_resume_reruns_failed_cells(tmp_path):
    j = tmp_path / "tiny.jsonl"
    run_spec(TINY, executor="serial", journal=j)
    lines = j.read_text().splitlines(keepends=True)
    # rewrite cell 0's entry as a failure record: resume must re-run it
    e = json.loads(lines[1])
    e["cell"] = {**Cell.from_dict(e["cell"]).to_dict(),
                 "failed": True, "error": "injected"}
    (tmp_path / "tiny.jsonl").write_text(
        lines[0] + json.dumps(e) + "\n" + "".join(lines[2:]))
    resumed = run_spec(TINY, executor="serial", journal=j, resume=True)
    assert not any(c.get("failed") for c in resumed["cells"])


def test_resume_refuses_foreign_journal(tmp_path):
    j = tmp_path / "other.jsonl"
    run_spec(BROKEN, executor="serial", journal=j, retries=0)
    with pytest.raises(ValueError, match="refusing to resume"):
        run_spec(TINY, executor="serial", journal=j, resume=True)


def test_retry_exhaustion_degrades_gracefully():
    """A cell that always raises is recorded with failure metadata; the
    sweep completes and the validations flag the degradation."""
    rec = run_spec(BROKEN, executor="serial", retries=1)
    ok = [c for c in rec["cells"] if not c.get("failed")]
    bad = [c for c in rec["cells"] if c.get("failed")]
    assert len(ok) == 1 and len(bad) == 1
    assert bad[0]["model"] == "no-such-model" and "error" in bad[0]
    assert rec["validations"]["no_failed_cells"] is False


def test_hardened_process_pool_completes(tmp_path):
    """The process path with a generous timeout must agree with the
    serial single-shot run byte for byte."""
    plain = run_spec(TINY, executor="serial")
    hard = run_spec(TINY, executor="process", cell_timeout=300.0, retries=1,
                    journal=tmp_path / "j.jsonl")
    assert json.dumps(plain, sort_keys=True) == \
        json.dumps(hard, sort_keys=True)


def test_process_timeout_degrades_gracefully():
    """An absurdly small per-cell budget: every charged cell eventually
    exhausts its retries, the sweep still completes with every cell
    recorded (done or failed), and nothing raises."""
    rec = run_spec(TINY, executor="process", cell_timeout=1e-4, retries=0)
    assert len(rec["cells"]) == TINY.n_cells
    for c in rec["cells"]:
        assert c.get("failed") or "t_sync" in c


def test_run_suite_journal_dir(tmp_path):
    out = run_suite([TINY], journal_dir=tmp_path / "journals")
    assert (tmp_path / "journals" / "tiny-hardening.jsonl").exists()
    assert len(out) == 1 and len(out[0]["cells"]) == TINY.n_cells


# ---------------------------------------------------------------------------
# atomic artifact writes
# ---------------------------------------------------------------------------

def test_artifact_write_is_atomic(tmp_path):
    p = tmp_path / "art.json"
    artifacts.write(p, [{"name": "x", "cells": []}])
    artifacts.write(p, [{"name": "y", "cells": []}])  # overwrite in place
    assert artifacts.read(p)["experiments"][0]["name"] == "y"
    # no temp debris left behind in the directory
    assert [f.name for f in tmp_path.iterdir()] == ["art.json"]


def test_artifact_write_failure_leaves_no_partial(tmp_path):
    p = tmp_path / "art.json"
    artifacts.write(p, [{"name": "x", "cells": []}])
    before = p.read_bytes()
    with pytest.raises(TypeError):
        artifacts.write(p, [{"bad": object()}])  # not JSON-serializable
    assert p.read_bytes() == before
    assert [f.name for f in tmp_path.iterdir()] == ["art.json"]


# ---------------------------------------------------------------------------
# compare: failed cells are skip-and-report, not crashes
# ---------------------------------------------------------------------------

def _art(cells, validations=None):
    return {"kind": "repro-experiment-artifact", "schema_version": 1,
            "experiments": [{"name": "tiny-hardening",
                             "spec_hash": TINY.spec_hash(),
                             "cells": cells,
                             "validations": validations or {}}]}


def test_compare_flags_new_side_failure():
    cells = [run_cell(TINY, c) for c in TINY.expand()]
    broken = [dict(cells[0]), {**Cell.from_dict(cells[1]).to_dict(),
                               "failed": True, "error": "boom"}]
    report = compare(_art(cells), _art(broken))
    assert not report.ok
    assert any("failed in new artifact" in v.detail
               for v in report.violations)


def test_compare_skips_and_reports_old_side_failure():
    cells = [run_cell(TINY, c) for c in TINY.expand()]
    broken = [dict(cells[0]), {**Cell.from_dict(cells[1]).to_dict(),
                               "failed": True, "error": "boom"}]
    report = compare(_art(broken), _art(cells))
    assert report.ok
    assert any("old-side cell failed" in n for n in report.notes)
