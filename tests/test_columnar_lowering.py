"""Columnar/NamedTuple bit-identity for the structure-of-arrays pipeline.

The FlowSpec tuple path is the reference; every columnar stage must put
*the same float values* in its columns:

- ``plan_to_flow_batch`` vs ``plan_to_flows`` element-wise across
  scheduler x n_rails x codec x topology;
- ``FlowBatch.relabel`` vs ``clone_flows``;
- ``perturb_batch`` vs ``perturb_flows`` at matched seed/stream;
- the simulator's columnar dispatch (``_serve_plan`` /
  ``simulate_contention``) vs the tuple path under
  ``REPRO_SIM_FASTPATH=0``, through buckets, busy time and utilization.

Equality below is ``==`` on the column values — no tolerances.
"""
from __future__ import annotations

import itertools

import numpy as np
import pytest

from repro.configs.base import CommConfig
from repro.core.addest import AddEst
from repro.core.codec import NONE_CODEC, get_codec
from repro.core.events import (FlowBatch, concat_batches, perturb_batch,
                               perturb_flows)
from repro.core.network_model import make_cost_model
from repro.core.schedule import (assign_codec, assign_rails, clone_flows,
                                 lower_buckets, plan_to_flow_batch,
                                 plan_to_flows)
from repro.core.simulator import _codec_lowerings, fuse_buckets
from repro.core.timeline import from_cnn

GBPS = 1e9 / 8
ADDEST = AddEst.v100()


@pytest.fixture(scope="module")
def raw_buckets():
    tl = from_cnn("vgg16")
    return [(b.flush_time, b.size, b.n_tensors)
            for b in fuse_buckets(tl, CommConfig())]


def assert_batch_equal(flows, batch, tag=""):
    """The batch's columns hold exactly the tuple path's values."""
    ref = FlowBatch.from_flows(flows)
    assert ref.jobs == batch.jobs, tag
    assert ref.links == batch.links, tag
    for f in ref._fields:
        a, b = getattr(ref, f), getattr(batch, f)
        if isinstance(a, tuple):
            continue
        if f in ("path_off", "path_link"):
            # path CSR columns: None and an all-empty CSR both mean
            # "no flow in this batch has a multi-link route"
            def _entries(col):
                if col is None:
                    return 0
                return int(col[-1]) if f == "path_off" else col.shape[0]
            assert _entries(a) == _entries(b), (tag, f)
            if _entries(a):
                assert (a == b).all(), (tag, f)
            continue
        if a.dtype.kind == "f":
            eq = (a == b) | (np.isnan(a) & np.isnan(b))
        else:
            eq = a == b
        assert eq.all(), (tag, f, np.flatnonzero(~eq)[:5])


def _lowered(raw, scheduler, n_rails, codec_name, topology="ring"):
    cost = make_cost_model(64, 25 * GBPS, ADDEST, topology=topology,
                           n_pods=4)
    plan = lower_buckets(raw, scheduler=scheduler, n_chunks=8)
    if n_rails > 1:
        plan = assign_rails(plan, n_rails)
    codecs = None
    if codec_name is not None:
        resolved = (NONE_CODEC if codec_name == "none"
                    else get_codec(codec_name))
        plan = assign_codec(plan, resolved.name,
                            policy="size-adaptive" if codec_name == "topk"
                            else "uniform")
        codec_cost = make_cost_model(64, 25 * GBPS, ADDEST,
                                     topology=topology, n_pods=4,
                                     compression_ratio=resolved.wire_ratio)
        codecs = _codec_lowerings(plan, resolved, cost, codec_cost)
    return plan, cost, codecs


@pytest.mark.parametrize("scheduler", ["fifo", "priority", "chunked"])
@pytest.mark.parametrize("n_rails", [1, 2, 4])
@pytest.mark.parametrize("codec_name", [None, "none", "int8", "topk"])
def test_plan_to_flow_batch_matches_tuple_path(raw_buckets, scheduler,
                                               n_rails, codec_name):
    plan, cost, codecs = _lowered(raw_buckets, scheduler, n_rails,
                                  codec_name)
    flows = plan_to_flows(plan, cost, 5e-6, n_rails=n_rails, codecs=codecs)
    batch = plan_to_flow_batch(plan, cost, 5e-6, n_rails=n_rails,
                               codecs=codecs)
    assert_batch_equal(flows, batch)
    # and the round trip back to tuples is lossless
    assert batch.to_flows() == flows


@pytest.mark.parametrize("topology",
                         ["hierarchical", "switchml", "param_server"])
def test_plan_to_flow_batch_vectorized_cost_models(raw_buckets, topology):
    for codec_name in (None, "ternary"):
        plan, cost, codecs = _lowered(raw_buckets, "chunked", 1, codec_name,
                                      topology=topology)
        flows = plan_to_flows(plan, cost, 5e-6, codecs=codecs)
        batch = plan_to_flow_batch(plan, cost, 5e-6, codecs=codecs)
        assert_batch_equal(flows, batch, topology)


def test_relabel_matches_clone_flows(raw_buckets):
    plan, cost, _ = _lowered(raw_buckets, "chunked", 2, None)
    flows = plan_to_flows(plan, cost, 5e-6, n_rails=2)
    batch = plan_to_flow_batch(plan, cost, 5e-6, n_rails=2)
    base = 0
    for j in range(5):
        cloned = clone_flows(flows, base, f"job{j}")
        relabeled = batch.relabel(base, f"job{j}")
        assert_batch_equal(cloned, relabeled, j)
        base += len(flows)
    # identity relabel returns the batch itself — the O(1) fast path
    assert batch.relabel(0, "job0") is batch


@pytest.mark.parametrize("jitter", [1e-5, 2e-3])
def test_perturb_batch_matches_perturb_flows(raw_buckets, jitter):
    plan, cost, _ = _lowered(raw_buckets, "priority", 1, None)
    flows = plan_to_flows(plan, cost, 5e-6)
    batch = plan_to_flow_batch(plan, cost, 5e-6)
    for seed, stream in [(0, 0), (7, 0), (7, 3), (2026, 15)]:
        pf = perturb_flows(flows, jitter, seed, stream=stream)
        pb = perturb_batch(batch, jitter, seed, stream=stream)
        assert_batch_equal(pf, pb, (seed, stream))
    # jitter=0 is the identity, sharing columns
    assert perturb_batch(batch, 0.0, 1).ready is batch.ready


def test_concat_batches_remaps_name_tables(raw_buckets):
    plan, cost, _ = _lowered(raw_buckets, "chunked", 2, None)
    flows = plan_to_flows(plan, cost, 5e-6, n_rails=2)
    batch = plan_to_flow_batch(plan, cost, 5e-6, n_rails=2)
    parts, all_flows, base = [], [], 0
    for j in range(3):
        parts.append(batch.relabel(base, f"job{j}"))
        all_flows.extend(clone_flows(flows, base, f"job{j}"))
        base += len(flows)
    assert_batch_equal(all_flows, concat_batches(parts))


def _snap(r):
    return (r.t_sync, r.t_overhead, r.scaling_factor,
            r.wire_bytes_per_worker, r.network_utilization,
            r.codec_compute_s,
            tuple((b.start, b.end) for b in r.buckets))


@pytest.mark.parametrize("scheduler,n_rails,jitter,codec", [
    ("fifo", 1, 0.0, "none"),
    ("priority", 1, 2e-3, "none"),
    ("chunked", 2, 0.0, "int8"),
    ("chunked", 1, 1e-4, "size-adaptive"),
])
def test_columnar_dispatch_matches_tuple_path(monkeypatch, scheduler,
                                              n_rails, jitter, codec):
    """The simulator's columnar dispatch (fastpath on) reproduces the
    tuple path (REPRO_SIM_FASTPATH=0) exactly, solo and contended."""
    from repro.core.simulator import simulate, simulate_contention

    tl = from_cnn("vgg16")
    kw = dict(n_workers=64, bandwidth=25 * GBPS, scheduler=scheduler,
              n_chunks=16, n_rails=n_rails, jitter=jitter, jitter_seed=3,
              codec=codec, transport="horovod_tcp")
    monkeypatch.setenv("REPRO_SIM_FASTPATH", "0")
    solo_ref = simulate(tl, **kw)
    cont_ref = simulate_contention([tl] * 4, **kw)
    monkeypatch.setenv("REPRO_SIM_FASTPATH", "1")
    solo_new = simulate(tl, **kw)
    cont_new = simulate_contention([tl] * 4, **kw)
    assert _snap(solo_ref) == _snap(solo_new)
    assert [_snap(r) for r in cont_ref] == [_snap(r) for r in cont_new]


def test_small_plans_never_take_columnar_setup(monkeypatch):
    """Below the engine's small-plan threshold the simulator must not
    build a FlowBatch at all — paper-size cells keep the list path."""
    from repro.core import simulator as sim
    from repro.core.simulator import simulate

    calls = []
    orig = sim.plan_to_flow_batch
    monkeypatch.setattr(sim, "plan_to_flow_batch",
                        lambda *a, **k: calls.append(1) or orig(*a, **k))
    tl = from_cnn("vgg16")
    r = simulate(tl, n_workers=8, bandwidth=25 * GBPS, scheduler="fifo")
    assert r.t_sync > 0.0
    assert not calls, "columnar lowering engaged on a paper-size plan"
    # and a big chunked plan does engage it
    simulate(tl, n_workers=8, bandwidth=25 * GBPS, scheduler="chunked",
             n_chunks=32)
    assert calls
