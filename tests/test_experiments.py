"""Experiment engine: grid expansion, artifact round-trip, regression
gating, CLI exit codes, and golden transport-utilization values."""
import json

import pytest

from repro.core.transport import GBPS, get_transport
from repro.core.whatif import sim_scaling
from repro.experiments import (GRIDS, SUITES, Cell, ExperimentSpec, artifacts,
                               compare, grids, index_cells, run_cell,
                               run_spec, run_suite)
from repro.experiments.cli import main as cli_main


# ---------------------------------------------------------------------------
# spec / grid expansion
# ---------------------------------------------------------------------------

def test_expand_is_cartesian_product_in_stable_order():
    spec = ExperimentSpec(name="t", models=("a", "b"), n_servers=(2, 4),
                          bandwidth_gbps=(1.0, 10.0), transport=("ideal",))
    cells = spec.expand()
    assert len(cells) == spec.n_cells == 8
    # model is the outermost axis, bandwidth the fastest-varying here
    assert cells[0] == Cell("a", 2, 1.0, "ideal", 1.0, "ring")
    assert cells[1] == Cell("a", 2, 10.0, "ideal", 1.0, "ring")
    assert cells[-1] == Cell("b", 4, 10.0, "ideal", 1.0, "ring")
    assert len({c.key() for c in cells}) == 8


def test_spec_hash_stable_and_sensitive():
    a = ExperimentSpec(name="t", bandwidth_gbps=(10.0,))
    b = ExperimentSpec(name="t", bandwidth_gbps=(10.0,))
    c = ExperimentSpec(name="t", bandwidth_gbps=(25.0,))
    assert a.spec_hash() == b.spec_hash()
    assert a.spec_hash() != c.spec_hash()


def test_spec_round_trips_through_dict_and_accepts_lists():
    spec = ExperimentSpec(name="t", models=["resnet50"], n_servers=[2])
    assert spec.models == ("resnet50",)       # lists frozen to tuples
    again = ExperimentSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert again == spec
    assert again.spec_hash() == spec.spec_hash()


def test_registered_grids_expand():
    for name, spec in GRIDS.items():
        assert spec.name == name
        assert spec.n_cells == len(spec.expand()) > 0
    assert set(SUITES["paper"]) <= set(GRIDS)


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def test_run_cell_matches_whatif_sim_scaling():
    spec = GRIDS["paper-fig1"]
    cell = Cell("resnet50", 2, 100.0, "horovod_tcp", 1.0, "ring")
    got = run_cell(spec, cell)
    want = sim_scaling("resnet50", n_servers=2, bandwidth_gbps=100.0,
                       transport="horovod_tcp")
    assert got["scaling_factor"] == want.scaling_factor
    assert got["t_sync"] == want.t_sync
    assert got["n_buckets"] == len(want.buckets)


@pytest.mark.parametrize("executor", ["serial", "thread", "process"])
def test_executors_agree_bitwise(executor):
    spec = ExperimentSpec(name="t", models=("resnet50",), n_servers=(2, 8),
                          bandwidth_gbps=(10.0, 100.0))
    serial = run_spec(spec, executor="serial")
    other = run_spec(spec, executor=executor)
    assert serial["cells"] == other["cells"]
    assert serial["spec_hash"] == other["spec_hash"]


def test_auto_executor_resolution():
    from repro.experiments.runner import PROCESS_THRESHOLD, resolve_executor
    assert resolve_executor("auto", PROCESS_THRESHOLD - 1) == "thread"
    assert resolve_executor("auto", PROCESS_THRESHOLD) == "process"
    # explicit choices pass through untouched (serial stays debuggable)
    for mode in ("serial", "thread", "process"):
        assert resolve_executor(mode, 10_000) == mode


def test_auto_executor_weighs_contention_cells():
    """A grid of few-but-heavy contention cells must land on the process
    pool: the dispatch measure is workload units (n_jobs-weighted cells),
    not the raw cell count."""
    from repro.experiments.runner import PROCESS_THRESHOLD, resolve_executor
    # raw count below the threshold, workload far above it
    assert resolve_executor("auto", 8, workload=8 * 16) == "process"
    assert resolve_executor("auto", 8, workload=8) == "thread"

    heavy = ExperimentSpec(name="t", models=("vgg16",), n_servers=(8,),
                           bandwidth_gbps=(10.0, 25.0),
                           scheduler=("priority", "chunked"),
                           n_jobs=(1, 4, 16), jitter_ms=(0.0, 2.0))
    assert heavy.n_cells == 24 < PROCESS_THRESHOLD
    assert heavy.workload_units == 8 * 21 >= PROCESS_THRESHOLD
    cells = heavy.expand()
    assert sum(c.weight for c in cells) == heavy.workload_units
    assert {c.weight for c in cells} == {1, 4, 16}


def test_xxl_contention_grid_registered_and_gated():
    """The 10k-flow grid: registered, validated, suite-resolvable, and
    actually at the scale its name claims (>10k flows in the worst cell:
    18 VGG16 buckets x 64 chunks x 16 jobs)."""
    from repro.experiments.validations import VALIDATORS
    spec = GRIDS["xxl-contention"]
    assert spec.name in VALIDATORS, "gated grid must carry claim checks"
    assert grids.resolve("xxl")[0] is spec
    assert max(spec.n_jobs) == 16 and spec.sched_chunks == 64
    assert "priority" in spec.scheduler
    assert spec.jitter_seed != 0
    from repro.core.simulator import fuse_buckets
    from repro.core.timeline import from_cnn
    from repro.configs.base import CommConfig
    n_buckets = len(fuse_buckets(from_cnn("vgg16"), CommConfig(
        fusion_buffer_mb=spec.fusion_buffer_mb,
        timeout_ms=spec.timeout_ms)))
    assert n_buckets * spec.sched_chunks * max(spec.n_jobs) > 10_000


def test_contention_axis_runs_and_matches_simulate_contention():
    from repro.core.simulator import simulate_contention
    from repro.core.timeline import from_cnn

    spec = ExperimentSpec(name="t", models=("resnet50",), n_servers=(2,),
                          bandwidth_gbps=(10.0,), n_jobs=(1, 4))
    rec = run_spec(spec, executor="serial")
    by_jobs = {c.get("n_jobs", 1): c for c in rec["cells"]}
    assert set(by_jobs) == {1, 4}
    # contention can only hurt
    assert by_jobs[4]["scaling_factor"] < by_jobs[1]["scaling_factor"]
    # and the cell must be exactly simulate_contention's first job
    want = simulate_contention([from_cnn("resnet50")] * 4, n_workers=16,
                               bandwidth=10.0 * GBPS)[0]
    assert by_jobs[4]["t_sync"] == want.t_sync
    assert by_jobs[4]["scaling_factor"] == want.scaling_factor


def test_contention_cell_rejects_non_ring_topology():
    from repro.experiments.runner import run_cell
    spec = ExperimentSpec(name="t")
    cell = Cell("resnet50", 2, 10.0, "ideal", 1.0, "switchml", "fifo", 4)
    with pytest.raises(ValueError, match="ring"):
        run_cell(spec, cell)


def test_n_jobs_axis_elided_at_default():
    """The contention axis must not disturb the seed schema: cells and
    specs omit it at its default, so spec hashes (the golden-artifact CI
    gate) and artifact bytes are unchanged for grids that don't sweep it."""
    solo = Cell("resnet50", 2, 10.0, "ideal", 1.0, "ring")
    assert "n_jobs" not in solo.to_dict()
    assert Cell.from_dict(solo.to_dict()) == solo
    multi = Cell("resnet50", 2, 10.0, "ideal", 1.0, "ring", "fifo", 4)
    assert multi.to_dict()["n_jobs"] == 4
    assert Cell.from_dict(multi.to_dict()) == multi

    plain = ExperimentSpec(name="t")
    assert "n_jobs" not in plain.to_dict()
    swept = ExperimentSpec(name="t", n_jobs=(1, 2))
    assert swept.to_dict()["n_jobs"] == (1, 2)
    assert swept.spec_hash() != plain.spec_hash()
    assert ExperimentSpec.from_dict(plain.to_dict()) == plain
    assert ExperimentSpec.from_dict(swept.to_dict()) == swept


def test_scenario_axes_elided_at_default():
    """n_rails / jitter_ms (and their spec-level knobs) must not disturb
    the seed schema: cells and specs omit them at defaults, so spec
    hashes and artifact bytes of the historical grids never move."""
    solo = Cell("resnet50", 2, 10.0, "ideal", 1.0, "ring")
    assert "n_rails" not in solo.to_dict()
    assert "jitter_ms" not in solo.to_dict()
    assert Cell.from_dict(solo.to_dict()) == solo
    railed = Cell("resnet50", 2, 10.0, "ideal", 1.0, "ring", "chunked",
                  1, 2, 5.0)
    d = railed.to_dict()
    assert d["n_rails"] == 2 and d["jitter_ms"] == 5.0
    assert Cell.from_dict(d) == railed

    plain = ExperimentSpec(name="t")
    for key in ("n_rails", "jitter_ms", "rail_policy", "jitter_seed"):
        assert key not in plain.to_dict()
    swept = ExperimentSpec(name="t", n_rails=(1, 2), jitter_ms=(0.0, 5.0),
                           rail_policy="size-balanced", jitter_seed=7)
    d = swept.to_dict()
    assert d["n_rails"] == (1, 2) and d["rail_policy"] == "size-balanced"
    assert swept.spec_hash() != plain.spec_hash()
    assert ExperimentSpec.from_dict(json.loads(json.dumps(
        swept.to_dict()))) == swept
    # the historical paper grid's canonical JSON mentions no new axis
    assert "n_rails" not in GRIDS["paper-fig1"].canonical_json()


@pytest.mark.parametrize("executor", ["thread", "process"])
def test_executors_bit_identical_on_scenario_axes(executor):
    """Seeded jitter and rails must not break executor determinism: the
    perturbation depends only on (seed, job, flow count), never on which
    thread or process ran the cell."""
    spec = ExperimentSpec(name="t", models=("resnet50",), n_servers=(2,),
                          bandwidth_gbps=(10.0, 100.0),
                          scheduler=("fifo", "chunked"), sched_chunks=8,
                          n_rails=(1, 2), jitter_ms=(0.0, 5.0),
                          jitter_seed=13)
    serial = run_spec(spec, executor="serial")
    other = run_spec(spec, executor=executor)
    assert serial["cells"] == other["cells"]


def test_scenario_suite_resolves_and_validates():
    specs = grids.resolve("scenario")
    assert [s.name for s in specs] == ["multirail", "straggler"]
    from repro.experiments.validations import VALIDATORS
    for s in specs:
        assert s.name in VALIDATORS, f"gated grid {s.name} must carry checks"
    assert GRIDS["multirail"].n_rails == (1, 2, 4)
    assert GRIDS["straggler"].jitter_ms == (0.0, 2.0, 10.0)
    assert GRIDS["straggler"].jitter_seed != 0   # seed is pinned, not implicit


def test_paper_xl_suite_resolves_and_validates():
    specs = grids.resolve("paper-xl")
    assert [s.name for s in specs] == ["xl-bandwidth", "xl-sched",
                                      "xl-contention"]
    assert sum(s.n_cells for s in specs) >= 256
    from repro.experiments.validations import VALIDATORS
    for s in specs:
        assert s.name in VALIDATORS, f"xl grid {s.name} must carry checks"


def test_validations_recorded_for_paper_grids():
    rec = run_spec(GRIDS["paper-fig1"])
    assert rec["validations"], "paper grids must carry claim checks"
    assert all(isinstance(v, bool) for v in rec["validations"].values())


# ---------------------------------------------------------------------------
# artifacts: write -> read -> compare is a no-op
# ---------------------------------------------------------------------------

def _small_artifact(tmp_path, name="a.json"):
    rec = run_spec(ExperimentSpec(name="small", models=("resnet50",),
                                  n_servers=(2,), bandwidth_gbps=(10.0,)))
    path = tmp_path / name
    artifacts.write(path, [rec])
    return path, rec


def test_artifact_round_trip_compare_is_noop(tmp_path):
    path, rec = _small_artifact(tmp_path)
    art = artifacts.read(path)
    assert art["schema_version"] == artifacts.SCHEMA_VERSION
    assert art["experiments"][0]["cells"] == rec["cells"]
    report = compare(art, art)
    assert report.ok and report.n_cells == 1


def test_artifact_write_is_deterministic(tmp_path):
    p1, _ = _small_artifact(tmp_path, "a.json")
    p2, _ = _small_artifact(tmp_path, "b.json")
    assert p1.read_bytes() == p2.read_bytes()


def test_artifact_read_rejects_garbage(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text("{\"kind\": \"something-else\"}")
    with pytest.raises(artifacts.ArtifactError):
        artifacts.read(p)
    p.write_text("not json")
    with pytest.raises(artifacts.ArtifactError):
        artifacts.read(p)


# ---------------------------------------------------------------------------
# compare: tolerance violations, spec drift, claim flips
# ---------------------------------------------------------------------------

def test_compare_detects_value_drift(tmp_path):
    path, rec = _small_artifact(tmp_path)
    art = artifacts.read(path)
    import copy
    mutated = copy.deepcopy(art)
    mutated["experiments"][0]["cells"][0]["scaling_factor"] += 1e-6
    report = compare(art, mutated)
    assert not report.ok
    assert any(v.kind == "field" and "scaling_factor" in v.where
               for v in report.violations)
    # a loose explicit tolerance lets the same drift through
    assert compare(art, mutated, tolerances={"scaling_factor": 1e-3}).ok


def test_compare_detects_dropped_result_field(tmp_path):
    """A schema regression that removes a result field must not silently
    disable its drift gate."""
    import copy
    path, _ = _small_artifact(tmp_path)
    art = artifacts.read(path)
    shrunk = copy.deepcopy(art)
    del shrunk["experiments"][0]["cells"][0]["t_sync"]
    report = compare(art, shrunk)
    assert not report.ok
    assert any("t_sync" in v.where and "only in old" in v.detail
               for v in report.violations)


def test_compare_detects_spec_drift_and_missing_experiment(tmp_path):
    rec_a = run_spec(ExperimentSpec(name="g", bandwidth_gbps=(10.0,),
                                    models=("resnet50",), n_servers=(2,)))
    rec_b = run_spec(ExperimentSpec(name="g", bandwidth_gbps=(25.0,),
                                    models=("resnet50",), n_servers=(2,)))
    art_a = artifacts.make_artifact([rec_a])
    art_b = artifacts.make_artifact([rec_b])
    report = compare(art_a, art_b)
    assert not report.ok
    assert any(v.kind == "spec" for v in report.violations)
    report = compare(art_a, artifacts.make_artifact([]))
    assert any("missing" in v.detail for v in report.violations)


def test_compare_detects_claim_flip(tmp_path):
    import copy
    rec = run_spec(GRIDS["paper-fig1"])
    art = artifacts.make_artifact([rec])
    flipped = copy.deepcopy(art)
    for k in flipped["experiments"][0]["validations"]:
        flipped["experiments"][0]["validations"][k] = False
    report = compare(art, flipped)
    assert not report.ok
    assert all(v.kind == "validation" for v in report.violations)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_run_compare_report_roundtrip(tmp_path, capsys):
    out = tmp_path / "fig1.json"
    assert cli_main(["run", "--grid", "paper-fig1", "--out", str(out)]) == 0
    assert cli_main(["compare", str(out), str(out)]) == 0
    assert cli_main(["report", str(out)]) == 0
    assert cli_main(["list"]) == 0
    text = capsys.readouterr().out
    assert "paper-fig1" in text and "OK" in text


def test_cli_compare_exits_nonzero_on_violation(tmp_path):
    out = tmp_path / "fig1.json"
    cli_main(["run", "--grid", "paper-fig1", "--out", str(out)])
    art = artifacts.read(out)
    art["experiments"][0]["cells"][0]["t_sync"] *= 1.01
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(art))
    assert cli_main(["compare", str(out), str(bad)]) == 1


def test_cli_unknown_grid_raises():
    with pytest.raises(KeyError):
        cli_main(["run", "--grid", "nope", "--out", "/dev/null"])


# ---------------------------------------------------------------------------
# golden transport values (the paper's calibrated horovod_tcp curve)
# ---------------------------------------------------------------------------

def test_transport_utilization_golden_values():
    """utilization(bw) = cap / (bw^4 + cap^4)^(1/4), cap = 30 Gbps.

    These literals gate the calibration: Fig. 4's "<32 Gbps at a 100 Gbps
    NIC" claim lives or dies on this curve."""
    tr = get_transport("horovod_tcp")
    golden = {
        10.0: 0.9969371768941204,
        25.0: 0.906294635134345,
        100.0: 0.29939555690739733,
    }
    for gbps, want in golden.items():
        assert tr.utilization(gbps * GBPS) == pytest.approx(want, rel=1e-12)
        assert tr.effective(gbps * GBPS) / GBPS == pytest.approx(
            gbps * want, rel=1e-12)
    assert get_transport("ideal").utilization(100 * GBPS) == 1.0
