"""MoE dispatch invariants (GShard einsum path)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models import moe as moe_lib

# interpret-mode Pallas / full-model tests: minutes of wall clock on CPU
pytestmark = pytest.mark.slow



def _cfg(E=4, K=2, cf=1.25, shared=0):
    base = get_config("arctic-480b").smoke()
    return base.replace(moe=dataclasses.replace(
        base.moe, num_experts=E, top_k=K, capacity_factor=cf,
        num_shared_experts=shared))


def test_outputs_finite_and_shaped():
    cfg = _cfg()
    p = moe_lib.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    out, aux = moe_lib.moe_block(p, x, cfg)
    assert out.shape == x.shape
    assert jnp.all(jnp.isfinite(out))
    assert set(aux) == {"load_balance", "router_z"}
    assert float(aux["load_balance"]) >= 0


def test_no_drop_capacity_is_linear_in_gates():
    """With capacity >= top_k*S the block must process every token: output
    equals the gate-weighted sum of per-expert MLPs (dense check)."""
    cfg = _cfg(E=4, K=2, cf=4.0)     # capacity = K*S*cf/E >= S with cf=E/K*...
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    p = moe_lib.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model)) * 0.3
    out, _ = moe_lib.moe_block(p, x, cfg)

    # dense reference
    logits = (x @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gv, gi = jax.lax.top_k(probs, cfg.moe.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for e in range(cfg.moe.num_experts):
        h = jax.nn.silu(x @ p["wg"][e]) * (x @ p["wi"][e])
        y_e = h @ p["wo"][e]
        w_e = jnp.sum(jnp.where(gi == e, gv, 0.0), axis=-1)
        ref += w_e[..., None].astype(x.dtype) * y_e
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_capacity_never_exceeded(seed):
    cfg = _cfg(E=4, K=2, cf=1.0)
    S = 16
    C = moe_lib.expert_capacity(cfg.moe, S)
    p = moe_lib.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(seed), (2, S, cfg.d_model))
    # reproduce the dispatch tensor and check per-expert token counts
    logits = (x @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gv, gi = jax.lax.top_k(probs, cfg.moe.top_k)
    sel = jax.nn.one_hot(gi, cfg.moe.num_experts, dtype=jnp.float32)
    flat = sel.reshape(2, S * cfg.moe.top_k, cfg.moe.num_experts)
    pos = jnp.cumsum(flat, axis=1) - flat
    within = (pos < C).reshape(2, S, cfg.moe.top_k, cfg.moe.num_experts)
    kept = sel.reshape(2, S, cfg.moe.top_k, -1) * within
    per_expert = kept.sum(axis=(1, 2))
    assert np.all(np.asarray(per_expert) <= C + 1e-6)


def test_shared_expert_added():
    cfg_with = _cfg(shared=1)
    p = moe_lib.init_moe(jax.random.key(0), cfg_with)
    assert "shared" in p
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg_with.d_model)) * 0.1
    out_with, _ = moe_lib.moe_block(p, x, cfg_with)
    p2 = dict(p)
    del p2["shared"]
    cfg_wo = _cfg(shared=0)
    out_wo, _ = moe_lib.moe_block(p2, x, cfg_wo)
    assert float(jnp.max(jnp.abs(out_with - out_wo))) > 1e-6
