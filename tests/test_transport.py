"""Lossy-link transport layer: parsing, deterministic pricing, seeded
retransmission draws, and the bitwise contracts (null bypass, loss=0 ==
pre-transport build, tuple/columnar and replay bit-identity, stall-detector
headroom under dense _RETX calendars)."""
import math

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import events as ev
from repro.core.events import ChurnEvent, FlowSpec, run_flows
from repro.core.schedule import _apply_link, _apply_link_batch
from repro.core.simulator import simulate, simulate_contention
from repro.core.timeline import from_cnn
from repro.core.transport import (GBPS, NULL_LINK, LinkProfile,
                                  parse_link_profile, retx_events)


# ---------------------------------------------------------------------------
# parsing
# ---------------------------------------------------------------------------

def test_parse_link_profile():
    assert parse_link_profile("none") == NULL_LINK
    assert parse_link_profile("") == NULL_LINK
    assert parse_link_profile(None) == NULL_LINK
    lp = LinkProfile(loss=0.3)
    assert parse_link_profile(lp) is lp
    lp = parse_link_profile("wan:loss=0.01,rtt=20")
    assert lp.loss == 0.01 and lp.rtt == 0.02
    assert lp.timeout == 0.2 and lp.backoff == 2.0 and lp.segment == 64e3
    lp = parse_link_profile("wan:loss=0.05,rtt=80:timeout=100,backoff=4")
    assert lp.timeout == 0.1 and lp.backoff == 4.0 and lp.rtt == 0.08
    # section separators are cosmetic: any pair may appear in any section
    assert parse_link_profile("wan:loss=0.05:rtt=80") == \
        parse_link_profile("wan:loss=0.05,rtt=80")


def test_parse_link_profile_errors():
    with pytest.raises(ValueError, match="unknown link profile"):
        parse_link_profile("lan:loss=0.1")
    with pytest.raises(ValueError, match="unknown link profile"):
        parse_link_profile("wan")
    with pytest.raises(ValueError, match="not key=value"):
        parse_link_profile("wan:loss")
    with pytest.raises(ValueError, match="non-numeric"):
        parse_link_profile("wan:loss=lots")
    with pytest.raises(ValueError, match="unknown link profile field"):
        parse_link_profile("wan:loss=0.1,mtu=1500")
    with pytest.raises(ValueError, match=r"loss must be in \[0, 1\)"):
        parse_link_profile("wan:loss=1.0")


def test_null_detection():
    assert NULL_LINK.is_null
    assert parse_link_profile("wan:loss=0,rtt=0").is_null
    assert not parse_link_profile("wan:loss=0.01,rtt=0").is_null
    assert not parse_link_profile("wan:loss=0,rtt=5").is_null


# ---------------------------------------------------------------------------
# deterministic pricing in the lowering
# ---------------------------------------------------------------------------

def _flows():
    return [FlowSpec(op_id=i, ready=0.1 * i, work=1e-3 * (i + 1),
                     latency=1e-4, duration=1e-3 * (i + 1) + 1e-4)
            for i in range(4)]


def test_apply_link_null_is_same_object():
    flows = _flows()
    assert _apply_link(flows, None) is flows
    assert _apply_link(flows, NULL_LINK) is flows


def test_apply_link_prices_inflation_and_rtt():
    lp = parse_link_profile("wan:loss=0.2,rtt=50")
    out = _apply_link(_flows(), lp)
    for f0, f1 in zip(_flows(), out):
        assert f1.work == f0.work / 0.8
        assert f1.latency == f0.latency + 0.05
        # duration uses the same float association as the batch path
        assert f1.duration == f0.duration + (f1.work - f0.work) + 0.05
        assert f1.ready == f0.ready and f1.priority == f0.priority


def test_apply_link_batch_matches_tuple_path_bitwise():
    from repro.core.events import FlowBatch
    lp = parse_link_profile("wan:loss=0.13,rtt=7")
    flows = _flows()
    a = FlowBatch.from_flows(_apply_link(flows, lp))
    b = _apply_link_batch(FlowBatch.from_flows(flows), lp)
    assert np.array_equal(a.work, b.work)
    assert np.array_equal(a.latency, b.latency)
    assert np.array_equal(a.duration, b.duration)


# ---------------------------------------------------------------------------
# seeded retransmission draws
# ---------------------------------------------------------------------------

_LP = parse_link_profile("wan:loss=0.05,rtt=20")


def test_retx_events_deterministic():
    a = retx_events(_LP, 100e6, 0.5, seed=7, stream=3)
    b = retx_events(_LP, 100e6, 0.5, seed=7, stream=3)
    assert a == b and len(a) > 0
    assert retx_events(_LP, 100e6, 0.5, seed=8, stream=3) != a
    assert retx_events(_LP, 100e6, 0.5, seed=7, stream=4) != a


def test_retx_events_empty_cases():
    assert retx_events(NULL_LINK, 100e6, 0.5) == []
    assert retx_events(_LP, 0.0, 0.5) == []
    assert retx_events(_LP, 100e6, 0.0) == []


def test_retx_events_shape():
    evs = retx_events(_LP, 100e6, 0.5, seed=7, job="job3")
    assert all(e.kind == "retx" and e.job == "job3" and e.worker == -1
               for e in evs)
    assert all(0.0 <= e.t <= 0.5 for e in evs)
    assert [e.t for e in evs] == sorted(e.t for e in evs)
    # stalls are timeout * backoff**k for integer k in [0, 6]
    for e in evs:
        k = math.log(e.stall / _LP.timeout) / math.log(_LP.backoff)
        assert abs(k - round(k)) < 1e-9 and 0 <= round(k) <= 6


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       lo=st.sampled_from([0.001, 0.005, 0.01]),
       hi=st.sampled_from([0.02, 0.05, 0.1]))
def test_retx_loss_superset_property(seed, lo, hi):
    """Raising the loss axis keeps a superset of the same timed events —
    the thinning-gate construction the monotonicity validators rely on."""
    a = retx_events(LinkProfile(loss=lo, rtt=0.02), 100e6, 0.5, seed=seed)
    b = retx_events(LinkProfile(loss=hi, rtt=0.02), 100e6, 0.5, seed=seed)
    assert {e.t for e in a} <= {e.t for e in b}


def test_retx_backoff_scales_stalls_without_moving_events():
    base = parse_link_profile("wan:loss=0.05,rtt=20:timeout=100,backoff=1")
    quad = parse_link_profile("wan:loss=0.05,rtt=20:timeout=100,backoff=4")
    a = retx_events(base, 100e6, 0.5, seed=2029)
    b = retx_events(quad, 100e6, 0.5, seed=2029)
    assert [e.t for e in a] == [e.t for e in b]
    assert all(x.stall <= y.stall for x, y in zip(a, b))


# ---------------------------------------------------------------------------
# engine integration: bitwise contracts
# ---------------------------------------------------------------------------

_TL = from_cnn("resnet50")
_KW = dict(n_workers=64, bandwidth=10 * GBPS, transport="horovod_tcp",
           scheduler="priority", n_chunks=8, fault_seed=2029)


def test_zero_loss_is_bitwise_pre_transport():
    base = simulate(_TL, **_KW)
    for spec in ("none", "", "wan:loss=0,rtt=0"):
        r = simulate(_TL, **_KW, link_profile=spec)
        assert r.t_sync == base.t_sync
        assert r.t_overhead == base.t_overhead
        assert r.effective_bw == base.effective_bw


def test_lossy_replay_is_bitwise(monkeypatch):
    lp = "wan:loss=0.01,rtt=20"
    a = simulate(_TL, **_KW, link_profile=lp)
    b = simulate(_TL, **_KW, link_profile=lp)
    assert a.t_sync == b.t_sync
    # tuple vs columnar lowering
    monkeypatch.setenv("REPRO_SIM_FASTPATH", "0")
    c = simulate(_TL, **_KW, link_profile=lp)
    assert c.t_sync == a.t_sync


def test_rtt_only_profile_prices_on_fast_path(monkeypatch):
    """An rtt-only profile draws no retx events, so the fifo closed form
    stays eligible — and must agree with the event engine bitwise."""
    kw = dict(_KW, scheduler="fifo")
    a = simulate(_TL, **kw, link_profile="wan:loss=0,rtt=20")
    monkeypatch.setenv("REPRO_SIM_FASTPATH", "0")
    b = simulate(_TL, **kw, link_profile="wan:loss=0,rtt=20")
    assert a.t_sync == b.t_sync
    base = simulate(_TL, **kw)
    assert a.t_sync > base.t_sync


def test_contention_per_job_retx_bitwise(monkeypatch):
    lp = "wan:loss=0.01,rtt=20"
    tls = [_TL, from_cnn("vgg16")]
    kw = dict(_KW)
    a = [r.t_sync for r in simulate_contention(tls, **kw, link_profile=lp)]
    monkeypatch.setenv("REPRO_SIM_FASTPATH", "0")
    b = [r.t_sync for r in simulate_contention(tls, **kw, link_profile=lp)]
    assert a == b
    # solo contention degenerates to plain simulate under the same draws
    solo = simulate_contention([_TL], **kw, link_profile=lp)[0]
    assert solo.t_sync == simulate(_TL, **kw, link_profile=lp).t_sync


def test_t_sync_monotone_in_loss():
    ladder = ("none", "wan:loss=0.001,rtt=20", "wan:loss=0.01,rtt=20",
              "wan:loss=0.05,rtt=20")
    ts = [simulate(_TL, **_KW, link_profile=p).t_sync for p in ladder]
    assert ts == sorted(ts)


# ---------------------------------------------------------------------------
# stall-detector regression: dense _RETX calendars with long stalls
# ---------------------------------------------------------------------------

def test_stall_detector_headroom_under_dense_retx():
    """A calendar dense with retx stalls commits zero work while each
    stall is pending; the progress-based stall detector must count those
    calendar entries as expected idle wakeups, not runaway looping.
    Regression for the pre-audit limit, which was tuned for fault-free
    calendars."""
    n = 40
    flows = [FlowSpec(op_id=i, ready=0.0, work=1e-3, latency=0.0)
             for i in range(n)]
    # several long-backoff stalls per flow, all targeting the same job
    churn = [ChurnEvent(1e-4 * i, "job0", "retx", -1, 0.05 * (1 + i % 4))
             for i in range(3 * n)]
    res = run_flows(flows, churn=churn)  # must not RuntimeError
    assert len(res) == n
    assert all(r.end >= r.start for r in res)
    # the same flows with no churn finish strictly earlier
    base = run_flows(flows)
    assert max(r.end for r in res) > max(r.end for r in base)


def test_stall_limit_counts_retx_entries():
    """The audit's contract, pinned structurally: _RETX calendar entries
    widen the stall budget exactly like _FAULT entries."""
    assert ev._RETX == 3 and ev._FAULT == 1
    cal = [(0.0, ev._DONE, 0, None, None),
           (0.0, ev._FAULT, 1, None, None),
           (0.0, ev._RETX, 2, None, None)]
    n_faults = sum(1 for e in cal if e[1] == ev._FAULT or e[1] == ev._RETX)
    assert n_faults == 2
