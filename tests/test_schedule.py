"""The comm-schedule IR and discrete-event engine: schedule invariants
(property tests), bit-exact fifo equivalence with the pre-engine serialized
loop, fair-share link semantics, multi-job contention, and the
simulator <-> runtime plan parity."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import CommConfig
from repro.core.addest import AddEst
from repro.core.events import FlowSpec, run_flows
from repro.core.network_model import RingAllReduce
from repro.core.schedule import (SCHEDULERS, canonical_scheduler,
                                 lower_buckets, plan_to_flows)
from repro.core.simulator import (fuse_buckets, simulate, simulate_contention)
from repro.core.timeline import GradTimeline, from_cnn
from repro.core.transport import GBPS, get_transport


def _mk_timeline(ready, sizes, t_back=None):
    t_back = t_back if t_back is not None else (max(ready) if ready else 0.0)
    return GradTimeline("t", tuple(ready), tuple(sizes), t_back, t_back * 1.5)


def _random_timeline(n, seed, max_mb=120):
    rng = np.random.default_rng(seed)
    ready = np.sort(rng.uniform(0, 0.1, n))
    sizes = rng.uniform(1e3, max_mb * 1e6, n)
    return _mk_timeline(list(ready), list(sizes))


def _legacy_serialized(timeline, n_workers, bandwidth, transport="ideal",
                       compression_ratio=1.0, comm=None):
    """The pre-refactor all-reduce loop, verbatim: FIFO, one serialized
    collective in flight at a time.  The engine's fifo scheduler must
    reproduce it bit-for-bit."""
    comm = comm or CommConfig()
    tr = get_transport(transport)
    cost = RingAllReduce(n_workers, tr.effective(bandwidth), AddEst.v100(),
                         compression_ratio)
    served, prev_end = [], 0.0
    for b in fuse_buckets(timeline, comm):
        start = max(b.flush_time, prev_end)
        dur = cost.time(b.size) + tr.per_tensor_overhead * b.n_tensors
        prev_end = start + dur
        served.append((start, prev_end))
    return served


# ---------------------------------------------------------------------------
# event engine semantics
# ---------------------------------------------------------------------------

def test_single_flow_closed_form():
    (r,) = run_flows([FlowSpec(op_id=0, ready=1.0, work=2.0, latency=0.5,
                               hold=True, duration=2.5)])
    assert r.start == 1.0 and r.wire_end == 3.0 and r.end == 3.5
    assert not r.contended


def test_fair_share_splits_bandwidth():
    # two jobs, identical flows, same link: each gets half rate -> both
    # wires take twice as long
    flows = [FlowSpec(op_id=i, ready=0.0, work=1.0, job=f"j{i}")
             for i in range(2)]
    res = run_flows(flows)
    for r in res:
        assert r.contended
        assert r.wire_end == pytest.approx(2.0, rel=1e-12)


def test_fair_share_releases_capacity():
    # j1's short flow finishes first; j0 then speeds back up:
    # overlap at half rate for 1s burns 0.5 of j0's 1.0 work -> ends at 1.5
    res = run_flows([
        FlowSpec(op_id=0, ready=0.0, work=1.0, job="j0"),
        FlowSpec(op_id=1, ready=0.0, work=0.5, job="j1"),
    ])
    assert res[1].wire_end == pytest.approx(1.0, rel=1e-12)
    assert res[0].wire_end == pytest.approx(1.5, rel=1e-12)


def test_job_serializes_but_latency_overlaps_when_not_held():
    # same job: second wire starts at first wire's end, not after its latency
    res = run_flows([
        FlowSpec(op_id=0, ready=0.0, work=1.0, latency=10.0, priority=0),
        FlowSpec(op_id=1, ready=0.0, work=1.0, latency=0.0, priority=1),
    ])
    assert res[0].wire_end == pytest.approx(1.0)
    assert res[0].end == pytest.approx(11.0)
    assert res[1].start == pytest.approx(1.0)


def test_priority_orders_admission_within_job():
    res = run_flows([
        FlowSpec(op_id=0, ready=0.0, work=1.0, priority=1.0),
        FlowSpec(op_id=1, ready=0.0, work=1.0, priority=0.0),
    ])
    assert res[1].start == 0.0 and res[0].start == pytest.approx(1.0)


def test_fractional_link_capacity_consistent():
    # capacity < 1.0 means no flow ever runs at full rate: the closed-form
    # (share == 1) completion must not apply, and the reported times must
    # agree with the fluid clock that admits the next flow
    res = run_flows([FlowSpec(op_id=0, ready=0.0, work=1.0, job="a"),
                     FlowSpec(op_id=1, ready=0.0, work=1.0, job="a")],
                    capacities={"nic": 0.5})
    assert res[0].wire_end == pytest.approx(2.0, rel=1e-12)
    assert res[1].start == pytest.approx(2.0, rel=1e-12)
    assert res[1].wire_end == pytest.approx(4.0, rel=1e-12)


def test_tiny_residual_work_terminates():
    # sub-ulp residuals must complete instead of stalling the loop
    flows = [FlowSpec(op_id=i, ready=0.1 * i, work=1e-7 if i % 2 else 1e3,
                      job=f"j{i % 3}") for i in range(30)]
    res = run_flows(flows)
    assert len(res) == 30


# ---------------------------------------------------------------------------
# schedule invariants (satellite: property tests)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 50), seed=st.integers(0, 10_000),
       sched=st.sampled_from(["fifo", "priority", "chunked"]),
       k=st.integers(1, 8))
def test_lowering_conserves_bucket_bytes(n, seed, sched, k):
    tl = _random_timeline(n, seed)
    buckets = fuse_buckets(tl, CommConfig())
    plan = lower_buckets([(b.flush_time, b.size, b.n_tensors) for b in buckets],
                         scheduler=sched, n_chunks=k)
    assert plan.n_buckets == len(buckets)
    # bytes conserved overall and per bucket
    assert plan.total_bytes == pytest.approx(sum(b.size for b in buckets),
                                             rel=1e-9)
    per_bucket = {}
    for op in plan.ops:
        per_bucket[op.bucket_id] = per_bucket.get(op.bucket_id, 0.0) + op.size
    for i, b in enumerate(buckets):
        assert per_bucket[i] == pytest.approx(b.size, rel=1e-9)
    # per-tensor negotiation charged exactly once per bucket
    tensors = {}
    for op in plan.ops:
        tensors[op.bucket_id] = tensors.get(op.bucket_id, 0) + op.n_tensors
    for i, b in enumerate(buckets):
        assert tensors[i] == b.n_tensors


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 40), seed=st.integers(0, 10_000),
       bw=st.floats(1.0, 100.0))
def test_fifo_bit_exact_vs_legacy_serialized_loop(n, seed, bw):
    tl = _random_timeline(n, seed)
    r = simulate(tl, n_workers=16, bandwidth=bw * GBPS,
                 transport="horovod_tcp")
    ref = _legacy_serialized(tl, 16, bw * GBPS, "horovod_tcp")
    assert len(r.buckets) == len(ref)
    for b, (start, end) in zip(r.buckets, ref):
        assert b.start == start          # bit-exact, not approx
        assert b.end == end
    if ref:
        assert r.t_sync == max(e for _, e in ref)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 40), seed=st.integers(0, 10_000),
       bw=st.floats(1.0, 100.0),
       sched=st.sampled_from(["priority", "chunked"]),
       transport=st.sampled_from(["ideal", "horovod_tcp"]))
def test_pipelined_schedules_end_no_later_than_serialized(n, seed, bw, sched,
                                                          transport):
    tl = _random_timeline(n, seed)
    fifo = simulate(tl, n_workers=16, bandwidth=bw * GBPS,
                    transport=transport)
    other = simulate(tl, n_workers=16, bandwidth=bw * GBPS,
                     transport=transport, scheduler=sched)
    assert other.t_sync <= fifo.t_sync + 1e-12
    assert other.t_overhead <= fifo.t_overhead + 1e-12


def test_paper_models_schedulers_never_worse():
    for model in ("resnet50", "vgg16"):
        tl = from_cnn(model)
        for bw in (5.0, 25.0, 100.0):
            fifo = simulate(tl, n_workers=64, bandwidth=bw * GBPS,
                            transport="horovod_tcp")
            for sched in ("priority", "chunked"):
                r = simulate(tl, n_workers=64, bandwidth=bw * GBPS,
                             transport="horovod_tcp", scheduler=sched)
                assert r.t_overhead <= fifo.t_overhead + 1e-12
                assert r.scheduler == sched


def test_chunked_alias_and_unknown_scheduler():
    assert canonical_scheduler("chunked-pipelined") == "chunked"
    assert canonical_scheduler("bytescheduler") == "priority"
    with pytest.raises(KeyError):
        canonical_scheduler("nope")
    assert set(SCHEDULERS) == {"fifo", "priority", "chunked"}


def test_priority_serves_front_layers_first():
    # backward emits last layers first -> bucket 0 is the model's tail;
    # priority must serve the *front* (last-flushed) buckets first
    buckets = [(0.0, 100.0, 1), (0.01, 100.0, 1), (0.02, 100.0, 1)]
    plan = lower_buckets(buckets, scheduler="priority", n_chunks=1)
    assert plan.bucket_order() == (2, 1, 0)
    assert lower_buckets(buckets, scheduler="fifo").bucket_order() == (0, 1, 2)


# ---------------------------------------------------------------------------
# fusion-buffer tensor accounting (satellite: slab-split fix)
# ---------------------------------------------------------------------------

def test_slab_split_counts_split_tensor_in_remainder():
    comm = CommConfig(fusion_buffer_mb=1.0, timeout_ms=1e9)
    limit = 1024 * 1024
    # one huge gradient (3.5 slabs), then two small ones
    tl = _mk_timeline([0.0, 0.001, 0.002],
                      [3.5 * limit, 1024.0, 2048.0])
    buckets = fuse_buckets(tl, comm)
    assert [b.n_tensors for b in buckets] == [1, 1, 1, 3]
    # remainder bucket carries the split tensor's tail + the two new ones
    assert buckets[-1].size == pytest.approx(0.5 * limit + 3072)
    assert sum(b.size for b in buckets) == pytest.approx(3.5 * limit + 3072)


def test_exact_slab_fit_has_no_phantom_tensor():
    comm = CommConfig(fusion_buffer_mb=1.0, timeout_ms=1e9)
    limit = 1024 * 1024
    tl = _mk_timeline([0.0, 0.001], [2.0 * limit, 1024.0])
    buckets = fuse_buckets(tl, comm)
    # the big tensor fills exactly two slabs; the small one starts fresh
    assert [b.n_tensors for b in buckets] == [1, 1, 1]


# ---------------------------------------------------------------------------
# topology-aware wire bytes (satellite)
# ---------------------------------------------------------------------------

def test_switchml_wire_bytes_independent_of_n():
    tl = from_cnn("resnet50")
    ring = simulate(tl, n_workers=64, bandwidth=25 * GBPS, topology="ring")
    sw = simulate(tl, n_workers=64, bandwidth=25 * GBPS, topology="switchml")
    total = tl.total_bytes
    # in-network aggregation: each worker streams ~S; ring moves 2S(N-1)/N
    assert sw.wire_bytes_per_worker == pytest.approx(total, rel=1e-6)
    assert ring.wire_bytes_per_worker == pytest.approx(
        2 * total * 63 / 64, rel=1e-6)


def test_hierarchical_wire_bytes_counts_ici_stage():
    tl = from_cnn("resnet50")
    r = simulate(tl, n_workers=64, bandwidth=100 * GBPS,
                 topology="hierarchical", n_pods=4)
    # 16 devices per pod: ICI carries 2*S*15/16
    assert r.wire_bytes_per_worker == pytest.approx(
        2 * tl.total_bytes * 15 / 16, rel=1e-6)


def test_utilization_bounded_everywhere():
    tl = from_cnn("vgg16")
    for topo in ("ring", "switchml", "param_server"):
        for sched in ("fifo", "priority", "chunked"):
            r = simulate(tl, n_workers=16, bandwidth=10 * GBPS,
                         topology=topo, scheduler=sched)
            assert 0.0 <= r.network_utilization <= 1.0


# ---------------------------------------------------------------------------
# rail assignment (multi-rail NICs)
# ---------------------------------------------------------------------------

def _rail_plan(n_buckets=6, sched="chunked", k=4, seed=0):
    rng = np.random.default_rng(seed)
    buckets = [(0.001 * i, float(rng.uniform(1e6, 8e7)), 1)
               for i in range(n_buckets)]
    return lower_buckets(buckets, scheduler=sched, n_chunks=k)


def test_assign_rails_one_rail_is_same_object():
    from repro.core.schedule import assign_rails
    plan = _rail_plan()
    assert assign_rails(plan, 1) is plan
    assert assign_rails(plan, 0) is plan


def test_assign_rails_round_robin_stripes_and_conserves():
    from repro.core.schedule import assign_rails
    plan = _rail_plan(sched="chunked", k=4)
    out = assign_rails(plan, 2)
    assert out is not plan
    # only channels change — ids, sizes, order, readies all intact
    from dataclasses import replace
    assert [replace(op, channel=0) for op in out.ops] == list(plan.ops)
    assert [op.channel for op in out.ops] == [i % 2
                                              for i in range(len(out.ops))]
    # k divisible by rails: every bucket is striped across both rails
    for b in range(plan.n_buckets):
        rails = {op.channel for op in out.ops if op.bucket_id == b}
        assert rails == {0, 1}


def test_assign_rails_size_balanced_bounds_imbalance():
    from repro.core.schedule import assign_rails
    for seed in range(5):
        plan = _rail_plan(n_buckets=9, sched="fifo", seed=seed)
        out = assign_rails(plan, 3, policy="size-balanced")
        load = {r: 0.0 for r in range(3)}
        for op in out.ops:
            load[op.channel] += op.size
        assert sum(load.values()) == pytest.approx(plan.total_bytes)
        # greedy bound: spread no worse than the largest single op
        biggest = max(op.size for op in plan.ops)
        assert max(load.values()) - min(load.values()) <= biggest + 1e-6


def test_assign_rails_rejects_unknown_policy():
    from repro.core.schedule import assign_rails
    with pytest.raises(KeyError, match="rail policy"):
        assign_rails(_rail_plan(), 2, policy="affinity")


def test_plan_to_flows_rails_scale_work_and_split_lanes():
    from repro.core.schedule import assign_rails
    cost = RingAllReduce(64, 10 * GBPS, AddEst.v100())
    unassigned = _rail_plan(sched="chunked", k=4)
    plan = assign_rails(unassigned, 2)
    base = plan_to_flows(unassigned, cost, 1e-6)
    railed = plan_to_flows(plan, cost, 1e-6, n_rails=2)
    for f0, f2, op in zip(base, railed, plan.ops):
        assert f2.work == f0.work * 2          # per-rail bw = aggregate/2
        assert f2.latency == f0.latency        # reductions don't scale
        assert f2.rail == op.channel
        assert f2.link == f0.link == "nic"     # one named link, two rails
        assert f2.job == ("job0" if op.channel == 0 else "job0@r1")
    # total wire work is conserved: n x rails at 1/n rate
    assert sum(f.work for f in railed) == pytest.approx(
        2 * sum(f.work for f in base))


# ---------------------------------------------------------------------------
# multi-job contention
# ---------------------------------------------------------------------------

def test_contention_single_job_degenerates_to_simulate():
    tl = from_cnn("resnet50")
    (r,) = simulate_contention([tl], n_workers=64, bandwidth=25 * GBPS)
    ref = simulate(tl, n_workers=64, bandwidth=25 * GBPS)
    assert r.t_sync == ref.t_sync and r.t_overhead == ref.t_overhead


def test_contention_two_jobs_slower_than_alone():
    tls = [from_cnn("resnet50"), from_cnn("vgg16")]
    shared = simulate_contention(tls, n_workers=64, bandwidth=25 * GBPS)
    for tl, r in zip(tls, shared):
        alone = simulate(tl, n_workers=64, bandwidth=25 * GBPS)
        assert r.t_sync >= alone.t_sync - 1e-12
    # at least one job must actually feel the contention
    assert any(r.t_sync > simulate(tl, n_workers=64,
                                   bandwidth=25 * GBPS).t_sync + 1e-6
               for tl, r in zip(tls, shared))


def test_clone_flows_bit_identical_to_plan_to_flows():
    """simulate_contention's one-lowering-per-timeline reuse rests on
    this: relabeling a lowered flow list must equal a fresh
    ``plan_to_flows`` call for that job, bit for bit — including rail
    lanes, whose ``job@r<k>`` names must be relabeled consistently."""
    from repro.core.schedule import assign_rails, clone_flows
    tl = from_cnn("vgg16")
    tr = get_transport("horovod_tcp")
    cost = RingAllReduce(64, tr.effective(25 * GBPS), AddEst.v100())
    buckets = [(b.flush_time, b.size, b.n_tensors)
               for b in fuse_buckets(tl, CommConfig())]
    for n_rails in (1, 3):
        plan = assign_rails(lower_buckets(buckets, scheduler="priority",
                                          n_chunks=8), n_rails)
        base_flows = plan_to_flows(plan, cost, tr.per_tensor_overhead,
                                   n_rails=n_rails)
        for j, op_base in ((0, 0), (3, 517)):
            want = plan_to_flows(plan, cost, tr.per_tensor_overhead,
                                 job=f"job{j}", op_id_base=op_base,
                                 n_rails=n_rails)
            got = clone_flows(base_flows, op_base, f"job{j}")
            assert got == want
    # the degenerate clone returns an equal list without relabeling work
    assert clone_flows(base_flows, 0, "job0") == base_flows


def test_contention_reuses_one_lowering_per_timeline():
    """An n-job cell over one shared timeline object must lower once: the
    cost model is consulted a constant number of times per op, not once
    per job per op."""
    calls = {"n": 0}

    class _CountingCost:
        def time(self, size):
            calls["n"] += 1
            return size / 1e9 + 1e-4

        def wire_time(self, size):
            return size / 1e9

    from repro.core.schedule import clone_flows
    buckets = [(0.001 * i, 1e6, 1) for i in range(10)]
    plan = lower_buckets(buckets, scheduler="priority", n_chunks=4)
    base_flows = plan_to_flows(plan, _CountingCost(), 0.0)
    lowered_calls = calls["n"]
    for j in range(1, 8):
        clone_flows(base_flows, j * len(base_flows), f"job{j}")
    assert calls["n"] == lowered_calls, "cloning must not re-price ops"


# ---------------------------------------------------------------------------
# simulator <-> runtime parity
# ---------------------------------------------------------------------------

def test_bucket_plan_comm_plan_parity():
    jnp = pytest.importorskip("jax.numpy")
    from repro.parallel.grad_sync import BucketPlan

    shapes = [(1000, 100)] * 5 + [(10,)]
    bp = BucketPlan(shapes, [jnp.float32] * len(shapes),
                    limit_bytes=1024 * 1024)
    assert bp.n_buckets > 1
    assert sum(bp.bucket_tensors) == len(shapes)
    fifo = bp.comm_plan(CommConfig(scheduler="fifo"))
    pri = bp.comm_plan(CommConfig(scheduler="priority"))
    assert fifo.bucket_order() == tuple(range(bp.n_buckets))
    assert pri.bucket_order() == tuple(reversed(range(bp.n_buckets)))
    # same bytes the simulator's lowering would schedule: packed f32 slabs
    assert fifo.total_bytes == pytest.approx(
        sum(s * 4 for s in bp.bucket_sizes))
    # the runtime and the simulator lower through the *same* registry
    from repro.core import schedule
    assert bp.comm_plan.__module__ == "repro.parallel.grad_sync"
    assert schedule.lower_buckets is lower_buckets
