"""HLO collective parser and transport-curve tests."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.transport import GBPS, get_transport
from repro.utils.hlo import collective_bytes, collective_counts

HLO_SAMPLE = """
HloModule jit_step
  %x.1 = bf16[16,128]{1,0} all-gather(%p0), replica_groups={}
  %y = f32[256]{0} all-reduce(%q), to_apply=%add
  %z = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(%a, %b)
  %w = f32[64]{0} reduce-scatter(%c), dimensions={0}
  %cp = u32[] collective-permute(%d), source_target_pairs={{0,1}}
  %ag2 = bf16[4,4]{1,0} all-gather-start(%p1)
  %agd = bf16[4,4]{1,0} all-gather-done(%ag2)
"""


def test_collective_bytes_parses_kinds():
    out = collective_bytes(HLO_SAMPLE)
    assert out["all-gather"] == 16 * 128 * 2 + 4 * 4 * 2   # incl. -start
    assert out["all-reduce"] == 256 * 4
    assert out["all-to-all"] == 2 * 64 * 4
    assert out["reduce-scatter"] == 64 * 4
    assert out["collective-permute"] == 4


def test_collective_counts():
    out = collective_counts(HLO_SAMPLE)
    assert out["all-gather"] == 2          # plain + -start (done skipped)
    assert out["all-reduce"] == 1


def test_done_ops_not_double_counted():
    text = "%a = bf16[8]{0} all-gather-start(%x)\n%b = bf16[8]{0} all-gather-done(%a)"
    assert collective_counts(text)["all-gather"] == 1


# ---------------------------------------------------------------------------
# transport curves
# ---------------------------------------------------------------------------

def test_ideal_transport():
    t = get_transport("ideal")
    assert t.effective(100 * GBPS) == 100 * GBPS


def test_horovod_transport_calibration():
    t = get_transport("horovod_tcp")
    # paper Fig. 4: 1 Gbps fully utilized; 100 Gbps capped below 32 Gbps
    assert t.utilization(1 * GBPS) > 0.95
    assert t.effective(100 * GBPS) < 32 * GBPS
    # plateau: going 25 -> 100 Gbps gains little
    assert t.effective(100 * GBPS) / t.effective(25 * GBPS) < 1.5


@settings(max_examples=30, deadline=None)
@given(bw=st.floats(0.1, 400))
def test_transport_effective_never_exceeds_physical(bw):
    for name in ("ideal", "horovod_tcp", "tpu_ici"):
        t = get_transport(name)
        assert t.effective(bw * GBPS) <= bw * GBPS + 1e-6


@settings(max_examples=30, deadline=None)
@given(bw1=st.floats(0.1, 100), bw2=st.floats(100, 400))
def test_transport_monotone(bw1, bw2):
    for name in ("ideal", "horovod_tcp", "tpu_ici"):
        t = get_transport(name)
        assert t.effective(bw2 * GBPS) >= t.effective(bw1 * GBPS) - 1e-6


# ---------------------------------------------------------------------------
# loop-trip-aware analyzer (repro.utils.hlo.analyze)
# ---------------------------------------------------------------------------

def test_analyze_scales_by_trip_count():
    """A 6-iteration scan of one 64x64 matmul must report ~6x the flops of
    the single-layer cost, with the collectives inside the loop scaled too."""
    import subprocess, sys, json, os
    from pathlib import Path
    script = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys, json
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.utils.hlo import analyze

mesh = jax.make_mesh((2, 2), ("data", "model"))
def step(w, x):
    def body(c, wi):
        return jnp.tanh(c @ wi), ()
    c, _ = jax.lax.scan(body, x, w)
    return c.sum()
w = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
with mesh:
    c = jax.jit(jax.grad(step),
                in_shardings=(NamedSharding(mesh, P(None, "model", None)),
                              NamedSharding(mesh, P("data", None)))
                ).lower(w, x).compile()
a = analyze(c.as_text())
ca = c.cost_analysis()
if isinstance(ca, (list, tuple)):   # older jax returns one dict per program
    ca = ca[0] if ca else {}
print(json.dumps({"flops": a.flops, "trips": a.while_trips,
                  "coll": a.collective_bytes,
                  "cost": ca.get("flops", 0.0)}))
'''
    repo = Path(__file__).resolve().parent.parent
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", script], cwd=repo, env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    # fwd dot (2*4*64*32) + bwd dx (2*4*32*64) + bwd dw (2*64*32*4), x6 trips
    assert out["flops"] == pytest.approx(6 * 3 * 2 * 4 * 64 * 32, rel=0.01)
    assert 6 in out["trips"]
    # the fwd TP all-reduce runs 6 times: 6 * (4*64*4B) at minimum
    assert out["coll"].get("all-reduce", 0) >= 6 * 4 * 64 * 4
    # and the trip-aware flops exceed the while-body-once cost_analysis
    assert out["flops"] > out["cost"]


def test_analyze_handles_tuple_while_types():
    from repro.utils.hlo import parse_computations
    txt = """
%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  ROOT %t = (s32[], f32[4]) tuple(%gte, %gte2)
}
%cond (p2: (s32[], f32[4])) -> pred[] {
  %p2 = (s32[], f32[4]) parameter(0)
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%gte3, %c), direction=LT
}
ENTRY %main (a: f32[4]) -> f32[4] {
  %w = (s32[], f32[4]) while(%init), condition=%cond, body=%body
}
"""
    comps = parse_computations(txt)
    assert {"body", "cond", "main"} <= set(comps)
