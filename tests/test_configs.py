"""The 10 assigned architecture configs match the assignment exactly."""
import pytest

from repro.configs import get_config, list_configs

# (id, layers, d_model, heads, kv, d_ff, vocab)
ASSIGNED = [
    ("jamba-v0.1-52b", 32, 4096, 32, 8, 14336, 65536),
    ("command-r-35b", 40, 8192, 64, 8, 22528, 256000),
    ("rwkv6-1.6b", 24, 2048, 32, 32, 7168, 65536),
    ("internvl2-2b", 24, 2048, 16, 8, 8192, 92553),
    ("stablelm-3b", 32, 2560, 32, 32, 6912, 50304),
    ("whisper-base", 6, 512, 8, 8, 2048, 51865),
    ("deepseek-v2-236b", 60, 5120, 128, 128, 12288, 102400),
    ("arctic-480b", 35, 7168, 56, 8, 4864, 32000),
    ("deepseek-coder-33b", 62, 7168, 56, 8, 19200, 32256),
    ("moonshot-v1-16b-a3b", 48, 2048, 16, 16, 11264, 163840),
]


@pytest.mark.parametrize("name,L,D,H,KV,F,V", ASSIGNED)
def test_assigned_dims(name, L, D, H, KV, F, V):
    cfg = get_config(name)
    assert cfg.num_layers == L
    assert cfg.d_model == D
    assert cfg.num_heads == H
    assert cfg.num_kv_heads == KV
    assert cfg.d_ff == F
    assert cfg.vocab_size == V
    assert cfg.source, "every config must cite its source"


def test_all_registered():
    names = list_configs()
    for name, *_ in ASSIGNED:
        assert name in names


def test_moe_settings():
    jamba = get_config("jamba-v0.1-52b")
    assert jamba.moe.num_experts == 16 and jamba.moe.top_k == 2
    dsv2 = get_config("deepseek-v2-236b")
    assert dsv2.moe.num_experts == 160 and dsv2.moe.top_k == 6
    assert dsv2.moe.num_shared_experts == 2
    assert dsv2.attention == "mla" and dsv2.mla_kv_lora == 512
    assert dsv2.moe.d_ff_expert == 1536
    arctic = get_config("arctic-480b")
    assert arctic.moe.num_experts == 128 and arctic.moe.top_k == 2
    moon = get_config("moonshot-v1-16b-a3b")
    assert moon.moe.num_experts == 64 and moon.moe.top_k == 6
    assert moon.moe.d_ff_expert == 1408


def test_family_coverage():
    fams = {get_config(n).family for n, *_ in ASSIGNED}
    assert fams >= {"dense", "moe", "ssm", "hybrid", "vlm", "encdec"}


def test_smoke_reduction_bounds():
    for name, *_ in ASSIGNED:
        cfg = get_config(name).smoke()
        assert cfg.num_layers <= 8
        assert cfg.d_model <= 512
        if cfg.moe:
            assert cfg.moe.num_experts <= 4


def test_padded_vocab():
    for name, *_ in ASSIGNED:
        cfg = get_config(name)
        assert cfg.padded_vocab >= cfg.vocab_size
        assert cfg.padded_vocab % 256 == 0
