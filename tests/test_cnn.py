"""Executable paper workloads (ResNet/VGG): param counts match the paper's
model sizes, forward/train steps run, and param counts agree with the
analytic profiles the simulator uses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cnn_profiles import get_profile
from repro.models.cnn import cnn_loss, get_cnn

# interpret-mode Pallas / full-model tests: minutes of wall clock on CPU
pytestmark = pytest.mark.slow



def _count(params):
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params)
               if hasattr(p, "size"))


@pytest.mark.parametrize("name", ["resnet50", "resnet101", "vgg16"])
def test_param_counts_match_paper_profiles(name):
    params, _ = get_cnn(name, jax.random.key(0))
    real = _count(params)
    prof = get_profile(name).total_params
    # analytic profile omits bn in fcs etc.; must agree within 1%
    assert abs(real - prof) / prof < 0.01, (name, real, prof)


@pytest.mark.parametrize("name", ["resnet50", "vgg16"])
def test_forward_and_train_step(name):
    params, forward = get_cnn(name, jax.random.key(0), num_classes=10,
                              width_mult=0.125)
    B = 2
    batch = {"images": jax.random.normal(jax.random.key(1), (B, 224, 224, 3)),
             "labels": jnp.asarray([1, 3], jnp.int32)}
    logits = jax.jit(forward)(params, batch["images"])
    assert logits.shape == (B, 10)
    assert jnp.all(jnp.isfinite(logits))

    loss0 = float(cnn_loss(forward, params, batch))
    grads = jax.jit(jax.grad(lambda p: cnn_loss(forward, p, batch)))(params)
    params2 = jax.tree_util.tree_map(
        lambda p, g: p - 0.05 * g if hasattr(p, "shape") else p, params, grads)
    loss1 = float(cnn_loss(forward, params2, batch))
    assert np.isfinite(loss1) and loss1 < loss0


def test_resnet_sizes_vs_paper_mb():
    # paper: 97 / 170 / 527 MB
    for name, mb in [("resnet50", 97), ("resnet101", 170), ("vgg16", 527)]:
        params, _ = get_cnn(name, jax.random.key(0))
        size_mib = _count(params) * 4 / 1024 ** 2
        assert abs(size_mib - mb) < mb * 0.05, (name, size_mib)
