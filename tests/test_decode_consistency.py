"""Prefill-vs-decode consistency: decoding token S+1 against the prefill
cache must match running prefill over S+1 tokens, per architecture family.

This is the system invariant that catches ring-buffer indexing, rope offset
and state-carry bugs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.registry import get_model, pad_cache

# interpret-mode Pallas / full-model tests: minutes of wall clock on CPU
pytestmark = pytest.mark.slow


# all ten assigned architectures (every decode path: GQA ring buffer, MLA
# latent cache, RWKV recurrent state, Jamba hybrid, whisper enc-dec, MoE)
ARCHS = ["stablelm-3b", "deepseek-v2-236b", "rwkv6-1.6b", "jamba-v0.1-52b",
         "whisper-base", "command-r-35b", "internvl2-2b", "arctic-480b",
         "deepseek-coder-33b", "moonshot-v1-16b-a3b"]
B, S = 2, 32


def _mk_batch(cfg, tokens):
    batch = {"tokens": tokens}
    if cfg.family == "vlm" and cfg.prefix_embeds:
        batch["prefix_embeds"] = jnp.zeros((B, cfg.prefix_embeds, cfg.d_model),
                                           jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.key(9), (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


def _no_drop(cfg):
    """Disable MoE capacity dropping: prefill drops over-capacity tokens
    while a single decode token always fits, so exact prefill==decode
    equality only holds with capacity >= top_k * S (semantics, not a cache
    bug — documented in DESIGN.md)."""
    if cfg.moe is not None:
        import dataclasses
        return cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    return cfg


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    cfg = _no_drop(get_config(arch).smoke())
    api = get_model(cfg)
    params = api.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                              cfg.vocab_size, jnp.int32)

    # ground truth: prefill over S+1 tokens -> logits at the last position
    logits_full, _ = jax.jit(api.prefill)(params, _mk_batch(cfg, toks))

    # incremental: prefill over S tokens, then decode token S.  For the VLM
    # the cache also holds the prefix patch embeddings, so the decode index
    # is prefix_len + S (the number of cache entries written).
    n_cached = S + (cfg.prefix_embeds if cfg.family == "vlm" else 0)
    logits_s, cache = jax.jit(api.prefill)(params, _mk_batch(cfg, toks[:, :S]))
    cache = pad_cache(cache, n_cached + 1)
    logits_inc, _ = jax.jit(api.decode_step)(
        params, {"tokens": toks[:, S:S + 1]}, cache,
        jnp.asarray(n_cached, jnp.int32))

    a = np.asarray(logits_full[:, -1], dtype=np.float32)
    b = np.asarray(logits_inc[:, -1], dtype=np.float32)
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)
    # and the argmax (the actual served token) agrees
    np.testing.assert_array_equal(a.argmax(-1), b.argmax(-1))


@pytest.mark.parametrize("arch", ["stablelm-3b", "rwkv6-1.6b"])
def test_multi_step_decode_consistency(arch):
    """Three consecutive decode steps equal prefill over S+3 tokens."""
    cfg = get_config(arch).smoke()
    api = get_model(cfg)
    params = api.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(2), (B, S + 3), 0,
                              cfg.vocab_size, jnp.int32)
    logits_full, _ = jax.jit(api.prefill)(params, _mk_batch(cfg, toks))

    _, cache = jax.jit(api.prefill)(params, _mk_batch(cfg, toks[:, :S]))
    cache = pad_cache(cache, S + 3)
    decode = jax.jit(api.decode_step)
    logits = None
    for i in range(3):
        logits, cache = decode(params, {"tokens": toks[:, S + i:S + i + 1]},
                               cache, jnp.asarray(S + i, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits_full[:, -1], np.float32),
        np.asarray(logits[:, -1], np.float32), rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_buffer():
    """With window W < S the ring buffer overwrites old slots; attention over
    the last W tokens only.  Validated against a fresh prefill of the
    window-sized suffix... (positions differ, so instead: decode stays finite
    and the cache index wraps without shape errors)."""
    cfg = get_config("stablelm-3b").smoke().replace(sliding_window=16)
    api = get_model(cfg)
    params = api.init(jax.random.key(0))
    toks = jnp.ones((B, 40), jnp.int32)
    _, cache = jax.jit(api.prefill)(params, {"tokens": toks[:, :16]})
    decode = jax.jit(api.decode_step)
    logits = None
    for i in range(20):   # wraps the 16-slot buffer
        logits, cache = decode(params, {"tokens": toks[:, :1]}, cache,
                               jnp.asarray(16 + i, jnp.int32))
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
