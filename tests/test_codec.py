"""The priced compression-codec axis: codec resolution and wire formats,
the encode -> wire -> decode lowering's invariants (byte conservation,
codec=none bit-exactness, encode-chain monotonicity), legacy
``compression_ratio`` equivalence, error feedback, size-adaptive policy,
regime classification (fig13), and the codec axis's spec-hash elision."""
import json
from pathlib import Path

import pytest

from repro.core.addest import AddEst
from repro.core.codec import (FALLBACK_PASSES, INT8_WIRE_RATIO, NONE_CODEC,
                              REGIME_LOSES, REGIME_NEUTRAL,
                              REGIME_PURE_OVERHEAD, REGIME_WINS,
                              SIZE_ADAPTIVE_THRESHOLD, TERNARY_WIRE_RATIO,
                              classify_regime, get_codec, parse_codec)
from repro.core.network_model import RingAllReduce
from repro.core.schedule import (CodecLowering, assign_codec, assign_rails,
                                 codec_compute_seconds, lower_buckets,
                                 plan_to_flows)
from repro.core.simulator import simulate, simulate_contention
from repro.core.timeline import GradTimeline
from repro.core.transport import GBPS

REPO = Path(__file__).resolve().parents[1]


def _mk_timeline(ready, sizes, t_back=None):
    t_back = t_back if t_back is not None else (max(ready) if ready else 0.0)
    return GradTimeline("t", tuple(ready), tuple(sizes), t_back, t_back * 1.5)


def _plan(sizes=(8e6, 2e6, 16e6), sched="chunked", k=4):
    buckets = [(0.01 * i, s, 3) for i, s in enumerate(sizes)]
    return lower_buckets(buckets, scheduler=sched, n_chunks=k)


def _cost(ratio=1.0, n=64, bw=10 * GBPS):
    return RingAllReduce(n, bw, AddEst.v100(), ratio)


# ---------------------------------------------------------------------------
# codec resolution and wire formats
# ---------------------------------------------------------------------------

def test_fallback_passes_pinned_to_committed_calibration():
    # FALLBACK_PASSES (used when the artifact checkout is absent) must
    # price codecs identically to the committed calibration table the CI
    # bench job gates against fresh kernel measurements
    table = json.loads(
        (REPO / "artifacts" / "bench" / "BENCH_codec.json").read_text())
    assert set(table["codecs"]) == set(FALLBACK_PASSES)
    for name, stages in FALLBACK_PASSES.items():
        assert table["codecs"][name]["encode_passes"] == stages["encode"]
        assert table["codecs"][name]["decode_passes"] == stages["decode"]


def test_parse_codec():
    assert parse_codec("int8") == ("int8", None)
    assert parse_codec("topk:8") == ("topk", 8.0)
    assert parse_codec("ratio:2.5") == ("ratio", 2.5)
    with pytest.raises(ValueError, match="bad codec parameter"):
        parse_codec("topk:lots")


def test_wire_ratios_match_kernel_block_format():
    # BLOCK = 256 f32: int8 emits 256 bytes + one f32 scale, ternary packs
    # 2 bits/element + one f32 scale
    assert get_codec("int8").wire_ratio == pytest.approx(1024 / 260)
    assert get_codec("ternary").wire_ratio == pytest.approx(1024 / 68)
    assert get_codec("topk:8").wire_ratio == 8.0
    assert get_codec("ratio:4").wire_ratio == 4.0
    assert INT8_WIRE_RATIO < TERNARY_WIRE_RATIO


def test_kernel_codecs_are_priced_and_ratio_is_free():
    for name in ("int8", "ternary", "topk:8"):
        c = get_codec(name)
        assert not c.is_free
        assert c.encode_seconds(1e6) > 0.0 and c.decode_seconds(1e6) > 0.0
    assert get_codec("ratio:4").is_free
    assert NONE_CODEC.is_free and NONE_CODEC.wire_ratio == 1.0


def test_legacy_compression_ratio_routes_through_ratio_codec():
    c = get_codec("none", compression_ratio=10.0)
    assert c.kind == "ratio" and c.wire_ratio == 10.0 and c.is_free


def test_get_codec_rejections():
    with pytest.raises(ValueError, match="takes no parameter"):
        get_codec("none:2")
    with pytest.raises(ValueError, match="takes no parameter"):
        get_codec("int8:4")
    with pytest.raises(ValueError, match="intrinsic wire ratio"):
        get_codec("ternary", compression_ratio=4.0)
    with pytest.raises(ValueError, match="unknown codec"):
        get_codec("gzip")


def test_error_feedback_prices_residual_and_rejects_free_codecs():
    c = get_codec("int8")
    ef = c.with_error_feedback()
    assert ef.name == "int8+ef"
    assert ef.encode_seconds(1e6) > c.encode_seconds(1e6)
    assert ef.decode_seconds(1e6) == c.decode_seconds(1e6)
    with pytest.raises(ValueError, match="lossy codec"):
        get_codec("ratio:4").with_error_feedback()


# ---------------------------------------------------------------------------
# assign_codec: stamping preserves the IR's conserved quantity
# ---------------------------------------------------------------------------

def test_assign_codec_none_uniform_is_same_object():
    plan = _plan()
    assert assign_codec(plan, "none") is plan


def test_assign_codec_preserves_total_bytes_and_structure():
    plan = _plan()
    for codec, policy in (("int8", "uniform"), ("ternary", "size-adaptive")):
        stamped = assign_codec(plan, codec, policy=policy)
        assert stamped.total_bytes == plan.total_bytes
        assert stamped.n_buckets == plan.n_buckets
        assert [op.op_id for op in stamped.ops] == \
            [op.op_id for op in plan.ops]
        assert [op.size for op in stamped.ops] == \
            [op.size for op in plan.ops]


def test_assign_codec_size_adaptive_is_per_bucket_threshold():
    small, large = 1e3, 1e6
    plan = _plan(sizes=(small, large), sched="chunked", k=4)
    stamped = assign_codec(plan, "int8", policy="size-adaptive",
                           threshold=SIZE_ADAPTIVE_THRESHOLD)
    by_bucket = {}
    for op in stamped.ops:
        by_bucket.setdefault(op.bucket_id, set()).add(op.codec)
    # all chunks of a bucket agree; small bucket stays uncompressed
    assert by_bucket[0] == {"none"}
    assert by_bucket[1] == {"int8"}


def test_assign_codec_rejects_unknown_policy():
    with pytest.raises(KeyError, match="unknown codec policy"):
        assign_codec(_plan(), "int8", policy="per-tensor")


# ---------------------------------------------------------------------------
# plan_to_flows: the encode -> wire -> decode lowering
# ---------------------------------------------------------------------------

def test_codec_none_lowering_bit_identical_to_no_codecs():
    plan = _plan()
    cost = _cost()
    legacy = plan_to_flows(plan, cost, 5e-6)
    table = {"none": CodecLowering(NONE_CODEC, cost)}
    priced = plan_to_flows(plan, cost, 5e-6, codecs=table)
    assert legacy == priced


def test_codec_lowering_keeps_one_flow_per_op_and_shifts_ready():
    plan = _plan()
    base_cost = _cost()
    codec = get_codec("int8")
    stamped = assign_codec(plan, "int8")
    table = {"int8": CodecLowering(codec, _cost(codec.wire_ratio))}
    legacy = plan_to_flows(plan, base_cost, 5e-6)
    priced = plan_to_flows(stamped, base_cost, 5e-6, codecs=table)
    assert len(priced) == len(legacy) == len(plan.ops)
    prev_ready = 0.0
    for lo, hi in zip(legacy, priced):
        assert hi.op_id == lo.op_id
        # encode runs after the bucket flush, so ready can only move later
        assert hi.ready > lo.ready
        # the encode chain is serialized on the GPU: non-decreasing starts
        assert hi.ready >= prev_ready
        prev_ready = hi.ready
        # the wire shrinks by the codec ratio; decode pads the latency
        assert hi.work < lo.work
        assert hi.latency > 0.0


def test_codec_compute_seconds_counts_both_stages_once():
    plan = assign_codec(_plan(), "int8")
    codec = get_codec("int8")
    table = {"int8": CodecLowering(codec, _cost(codec.wire_ratio))}
    total = codec_compute_seconds(plan, table)
    by_hand = 0.0
    for op in plan.ops:
        launch = 2 * codec.launch_overhead if op.chunk == 0 else 0.0
        by_hand += launch + codec.encode_seconds(op.size) \
            + codec.decode_seconds(op.size)
    assert total == pytest.approx(by_hand, rel=1e-12)
    assert codec_compute_seconds(plan, None) == 0.0


def test_codec_lowering_composes_with_rails():
    codec = get_codec("int8")
    plan = assign_codec(assign_rails(_plan(), 2), "int8")
    table = {"int8": CodecLowering(codec, _cost(codec.wire_ratio))}
    flows = plan_to_flows(plan, _cost(), 5e-6, n_rails=2, codecs=table)
    lanes = {f.job for f in flows}
    # rail 0 keeps the plain job lane, matching the legacy rail lowering
    assert lanes == {"job0", "job0@r1"}
    assert len(flows) == len(plan.ops)


# ---------------------------------------------------------------------------
# simulate: end-to-end equivalences and physics
# ---------------------------------------------------------------------------

_TL = _mk_timeline([0.0, 0.02, 0.05], [30e6, 10e6, 60e6], t_back=0.06)
_SIM = dict(n_workers=64, bandwidth=10 * GBPS, transport="ideal",
            scheduler="chunked", n_chunks=4)


def test_simulate_codec_none_bit_identical_to_no_kwarg():
    base = simulate(_TL, **_SIM)
    priced = simulate(_TL, codec="none", **_SIM)
    assert base.to_dict() == priced.to_dict()
    assert "codec" not in base.to_dict()          # elided at default


def test_simulate_legacy_ratio_bit_identical_to_ratio_codec():
    # the deprecated NetworkModel.compression_ratio byte divisor and the
    # parametric ratio codec must be the same arithmetic, to the bit
    legacy = simulate(_TL, compression_ratio=10.0, **_SIM)
    ratio = simulate(_TL, codec="ratio:10", **_SIM)
    assert legacy.t_sync == ratio.t_sync
    assert legacy.t_overhead == ratio.t_overhead
    assert legacy.wire_bytes_per_worker == ratio.wire_bytes_per_worker
    assert ratio.codec_compute_s == 0.0


def test_simulate_codec_record_and_wire_bytes():
    none = simulate(_TL, **_SIM)
    int8 = simulate(_TL, codec="int8", **_SIM)
    d = int8.to_dict()
    assert d["codec"] == "int8"
    assert int8.codec_compute_s > 0.0
    assert int8.wire_bytes_per_worker == pytest.approx(
        none.wire_bytes_per_worker / INT8_WIRE_RATIO, rel=1e-12)


def test_simulate_codec_wins_when_network_bound():
    none = simulate(_TL, **_SIM)
    int8 = simulate(_TL, codec="int8", **_SIM)
    assert int8.t_overhead < none.t_overhead
    assert int8.scaling_factor > none.scaling_factor


def test_simulate_error_feedback_adds_encode_cost():
    plain = simulate(_TL, codec="int8", **_SIM)
    ef = simulate(_TL, codec="int8", error_feedback=True, **_SIM)
    assert ef.codec_compute_s > plain.codec_compute_s
    assert ef.t_sync >= plain.t_sync
    with pytest.raises(ValueError, match="lossy codec"):
        simulate(_TL, codec="none", error_feedback=True, **_SIM)


def test_simulate_size_adaptive_between_none_and_int8():
    tl = _mk_timeline([0.0, 0.02], [1e3, 60e6], t_back=0.03)
    kw = dict(_SIM, comm=None)
    none = simulate(tl, **kw)
    int8 = simulate(tl, codec="int8", **kw)
    ada = simulate(tl, codec="size-adaptive", **kw)
    assert int8.wire_bytes_per_worker <= ada.wire_bytes_per_worker \
        <= none.wire_bytes_per_worker
    assert ada.to_dict()["codec"] == "size-adaptive"


def test_simulate_codec_composes_with_rails_and_jitter():
    # the PR-4 scenario axes must keep working under a priced codec, and
    # codec=none must stay bit-exact on those paths
    kw = dict(_SIM, n_rails=2, jitter=1e-3, jitter_seed=7)
    base = simulate(_TL, **kw)
    none = simulate(_TL, codec="none", **kw)
    assert base.to_dict() == none.to_dict()
    int8 = simulate(_TL, codec="int8", **kw)
    assert int8.t_sync > 0.0 and int8.codec_compute_s > 0.0
    assert int8.wire_bytes_per_worker < base.wire_bytes_per_worker


def test_contention_single_job_codec_degenerates_to_simulate():
    (shared,) = simulate_contention([_TL], codec="ternary", **_SIM)
    alone = simulate(_TL, codec="ternary", **_SIM)
    assert shared.t_sync == pytest.approx(alone.t_sync, rel=1e-12)
    assert shared.codec_compute_s == pytest.approx(alone.codec_compute_s,
                                                   rel=1e-12)


def test_contention_codec_relieves_shared_link():
    jobs = [_TL, _TL]
    none = simulate_contention(jobs, **_SIM)
    int8 = simulate_contention(jobs, codec="int8", **_SIM)
    for n, c in zip(none, int8):
        assert c.t_overhead < n.t_overhead


# ---------------------------------------------------------------------------
# regime classification (fig13)
# ---------------------------------------------------------------------------

def test_classify_regime_all_four_outcomes():
    # real baseline overhead, materially reduced -> wins
    assert classify_regime(0.1, 0.5, 1.0, 1e-3) == REGIME_WINS
    # compute outweighs wire savings -> loses
    assert classify_regime(0.8, 0.5, 1.0, 1e-3) == REGIME_LOSES
    # negligible baseline: compression had nothing to buy
    assert classify_regime(3e-4, 4e-4, 1.0, 1e-3) == REGIME_PURE_OVERHEAD
    # free codec on a negligible baseline changes nothing
    assert classify_regime(4e-4, 4e-4, 1.0, 0.0) == REGIME_NEUTRAL


def test_classify_regime_micro_delta_on_negligible_baseline():
    # a tiny improvement on an already-negligible overhead must NOT count
    # as a win — the nothing-to-win check runs first
    assert classify_regime(3.3e-4, 3.4e-4, 0.43, 2e-3) \
        == REGIME_PURE_OVERHEAD


# ---------------------------------------------------------------------------
# the experiments axis: elision keeps pre-codec artifacts bit-stable
# ---------------------------------------------------------------------------

def test_codec_axis_elided_at_default():
    from repro.experiments import GRIDS, Cell, ExperimentSpec
    cell = Cell("resnet50", 8, 10.0, "ideal", 1.0, "ring")
    assert "codec" not in cell.to_dict()
    assert Cell.from_dict(cell.to_dict()) == cell
    stamped = Cell("resnet50", 8, 10.0, "ideal", 1.0, "ring", codec="int8")
    assert stamped.to_dict()["codec"] == "int8"
    assert Cell.from_dict(stamped.to_dict()) == stamped
    # pre-codec grids keep their canonical JSON — and hence spec hash,
    # the golden-artifact gate
    assert "codec" not in GRIDS["paper-fig1"].canonical_json()
    a = ExperimentSpec(name="t")
    b = ExperimentSpec(name="t", codec=("none", "int8"))
    assert a.spec_hash() != b.spec_hash()
    assert "codec" not in a.canonical_json()


def test_codec_axis_expands_last():
    from repro.experiments import ExperimentSpec
    spec = ExperimentSpec(name="t", models=("a",), codec=("none", "int8"))
    cells = spec.expand()
    assert spec.n_cells == len(cells) == 2
    assert [c.codec for c in cells] == ["none", "int8"]


def test_compression_grid_registered_and_gated():
    from repro.experiments import GRIDS, SUITES
    spec = GRIDS["compression"]
    assert set(spec.codec) == {"none", "int8", "ternary", "topk:8",
                               "size-adaptive"}
    assert SUITES["compression"] == ("compression",)
