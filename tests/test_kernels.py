"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle in
ref.py, swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.quantize import BLOCK, ROW_TILE

SHAPES = [(BLOCK * ROW_TILE,), (BLOCK * ROW_TILE * 3,), (999,), (1, 1),
          (123, 45), (BLOCK,), (2 * BLOCK + 17,)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(shape, dtype, seed=0):
    return jax.random.normal(jax.random.key(seed), shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_quantize_int8_matches_ref(shape, dtype):
    x = _rand(shape, dtype)
    q, s, n = ops.quantize_int8(x)
    out = ops.dequantize_int8(q, s, n)
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % (BLOCK * ROW_TILE)
    padded = jnp.concatenate([flat, jnp.zeros((pad,))]) if pad else flat
    q_ref, s_ref = ref.quantize_int8(padded, BLOCK)
    # bf16 inputs land exactly on .5 rounding boundaries after upcast, where
    # interpret-mode and XLA-jnp tie-breaking may differ by one step
    atol_q = 1 if dtype == jnp.bfloat16 else 0
    diff = np.abs(np.asarray(q, np.int32).reshape(-1)
                  - np.asarray(q_ref, np.int32))
    assert diff.max() <= atol_q, f"max int8 diff {diff.max()}"
    np.testing.assert_allclose(np.asarray(s).reshape(-1), np.asarray(s_ref),
                               rtol=1e-6)
    # quantization error bound: half a quantization step per element
    scale_full = np.repeat(np.asarray(s_ref), BLOCK)[:flat.size]
    err = np.abs(np.asarray(out) - np.asarray(flat))
    assert np.all(err <= 0.5 * scale_full + 1e-7)


@pytest.mark.parametrize("shape", SHAPES)
def test_ternarize_matches_ref(shape):
    x = _rand(shape, jnp.float32, seed=1)
    t, s, n = ops.ternarize(x)
    flat = x.reshape(-1)
    pad = (-flat.size) % (BLOCK * ROW_TILE)
    padded = jnp.concatenate([flat, jnp.zeros((pad,))]) if pad else flat
    t_ref, s_ref = ref.ternarize(padded, BLOCK)
    np.testing.assert_array_equal(np.asarray(t).reshape(-1), np.asarray(t_ref))
    np.testing.assert_allclose(np.asarray(s).reshape(-1), np.asarray(s_ref),
                               rtol=1e-6)
    assert set(np.unique(np.asarray(t))) <= {-1, 0, 1}


@pytest.mark.parametrize("ratio", [0.01, 0.1, 0.5])
@pytest.mark.parametrize("n", [4096, 100_000])
def test_topk_sparsify(ratio, n):
    x = _rand((n,), jnp.float32, seed=2)
    y = ops.topk_sparsify(x, ratio)
    kept = int(jnp.sum(y != 0))
    k = max(int(ratio * n), 1)
    assert abs(kept - k) <= max(2, int(0.01 * n)), (kept, k)
    # exactly the largest-magnitude entries survive
    y_ref = ref.topk_mask(x, ref.topk_threshold(x, ratio))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


@pytest.mark.parametrize("k", [1, 2, 8, 16])
@pytest.mark.parametrize("n", [2048, 5000])
def test_fused_add(k, n):
    bufs = _rand((k, n), jnp.float32, seed=3)
    out = ops.fused_add(bufs)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.fused_add(bufs)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 5000), seed=st.integers(0, 2**31 - 1),
       scale=st.floats(1e-3, 1e3))
def test_quantize_roundtrip_error_bounded(n, seed, scale):
    """|dequant(quant(x)) - x| <= max|block| / 254 for every element."""
    x = _rand((n,), jnp.float32, seed=seed) * scale
    q, s, m = ops.quantize_int8(x)
    out = ops.dequantize_int8(q, s, m)
    err = np.abs(np.asarray(out) - np.asarray(x))
    bound = float(jnp.max(jnp.abs(x))) / 254.0 + 1e-6
    assert err.max() <= bound * 1.01


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 3000), seed=st.integers(0, 2**31 - 1))
def test_ternary_sign_preserved(n, seed):
    x = _rand((n,), jnp.float32, seed=seed)
    t, s, m = ops.ternarize(x)
    tt = np.asarray(t).ravel()[:n]
    xx = np.asarray(x)
    nz = tt != 0
    assert np.all(np.sign(xx[nz]) == tt[nz])


@settings(max_examples=20, deadline=None)
@given(k=st.integers(1, 10), n=st.integers(1, 4000),
       seed=st.integers(0, 2**31 - 1))
def test_fused_add_linearity(k, n, seed):
    bufs = _rand((k, n), jnp.float32, seed=seed)
    out2 = ops.fused_add(2.0 * bufs)
    out1 = ops.fused_add(bufs)
    np.testing.assert_allclose(np.asarray(out2), 2 * np.asarray(out1),
                               rtol=1e-5, atol=1e-5)
