"""The paper's what-if simulator: unit tests of the cost model and fusion
buffer, property tests of simulator invariants, and checks of the paper's
own numbers."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import CommConfig
from repro.core.addest import AddEst
from repro.core.network_model import (HierarchicalAllReduce, RingAllReduce,
                                      ring_reduction_time,
                                      ring_transmission_time)
from repro.core.simulator import fuse_buckets, simulate
from repro.core.timeline import GradTimeline, from_cnn
from repro.core.transport import GBPS, get_transport
from repro.core.whatif import sim_scaling, transmission_table


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_ring_transmission_formula():
    # paper: (2 S (N-1)/N) / bw
    assert ring_transmission_time(100e6, 4, 10e9) == pytest.approx(
        2 * 100e6 * 3 / 4 / 10e9)
    assert ring_transmission_time(100e6, 1, 10e9) == 0.0


def test_ring_reduction_uses_addest():
    addest = AddEst((0.0, 1e9), (0.0, 1.0))       # 1 s per GB
    # (N-1) adds of S/N
    assert ring_reduction_time(8e8, 4, addest) == pytest.approx(3 * 0.2)


def test_hierarchical_less_than_flat_on_slow_dcn():
    addest = AddEst.v100()
    size = 512 * 1024 * 1024
    flat = RingAllReduce(64, 10 * GBPS, addest).time(size)
    hier = HierarchicalAllReduce(8, 8, 100 * GBPS, 10 * GBPS, addest).time(size)
    assert hier < flat


# ---------------------------------------------------------------------------
# fusion buffer
# ---------------------------------------------------------------------------

def _mk_timeline(ready, sizes, t_back=None, t_batch=None):
    t_back = t_back if t_back is not None else (max(ready) if ready else 0.0)
    return GradTimeline("t", tuple(ready), tuple(sizes), t_back,
                        t_batch if t_batch is not None else t_back * 1.5)


def test_fusion_size_flush():
    comm = CommConfig(fusion_buffer_mb=1.0, timeout_ms=1e9)
    tl = _mk_timeline([0.001 * i for i in range(10)],
                      [300 * 1024] * 10)           # 10 x 300 KB
    buckets = fuse_buckets(tl, comm)
    assert sum(b.size for b in buckets) == pytest.approx(10 * 300 * 1024)
    assert all(b.size <= 1024 * 1024 + 1 for b in buckets)
    assert len(buckets) >= 3


def test_fusion_timeout_flush():
    comm = CommConfig(fusion_buffer_mb=1e6, timeout_ms=5.0)
    tl = _mk_timeline([0.0, 0.001, 0.020], [1024, 1024, 1024])
    buckets = fuse_buckets(tl, comm)
    # first two fuse (within 5 ms), third arrives after the timeout
    assert len(buckets) == 2
    assert buckets[0].size == 2048
    assert buckets[0].flush_time == pytest.approx(0.005)


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 60), seed=st.integers(0, 10_000))
def test_fusion_conserves_bytes(n, seed):
    rng = np.random.default_rng(seed)
    ready = np.sort(rng.uniform(0, 0.1, n))
    sizes = rng.uniform(1e3, 80e6, n)
    tl = _mk_timeline(list(ready), list(sizes))
    buckets = fuse_buckets(tl, CommConfig())
    assert sum(b.size for b in buckets) == pytest.approx(sizes.sum(), rel=1e-9)
    # flush times are non-decreasing and within [0, t_back]
    ft = [b.flush_time for b in buckets]
    assert all(a <= b + 1e-12 for a, b in zip(ft, ft[1:]))
    assert ft[-1] <= tl.t_back + 1e-12


# ---------------------------------------------------------------------------
# simulator invariants
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(bw1=st.floats(1, 50), bw2=st.floats(51, 400),
       n=st.sampled_from([8, 16, 64]))
def test_scaling_monotonic_in_bandwidth(bw1, bw2, n):
    tl = from_cnn("resnet50")
    f1 = simulate(tl, n_workers=n, bandwidth=bw1 * GBPS).scaling_factor
    f2 = simulate(tl, n_workers=n, bandwidth=bw2 * GBPS).scaling_factor
    assert f2 >= f1 - 1e-9


@settings(max_examples=25, deadline=None)
@given(ratio=st.floats(1.0, 100.0), bw=st.floats(1, 100))
def test_compression_never_hurts(ratio, bw):
    tl = from_cnn("vgg16")
    f1 = simulate(tl, n_workers=64, bandwidth=bw * GBPS).scaling_factor
    f2 = simulate(tl, n_workers=64, bandwidth=bw * GBPS,
                  compression_ratio=ratio).scaling_factor
    assert f2 >= f1 - 1e-9
    assert 0.0 < f2 <= 1.0


def test_scaling_factor_bounds():
    tl = from_cnn("resnet101")
    for n in (8, 16, 32, 64):
        r = simulate(tl, n_workers=n, bandwidth=100 * GBPS)
        assert 0.0 < r.scaling_factor <= 1.0
        assert r.t_sync >= r.t_back - 1e-12


# ---------------------------------------------------------------------------
# paper claims
# ---------------------------------------------------------------------------

def test_paper_transmission_times():
    by = {r["model"]: r["time_ms"] for r in transmission_table()}
    assert by["resnet50"] == pytest.approx(7.8, abs=1.5)
    assert by["resnet101"] == pytest.approx(13.6, abs=2.0)
    assert by["vgg16"] == pytest.approx(42.2, abs=4.0)


def test_paper_full_util_scaling():
    for model in ("resnet50", "resnet101", "vgg16"):
        f = sim_scaling(model, n_servers=8, bandwidth_gbps=100,
                        transport="ideal").scaling_factor
        assert f > 0.99, (model, f)


def test_paper_measured_mode_plateaus():
    f25 = sim_scaling("resnet50", bandwidth_gbps=25,
                      transport="horovod_tcp").scaling_factor
    f100 = sim_scaling("resnet50", bandwidth_gbps=100,
                       transport="horovod_tcp").scaling_factor
    assert f100 - f25 < 0.15


def test_paper_compression_2_to_5x_at_10g():
    f5 = sim_scaling("resnet50", bandwidth_gbps=10, transport="ideal",
                     compression_ratio=5).scaling_factor
    assert f5 > 0.95


def test_model_sizes_match_paper():
    from repro.core.cnn_profiles import get_profile
    # paper: 97 / 170 / 527 MB (we compute exact torchvision param counts)
    assert get_profile("resnet50").size_mib == pytest.approx(97, abs=3)
    assert get_profile("resnet101").size_mib == pytest.approx(170, abs=4)
    assert get_profile("vgg16").size_mib == pytest.approx(527, abs=3)


# ---------------------------------------------------------------------------
# paper §4 extensions: other-system what-ifs
# ---------------------------------------------------------------------------

def test_switchml_beats_ring_at_low_bw():
    """In-network aggregation halves-ish wire time (2S/bw vs 2S(N-1)/N/bw is
    ~equal at large N, but removes the (N-1) reduction term entirely) —
    SwitchML must never be worse than ring under full utilization."""
    from repro.core.whatif import fig9_other_systems
    for row in fig9_other_systems(bws=(1, 10)):
        assert row["switchml"] >= row["ring"] - 1e-9, row


def test_param_server_matches_ring_asymptotically():
    from repro.core.whatif import fig9_other_systems
    for row in fig9_other_systems(bws=(10,)):
        assert abs(row["param_server"] - row["ring"]) < 0.05, row


def test_bytescheduler_bound_improves_low_bw():
    from repro.core.whatif import bytescheduler_whatif
    r = bytescheduler_whatif("vgg16", bandwidth_gbps=10)
    assert r["bytescheduler_bound"] >= r["baseline"]
    # at 10 Gbps VGG16 has a large sync tail: scheduling must help
    assert r["bytescheduler_bound"] - r["baseline"] > 0.005


# ---------------------------------------------------------------------------
# TPU what-if (beyond-paper transplant of the analysis)
# ---------------------------------------------------------------------------

def test_tpu_whatif_dense_near_linear():
    """On 400 Gbps ICI, data-parallel gradient sync for <=35B dense models
    is near-invisible (the paper's conclusion, transplanted)."""
    from repro.configs import INPUT_SHAPES, get_config
    from repro.core.whatif import tpu_whatif
    shape = INPUT_SHAPES["train_4k"]
    for arch in ("stablelm-3b", "command-r-35b"):
        r = tpu_whatif(get_config(arch), shape)
        assert r.scaling_factor > 0.95, (arch, r.scaling_factor)


def test_tpu_whatif_multipod_worse_or_equal():
    from repro.configs import INPUT_SHAPES, get_config
    from repro.core.whatif import tpu_whatif
    shape = INPUT_SHAPES["train_4k"]
    cfg = get_config("deepseek-coder-33b")
    one = tpu_whatif(cfg, shape, n_pods=1)
    two = tpu_whatif(cfg, shape, n_pods=2)
    # crossing the DCN can only add overhead per step
    assert two.t_overhead >= one.t_overhead - 1e-9


def test_tpu_whatif_compression_helps_multipod():
    from repro.configs import INPUT_SHAPES, get_config
    from repro.core.whatif import tpu_whatif
    shape = INPUT_SHAPES["train_4k"]
    cfg = get_config("command-r-35b")
    plain = tpu_whatif(cfg, shape, n_pods=2, dcn_gbps=25.0)
    comp = tpu_whatif(cfg, shape, n_pods=2, dcn_gbps=25.0,
                      compression_ratio=4.0)
    assert comp.scaling_factor >= plain.scaling_factor - 1e-9


# ---------------------------------------------------------------------------
# scenario axes: multi-rail and straggler jitter through simulate()
# ---------------------------------------------------------------------------

def test_simulate_default_rails_and_jitter_are_identity():
    """n_rails=1, jitter=0 must be the same code path bit-for-bit — the
    contract that keeps the committed golden artifacts valid."""
    tl = from_cnn("vgg16")
    plain = simulate(tl, n_workers=64, bandwidth=25 * GBPS,
                     transport="horovod_tcp")
    expl = simulate(tl, n_workers=64, bandwidth=25 * GBPS,
                    transport="horovod_tcp", n_rails=1, jitter=0.0,
                    rail_policy="round-robin", jitter_seed=99)
    assert plain.t_sync == expl.t_sync
    assert plain.buckets == expl.buckets


def test_simulate_chunked_rails_invariant_at_equal_aggregate():
    """Striped chunked plans: splitting one fat NIC into rails moves
    overhead by no more than the tail-bucket negotiation skew."""
    tl = from_cnn("vgg16")
    base = simulate(tl, n_workers=64, bandwidth=10 * GBPS,
                    transport="horovod_tcp", scheduler="chunked",
                    n_chunks=8)
    for r in (2, 4):
        split = simulate(tl, n_workers=64, bandwidth=10 * GBPS,
                         transport="horovod_tcp", scheduler="chunked",
                         n_chunks=8, n_rails=r)
        assert abs(split.t_overhead - base.t_overhead) < 1e-3


def test_simulate_fifo_rails_regime_split():
    """The serialized stream cannot stripe: rails help the latency-bound
    resnet101 (parallel reductions) and hurt the bandwidth-bound vgg16."""
    rn = from_cnn("resnet101")
    helped = (simulate(rn, n_workers=64, bandwidth=100 * GBPS,
                       transport="horovod_tcp", n_rails=2).t_overhead
              < simulate(rn, n_workers=64, bandwidth=100 * GBPS,
                         transport="horovod_tcp").t_overhead)
    vgg = from_cnn("vgg16")
    hurt = (simulate(vgg, n_workers=64, bandwidth=10 * GBPS,
                     transport="horovod_tcp", n_rails=2).t_overhead
            > simulate(vgg, n_workers=64, bandwidth=10 * GBPS,
                       transport="horovod_tcp").t_overhead)
    assert helped and hurt


def test_simulate_rail_policies_conserve_scaling_bounds():
    tl = from_cnn("resnet50")
    for policy in ("round-robin", "size-balanced"):
        r = simulate(tl, n_workers=64, bandwidth=25 * GBPS,
                     transport="horovod_tcp", scheduler="chunked",
                     n_chunks=8, n_rails=2, rail_policy=policy)
        assert 0.0 < r.scaling_factor <= 1.0
        assert 0.0 <= r.network_utilization <= 1.0


def test_simulate_jitter_monotone_and_seeded():
    tl = from_cnn("resnet50")
    kw = dict(n_workers=64, bandwidth=100 * GBPS, transport="horovod_tcp")
    prev = -1.0
    for j in (0.0, 0.002, 0.01):
        r = simulate(tl, jitter=j, jitter_seed=5, **kw)
        assert r.t_sync >= prev - 1e-12
        prev = r.t_sync
    a = simulate(tl, jitter=0.01, jitter_seed=5, **kw)
    b = simulate(tl, jitter=0.01, jitter_seed=5, **kw)
    c = simulate(tl, jitter=0.01, jitter_seed=6, **kw)
    assert a.t_sync == b.t_sync          # deterministic given the seed
    assert a.t_sync != c.t_sync          # and sensitive to it


def test_simulate_contention_rails_and_jitter():
    from repro.core.simulator import simulate_contention
    tls = [from_cnn("resnet50"), from_cnn("vgg16")]
    plain = simulate_contention(tls, n_workers=64, bandwidth=25 * GBPS,
                                scheduler="chunked", n_chunks=8)
    railed = simulate_contention(tls, n_workers=64, bandwidth=25 * GBPS,
                                 scheduler="chunked", n_chunks=8, n_rails=2)
    assert len(railed) == 2
    for p, r in zip(plain, railed):
        assert 0.0 < r.scaling_factor <= 1.0
        assert r.name == p.name
    # jobs straggle from independent streams: both jobs' results move
    jit = simulate_contention(tls, n_workers=64, bandwidth=25 * GBPS,
                              scheduler="chunked", n_chunks=8,
                              jitter=0.005, jitter_seed=11)
    assert all(j.t_sync >= p.t_sync - 1e-12 for p, j in zip(plain, jit))
    assert any(j.t_sync != p.t_sync for p, j in zip(plain, jit))
