"""Independent max-min fairness oracle for the multi-link engine.

This module is the differential-testing counterpart of the fabric
tentpole: a from-scratch O(n^2) implementation of bottleneck max-min
fair sharing and a rescan-everything event loop over multi-link paths,
sharing **no code** with ``repro.core.events`` beyond the ``FlowSpec`` /
``FlowResult`` data types.  Its value is being written differently:

- :func:`reference_maxmin` computes each round's fill level from the
  *flow* perspective (every unfrozen flow's own bottleneck rate, take
  the global minimum) where the engine's ``maxmin_rates`` works from the
  *link* perspective (each link's saturation level, take the minimum).
  The max-min fair allocation is unique, so both must land on the same
  rate vector to rounding error — that uniqueness is the whole contract
  ``tests/test_fabric.py`` checks on randomized instances.
- :class:`ReferenceFabricEngine` generalizes the frozen seed loop in
  ``tests/_reference_engine.py`` to paths: rescan all pending flows at
  every event, recompute the full rate vector from scratch, advance all
  wires stepwise.  Quadratic and proud of it.

Like the seed reference, flows follow the engine's job semantics: one
wire in flight per job in (priority, op_id) service order, ready gating,
``hold``/``latency``/``duration`` completion bookkeeping, and the exact
``start + work`` closed form for flows that were never contended.  A
flow is contended when it ever shared a link with another active flow or
cannot run at rate 1.0 alone (some path link's capacity is below the
flow's own multiplicity on it).

Churn is deliberately out of scope here — teardown semantics are pinned
by the engine-vs-engine tests in ``tests/test_faults.py``, not by this
oracle.
"""
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.events import FlowResult, FlowSpec


def _demand(flow: FlowSpec) -> Dict[str, float]:
    """link id -> multiplicity along the flow's route."""
    d: Dict[str, float] = {}
    for nm in (flow.path or (flow.link,)):
        d[nm] = d.get(nm, 0.0) + 1.0
    return d


def reference_maxmin(demands: Sequence[Dict[str, float]],
                     capacities: Dict[str, float]) -> List[float]:
    """Max-min fair rates, solved from the flow perspective.

    Water-filling: all unfrozen flows rise together; each round, every
    unfrozen flow's own ceiling is the tightest ``residual / load`` over
    its links, and the *global* fill level is the smallest such ceiling.
    Flows whose ceiling equals that level (their bottleneck is tight)
    freeze there; their consumption leaves the pool and the rest keep
    rising.  Rates cap at 1.0 — the engine's NIC-relative full rate.

    Every round freezes at least one flow, so the loop is O(n) rounds of
    O(n * L) scans — quadratic, independent of the engine's link-indexed
    bookkeeping.
    """
    n = len(demands)
    rates = [0.0] * n
    frozen = [False] * n
    residual: Dict[str, float] = {}
    for d in demands:
        for nm in d:
            residual.setdefault(nm, float(capacities.get(nm, 1.0)))
    while not all(frozen):
        # load each link carries from still-rising flows
        load: Dict[str, float] = {nm: 0.0 for nm in residual}
        for i, d in enumerate(demands):
            if frozen[i]:
                continue
            for nm, m in d.items():
                load[nm] += m
        # each unfrozen flow's ceiling; the fill level is the global min
        ceil: List[Optional[float]] = [None] * n
        level = None
        for i, d in enumerate(demands):
            if frozen[i]:
                continue
            c = min(max(residual[nm], 0.0) / load[nm] for nm in d)
            ceil[i] = c
            if level is None or c < level:
                level = c
        if level is None or level >= 1.0:
            for i in range(n):
                if not frozen[i]:
                    rates[i] = 1.0   # per-flow full-rate cap
                    frozen[i] = True
            break
        # freeze every flow whose own bottleneck is (within float ties)
        # the tight one; at least the argmin freezes, so progress is
        # guaranteed
        cut = level * (1.0 + 1e-12) + 1e-18
        for i in range(n):
            if frozen[i] or ceil[i] is None or ceil[i] > cut:
                continue
            rates[i] = level
            frozen[i] = True
            for nm, m in demands[i].items():
                residual[nm] -= m * level
    return rates


class _Run:
    __slots__ = ("flow", "demand", "start", "remaining", "contended")

    def __init__(self, flow: FlowSpec, start: float):
        self.flow = flow
        self.demand = _demand(flow)
        self.start = start
        self.remaining = flow.work
        self.contended = False


class ReferenceFabricEngine:
    """Rescan-everything multi-link loop: the seed structure, plus paths."""

    def __init__(self, capacities: Optional[Dict[str, float]] = None,
                 max_iters_factor: int = 10):
        self.capacities = dict(capacities or {})
        self.max_iters_factor = max_iters_factor

    def _rates(self, running: Dict[str, _Run]) -> Dict[str, float]:
        """job -> current max-min rate of its in-flight wire."""
        jobs = list(running)
        rs = reference_maxmin([running[j].demand for j in jobs],
                              self.capacities)
        return dict(zip(jobs, rs))

    def run(self, flows: Sequence[FlowSpec]) -> List[FlowResult]:
        """Execute ``flows``; returns results in input order."""
        pending: Dict[str, List[FlowSpec]] = {}
        for f in flows:
            pending.setdefault(f.job, []).append(f)
        for q in pending.values():
            q.sort(key=lambda f: (f.priority, f.op_id), reverse=True)

        job_free: Dict[str, float] = {j: 0.0 for j in pending}
        running: Dict[str, _Run] = {}
        results: Dict[int, FlowResult] = {}
        t = 0.0
        n_total = len(flows)
        max_iters = self.max_iters_factor * n_total + 100

        def _pick(job: str) -> Optional[FlowSpec]:
            q = pending[job]
            for i in range(len(q) - 1, -1, -1):  # sorted reverse: best last
                if q[i].ready <= t:
                    return q.pop(i)
            return None

        iters = 0
        while len(results) < n_total:
            iters += 1
            if iters > max_iters:
                raise RuntimeError("reference fabric engine failed to "
                                   f"converge ({len(results)}/{n_total})")

            # -- admissions at the current time ---------------------------
            admitted = False
            for job in pending:
                if job in running or job_free[job] > t or not pending[job]:
                    continue
                flow = _pick(job)
                if flow is None:
                    continue
                run = _Run(flow, start=t)
                if any(self.capacities.get(nm, 1.0) < m
                       for nm, m in run.demand.items()):
                    # cannot run at full rate even alone: no closed form
                    run.contended = True
                for other in running.values():
                    if any(nm in other.demand for nm in run.demand):
                        run.contended = True
                        other.contended = True
                running[job] = run
                admitted = True
            if admitted:
                continue  # membership changed; recompute the rate vector

            rates = self._rates(running)

            # -- next event: a completion or a job becoming serviceable ---
            t_next = None
            for job, run in running.items():
                r = rates[job]
                if r > 0.0:
                    proj = t + run.remaining / r
                    if t_next is None or proj < t_next:
                        t_next = proj
            for job, q in pending.items():
                if job in running or not q:
                    continue
                trigger = max(job_free[job], min(f.ready for f in q))
                if t_next is None or trigger < t_next:
                    t_next = trigger
            if t_next is None:
                raise RuntimeError(
                    "reference fabric engine stalled with pending flows")
            t_next = max(t_next, t)

            # -- advance all running wires to t_next ----------------------
            dt = t_next - t
            done: List[Tuple[str, _Run]] = []
            for job, run in running.items():
                r = rates[job]
                run.remaining -= dt * r
                if r > 0.0 and (
                        run.remaining <= run.flow.work * 1e-12 + 1e-18
                        or t_next + run.remaining / r <= t_next):
                    done.append((job, run))
            t = t_next

            for job, run in done:
                flow = run.flow
                if not run.contended:
                    wire_end = run.start + flow.work  # rate 1.0 throughout
                    if flow.hold and flow.duration is not None:
                        end = run.start + flow.duration
                    else:
                        end = wire_end + flow.latency
                else:
                    wire_end = t
                    end = wire_end + flow.latency
                results[flow.op_id] = FlowResult(
                    flow.op_id, job, run.start, wire_end, end, run.contended)
                del running[job]
                job_free[job] = end if flow.hold else wire_end

        return [results[f.op_id] for f in flows]


def run_reference_fabric_flows(flows: Sequence[FlowSpec],
                               capacities: Optional[Dict[str, float]] = None,
                               max_iters_factor: int = 10
                               ) -> List[FlowResult]:
    """Convenience wrapper: execute ``flows`` on a fresh oracle engine."""
    return ReferenceFabricEngine(capacities, max_iters_factor).run(flows)
