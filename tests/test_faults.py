"""Fault-injection layer: parsing, determinism, engine churn semantics,
and the bitwise contracts (null bypass, correlation-0 == jitter, executor
and lowering-path bit-identity)."""
import numpy as np
import pytest

from repro.core import events as ev
from repro.core.events import ChurnEvent, FlowBatch, FlowSpec, run_flows
from repro.core.faults import (FaultModel, apply_faults_batch,
                               apply_faults_flows, bw_factors, churn_events,
                               fault_delays, parse_fault_model, worker_codes)
from repro.core.simulator import simulate
from repro.core.timeline import from_cnn
from repro.core.transport import GBPS


# ---------------------------------------------------------------------------
# parsing
# ---------------------------------------------------------------------------

def test_parse_fault_model():
    assert parse_fault_model("none") == FaultModel()
    assert parse_fault_model("") == FaultModel()
    fm = parse_fault_model("slowdown:2")
    assert fm.slowdown == 2e-3 and fm.correlation == 1.0
    fm = parse_fault_model("slowdown:5:0.25", churn_rate=0.5, bw_skew=0.1)
    assert fm.slowdown == 5e-3 and fm.correlation == 0.25
    assert fm.churn_rate == 0.5 and fm.bw_skew == 0.1
    with pytest.raises(ValueError, match="unknown fault model"):
        parse_fault_model("speedup:2")
    with pytest.raises(ValueError, match="outside"):
        parse_fault_model("slowdown:2:1.5")


def test_null_model_detection():
    assert FaultModel().is_null
    assert parse_fault_model("none").is_null
    assert not FaultModel(slowdown=1e-3).is_null
    assert not FaultModel(churn_rate=0.5).is_null
    assert not FaultModel(bw_skew=0.1).is_null


# ---------------------------------------------------------------------------
# draws: determinism + structure
# ---------------------------------------------------------------------------

def test_fault_delays_deterministic_and_correlated():
    fm = FaultModel(slowdown=2e-3, correlation=1.0)
    codes = np.array([0, 1, 0, 1, 2], dtype=np.intp)
    d1 = fault_delays(fm, codes, 3, seed=7)
    d2 = fault_delays(fm, codes, 3, seed=7)
    assert np.array_equal(d1, d2)
    # fully correlated: same worker -> identical delay
    assert d1[0] == d1[2] and d1[1] == d1[3]
    assert d1[0] != d1[1]
    assert fault_delays(FaultModel(), codes, 3, seed=7) is None


def test_correlation_zero_is_bitwise_per_flow_jitter():
    """rho=0 must reduce to jitter_delays on the same stream — the exact
    draws and the exact single multiply, not a statistical lookalike."""
    fm = FaultModel(slowdown=3e-3, correlation=0.0)
    codes = np.zeros(64, dtype=np.intp)
    d = fault_delays(fm, codes, 8, seed=13, stream=2)
    want = ev.jitter_delays(64, 3e-3, 13, stream=2)
    assert np.array_equal(d, want)


def test_bw_factors_floor_at_one():
    fac = bw_factors(FaultModel(bw_skew=0.5), 16, seed=3)
    assert fac.shape == (16,) and (fac >= 1.0).all()
    assert bw_factors(FaultModel(), 16, seed=3) is None


def test_worker_codes_are_structural():
    from repro.configs.base import CommConfig
    from repro.core.schedule import lower_buckets
    plan = lower_buckets([(0.0, 1e6, 4)] * 6, scheduler="fifo")
    codes = worker_codes(plan, 4)
    assert np.array_equal(codes, np.array([op.bucket_id % 4
                                           for op in plan.ops]))


def test_churn_events_deterministic_sorted_paired():
    fm = FaultModel(churn_rate=3.0, downtime=0.01, rebucket=0.005)
    a = churn_events(fm, 16, horizon=1.0, seed=5)
    b = churn_events(fm, 16, horizon=1.0, seed=5)
    assert a == b and a
    assert a == sorted(a)
    drops = [e for e in a if e.kind == "drop"]
    rejoins = [e for e in a if e.kind == "rejoin"]
    assert len(drops) == len(rejoins)
    assert all(0.0 <= e.t < 1.0 for e in drops)
    assert all(0 <= e.worker < 16 for e in drops)
    assert churn_events(FaultModel(), 16, 1.0, seed=5) == []


# ---------------------------------------------------------------------------
# lowering-path twins
# ---------------------------------------------------------------------------

def test_apply_faults_batch_and_flows_bit_identical():
    fm = FaultModel(slowdown=2e-3, correlation=0.6, bw_skew=0.4)
    flows = [FlowSpec(op_id=i, ready=0.1 * i, work=1e-3 * (i + 1),
                      latency=1e-4, job="j") for i in range(12)]
    codes = np.arange(12, dtype=np.intp) % 5
    batch = apply_faults_batch(FlowBatch.from_flows(flows), codes, fm, 5,
                               seed=9)
    twins = apply_faults_flows(flows, codes, fm, 5, seed=9)
    assert batch.to_flows() == twins
    assert batch.worker.tolist() == codes.tolist()


def test_flowspec_worker_roundtrips_through_batch():
    flows = [FlowSpec(op_id=i, ready=0.0, work=1.0, worker=i % 3)
             for i in range(6)]
    b = FlowBatch.from_flows(flows)
    assert b.worker.tolist() == [0, 1, 2, 0, 1, 2]
    assert b.to_flows() == flows


# ---------------------------------------------------------------------------
# engine churn semantics (hand-built scenario)
# ---------------------------------------------------------------------------

def test_engine_drop_cancels_dead_worker_and_restarts_wire():
    """Serial job, unit-work flows, workers alternating 0/1.  A worker-1
    drop at t=2.5 (stall 0.5) tears down the in-flight worker-0 transfer
    (restarts from scratch at 3.0), completes the dead worker's pending
    flow trivially at the drop time, and leaves the finished prefix
    untouched."""
    flows = [FlowSpec(op_id=i, ready=0.0, work=1.0, job="j", worker=i % 2)
             for i in range(4)]
    base = run_flows(flows)
    assert [r.end for r in base] == [1.0, 2.0, 3.0, 4.0]

    churn = [ChurnEvent(t=2.5, job="j", kind="drop", worker=1, stall=0.5)]
    res = {r.op_id: r for r in run_flows(flows, churn=churn)}
    assert res[0].end == 1.0 and res[1].end == 2.0       # already done
    assert res[3].start == res[3].end == 2.5             # dead worker's
    assert res[2].start == 3.0 and res[2].end == 4.0     # torn down, redone


def test_engine_rejoin_stalls_without_cancelling():
    flows = [FlowSpec(op_id=i, ready=0.0, work=1.0, job="j", worker=i % 2)
             for i in range(3)]
    churn = [ChurnEvent(t=1.5, job="j", kind="rejoin", worker=-1, stall=0.5)]
    res = {r.op_id: r for r in run_flows(flows, churn=churn)}
    # f1 was in flight: torn down at 1.5, restarted at 2.0 after the stall
    assert res[0].end == 1.0
    assert res[1].start == 2.0 and res[1].end == 3.0
    assert res[2].end == 4.0                             # nothing cancelled


def test_engine_churn_matches_rail_lane_jobs():
    """A ChurnEvent's job must also hit the job's rail lanes (job@r1...)."""
    flows = [FlowSpec(op_id=i, ready=0.0, work=1.0, job="j@r1", worker=0)
             for i in range(2)]
    churn = [ChurnEvent(t=0.5, job="j", kind="drop", worker=0, stall=0.0)]
    res = {r.op_id: r for r in run_flows(flows, churn=churn)}
    assert res[1].end == 0.5                             # cancelled via lane


def test_engine_churn_under_fabric_cancels_on_all_path_links():
    """A drop tears the in-flight flow off *every* link of its multi-link
    path at once: the survivor's max-min rate rises immediately (the
    freed uplink multiplicity is back in the pool), the dead worker's
    pending flow cancels at the drop time, and the torn-down wire
    restarts from scratch after the stall.

    Setup: path nic + 2x uplink (cap 1.0), so one flow alone runs at
    1/2 and two flows split the uplink at 1/4 each."""
    path = ("nic", "up", "up")
    caps = {"up": 1.0}
    flows = [
        FlowSpec(op_id=0, ready=0.0, work=1.0, job="a", worker=0, path=path),
        FlowSpec(op_id=1, ready=0.0, work=1.0, job="a", worker=1, path=path),
        FlowSpec(op_id=2, ready=0.0, work=1.0, job="b", worker=5, path=path),
    ]
    base = {r.op_id: r for r in run_flows(flows, capacities=caps)}
    # both wires at 1/4 until t=4, then a's second flow alone at 1/2
    assert base[0].wire_end == pytest.approx(4.0)
    assert base[2].wire_end == pytest.approx(4.0)
    assert base[1].wire_end == pytest.approx(6.0)

    churn = [ChurnEvent(t=1.0, job="a", kind="drop", worker=1, stall=2.0)]
    res = {r.op_id: r for r in run_flows(flows, capacities=caps,
                                         churn=churn)}
    # dead worker's pending flow completes trivially at the drop time
    assert res[1].start == res[1].wire_end == res[1].end == 1.0
    # survivor job b had 0.75 left: alone at 1/2 from t=1 -> done at 2.5,
    # which is only possible if the teardown freed both uplink slots
    assert res[2].wire_end == pytest.approx(2.5)
    # the torn-down wire restarts from scratch after the stall (t=3.0)
    # and runs alone at 1/2: done at 5.0
    assert res[0].start == pytest.approx(3.0)
    assert res[0].wire_end == pytest.approx(5.0)


def test_engine_zero_churn_list_keeps_small_path():
    flows = [FlowSpec(op_id=i, ready=0.0, work=1.0, job="j")
             for i in range(3)]
    assert run_flows(flows, churn=[]) == run_flows(flows)
    assert run_flows(flows, churn=None) == run_flows(flows)


@pytest.mark.parametrize("scheduler", ["fifo", "priority"])
def test_bulk_commit_bit_identical_under_churn(monkeypatch, scheduler):
    """The numpy bulk-commit path must fence at _FAULT entries and stay
    bit-identical to the scalar spin under churn, pointer and heap mode."""
    from repro.core.schedule import lower_buckets, plan_to_flows

    class _Cost:
        def time(self, size):
            return size / 1e9 + 5e-5

        def wire_time(self, size):
            return size / 1e9

    flows = []
    for j in range(4):
        plan = lower_buckets([(i * 1e-4, 2e6 * (i + 1), 4)
                              for i in range(24)],
                             scheduler=scheduler, n_chunks=4)
        fl = plan_to_flows(plan, _Cost(), 1e-6, job=f"j{j}",
                           op_id_base=len(flows))
        flows.extend(f._replace(worker=f.op_id % 8) for f in fl)
    assert len(flows) > ev._SMALL_PLAN_MAX_FLOWS
    churn = [ChurnEvent(t=5e-4, job="j1", kind="drop", worker=3,
                        stall=2e-4),
             ChurnEvent(t=9e-4, job="j2", kind="rejoin", worker=-1,
                        stall=2e-4),
             ChurnEvent(t=1.2e-3, job="j0", kind="drop", worker=1,
                        stall=2e-4)]
    fast = run_flows(flows, churn=churn)
    monkeypatch.setattr(ev, "_BULK_MIN_ACTIVE", 10 ** 9)
    slow = run_flows(flows, churn=churn)
    monkeypatch.undo()
    assert fast == slow


# ---------------------------------------------------------------------------
# simulate-level contracts
# ---------------------------------------------------------------------------

def _sim(**kw):
    return simulate(from_cnn("resnet50"), n_workers=16,
                    bandwidth=10.0 * GBPS, transport="horovod_tcp", **kw)


def test_zero_fault_simulate_bitwise_identical():
    """fault_model='none' with no churn/skew must be a byte-for-byte
    bypass of the fault layer, not a near-miss."""
    base = _sim()
    assert _sim(fault_model="none", churn_rate=0.0, worker_bw_skew=0.0,
                fault_seed=99) == base


def test_correlation_zero_simulate_matches_jitter_axis():
    """slowdown:<ms>:0 must reproduce the jitter axis bitwise (jitter is
    in seconds, the fault axis string in ms)."""
    want = _sim(jitter=2e-3, jitter_seed=11)
    got = _sim(fault_model="slowdown:2:0", fault_seed=11)
    assert got == want


def test_simulate_fault_overhead_monotone_in_slowdown():
    ts = [_sim(fault_model=f, fault_seed=3).t_sync
          for f in ("none", "slowdown:1", "slowdown:5")]
    assert ts[0] <= ts[1] <= ts[2]
    assert ts[2] > ts[0]


def test_simulate_churn_and_skew_replay_bitwise():
    kw = dict(fault_model="slowdown:2", churn_rate=2.0, worker_bw_skew=0.5,
              fault_seed=21)
    assert _sim(**kw) == _sim(**kw)
    assert _sim(**kw) != _sim(fault_model="slowdown:2", churn_rate=2.0,
                              worker_bw_skew=0.5, fault_seed=22)


def test_simulate_fault_paths_agree_columnar_vs_tuple(monkeypatch):
    """The columnar and tuple lowerings must produce bit-identical faulted
    results (shared draws, elementwise-equal application, one engine)."""
    kw = dict(fault_model="slowdown:3:0.5", churn_rate=1.5,
              worker_bw_skew=0.3, fault_seed=17, scheduler="priority",
              n_chunks=8)
    monkeypatch.setenv("REPRO_SIM_FASTPATH", "0")
    tup = _sim(**kw)
    monkeypatch.setenv("REPRO_SIM_FASTPATH", "1")
    col = _sim(**kw)
    assert tup == col


def test_simulate_contention_faults_bitwise_and_hurt():
    from repro.core.simulator import simulate_contention
    tls = [from_cnn("resnet50")] * 2
    kw = dict(n_workers=16, bandwidth=10.0 * GBPS)
    base = simulate_contention(tls, **kw)
    faulted = simulate_contention(tls, fault_model="slowdown:2",
                                  churn_rate=1.0, fault_seed=5, **kw)
    again = simulate_contention(tls, fault_model="slowdown:2",
                                churn_rate=1.0, fault_seed=5, **kw)
    assert faulted == again
    assert sum(r.t_sync for r in faulted) > sum(r.t_sync for r in base)
    # null model is a bypass under contention too
    assert simulate_contention(tls, fault_model="none", **kw) == base


# ---------------------------------------------------------------------------
# experiments: axes elided at default, executor bit-identity
# ---------------------------------------------------------------------------

def test_fault_axes_elided_at_default():
    from repro.experiments import GRIDS, Cell, ExperimentSpec
    solo = Cell("resnet50", 2, 10.0, "ideal", 1.0, "ring")
    for key in ("fault_model", "churn_rate", "worker_bw_skew"):
        assert key not in solo.to_dict()
    assert Cell.from_dict(solo.to_dict()) == solo
    faulted = Cell("resnet50", 2, 10.0, "ideal", 1.0, "ring",
                   fault_model="slowdown:5", churn_rate=0.64,
                   worker_bw_skew=0.5)
    d = faulted.to_dict()
    assert d["fault_model"] == "slowdown:5" and d["churn_rate"] == 0.64
    assert Cell.from_dict(d) == faulted

    plain = ExperimentSpec(name="t")
    for key in ("fault_model", "churn_rate", "worker_bw_skew", "fault_seed"):
        assert key not in plain.to_dict()
    swept = ExperimentSpec(name="t", fault_model=("none", "slowdown:5"),
                           churn_rate=(0.0, 0.64), fault_seed=2027)
    assert swept.spec_hash() != plain.spec_hash()
    assert ExperimentSpec.from_dict(swept.to_dict()) == swept
    # the historical grids' canonical JSON mentions no fault axis
    assert "fault_model" not in GRIDS["paper-fig1"].canonical_json()


@pytest.mark.parametrize("executor", ["thread", "process"])
def test_executors_bit_identical_on_fault_axes(executor):
    """Same (seed, fault_model) -> bitwise-identical artifacts regardless
    of executor: draws depend only on (fault_seed, stream, n), never on
    which thread or process ran the cell."""
    from repro.experiments import ExperimentSpec, run_spec
    spec = ExperimentSpec(name="t", models=("resnet50",), n_servers=(2,),
                          bandwidth_gbps=(10.0,),
                          scheduler=("fifo", "priority"), sched_chunks=8,
                          fault_model=("none", "slowdown:2"),
                          churn_rate=(0.0, 1.0), worker_bw_skew=(0.0, 0.5),
                          fault_seed=31)
    serial = run_spec(spec, executor="serial")
    other = run_spec(spec, executor=executor)
    assert serial["cells"] == other["cells"]
    assert serial["spec_hash"] == other["spec_hash"]


def test_churn_grid_registered_and_gated():
    from repro.experiments import GRIDS, grids
    from repro.experiments.validations import VALIDATORS
    spec = GRIDS["churn"]
    assert spec.name in VALIDATORS, "gated grid must carry claim checks"
    assert grids.resolve("churn")[0] is spec
    assert "priority" in spec.scheduler and 2 in spec.n_rails
    assert "slowdown:5" in spec.fault_model
    assert max(spec.churn_rate) > 0 and max(spec.worker_bw_skew) > 0
    assert spec.fault_seed != 0        # seed is pinned, not implicit


# ---------------------------------------------------------------------------
# launcher runtime parity (satellite: scheduler through train.py)
# ---------------------------------------------------------------------------

def test_train_dryrun_wires_scheduler_into_comm_plan():
    from repro.launch import train as train_mod
    fifo = train_mod.main(["--arch", "stablelm-3b", "--smoke", "--dryrun",
                           "--fusion-mb", "1"])
    pri = train_mod.main(["--arch", "stablelm-3b", "--smoke", "--dryrun",
                          "--fusion-mb", "1", "--scheduler", "priority",
                          "--sched-chunks", "8"])
    assert fifo["dryrun"] and fifo["scheduler"] == "fifo"
    assert pri["scheduler"] == "priority" and pri["sched_chunks"] == 8
    assert fifo["n_buckets"] == pri["n_buckets"] > 1
    # same buckets, different issue order: the IR order the simulator
    # prices is what the runtime would execute
    assert sorted(pri["bucket_order"]) == sorted(fifo["bucket_order"])
    assert fifo["bucket_order"] == sorted(fifo["bucket_order"])
    assert pri["bucket_order"] != fifo["bucket_order"]
