"""The seed event engine, retained verbatim as an executable reference.

This is the pre-heap ``NetworkEngine.run`` loop exactly as it shipped in
the seed ``repro.core.events`` — it rescans every pending/running flow at
every event, pops from list middles, and advances all wires on each step.
It is O(n^2)-ish and kept *only* so that:

- the property tests in ``test_events_equivalence.py`` can pit the indexed
  heap engine against the original semantics on randomized flow sets, and
- ``benchmarks/sweep_bench.py`` can measure the speedup honestly against
  the behaviour the golden artifacts were produced with.

Do not "fix" or optimize this file; its value is being frozen.  The one
permitted deviation is ``max_iters_factor``: the seed's convergence
heuristic (``10 * n + 100`` iterations) can false-trip on heavily
contended multi-job plans, so callers that stress it may raise the factor
without changing any arithmetic.
"""
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.events import FlowResult, FlowSpec


class _Run:
    __slots__ = ("flow", "start", "remaining", "contended")

    def __init__(self, flow: FlowSpec, start: float):
        self.flow = flow
        self.start = start
        self.remaining = flow.work
        self.contended = False


class ReferenceNetworkEngine:
    """The seed engine: list rescans + stepwise wire advancement."""

    def __init__(self, capacities: Optional[Dict[str, float]] = None,
                 max_iters_factor: int = 10):
        self.capacities = dict(capacities or {})
        self.max_iters_factor = max_iters_factor

    def _share(self, link: str, n_active: int) -> float:
        cap = self.capacities.get(link, 1.0)
        return min(1.0, cap / n_active) if n_active else 1.0

    def run(self, flows: Sequence[FlowSpec]) -> List[FlowResult]:
        """Execute ``flows``; returns results in input order."""
        pending: Dict[str, List[FlowSpec]] = {}
        for f in flows:
            pending.setdefault(f.job, []).append(f)
        for q in pending.values():
            # stable service order: (priority, op_id); ready gates admission
            q.sort(key=lambda f: (f.priority, f.op_id), reverse=True)

        job_free: Dict[str, float] = {j: 0.0 for j in pending}
        running: Dict[str, _Run] = {}          # job -> active wire
        on_link: Dict[str, List[_Run]] = {}
        results: Dict[int, FlowResult] = {}
        t = 0.0
        n_total = len(flows)
        max_iters = self.max_iters_factor * n_total + 100

        def _pick(job: str) -> Optional[FlowSpec]:
            """Highest-priority flow of ``job`` that is ready at ``t``."""
            q = pending[job]
            best_i = -1
            for i in range(len(q) - 1, -1, -1):  # sorted reverse: best last
                if q[i].ready <= t:
                    best_i = i
                    break
            if best_i < 0:
                return None
            return q.pop(best_i)

        iters = 0
        while len(results) < n_total:
            iters += 1
            if iters > max_iters:
                raise RuntimeError("event engine failed to converge "
                                   f"({len(results)}/{n_total} flows done)")

            # -- admissions at the current time ------------------------------
            admitted = False
            for job in pending:
                if job in running or job_free[job] > t or not pending[job]:
                    continue
                flow = _pick(job)
                if flow is None:
                    continue
                run = _Run(flow, start=t)
                active = on_link.setdefault(flow.link, [])
                if active:
                    run.contended = True
                    for other in active:
                        other.contended = True
                if self._share(flow.link, 1) < 1.0:
                    # a link with fractional capacity never runs a flow at
                    # full rate, so the closed-form completion is invalid
                    run.contended = True
                active.append(run)
                running[job] = run
                admitted = True
            if admitted:
                continue  # shares changed; recompute projections

            # -- next event: a wire completion or a job becoming serviceable -
            t_next = None
            for run in running.values():
                share = self._share(run.flow.link, len(on_link[run.flow.link]))
                proj = t + run.remaining / share
                if t_next is None or proj < t_next:
                    t_next = proj
            for job, q in pending.items():
                if job in running or not q:
                    continue
                earliest = min(f.ready for f in q)
                trigger = max(job_free[job], earliest)
                if t_next is None or trigger < t_next:
                    t_next = trigger
            if t_next is None:
                raise RuntimeError("event engine stalled with pending flows")
            t_next = max(t_next, t)

            # -- advance all running wires to t_next -------------------------
            dt = t_next - t
            done: List[Tuple[str, _Run]] = []
            for job, run in running.items():
                share = self._share(run.flow.link, len(on_link[run.flow.link]))
                run.remaining -= dt * share
                # done when the residual is negligible — or too small to
                # advance the clock at all (absorbed below ulp(t_next)),
                # which would otherwise stall the loop
                if (run.remaining <= run.flow.work * 1e-12 + 1e-18
                        or t_next + run.remaining / share <= t_next):
                    done.append((job, run))
            t = t_next

            for job, run in done:
                flow = run.flow
                if not run.contended:
                    # exact closed form: share was 1.0 throughout
                    wire_end = run.start + flow.work
                    if flow.hold and flow.duration is not None:
                        end = run.start + flow.duration
                    else:
                        end = wire_end + flow.latency
                else:
                    wire_end = t
                    end = wire_end + flow.latency
                results[flow.op_id] = FlowResult(
                    flow.op_id, job, run.start, wire_end, end, run.contended)
                on_link[flow.link].remove(run)
                del running[job]
                job_free[job] = end if flow.hold else wire_end

        return [results[f.op_id] for f in flows]


def run_reference_flows(flows: Sequence[FlowSpec],
                        capacities: Optional[Dict[str, float]] = None,
                        max_iters_factor: int = 10) -> List[FlowResult]:
    """Convenience wrapper: execute ``flows`` on a fresh reference engine."""
    return ReferenceNetworkEngine(capacities, max_iters_factor).run(flows)
