"""Per-architecture smoke tests (deliverable f): reduced same-family variant,
one forward/train step on CPU, output shapes asserted, no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.archs import ALL_ARCHS
from repro.models.registry import get_model

# interpret-mode Pallas / full-model tests: minutes of wall clock on CPU
pytestmark = pytest.mark.slow


B, S = 2, 64


def make_batch(cfg, kind="train"):
    batch = {"tokens": jnp.ones((B, S), jnp.int32)}
    if kind == "train":
        batch["labels"] = jnp.ones((B, S), jnp.int32)
    if cfg.family == "vlm" and cfg.prefix_embeds:
        batch["prefix_embeds"] = jnp.zeros((B, cfg.prefix_embeds, cfg.d_model),
                                           jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                    jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_loss(arch):
    cfg = get_config(arch).smoke()
    api = get_model(cfg)
    params = api.init(jax.random.key(0))
    loss, metrics = jax.jit(api.loss_fn)(params, make_batch(cfg))
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch):
    from repro.optim.optimizers import get_optimizer
    cfg = get_config(arch).smoke()
    api = get_model(cfg)
    opt = get_optimizer("adamw")
    params = api.init(jax.random.key(0))
    opt_state = opt.init(params)
    batch = make_batch(cfg)

    @jax.jit
    def step(p, o, b):
        (loss, _), grads = jax.value_and_grad(api.loss_fn, has_aux=True)(p, b)
        new_p, new_o = opt.update(p, o, grads, 1e-3)
        return new_p, new_o, loss

    new_params, _, loss = step(params, opt_state, batch)
    assert jnp.isfinite(loss)
    # params actually moved and stayed finite
    moved = jax.tree_util.tree_map(
        lambda a, b: jnp.any(a != b), params, new_params)
    assert any(bool(m) for m in jax.tree_util.tree_leaves(moved))
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_shapes(arch):
    cfg = get_config(arch).smoke()
    api = get_model(cfg)
    params = api.init(jax.random.key(0))
    batch = make_batch(cfg, kind="prefill")
    logits, cache = jax.jit(api.prefill)(params, batch)
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert logits.shape[2] == cfg.padded_vocab
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))

    dbatch = {"tokens": jnp.ones((B, 1), jnp.int32)}
    logits2, cache2 = jax.jit(api.decode_step)(
        params, dbatch, cache, jnp.asarray(S, jnp.int32))
    assert logits2.shape == (B, 1, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(logits2.astype(jnp.float32)))
    # cache structure preserved
    assert (jax.tree_util.tree_structure(cache2)
            == jax.tree_util.tree_structure(cache))
